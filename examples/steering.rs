//! Computational steering: a *cyclic* workflow (paper Sec. 3.2 —
//! "Wilkins supports any directed-graph topology of tasks, including
//! ... cycles").
//!
//! The simulation publishes its state each step; a steering task
//! analyzes it and publishes a control parameter; the simulation
//! consumes the control before its next step. Both tasks are plain
//! standalone codes coupled only through the data-centric YAML.
//!
//!     cargo run --release --example steering

use wilkins::lowfive::{AttrValue, DType, Hyperslab};
use wilkins::tasks::builtin_registry;
use wilkins::{Wilkins, WilkinsError};

const STEPS: i64 = 5;

fn main() -> wilkins::Result<()> {
    let mut reg = builtin_registry();

    // The "simulation": state decays by a steered gain each step.
    reg.register_fn("sim", |ctx| {
        let mut state = 100.0f32;
        for step in 0..STEPS {
            // Publish current state.
            let vol = &mut ctx.vol;
            vol.file_create("state.h5")?;
            vol.attr_write("state.h5", "step", AttrValue::Int(step))?;
            vol.dataset_create("state.h5", "/state", DType::F32, &[1])?;
            vol.dataset_write(
                "state.h5",
                "/state",
                Hyperslab::whole(&[1]),
                state.to_le_bytes().to_vec(),
            )?;
            vol.file_close("state.h5")?;
            // Receive the steering decision for the next step.
            let name = ctx.vol.file_open("control.h5")?;
            let gain_bytes = ctx.vol.dataset_read(
                &name,
                "/gain",
                &Hyperslab::whole(&[1]),
            )?;
            let gain = f32::from_le_bytes(gain_bytes[..4].try_into().unwrap());
            ctx.vol.file_close(&name)?;
            state *= gain;
            println!("  sim step {step}: state -> {state:.2} (gain {gain:.2})");
        }
        assert!(state < 100.0, "steering must have reduced the state");
        Ok(())
    });

    // The "steering" task: drive the state toward a setpoint of 10.
    reg.register_fn("steer", |ctx| {
        loop {
            let name = match ctx.vol.file_open("state.h5") {
                Ok(n) => n,
                Err(WilkinsError::EndOfStream) => return Ok(()),
                Err(e) => return Err(e),
            };
            let bytes = ctx
                .vol
                .dataset_read(&name, "/state", &Hyperslab::whole(&[1]))?;
            let state = f32::from_le_bytes(bytes[..4].try_into().unwrap());
            ctx.vol.file_close(&name)?;

            let gain: f32 = if state > 10.0 { 0.5 } else { 1.0 };
            let vol = &mut ctx.vol;
            vol.file_create("control.h5")?;
            vol.dataset_create("control.h5", "/gain", DType::F32, &[1])?;
            vol.dataset_write(
                "control.h5",
                "/gain",
                Hyperslab::whole(&[1]),
                gain.to_le_bytes().to_vec(),
            )?;
            vol.file_close("control.h5")?;
        }
    });

    let w = Wilkins::from_yaml_str(
        "\
tasks:
  - func: sim
    nprocs: 1
    inports:
      - filename: control.h5
        dsets: [ { name: /gain } ]
    outports:
      - filename: state.h5
        dsets: [ { name: /state } ]
  - func: steer
    nprocs: 1
    inports:
      - filename: state.h5
        dsets: [ { name: /state } ]
    outports:
      - filename: control.h5
        dsets: [ { name: /gain } ]
",
        reg,
    )?;
    println!("topology: {:?}\n", w.graph().topology());
    assert_eq!(w.graph().topology(), wilkins::graph::Topology::Cyclic);
    w.run()?;
    println!("\nsteering OK: cyclic workflow converged");
    Ok(())
}
