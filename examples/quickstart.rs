//! Quickstart: the paper's Listing 1 — one producer, two consumers,
//! coupled purely through a YAML description. No artifacts needed.
//!
//!     cargo run --release --example quickstart

use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const WORKFLOW: &str = "\
tasks:
  - func: producer
    nprocs: 4
    params: { steps: 3, grid_per_proc: 100000, particles_per_proc: 100000 }
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
          - name: /group1/particles
            file: 0
            memory: 1
  - func: consumer1
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
  - func: consumer2
    nprocs: 3
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/particles
            file: 0
            memory: 1
";

fn main() -> wilkins::Result<()> {
    let w = Wilkins::from_yaml_str(WORKFLOW, builtin_registry())?;
    println!("{}", w.graph().describe());
    let report = w.run()?;
    print!("{}", report.render());
    // Consumers verify every element they read (params verify defaults
    // to 1), so a clean run proves the data paths end-to-end.
    println!("\nquickstart OK: 3 timesteps verified across 2 channels");
    Ok(())
}
