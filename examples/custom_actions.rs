//! Custom actions demo (paper Sec. 3.5.2, Listings 3 + 5): imperative
//! customization inside the declarative interface.
//!
//! Shows both the built-in actions and a user-registered one — the
//! analogue of dropping a <25-line Python script next to the YAML. The
//! user action transfers data only when a threshold is exceeded
//! ("transfer data between tasks only if the data value exceeds some
//! predefined threshold", the paper's motivating example).
//!
//!     cargo run --release --example custom_actions

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use wilkins::lowfive::{AttrValue, DType, Hyperslab};
use wilkins::tasks::builtin_registry;
use wilkins::{Wilkins, WilkinsError};

static SERVED: AtomicU64 = AtomicU64::new(0);

fn main() -> wilkins::Result<()> {
    println!("== user-defined custom action: threshold-gated transfer ==\n");

    let mut reg = builtin_registry();
    // A producer whose "signal" grows each step; only steps whose
    // signal exceeds the threshold are worth analyzing.
    reg.register_fn("signal_source", |ctx| {
        for step in 0..6i64 {
            let vol = &mut ctx.vol;
            vol.file_create("signal.h5")?;
            vol.attr_write("signal.h5", "signal", AttrValue::Int(step))?;
            vol.dataset_create("signal.h5", "/value", DType::F32, &[8])?;
            let vals: Vec<u8> = (0..8)
                .flat_map(|i| ((step as f32) + i as f32).to_le_bytes())
                .collect();
            vol.dataset_write("signal.h5", "/value", Hyperslab::whole(&[8]), vals)?;
            vol.file_close("signal.h5")?;
        }
        Ok(())
    });
    reg.register_fn("analyzer", |ctx| loop {
        match ctx.vol.file_open("signal.h5") {
            Ok(name) => {
                let sig = ctx
                    .vol
                    .consumer_file(&name)?
                    .attr("signal")
                    .and_then(|a| a.as_i64())
                    .unwrap_or(0);
                println!("  analyzer received signal={sig}");
                assert!(sig >= 3, "threshold action must gate low signals");
                ctx.vol.file_close(&name)?;
            }
            Err(WilkinsError::EndOfStream) => return Ok(()),
            Err(e) => return Err(e),
        }
    });

    // The "user script": serve only when the signal attribute >= 3.
    let threshold_action: wilkins::actions::ActionFn = Arc::new(|vol, _rank| {
        vol.set_before_file_close(Box::new(|vol, name| {
            let low = vol
                .file(name)
                .ok()
                .and_then(|f| f.attrs.get("signal").cloned())
                .and_then(|a| a.as_i64())
                .is_some_and(|s| s < 3);
            if low {
                vol.skip_serve();
            } else {
                SERVED.fetch_add(1, Ordering::Relaxed);
            }
        }));
    });

    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: signal_source
    nprocs: 1
    actions: [\"user_script\", \"threshold\"]
    outports:
      - filename: signal.h5
        dsets: [ { name: /value } ]
  - func: analyzer
    nprocs: 1
    inports:
      - filename: signal.h5
        dsets: [ { name: /value } ]
",
        reg,
    )?
    .with_action("user_script", "threshold", threshold_action)
    .run()?;

    let src = report.node("signal_source").unwrap();
    println!(
        "\nproducer: {} served, {} suppressed by the action",
        src.files_served, src.serves_suppressed,
    );
    assert_eq!(SERVED.load(Ordering::Relaxed), 3); // signals 3, 4, 5
    assert_eq!(report.node("analyzer").unwrap().files_opened, 3);
    println!("custom_actions OK: declarative YAML + imperative callback");
    Ok(())
}
