//! Ensemble topologies demo (paper Figs. 3/6): the same producer and
//! consumer codes arranged into fan-out, fan-in, NxN and M:N shapes by
//! changing *only* the `taskCount` fields — the paper's headline
//! ease-of-use claim for ensembles — followed by the co-scheduling
//! layer: the NxN shape as N independent instances packed onto a
//! bounded rank budget with per-instance overrides.
//!
//!     cargo run --release --example ensemble_topologies

use wilkins::ensemble::Ensemble;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn workflow(producers: usize, consumers: usize) -> String {
    format!(
        "\
tasks:
  - func: producer
    taskCount: {producers} #Only change needed to define ensembles
    nprocs: 2
    params: {{ steps: 2, grid_per_proc: 20000, particles_per_proc: 20000 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    taskCount: {consumers} #Only change needed to define ensembles
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
    )
}

/// The same 1:1 pipeline as an ensemble spec: 4 co-scheduled instances
/// on half the ranks, one throttled, one with a different step count.
const ENSEMBLE_SPEC: &str = "\
ensemble:
  max_ranks: 8
  policy: round-robin
  tasks:
    - func: producer
      nprocs: 2
      params: { steps: 2, grid_per_proc: 20000, particles_per_proc: 20000 }
      outports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
    - func: consumer
      nprocs: 2
      inports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  instances:
    - name: pair
      count: 3
    - name: long
      params:
        producer: { steps: 4 }
      admission: -1   # only starts on an idle budget
";

fn main() -> wilkins::Result<()> {
    println!("== ensemble topologies from taskCount alone ==\n");
    for (label, p, c) in [
        ("pipeline (1:1)", 1, 1),
        ("fan-out  (1:8)", 1, 8),
        ("fan-in   (8:1)", 8, 1),
        ("M:N      (4:2)", 4, 2),
        ("NxN      (8:8)", 8, 8),
    ] {
        let w = Wilkins::from_yaml_str(&workflow(p, c), builtin_registry())?;
        let topo = w.graph().topology();
        let channels = w.graph().channels.len();
        let report = w.run()?;
        println!(
            "{label}:  topology {topo:?}, {channels} channels, {} ranks, {:.3}s",
            report.total_ranks,
            report.elapsed.as_secs_f64()
        );
    }

    println!("\n== co-scheduled ensemble: 4 pipelines on an 8-rank budget ==\n");
    let ens = Ensemble::from_yaml_str(ENSEMBLE_SPEC, builtin_registry())?;
    let report = ens.run()?;
    print!("{}", report.render());
    println!();
    print!("{}", report.trace.gantt_ascii(72));

    println!("\nensemble_topologies OK (round-robin linking per Figure 3,");
    println!("round-robin co-scheduling on a bounded budget)");
    Ok(())
}
