//! Ensemble topologies demo (paper Figs. 3/6): the same producer and
//! consumer codes arranged into fan-out, fan-in, NxN and M:N shapes by
//! changing *only* the `taskCount` fields — the paper's headline
//! ease-of-use claim for ensembles.
//!
//!     cargo run --release --example ensemble_topologies

use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn workflow(producers: usize, consumers: usize) -> String {
    format!(
        "\
tasks:
  - func: producer
    taskCount: {producers} #Only change needed to define ensembles
    nprocs: 2
    params: {{ steps: 2, grid_per_proc: 20000, particles_per_proc: 20000 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    taskCount: {consumers} #Only change needed to define ensembles
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
    )
}

fn main() -> wilkins::Result<()> {
    println!("== ensemble topologies from taskCount alone ==\n");
    for (label, p, c) in [
        ("pipeline (1:1)", 1, 1),
        ("fan-out  (1:8)", 1, 8),
        ("fan-in   (8:1)", 8, 1),
        ("M:N      (4:2)", 4, 2),
        ("NxN      (8:8)", 8, 8),
    ] {
        let w = Wilkins::from_yaml_str(&workflow(p, c), builtin_registry())?;
        let topo = w.graph().topology();
        let channels = w.graph().channels.len();
        let report = w.run()?;
        println!(
            "{label}:  topology {topo:?}, {channels} channels, {} ranks, {:.3}s",
            report.total_ranks,
            report.elapsed.as_secs_f64()
        );
    }
    println!("\nensemble_topologies OK (round-robin linking per Figure 3)");
    Ok(())
}
