//! End-to-end driver: the cosmology use case (paper Sec. 4.2.2).
//!
//! Nyx proxy (AOT `nyx_step`: mass-conserving structure growth on a
//! 64^3 grid) writes plotfiles with Nyx's pathological double
//! open/close pattern; the `("actions", "nyx")` custom action
//! (Listing 5) restores correct serving; the Reeber proxy (AOT
//! `halo_finder`, the Pallas stencil kernel) finds halos; the `some`
//! flow-control strategy keeps Nyx from idling behind slow analysis.
//! The halo counts it logs decrease over cosmic time as structures
//! merge — real physics from the payloads, coordinated by Wilkins.
//!
//!     make artifacts && cargo run --release --example cosmology

use std::path::PathBuf;
use std::time::Instant;

use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn workflow(io_freq: i64) -> String {
    format!(
        "\
tasks:
  - func: nyx
    nprocs: 8
    actions: [\"actions\", \"nyx\"]
    params: {{ snapshots: 6, steps_per_snapshot: 8 }}
    outports:
      - filename: plt*.h5
        dsets: [ {{ name: /level_0/density }} ]
  - func: reeber
    nprocs: 4
    params: {{ analysis_rounds: 4, threshold: 1.5 }}
    inports:
      - filename: plt*.h5
        io_freq: {io_freq} #Setting the flow control strategy
        dsets: [ {{ name: /level_0/density }} ]
",
    )
}

fn main() -> wilkins::Result<()> {
    init_logger();
    let dir = std::env::var("WILKINS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if !dir.join("manifest.tsv").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Engine::start(&dir)?;

    println!("== cosmology: Nyx + Reeber with flow control (end-to-end) ==\n");
    for (label, freq) in [("all", 1i64), ("some n=2", 2), ("some n=3", 3)] {
        let t0 = Instant::now();
        let w = Wilkins::from_yaml_str(&workflow(freq), builtin_registry())?
            .with_engine(engine.handle());
        let report = w.run()?;
        let nyx = report.node("nyx").unwrap();
        println!(
            "strategy {label:<9} completion {:.3}s  served {} skipped {}",
            t0.elapsed().as_secs_f64(),
            nyx.files_served,
            nyx.serves_skipped
        );
    }
    println!("\ncosmology OK: custom action + flow control end-to-end");
    Ok(())
}

fn init_logger() {
    struct Stdout;
    impl log::Log for Stdout {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                println!("  [{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: Stdout = Stdout;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Info);
}
