//! End-to-end driver: the materials-science use case (paper Sec.
//! 4.2.1) on a real small workload — this is the example that proves
//! all three layers compose:
//!
//!   L3 Wilkins coordinates an NxN ensemble of producer/consumer task
//!      instances with subset writers and stateless consumers;
//!   L2 the LAMMPS proxy advances 4096 Lennard-Jones atoms through the
//!      AOT-compiled `md_step` JAX payload, loaded via PJRT;
//!   L1 the diamond detector counts 4-coordinated atoms with the
//!      Pallas pairwise kernel inside `diamond_detector`.
//!
//! The run logs the nucleation signal (n_crystal) per dump and
//! reports ensemble completion times — Figure 10's quantity.
//!
//!     make artifacts && cargo run --release --example materials_science

use std::path::PathBuf;
use std::time::Instant;

use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn workflow(instances: usize, dumps: u64) -> String {
    format!(
        "\
tasks:
  - func: freeze
    taskCount: {instances}
    nprocs: 4
    nwriters: 1 #Only rank 0 performs I/O (LAMMPS gathers to rank 0)
    params: {{ dumps: {dumps}, execs_per_dump: 2 }}
    outports:
      - filename: dump-h5md.h5
        dsets: [ {{ name: /particles/* }} ]
  - func: detector
    taskCount: {instances}
    nprocs: 2
    stateless: 1
    inports:
      - filename: dump-h5md.h5
        dsets: [ {{ name: /particles/* }} ]
",
    )
}

fn main() -> wilkins::Result<()> {
    // Surface the detector's n_crystal log lines.
    init_logger();
    let dir = std::env::var("WILKINS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if !dir.join("manifest.tsv").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Engine::start(&dir)?;

    println!("== materials science: MD nucleation ensemble (end-to-end) ==\n");
    for instances in [1usize, 2, 4] {
        let t0 = Instant::now();
        let w = Wilkins::from_yaml_str(&workflow(instances, 3), builtin_registry())?
            .with_engine(engine.handle());
        let report = w.run()?;
        println!(
            "instances={instances:<2} completion {:.3}s  ({} ranks, {:.1} MiB moved)",
            t0.elapsed().as_secs_f64(),
            report.total_ranks,
            report.bytes_sent as f64 / (1024.0 * 1024.0)
        );
        for i in 0..instances {
            let d = report.node(&format!("detector[{i}]")).or_else(|| report.node("detector"));
            if let Some(d) = d {
                assert_eq!(d.files_opened, 3, "each detector sees every dump");
            }
        }
    }
    println!("\nmaterials_science OK: ensemble ran end-to-end through PJRT payloads");
    Ok(())
}

fn init_logger() {
    struct Stdout;
    impl log::Log for Stdout {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                println!("  [{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: Stdout = Stdout;
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Info);
}
