#!/usr/bin/env sh
# Repo check pipeline — runnable locally and in any future CI.
#
#   sh ci/check.sh          # build + tests + doc lint
#   sh ci/check.sh docs     # doc lint only (fast)
#
# The doc step denies rustdoc warnings (broken intra-doc links above
# all), so the documentation surface added in DESIGN.md / README.md /
# docs/ cannot silently rot out of sync with the rustdoc it points at.

set -eu

cd "$(dirname "$0")/.."

docs_check() {
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    # rust/src/lib.rs turns on missing_docs for the flow module, the
    # whole lowfive module (the routed data plane) AND the obs module
    # (the observability plane), so an undocumented public item in any
    # of those layers fails here (and under the clippy -D warnings
    # step below).
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace
}

if [ "${1:-all}" = "docs" ]; then
    docs_check
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed in this toolchain; skipping"
fi

docs_check

echo "== ensemble smoke run =="
cargo run --release -- ensemble configs/ensemble_pipeline.yaml \
    --artifacts /nonexistent >/dev/null

echo "== multi-process smoke run (2 workers) =="
cargo run --release -- up --workers 2 configs/listing1_3task.yaml \
    --artifacts /nonexistent >/dev/null

echo "== 8-worker smoke run (O(1) threads per worker) =="
# Every worker reports its own OS thread count after serving its
# world (WILKINS_DEBUG_THREADS=1 reads /proc/self/status). The
# event-loop transport keeps that count flat — the main serve thread
# plus one I/O thread — no matter how many mesh links the 8-worker
# full mesh hands each process; the thread-per-link pump model this
# replaced would sit at ~9 threads per worker here.
threads_err="${TMPDIR:-/tmp}/wilkins-ci-threads-$$.log"
WILKINS_DEBUG_THREADS=1 cargo run --release -- up --workers 8 \
    configs/fanout8.yaml --artifacts /nonexistent \
    >/dev/null 2>"$threads_err"
tn=$(grep -c "^wilkins-threads: worker=" "$threads_err" || true)
[ "$tn" = "8" ] || {
    echo "FAIL: expected 8 wilkins-threads reports, got $tn:"
    cat "$threads_err"; exit 1;
}
tbad=$(grep "^wilkins-threads: worker=" "$threads_err" \
    | sed 's/.*threads=//' | awk '$1 > 3 { c++ } END { print c + 0 }')
[ "$tbad" = "0" ] || {
    echo "FAIL: $tbad worker(s) exceeded the 3-thread budget:"
    grep "^wilkins-threads: worker=" "$threads_err"; exit 1;
}
rm -f "$threads_err"

echo "== shared-memory data-plane smoke (16 MiB grid, 2 workers) =="
# The same 16 MiB/step workflow twice: once on the default shm
# descriptor plane, once forced inline (WILKINS_SHM=0). The shm run
# must actually engage (bytes_shm > 0, zero fallbacks) and must move
# fewer bytes per delivered byte than the inline run — wire tx plus
# twice wire rx (the nonblocking reader zero-fills its lease before
# landing bytes in it) plus the segment writes.
shmdir="${TMPDIR:-/tmp}/wilkins-ci-shm-$$"
rm -rf "$shmdir"; mkdir -p "$shmdir"
cargo run --release -- up --workers 2 configs/shm_16mib.yaml \
    --artifacts /nonexistent --workdir "$shmdir/work-shm" \
    --json "$shmdir/shm.json" >/dev/null
WILKINS_SHM=0 cargo run --release -- up --workers 2 configs/shm_16mib.yaml \
    --artifacts /nonexistent --workdir "$shmdir/work-inline" \
    --json "$shmdir/inline.json" >/dev/null
if command -v python3 >/dev/null 2>&1; then
    python3 - "$shmdir/shm.json" "$shmdir/inline.json" <<'PYEOF'
import json, sys
shm = json.load(open(sys.argv[1]))
inline = json.load(open(sys.argv[2]))
def moved_per_byte(rep):
    c = rep["telemetry"]["counters"]
    moved = c["bytes_sent_wire"] + 2 * c["bytes_recv_wire"] + c["bytes_shm"]
    return moved / rep["bytes_sent"]
sc = shm["telemetry"]["counters"]
assert sc["bytes_shm"] > 0, "shm run moved no bytes through segments"
assert sc["shm_fallbacks"] == 0, f"shm run fell back inline {sc['shm_fallbacks']}x"
ic = inline["telemetry"]["counters"]
assert ic["bytes_shm"] == 0, "WILKINS_SHM=0 run still used segments"
s, i = moved_per_byte(shm), moved_per_byte(inline)
assert s < i, f"shm plane moved {s:.2f} bytes/byte, inline {i:.2f}"
print(f"shm smoke: {s:.2f} moved bytes/byte vs {i:.2f} inline")
PYEOF
else
    grep -Eq '"bytes_shm":[1-9][0-9]*' "$shmdir/shm.json" || {
        echo "FAIL: shm run reported no bytes_shm"; exit 1;
    }
    grep -Eq '"shm_fallbacks":0' "$shmdir/shm.json" || {
        echo "FAIL: shm run reported inline fallbacks"; exit 1;
    }
    echo "python3 not available; skipped moved-bytes comparison"
fi
rm -rf "$shmdir"

echo "== flow-control smoke run (latest policy must shed rounds) =="
flow_out=$(cargo run --release -- run configs/flow_control.yaml \
    --time-scale 0.02 --artifacts /nonexistent)
case "$flow_out" in
    *"dropped="*)
        # The flow summary line is unconditional; require a real
        # nonzero drop count under `flow: latest`.
        echo "$flow_out" | grep -Eq "dropped=[1-9][0-9]*" || {
            echo "FAIL: flow summary reported zero dropped rounds"; exit 1;
        }
        ;;
    *)
        echo "FAIL: no flow summary in the run report:"; echo "$flow_out"; exit 1
        ;;
esac

echo "== mixed-transport smoke run (routed data plane) =="
mixdir="${TMPDIR:-/tmp}/wilkins-ci-mixed-$$"
rm -rf "$mixdir"
mix_out=$(cargo run --release -- run configs/mixed_transport.yaml \
    --workdir "$mixdir" --artifacts /nonexistent)
# The write-through grid is served in situ within one process, so the
# zero-copy path must have engaged.
echo "$mix_out" | grep -Eq "bytes_shared=[1-9][0-9]*" || {
    echo "FAIL: mixed run reported no zero-copy shared bytes:"; echo "$mix_out"; exit 1;
}
# Allocation discipline, defense-in-depth: this single-process run
# serves every memory round over the zero-copy path, so no serve
# reply may ever report an allocation (the wire bench below is the
# check with real teeth — it asserts warm-pool alloc_rounds on the
# encode path itself).
echo "$mix_out" | grep -Eq "alloc_rounds=[1-9][0-9]*" && {
    echo "FAIL: mixed run reported nonzero alloc_rounds:"; echo "$mix_out"; exit 1;
}
# The disk write-through encodes must be recycling pooled buffers
# (the wire summary line only prints when the pool engaged).
echo "$mix_out" | grep -Eq "bytes_pooled=[1-9][0-9]*" || {
    echo "FAIL: mixed run reported no pooled encode bytes:"; echo "$mix_out"; exit 1;
}
# And the file-routed datasets must have landed as disk artifacts.
ls "$mixdir"/*.l5 >/dev/null 2>&1 || {
    echo "FAIL: no .l5 artifact in $mixdir after the mixed run"; exit 1;
}
rm -rf "$mixdir"

echo "== chaos smoke (worker killed mid-campaign must be survivable) =="
# Worker 0 hard-exits on its first instance (WILKINS_FAULT_HARD turns
# the injected kill into a real process death). The campaign must
# drain on the two survivors: every instance exactly once, the loss
# and the re-dispatch visible on the faults line.
chaos_out=$(WILKINS_FAULT="kill@0:after=0" WILKINS_FAULT_HARD=1 \
    cargo run --release -- ensemble configs/chaos_ensemble.yaml \
    --artifacts /nonexistent)
echo "$chaos_out" | grep -q "lost_workers=1" || {
    echo "FAIL: chaos run did not report exactly one lost worker:"
    echo "$chaos_out"; exit 1;
}
echo "$chaos_out" | grep -Eq "retries=[1-9]" || {
    echo "FAIL: chaos run reported no re-dispatches:"; echo "$chaos_out"; exit 1;
}
# Exactly one report row per instance (rows start at column 0; the
# admission preamble indents its instance lines).
for i in 0 1 2 3; do
    n=$(echo "$chaos_out" | grep -c "^chaos\[$i\]" || true)
    [ "$n" = "1" ] || {
        echo "FAIL: instance chaos[$i] has $n report rows (want exactly 1):"
        echo "$chaos_out"; exit 1;
    }
done

echo "== observability smoke (chaos ensemble with --trace/--json) =="
# Same chaos campaign, exporting the merged Chrome trace and the
# machine-readable report. The run must surface live telemetry (the
# 50 ms beats carry K_TELEMETRY counter frames), the trace must paint
# the WorkerLost marker, and both artifacts must parse as the schemas
# docs/observability.md documents.
obsdir="${TMPDIR:-/tmp}/wilkins-ci-obs-$$"
rm -rf "$obsdir"; mkdir -p "$obsdir"
obs_out=$(WILKINS_FAULT="kill@0:after=0" WILKINS_FAULT_HARD=1 \
    cargo run --release -- ensemble configs/chaos_ensemble.yaml \
    --artifacts /nonexistent \
    --trace "$obsdir/trace.json" --json "$obsdir/report.json")
echo "$obs_out" | grep -Eq "telemetry: frames=[1-9][0-9]*" || {
    echo "FAIL: chaos obs run reported no telemetry frames:"
    echo "$obs_out"; exit 1;
}
grep -q '"WorkerLost"' "$obsdir/trace.json" || {
    echo "FAIL: WorkerLost marker missing from the chrome trace"; exit 1;
}
if grep -q '"dur":-' "$obsdir/trace.json"; then
    echo "FAIL: negative span duration in the chrome trace"; exit 1
fi
if command -v python3 >/dev/null 2>&1; then
    python3 - "$obsdir/trace.json" "$obsdir/report.json" <<'PYEOF'
import json, sys
trace = json.load(open(sys.argv[1]))
events = trace["traceEvents"]
assert isinstance(events, list) and events, "empty traceEvents"
assert any(e.get("name") == "WorkerLost" for e in events), "no WorkerLost instant"
assert all(e.get("dur", 0) >= 0 for e in events), "negative duration"
report = json.load(open(sys.argv[2]))
assert report["schema"] == "wilkins.ensemble_report/1", report.get("schema")
assert report["telemetry"]["frames"] > 0, "no telemetry frames in the json report"
assert any(e["name"] == "WorkerLost" for e in report["events"]), "no WorkerLost event"
assert len(report["instances"]) == 4, "expected 4 instance reports"
for inst in report["instances"]:
    assert inst["report"]["schema"] == "wilkins.run_report/1", inst["name"]
print("obs json artifacts validate")
PYEOF
else
    echo "python3 not available; skipping json schema validation"
fi
rm -rf "$obsdir"

echo "== replay smoke (recorded chaos run must replay bit-identically) =="
# Record the same chaos campaign with full payload capture, then
# replay the wire logs in one process: the reconstructed report must
# diff clean against the recorded one (docs/replay.md).
rpdir="${TMPDIR:-/tmp}/wilkins-ci-replay-$$"
rm -rf "$rpdir"; mkdir -p "$rpdir"
WILKINS_FAULT="kill@0:after=0" WILKINS_FAULT_HARD=1 \
    WILKINS_TRACE_WIRE=full WILKINS_TRACE_DIR="$rpdir" \
    cargo run --release -- ensemble configs/chaos_ensemble.yaml \
    --artifacts /nonexistent --json "$rpdir/report.json" >/dev/null
replay_out=$(cargo run --release -- replay "$rpdir")
echo "$replay_out" | grep -q "report diff: identical" || {
    echo "FAIL: replay diverged from the recorded chaos run:"
    echo "$replay_out"; exit 1;
}
rm -rf "$rpdir"

echo "== paper benches (wire / flow / dataplane / ensembles) =="
# Each bench asserts its own acceptance shape — the wire bench covers
# the >=2x copy reduction AND that the disabled wire tap stays off the
# frame hot path — and emits a BENCH_<name>.json record at the repo
# root; archive every record so the trajectory accumulates run over
# run.
stamp=$(git rev-parse --short HEAD 2>/dev/null || date +%s)
mkdir -p ci/bench-archive
for b in wire flow dataplane ensembles; do
    cargo bench --bench "$b"
    test -s "BENCH_$b.json" || {
        echo "FAIL: $b bench did not emit BENCH_$b.json"; exit 1;
    }
    cp "BENCH_$b.json" "ci/bench-archive/BENCH_$b.$stamp.json"
done

echo "OK: all checks passed"
