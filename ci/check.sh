#!/usr/bin/env sh
# Repo check pipeline — runnable locally and in any future CI.
#
#   sh ci/check.sh          # build + tests + doc lint
#   sh ci/check.sh docs     # doc lint only (fast)
#
# The doc step denies rustdoc warnings (broken intra-doc links above
# all), so the documentation surface added in DESIGN.md / README.md /
# docs/ cannot silently rot out of sync with the rustdoc it points at.

set -eu

cd "$(dirname "$0")/.."

docs_check() {
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    # rust/src/lib.rs turns on missing_docs for the flow module AND
    # the whole lowfive module (the routed data plane), so an
    # undocumented public item in either layer fails here (and under
    # the clippy -D warnings step below).
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace
}

if [ "${1:-all}" = "docs" ]; then
    docs_check
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed in this toolchain; skipping"
fi

docs_check

echo "== ensemble smoke run =="
cargo run --release -- ensemble configs/ensemble_pipeline.yaml \
    --artifacts /nonexistent >/dev/null

echo "== multi-process smoke run (2 workers) =="
cargo run --release -- up --workers 2 configs/listing1_3task.yaml \
    --artifacts /nonexistent >/dev/null

echo "== flow-control smoke run (latest policy must shed rounds) =="
flow_out=$(cargo run --release -- run configs/flow_control.yaml \
    --time-scale 0.02 --artifacts /nonexistent)
case "$flow_out" in
    *"dropped="*)
        # The summary only prints with dropped > 0 or stalls; require
        # a real nonzero drop count under `flow: latest`.
        echo "$flow_out" | grep -Eq "dropped=[1-9][0-9]*" || {
            echo "FAIL: flow summary reported zero dropped rounds"; exit 1;
        }
        ;;
    *)
        echo "FAIL: no flow summary in the run report:"; echo "$flow_out"; exit 1
        ;;
esac

echo "== mixed-transport smoke run (routed data plane) =="
mixdir="${TMPDIR:-/tmp}/wilkins-ci-mixed-$$"
rm -rf "$mixdir"
mix_out=$(cargo run --release -- run configs/mixed_transport.yaml \
    --workdir "$mixdir" --artifacts /nonexistent)
# The write-through grid is served in situ within one process, so the
# zero-copy path must have engaged.
echo "$mix_out" | grep -Eq "bytes_shared=[1-9][0-9]*" || {
    echo "FAIL: mixed run reported no zero-copy shared bytes:"; echo "$mix_out"; exit 1;
}
# Allocation discipline, defense-in-depth: this single-process run
# serves every memory round over the zero-copy path, so no serve
# reply may ever report an allocation (the wire bench below is the
# check with real teeth — it asserts warm-pool alloc_rounds on the
# encode path itself).
echo "$mix_out" | grep -Eq "alloc_rounds=[1-9][0-9]*" && {
    echo "FAIL: mixed run reported nonzero alloc_rounds:"; echo "$mix_out"; exit 1;
}
# The disk write-through encodes must be recycling pooled buffers
# (the wire summary line only prints when the pool engaged).
echo "$mix_out" | grep -Eq "bytes_pooled=[1-9][0-9]*" || {
    echo "FAIL: mixed run reported no pooled encode bytes:"; echo "$mix_out"; exit 1;
}
# And the file-routed datasets must have landed as disk artifacts.
ls "$mixdir"/*.l5 >/dev/null 2>&1 || {
    echo "FAIL: no .l5 artifact in $mixdir after the mixed run"; exit 1;
}
rm -rf "$mixdir"

echo "== chaos smoke (worker killed mid-campaign must be survivable) =="
# Worker 0 hard-exits on its first instance (WILKINS_FAULT_HARD turns
# the injected kill into a real process death). The campaign must
# drain on the two survivors: every instance exactly once, the loss
# and the re-dispatch visible on the faults line.
chaos_out=$(WILKINS_FAULT="kill@0:after=0" WILKINS_FAULT_HARD=1 \
    cargo run --release -- ensemble configs/chaos_ensemble.yaml \
    --artifacts /nonexistent)
echo "$chaos_out" | grep -q "lost_workers=1" || {
    echo "FAIL: chaos run did not report exactly one lost worker:"
    echo "$chaos_out"; exit 1;
}
echo "$chaos_out" | grep -Eq "retries=[1-9]" || {
    echo "FAIL: chaos run reported no re-dispatches:"; echo "$chaos_out"; exit 1;
}
# Exactly one report row per instance (rows start at column 0; the
# admission preamble indents its instance lines).
for i in 0 1 2 3; do
    n=$(echo "$chaos_out" | grep -c "^chaos\[$i\]" || true)
    [ "$n" = "1" ] || {
        echo "FAIL: instance chaos[$i] has $n report rows (want exactly 1):"
        echo "$chaos_out"; exit 1;
    }
done

echo "== wire bench (pooled data plane: >=2x copy reduction, alloc_rounds) =="
# The bench asserts the acceptance shape itself (>=2x fewer
# bytes-copied-per-byte-delivered at 16 MiB vs the Vol::set_pooling
# ablation, pooled arms within the warm-up allocation budget) and
# emits BENCH_wire.json; archive the JSON so the trajectory
# accumulates run over run.
cargo bench --bench wire
test -s BENCH_wire.json || {
    echo "FAIL: wire bench did not emit BENCH_wire.json"; exit 1;
}
mkdir -p ci/bench-archive
cp BENCH_wire.json "ci/bench-archive/BENCH_wire.$(git rev-parse --short HEAD 2>/dev/null || date +%s).json"

echo "OK: all checks passed"
