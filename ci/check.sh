#!/usr/bin/env sh
# Repo check pipeline — runnable locally and in any future CI.
#
#   sh ci/check.sh          # build + tests + doc lint
#   sh ci/check.sh docs     # doc lint only (fast)
#
# The doc step denies rustdoc warnings (broken intra-doc links above
# all), so the documentation surface added in DESIGN.md / README.md /
# docs/ cannot silently rot out of sync with the rustdoc it points at.

set -eu

cd "$(dirname "$0")/.."

docs_check() {
    echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace
}

if [ "${1:-all}" = "docs" ]; then
    docs_check
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets (-D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed in this toolchain; skipping"
fi

docs_check

echo "== ensemble smoke run =="
cargo run --release -- ensemble configs/ensemble_pipeline.yaml \
    --artifacts /nonexistent >/dev/null

echo "== multi-process smoke run (2 workers) =="
cargo run --release -- up --workers 2 configs/listing1_3task.yaml \
    --artifacts /nonexistent >/dev/null

echo "OK: all checks passed"
