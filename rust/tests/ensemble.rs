//! End-to-end ensemble co-scheduling tests: spec YAML → co-scheduler →
//! N concurrent Wilkins instances on a bounded rank budget → merged
//! reports and Gantt trace.

use wilkins::ensemble::{Ensemble, Policy};
use wilkins::tasks::builtin_registry;

/// Three instances of the same pipeline with DISTINCT io_freq
/// settings, co-scheduled on a budget that forces waves (3 x 4 ranks
/// onto 8).
const THREE_WAY_SPEC: &str = "\
ensemble:
  max_ranks: 8
  policy: fifo
  tasks:
    - func: producer
      nprocs: 2
      params: { steps: 4, grid_per_proc: 500, particles_per_proc: 500 }
      outports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
    - func: consumer
      nprocs: 2
      inports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  instances:
    - name: all
      io_freq: 1
    - name: half
      io_freq: 2
    - name: latest
      io_freq: -1
      params:
        producer: { sleep_s: 0.005, verify: 0 }
        consumer: { sleep_s: 0.02, verify: 0 }
";

#[test]
fn three_instances_with_distinct_io_freq() {
    let ens = Ensemble::from_yaml_str(THREE_WAY_SPEC, builtin_registry()).unwrap();
    let report = ens.run().unwrap();

    assert_eq!(report.instances.len(), 3);
    assert_eq!(report.budget, 8);
    assert!(report.peak_ranks <= 8, "peak {} broke the budget", report.peak_ranks);
    assert!(report.peak_ranks >= 8, "two 4-rank instances should overlap");

    // io_freq: 1 — every timestep served and read.
    let all = report.instance("all").unwrap();
    assert_eq!(all.report.node("producer").unwrap().files_served, 4);
    assert_eq!(all.report.node("consumer").unwrap().files_opened, 4);

    // io_freq: 2 — every second timestep (attempts 2 and 4).
    let half = report.instance("half").unwrap();
    let p = half.report.node("producer").unwrap();
    assert_eq!(p.files_served, 2);
    assert_eq!(p.serves_skipped, 2);
    assert_eq!(half.report.node("consumer").unwrap().files_opened, 2);

    // io_freq: -1 — serve only when the slow consumer already waits;
    // the exact count is timing-dependent but bounded.
    let latest = report.instance("latest").unwrap();
    let opened = latest.report.node("consumer").unwrap().files_opened;
    assert!((1..=4).contains(&opened), "latest opened {opened}");

    // Scheduling facts: FIFO admits `all` and `half` first (8 ranks),
    // `latest` must wait for a completion.
    let t_latest = report.instance("latest").unwrap().started_s;
    assert!(
        t_latest >= all.started_s && t_latest >= half.started_s,
        "latest must be admitted last under fifo"
    );
    for inst in &report.instances {
        assert!(inst.finished_s >= inst.started_s);
    }

    // Merged trace: spans from every instance on the ensemble clock.
    assert!(!report.trace.is_empty());
    let csv = report.trace.to_csv();
    assert!(csv.starts_with("instance,rank,kind,label,start_s,end_s\n"));
    for name in ["all", "half", "latest"] {
        assert!(
            report.trace.spans().iter().any(|s| s.instance == name),
            "no spans for {name}"
        );
    }
    assert!(report.trace.gantt_ascii(60).contains("latest"));
}

#[test]
fn round_robin_policy_drains_the_same_spec() {
    let ens = Ensemble::from_yaml_str(THREE_WAY_SPEC, builtin_registry())
        .unwrap()
        .with_policy(Policy::RoundRobin);
    let report = ens.run().unwrap();
    assert_eq!(report.instances.len(), 3);
    assert_eq!(report.policy, Policy::RoundRobin);
    assert!(report.peak_ranks <= 8);
    // Flow-control outcomes are policy-independent.
    let half = report.instance("half").unwrap();
    assert_eq!(half.report.node("consumer").unwrap().files_opened, 2);
}

#[test]
fn sequential_budget_serializes_instances() {
    // Budget == one instance: strictly one at a time, so every
    // admission must wait for the previous finish.
    let spec = THREE_WAY_SPEC.replace("max_ranks: 8", "max_ranks: 4");
    let ens = Ensemble::from_yaml_str(&spec, builtin_registry()).unwrap();
    let report = ens.run().unwrap();
    assert_eq!(report.peak_ranks, 4);
    let mut insts: Vec<_> = report.instances.iter().collect();
    insts.sort_by(|a, b| a.started_s.partial_cmp(&b.started_s).unwrap());
    for w in insts.windows(2) {
        assert!(
            w[1].started_s >= w[0].finished_s - 0.05,
            "{} (start {:.3}) overlapped {} (finish {:.3}) despite budget 4",
            w[1].name,
            w[1].started_s,
            w[0].name,
            w[0].finished_s
        );
    }
}

#[test]
fn file_mode_instances_get_isolated_workdirs() {
    // Two instances move data through file-mode transports using THE
    // SAME filenames; per-instance workdirs must keep them apart.
    let spec = "\
ensemble:
  tasks:
    - func: producer
      nprocs: 2
      params: { steps: 2, grid_per_proc: 400, particles_per_proc: 400 }
      outports:
        - filename: outfile.h5
          dsets:
            - name: /group1/grid
              file: 1
              memory: 0
            - name: /group1/particles
              file: 1
              memory: 0
    - func: consumer
      nprocs: 2
      inports:
        - filename: outfile.h5
          dsets:
            - name: /group1/grid
              file: 1
              memory: 0
            - name: /group1/particles
              file: 1
              memory: 0
  instances:
    - name: fm
      count: 2
";
    let dir = std::env::temp_dir().join(format!("wilkins-ens-filemode-{}", std::process::id()));
    let ens = Ensemble::from_yaml_str(spec, builtin_registry())
        .unwrap()
        .with_workdir(dir.clone());
    let report = ens.run().unwrap();
    for i in 0..2 {
        let inst = report.instance(&format!("fm[{i}]")).unwrap();
        assert_eq!(inst.report.node("consumer").unwrap().files_opened, 2);
        assert!(dir.join(format!("fm[{i}]")).is_dir(), "missing per-instance workdir");
    }
}

#[test]
fn unknown_task_code_fails_fast_at_construction() {
    let spec = "\
ensemble:
  tasks:
    - func: nonexistent_code
      nprocs: 1
      outports:
        - filename: x.h5
          dsets: [ { name: /d } ]
  instances:
    - name: solo
";
    let err = match Ensemble::from_yaml_str(spec, builtin_registry()) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("unknown task code must fail before launch"),
    };
    assert!(err.contains("nonexistent_code"), "{err}");
}

#[test]
fn admission_throttles_hold_instances_back() {
    // `admission: -1` (latest): the throttled instance only starts on
    // an idle budget, i.e. after both pairs finish.
    let spec = "\
ensemble:
  max_ranks: 8
  policy: round-robin
  tasks:
    - func: producer
      nprocs: 2
      params: { steps: 2, grid_per_proc: 300, particles_per_proc: 300 }
      outports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
    - func: consumer
      nprocs: 2
      inports:
        - filename: outfile.h5
          dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  instances:
    - name: pair
      count: 2
    - name: quiet
      admission: -1
";
    let ens = Ensemble::from_yaml_str(spec, builtin_registry()).unwrap();
    let report = ens.run().unwrap();
    let quiet = report.instance("quiet").unwrap();
    for i in 0..2 {
        let pair = report.instance(&format!("pair[{i}]")).unwrap();
        assert!(
            quiet.started_s >= pair.finished_s - 0.05,
            "quiet (start {:.3}) must wait for pair[{i}] (finish {:.3})",
            quiet.started_s,
            pair.finished_s
        );
    }
}
