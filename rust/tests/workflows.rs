//! End-to-end workflow integration tests: full YAML → coordinator →
//! threads → transport → verification, using the synthetic tasks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use wilkins::config::WorkflowConfig;
use wilkins::flow::FlowControl;
use wilkins::graph::Topology;
use wilkins::henson::Registry;
use wilkins::tasks::builtin_registry;
use wilkins::{Wilkins, WilkinsError};

fn run_yaml(src: &str) -> wilkins::RunReport {
    Wilkins::from_yaml_str(src, builtin_registry())
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn listing1_three_task_workflow() {
    // Producer + two consumers, each consuming one dataset; with
    // verification on, consumers check every element they read.
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 4
    params:
      steps: 3
      grid_per_proc: 2000
      particles_per_proc: 2000
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
          - name: /group1/particles
  - func: consumer1
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
  - func: consumer2
    nprocs: 3
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/particles
",
    );
    assert_eq!(report.total_ranks, 12);
    let p = report.node("producer").unwrap();
    assert_eq!(p.files_served, 3);
    assert!(p.bytes_served > 0);
    let c1 = report.node("consumer1").unwrap();
    assert_eq!(c1.files_opened, 3);
    // consumer1 reads the full grid per step: 4*2000*8 bytes * 3 steps.
    // (It also reads particles: the channel carries only grid, but the
    // consumer task reads all datasets present in the served file —
    // both live in the same file here, matching the paper's Listing 1
    // where channels are per-dataset but the file is shared.)
    assert!(c1.bytes_read >= 4 * 2000 * 8 * 3);
}

#[test]
fn weak_scaling_shape_holds() {
    // Same per-proc size, more procs => more total bytes moved.
    let mut bytes = Vec::new();
    for nprocs in [1usize, 2, 4] {
        let report = run_yaml(&format!(
            "\
tasks:
  - func: producer
    nprocs: {nprocs}
    params: {{ steps: 1, grid_per_proc: 5000, particles_per_proc: 5000 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    nprocs: {c}
    inports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
            c = (nprocs + 3) / 4 * 1
        ));
        bytes.push(report.node("producer").unwrap().bytes_served);
    }
    assert!(bytes[1] > bytes[0] && bytes[2] > bytes[1]);
}

#[test]
fn ensemble_fan_in_round_robin() {
    // Listing-2 shape: 4 producers, 2 consumers; each consumer reads
    // from its 2 round-robin producers (2 steps each = 4 opens).
    let report = run_yaml(
        "\
tasks:
  - func: producer
    taskCount: 4
    nprocs: 2
    params: { steps: 2, grid_per_proc: 500, particles_per_proc: 500 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    taskCount: 2
    nprocs: 3
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    for i in 0..2 {
        let c = report.node(&format!("consumer[{i}]")).unwrap();
        assert_eq!(c.files_opened, 4, "consumer[{i}]");
    }
    for i in 0..4 {
        let p = report.node(&format!("producer[{i}]")).unwrap();
        assert_eq!(p.files_served, 2, "producer[{i}]");
    }
}

#[test]
fn nxn_ensemble_pairs() {
    let report = run_yaml(
        "\
tasks:
  - func: producer
    taskCount: 3
    nprocs: 2
    params: { steps: 2, grid_per_proc: 300, particles_per_proc: 300 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    taskCount: 3
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    for i in 0..3 {
        assert_eq!(
            report.node(&format!("consumer[{i}]")).unwrap().files_opened,
            2
        );
    }
}

#[test]
fn flow_control_some_skips_serves() {
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 10, grid_per_proc: 100, particles_per_proc: 100 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        io_freq: 5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    let p = report.node("producer").unwrap();
    assert_eq!(p.files_served, 2); // steps 5 and 10
    assert_eq!(p.serves_skipped, 8);
    assert_eq!(report.node("consumer").unwrap().files_opened, 2);
}

#[test]
fn flow_control_latest_drops_for_slow_consumer() {
    // Producer 10 fast steps; consumer sleeps per file. With *latest*
    // the producer must finish without serving all 10.
    let cfg = WorkflowConfig::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 10, grid_per_proc: 100, particles_per_proc: 100, sleep_s: 0.01 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 1
    params: { sleep_s: 0.05 }
    inports:
      - filename: outfile.h5
        io_freq: -1
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    )
    .unwrap();
    let report = Wilkins::new(cfg, builtin_registry()).unwrap().run().unwrap();
    let p = report.node("producer").unwrap();
    assert!(
        p.serves_dropped >= 2,
        "latest should drop several rounds, dropped={}",
        p.serves_dropped
    );
    let c = report.node("consumer").unwrap();
    assert!(c.files_opened >= 1 && c.files_opened < 10);
}

#[test]
fn flow_key_latest_drops_and_reports() {
    // The `flow:` key form of the same scenario, plus the RunReport
    // surface: dropped rounds show up per node and in the flow
    // summary line.
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 10, grid_per_proc: 100, particles_per_proc: 100, sleep_s: 0.01 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 1
    params: { sleep_s: 0.05 }
    inports:
      - filename: outfile.h5
        flow: latest
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    let p = report.node("producer").unwrap();
    assert!(p.serves_dropped >= 2, "dropped={}", p.serves_dropped);
    assert!(p.max_queue_depth >= 1);
    let rendered = report.render();
    assert!(rendered.contains("dropped="), "{rendered}");
}

#[test]
fn flow_bounded_block_depth_matches_all_data() {
    // depth: 3 pipelines the producer ahead of the consumer but must
    // still deliver every timestep (verify=1 checks the data values).
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 6, grid_per_proc: 100, particles_per_proc: 100 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 2
    params: { sleep_s: 0.01 }
    inports:
      - filename: outfile.h5
        flow: { policy: block, depth: 3 }
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    let p = report.node("producer").unwrap();
    assert_eq!(p.files_served, 6);
    assert_eq!(p.serves_dropped, 0);
    assert!(p.max_queue_depth <= 3, "maxq={}", p.max_queue_depth);
    assert_eq!(report.node("consumer").unwrap().files_opened, 6);
}

#[test]
fn flow_every_matches_io_freq_sugar() {
    // `io_freq: N` must behave exactly like its lowered `flow:` form.
    let base = "\
tasks:
  - func: producer
    nprocs: 2
    params: {{ steps: 10, grid_per_proc: 100, particles_per_proc: 100 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        {flow}
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
";
    let sugar = run_yaml(&base.replace("{flow}", "io_freq: 5").replace("{{", "{").replace("}}", "}"));
    let lowered = run_yaml(
        &base
            .replace("{flow}", "flow: { policy: block, every: 5 }")
            .replace("{{", "{")
            .replace("}}", "}"),
    );
    for (a, b) in [(&sugar, &lowered)] {
        let pa = a.node("producer").unwrap();
        let pb = b.node("producer").unwrap();
        assert_eq!(pa.files_served, pb.files_served);
        assert_eq!(pa.serves_skipped, pb.serves_skipped);
        assert_eq!(pa.bytes_served, pb.bytes_served);
        assert_eq!(
            a.node("consumer").unwrap().files_opened,
            b.node("consumer").unwrap().files_opened
        );
    }
    assert_eq!(sugar.node("producer").unwrap().files_served, 2);
    assert_eq!(sugar.node("producer").unwrap().serves_skipped, 8);
}

#[test]
fn subset_writers_workflow() {
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 4
    nwriters: 2
    params: { steps: 2, grid_per_proc: 1000, particles_per_proc: 1000 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
    );
    // With nwriters=2, all four ranks' slabs are redistributed onto
    // the two writer ranks (gather_to_writers) before serving, so the
    // consumer verifies every element (verify defaults to on).
    assert_eq!(report.node("producer").unwrap().files_served, 2);
    let c = report.node("consumer").unwrap();
    assert_eq!(c.files_opened, 2);
    assert!(c.bytes_read >= 2 * 4 * 1000 * 8);
}

#[test]
fn file_mode_workflow() {
    let report = run_yaml(
        "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 2, grid_per_proc: 500, particles_per_proc: 500 }
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 1
            memory: 0
          - name: /group1/particles
            file: 1
            memory: 0
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 1
            memory: 0
          - name: /group1/particles
            file: 1
            memory: 0
",
    );
    assert_eq!(report.node("consumer").unwrap().files_opened, 2);
}

#[test]
fn stateless_consumer_relaunched_per_file() {
    static LAUNCHES: AtomicUsize = AtomicUsize::new(0);
    LAUNCHES.store(0, Ordering::SeqCst);
    let mut reg = builtin_registry();
    reg.register_fn("counting_consumer", |ctx| {
        LAUNCHES.fetch_add(1, Ordering::SeqCst);
        // Unmodified-style stateless code: open one file, read, close.
        let name = ctx.vol.file_open("outfile.h5")?;
        let meta = ctx.vol.dataset_meta(&name, "/group1/grid")?;
        let want = wilkins::lowfive::split_rows(&meta.dims, ctx.size())[ctx.rank()].clone();
        ctx.vol.dataset_read(&name, "/group1/grid", &want)?;
        ctx.vol.file_close(&name)?;
        Ok(())
    });
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 4, grid_per_proc: 100, particles_per_proc: 100 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: counting_consumer
    nprocs: 1
    stateless: 1
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid } ]
",
        reg,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(LAUNCHES.load(Ordering::SeqCst), 4);
    assert_eq!(report.node("counting_consumer").unwrap().files_opened, 4);
}

#[test]
fn pipeline_intermediate_task() {
    // producer -> relay (intermediate) -> sink: data flows through.
    let mut reg = builtin_registry();
    reg.register_fn("relay", |ctx| {
        loop {
            let name = match ctx.vol.file_open("stage1.h5") {
                Ok(n) => n,
                Err(WilkinsError::EndOfStream) => return Ok(()),
                Err(e) => return Err(e),
            };
            let meta = ctx.vol.dataset_meta(&name, "/d")?;
            let want = wilkins::lowfive::split_rows(&meta.dims, ctx.size())[ctx.rank()].clone();
            let bytes = ctx.vol.dataset_read(&name, "/d", &want)?;
            ctx.vol.file_close(&name)?;
            // Transform: double every u64 and republish.
            let doubled: Vec<u8> = bytes
                .chunks_exact(8)
                .flat_map(|c| {
                    (u64::from_le_bytes(c.try_into().unwrap()) * 2).to_le_bytes()
                })
                .collect();
            ctx.vol.file_create("stage2.h5")?;
            ctx.vol
                .dataset_create("stage2.h5", "/d", wilkins::lowfive::DType::U64, &meta.dims)?;
            ctx.vol.dataset_write("stage2.h5", "/d", want, doubled)?;
            ctx.vol.file_close("stage2.h5")?;
        }
    });
    reg.register_fn("source", |ctx| {
        for step in 0..3u64 {
            ctx.vol.file_create("stage1.h5")?;
            ctx.vol
                .dataset_create("stage1.h5", "/d", wilkins::lowfive::DType::U64, &[16])?;
            let vals: Vec<u8> = (0u64..16).flat_map(|i| (i + step).to_le_bytes()).collect();
            ctx.vol.dataset_write(
                "stage1.h5",
                "/d",
                wilkins::lowfive::Hyperslab::whole(&[16]),
                vals,
            )?;
            ctx.vol.file_close("stage1.h5")?;
        }
        Ok(())
    });
    reg.register_fn("sink", |ctx| {
        let mut step = 0u64;
        loop {
            let name = match ctx.vol.file_open("stage2.h5") {
                Ok(n) => n,
                Err(WilkinsError::EndOfStream) => break,
                Err(e) => return Err(e),
            };
            let bytes = ctx.vol.dataset_read(
                &name,
                "/d",
                &wilkins::lowfive::Hyperslab::whole(&[16]),
            )?;
            for (i, c) in bytes.chunks_exact(8).enumerate() {
                let v = u64::from_le_bytes(c.try_into().unwrap());
                assert_eq!(v, (i as u64 + step) * 2);
            }
            ctx.vol.file_close(&name)?;
            step += 1;
        }
        assert_eq!(step, 3);
        Ok(())
    });
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: source
    nprocs: 1
    outports:
      - filename: stage1.h5
        dsets: [ { name: /d } ]
  - func: relay
    nprocs: 1
    inports:
      - filename: stage1.h5
        dsets: [ { name: /d } ]
    outports:
      - filename: stage2.h5
        dsets: [ { name: /d } ]
  - func: sink
    nprocs: 1
    inports:
      - filename: stage2.h5
        dsets: [ { name: /d } ]
",
        reg,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(report.node("relay").unwrap().files_opened, 3);
    assert_eq!(report.node("sink").unwrap().files_opened, 3);
}

#[test]
fn failing_task_surfaces_error() {
    let mut reg = builtin_registry();
    reg.register_fn("bad_consumer", |ctx| {
        let _ = ctx.vol.file_open("outfile.h5")?;
        Err(WilkinsError::Task("injected failure".into()))
    });
    let res = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 2, grid_per_proc: 50, particles_per_proc: 50 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: bad_consumer
    nprocs: 1
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid } ]
",
        reg,
    )
    .unwrap()
    .run();
    let err = res.unwrap_err().to_string();
    assert!(err.contains("injected failure"), "{err}");
}

#[test]
fn unknown_func_fails_before_launch() {
    let res = Wilkins::from_yaml_str(
        "\
tasks:
  - func: does_not_exist
    nprocs: 1
    outports:
      - filename: f.h5
        dsets: [ { name: /d } ]
  - func: consumer
    nprocs: 1
    inports:
      - filename: f.h5
        dsets: [ { name: /d } ]
",
        builtin_registry(),
    )
    .unwrap()
    .run();
    assert!(res.is_err());
}

#[test]
fn graph_topologies_via_api() {
    let w = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 1 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    taskCount: 3
    nprocs: 1
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
        builtin_registry(),
    )
    .unwrap();
    assert_eq!(w.graph().topology(), Topology::FanOut);
    let report = w.run().unwrap();
    // Fan-out: the producer serves all three consumers each step.
    assert_eq!(report.node("producer").unwrap().files_served, 1);
    for i in 0..3 {
        assert_eq!(report.node(&format!("consumer[{i}]")).unwrap().files_opened, 1);
    }
}

#[test]
fn custom_action_listing3_every_second_write() {
    // Producer writes two datasets per step; the action serves only
    // after the second write, so a single serve per step happens even
    // though the default close-serve is suppressed.
    let registry = builtin_registry();
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    actions: [\"actions\", \"every_second_write\"]
    params: { steps: 2, grid_per_proc: 100, particles_per_proc: 100 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 1
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
",
        registry,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(report.node("consumer").unwrap().files_opened, 2);
    assert_eq!(report.node("producer").unwrap().files_served, 2);
}

#[test]
fn flow_control_enum_exposed_in_graph() {
    let w = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: { steps: 1 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 1
    inports:
      - filename: outfile.h5
        io_freq: 10
        dsets: [ { name: /group1/grid } ]
",
        builtin_registry(),
    )
    .unwrap();
    assert_eq!(w.graph().channels[0].flow, FlowControl::Some(10).lower());
}

#[test]
fn registry_is_extensible() {
    let mut reg = Registry::new();
    let touched = Arc::new(AtomicUsize::new(0));
    let t2 = Arc::clone(&touched);
    reg.register_fn("my_producer", move |ctx| {
        t2.fetch_add(1, Ordering::SeqCst);
        ctx.vol.file_create("x.h5")?;
        ctx.vol
            .dataset_create("x.h5", "/d", wilkins::lowfive::DType::F32, &[4])?;
        ctx.vol.dataset_write(
            "x.h5",
            "/d",
            wilkins::lowfive::Hyperslab::whole(&[4]),
            vec![0; 16],
        )?;
        ctx.vol.file_close("x.h5")?;
        Ok(())
    });
    reg.register_fn("my_consumer", |ctx| {
        let name = ctx.vol.file_open("x.h5")?;
        ctx.vol.file_close(&name)?;
        Ok(())
    });
    Wilkins::from_yaml_str(
        "\
tasks:
  - func: my_producer
    nprocs: 2
    outports:
      - filename: x.h5
        dsets: [ { name: /d } ]
  - func: my_consumer
    nprocs: 1
    inports:
      - filename: x.h5
        dsets: [ { name: /d } ]
",
        reg,
    )
    .unwrap()
    .run()
    .unwrap();
    assert_eq!(touched.load(Ordering::SeqCst), 2);
}

#[test]
fn mixed_transport_workflow_end_to_end() {
    // Per-dataset routing in one channel (paper Sec. 4.2): the grid is
    // written through (in situ + archived), the particles are
    // file-only. With verify on (the default), the consumer
    // element-checks both datasets — the disk-routed bytes must be as
    // exact as the memory-routed ones.
    let dir = std::env::temp_dir().join(format!(
        "wilkins-wf-mixed-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 3, grid_per_proc: 1000, particles_per_proc: 1000 }
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            memory: 1
            file: 1
          - name: /group1/particles
            file: 1
            memory: 0
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            memory: 1
            file: 1
          - name: /group1/particles
            file: 1
            memory: 0
",
        builtin_registry(),
    )
    .unwrap()
    .with_workdir(dir.clone())
    .run()
    .unwrap();
    let p = report.node("producer").unwrap();
    assert_eq!(p.files_served, 3);
    assert!(p.bytes_shared > 0, "write-through grid must take the zero-copy path");
    assert!(p.bytes_served > p.bytes_shared + p.bytes_copied, "disk bytes must count");
    let c = report.node("consumer").unwrap();
    assert_eq!(c.files_opened, 3);
    assert!(c.bytes_read > 0);
    // One versioned .l5 artifact per close landed in the workdir.
    let l5 = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().ends_with(".l5"))
        .count();
    assert_eq!(l5, 3, "write-through must archive every close");
    let rendered = report.render();
    assert!(rendered.contains("dataplane: bytes_shared="), "{rendered}");
}
