//! Property-based tests over the core invariants: hyperslab algebra,
//! M-to-N redistribution, the YAML parser, graph construction and the
//! wire protocol. Uses the in-repo proptest_lite framework (S16).

use wilkins::comm::wire::{Reader, Writer};
use wilkins::config::WorkflowConfig;
use wilkins::graph::WorkflowGraph;
use wilkins::lowfive::model::{Dataset, DatasetMeta};
use wilkins::lowfive::protocol::{Reply, Request};
use wilkins::lowfive::{split_rows, DType, Hyperslab};
use wilkins::proptest_lite::run_prop;

#[test]
fn prop_intersection_commutative_and_contained() {
    run_prop("intersect", 500, |rng| {
        let nd = rng.usize(1, 4);
        let dims = rng.dims(nd, 24);
        let a = rng.slab_within(&dims);
        let b = rng.slab_within(&dims);
        let ab = a.intersect(&b);
        let ba = b.intersect(&a);
        assert_eq!(ab, ba, "commutativity");
        if let Some(i) = ab {
            assert!(i.fits_within(&dims));
            assert_eq!(a.intersect(&i).as_ref(), Some(&i), "contained in a");
            assert_eq!(b.intersect(&i).as_ref(), Some(&i), "contained in b");
            assert!(i.element_count() <= a.element_count().min(b.element_count()));
        }
    });
}

#[test]
fn prop_split_rows_partitions() {
    run_prop("split_rows", 500, |rng| {
        let nd = rng.usize(1, 4);
        let dims = rng.dims(nd, 40);
        let n = rng.usize(1, 12);
        let parts = split_rows(&dims, n);
        assert_eq!(parts.len(), n);
        // Complete: counts sum to the whole; disjoint: no overlaps.
        let total: u64 = parts.iter().map(Hyperslab::element_count).sum();
        assert_eq!(total, dims.iter().product::<u64>());
        for i in 0..n {
            for j in i + 1..n {
                assert!(
                    parts[i].is_empty()
                        || parts[j].is_empty()
                        || !parts[i].overlaps(&parts[j]),
                    "parts {i} and {j} overlap: {:?} {:?}",
                    parts[i],
                    parts[j]
                );
            }
        }
    });
}

#[test]
fn prop_redistribution_preserves_data() {
    // Write through an M-way row split, read back through an N-way
    // split: every element must survive the redistribution exactly.
    run_prop("redistribution", 200, |rng| {
        let nd = rng.usize(1, 4);
        let mut dims = rng.dims(nd, 12);
        dims[0] = rng.range(1, 30); // rows worth splitting
        let m = rng.usize(1, 8);
        let n = rng.usize(1, 8);
        let meta = DatasetMeta {
            name: "/d".into(),
            dtype: DType::U64,
            dims: dims.clone(),
        };
        let mut ds = Dataset::new(meta);
        // Writer side: M blocks with globally-indexed values.
        let elems_per_row: u64 = dims[1..].iter().product();
        for slab in split_rows(&dims, m) {
            if slab.is_empty() {
                continue;
            }
            let start = slab.offset[0] * elems_per_row;
            let count = slab.element_count();
            let bytes: Vec<u8> = (start..start + count)
                .flat_map(|v| v.to_le_bytes())
                .collect();
            ds.write_slab(slab, bytes).unwrap();
        }
        // Reader side: N wanted slabs.
        for want in split_rows(&dims, n) {
            if want.is_empty() {
                continue;
            }
            let mut out = vec![0u8; want.element_count() as usize * 8];
            let filled = ds.read_into(&want, &mut out);
            assert_eq!(filled, want.element_count());
            let start = want.offset[0] * elems_per_row;
            for (k, chunk) in out.chunks_exact(8).enumerate() {
                assert_eq!(
                    u64::from_le_bytes(chunk.try_into().unwrap()),
                    start + k as u64
                );
            }
        }
    });
}

#[test]
fn prop_arbitrary_slab_reads_match() {
    // Random (not row-aligned) consumer slabs over a 2-D dataset.
    run_prop("arbitrary-slabs", 200, |rng| {
        let dims = vec![rng.range(2, 20), rng.range(2, 20)];
        let m = rng.usize(1, 5);
        let meta = DatasetMeta { name: "/d".into(), dtype: DType::U64, dims: dims.clone() };
        let mut ds = Dataset::new(meta);
        for slab in split_rows(&dims, m) {
            if slab.is_empty() {
                continue;
            }
            let bytes: Vec<u8> = iter_coords(&slab)
                .map(|c| c[0] * dims[1] + c[1])
                .flat_map(|v| v.to_le_bytes())
                .collect();
            ds.write_slab(slab, bytes).unwrap();
        }
        for _ in 0..5 {
            let want = rng.slab_within(&dims);
            let mut out = vec![0u8; want.element_count() as usize * 8];
            assert_eq!(ds.read_into(&want, &mut out), want.element_count());
            for (k, c) in iter_coords(&want).enumerate() {
                let v = u64::from_le_bytes(out[k * 8..k * 8 + 8].try_into().unwrap());
                assert_eq!(v, c[0] * dims[1] + c[1], "coord {c:?}");
            }
        }
    });
}

/// Row-major coordinate iterator over a slab (test helper).
fn iter_coords(slab: &Hyperslab) -> impl Iterator<Item = Vec<u64>> + '_ {
    let total = slab.element_count();
    (0..total).map(move |idx| {
        let mut rem = idx;
        let mut coord = vec![0u64; slab.dims()];
        for d in (0..slab.dims()).rev() {
            coord[d] = slab.offset[d] + rem % slab.count[d];
            rem /= slab.count[d];
        }
        coord
    })
}

#[test]
fn prop_wire_roundtrip_random_payloads() {
    run_prop("wire", 300, |rng| {
        let mut w = Writer::new();
        let n = rng.usize(0, 20);
        let mut expect = Vec::new();
        for _ in 0..n {
            let v = rng.next_u64();
            w.put_u64(v);
            expect.push(v);
        }
        let blob: Vec<u8> = (0..rng.usize(0, 64)).map(|_| rng.next_u64() as u8).collect();
        w.put_bytes(&blob);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        for v in expect {
            assert_eq!(r.get_u64().unwrap(), v);
        }
        assert_eq!(r.get_bytes().unwrap(), blob.as_slice());
        assert_eq!(r.remaining(), 0);
    });
}

#[test]
fn prop_protocol_roundtrip_random() {
    run_prop("protocol", 300, |rng| {
        let req = match rng.usize(0, 4) {
            0 => Request::MetaReq {
                pattern: format!("f{}.h5", rng.range(0, 1000)),
                min_version: rng.next_u64(),
            },
            1 => {
                let nd = rng.usize(1, 4);
                let dims = rng.dims(nd, 30);
                Request::DataReq {
                    file: "x.h5".into(),
                    dset: format!("/g/d{}", rng.range(0, 10)),
                    slab: rng.slab_within(&dims),
                }
            }
            2 => Request::Done { version: rng.next_u64() },
            _ => Request::EofAck,
        };
        assert_eq!(Request::decode(&req.encode()).unwrap(), req);

        let blocks: Vec<(Hyperslab, wilkins::comm::Payload)> = (0..rng.usize(0, 4))
            .map(|_| {
                let dims = rng.dims(2, 10);
                let s = rng.slab_within(&dims);
                let bytes = vec![rng.next_u64() as u8; rng.usize(0, 32)];
                (s, wilkins::comm::Payload::from(bytes))
            })
            .collect();
        let rep = Reply::Data(blocks);
        assert_eq!(Reply::decode(&rep.encode()).unwrap(), rep);
    });
}

#[test]
fn prop_graph_round_robin_covers_all_instances() {
    run_prop("round-robin", 200, |rng| {
        let p = rng.usize(1, 12);
        let c = rng.usize(1, 12);
        let yaml = format!(
            "\
tasks:
  - func: prod
    taskCount: {p}
    nprocs: {np}
    outports:
      - filename: f.h5
        dsets: [ {{ name: /d }} ]
  - func: cons
    taskCount: {c}
    nprocs: {nc}
    inports:
      - filename: f.h5
        dsets: [ {{ name: /d }} ]
",
            np = rng.usize(1, 4),
            nc = rng.usize(1, 4),
        );
        let cfg = WorkflowConfig::from_yaml_str(&yaml).unwrap();
        let g = WorkflowGraph::build(&cfg).unwrap();
        // Figure 3 invariants: max(p, c) channels; every producer and
        // every consumer instance appears in at least one channel.
        assert_eq!(g.channels.len(), p.max(c));
        for node in 0..p {
            assert!(
                g.channels.iter().any(|ch| ch.producer == node),
                "producer {node} unlinked (p={p}, c={c})"
            );
        }
        for node in p..p + c {
            assert!(
                g.channels.iter().any(|ch| ch.consumer == node),
                "consumer {} unlinked (p={p}, c={c})",
                node - p
            );
        }
        // Balance: instance loads differ by at most 1.
        let mut ploads = vec![0usize; p];
        let mut cloads = vec![0usize; c];
        for ch in &g.channels {
            ploads[ch.producer] += 1;
            cloads[ch.consumer - p] += 1;
        }
        for loads in [&ploads, &cloads] {
            let lo = loads.iter().min().unwrap();
            let hi = loads.iter().max().unwrap();
            assert!(hi - lo <= 1, "unbalanced round robin: {loads:?}");
        }
    });
}

#[test]
fn prop_rank_assignment_disjoint_complete() {
    run_prop("ranks", 200, |rng| {
        let ntasks = rng.usize(1, 5);
        let mut yaml = String::from("tasks:\n");
        for t in 0..ntasks {
            yaml.push_str(&format!(
                "  - func: t{t}\n    taskCount: {}\n    nprocs: {}\n    outports:\n      - filename: f{t}.h5\n        dsets: [ {{ name: /d }} ]\n",
                rng.usize(1, 5),
                rng.usize(1, 6),
            ));
        }
        // Add one consumer reading every file so nothing dangles.
        yaml.push_str("  - func: sink\n    nprocs: 1\n    inports:\n");
        for t in 0..ntasks {
            yaml.push_str(&format!(
                "      - filename: f{t}.h5\n        dsets: [ {{ name: /d }} ]\n"
            ));
        }
        let cfg = WorkflowConfig::from_yaml_str(&yaml).unwrap();
        let g = WorkflowGraph::build(&cfg).unwrap();
        let mut owner = vec![usize::MAX; g.total_ranks];
        for (i, node) in g.nodes.iter().enumerate() {
            for r in node.ranks() {
                assert_eq!(owner[r], usize::MAX, "rank {r} double-assigned");
                owner[r] = i;
            }
        }
        assert!(owner.iter().all(|&o| o != usize::MAX), "unassigned ranks");
        for r in 0..g.total_ranks {
            assert_eq!(g.node_of_rank(r), Some(owner[r]));
        }
    });
}

#[test]
fn prop_yaml_scalars_roundtrip() {
    run_prop("yaml-scalars", 300, |rng| {
        let i = rng.next_u64() as i64 / 2;
        let doc = wilkins::configyaml::parse(&format!("v: {i}\n")).unwrap();
        assert_eq!(doc.get("v").and_then(|y| y.as_i64()), Some(i));

        let words = ["alpha", "beta-3", "/a/b/c", "plt*.h5", "x_y.z"];
        let s = rng.choose(&words);
        let doc = wilkins::configyaml::parse(&format!("v: {s}\n")).unwrap();
        assert_eq!(doc.get("v").and_then(|y| y.as_str()), Some(*s));
    });
}

#[test]
fn prop_yaml_nested_structure() {
    run_prop("yaml-nested", 100, |rng| {
        // Generate a random 2-level mapping and verify field access.
        let nkeys = rng.usize(1, 6);
        let mut yaml = String::new();
        let mut expect = Vec::new();
        for k in 0..nkeys {
            yaml.push_str(&format!("key{k}:\n"));
            let nsub = rng.usize(1, 4);
            for s in 0..nsub {
                let v = rng.range(0, 1_000_000);
                yaml.push_str(&format!("  sub{s}: {v}\n"));
                expect.push((k, s, v));
            }
        }
        let doc = wilkins::configyaml::parse(&yaml).unwrap();
        for (k, s, v) in expect {
            let got = doc
                .get(&format!("key{k}"))
                .and_then(|m| m.get(&format!("sub{s}")))
                .and_then(|y| y.as_i64());
            assert_eq!(got, Some(v as i64));
        }
    });
}

// ---- Routed data plane: mixed per-dataset transports must be
// ---- invisible to the consumer's bytes.

use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex};
use std::thread;

use wilkins::comm::{InterComm, World};
use wilkins::lowfive::{InChannel, OutChannel, Route, RouteTable, Vol};

static MIXED_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run one m→n coupling whose three datasets take the given routes;
/// returns every (consumer rank, open index, dataset) read, sorted.
fn run_routed_coupling(
    routes: [Route; 3],
    m: usize,
    n: usize,
    rows: u64,
    steps: u64,
) -> Vec<((usize, u64, String), Vec<u8>)> {
    const DSETS: [&str; 3] = ["/d0", "/d1", "/d2"];
    let table = RouteTable::new(
        DSETS
            .iter()
            .zip(routes)
            .map(|(d, r)| (d.to_string(), r))
            .collect(),
    );
    let world = World::new(m + n);
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let ioid = world.alloc_comm_id();
    let chid = world.alloc_comm_id();
    let prod: Vec<usize> = (0..m).collect();
    let cons: Vec<usize> = (m..m + n).collect();
    let workdir = std::env::temp_dir().join(format!(
        "wilkins-prop-mixed-{}-{}",
        std::process::id(),
        MIXED_SEQ.fetch_add(1, AtomicOrdering::Relaxed)
    ));
    let out: Arc<Mutex<Vec<((usize, u64, String), Vec<u8>)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let mut hs = Vec::new();
    for g in 0..m + n {
        let world = world.clone();
        let table = table.clone();
        let prod = prod.clone();
        let cons = cons.clone();
        let workdir = workdir.clone();
        let out = Arc::clone(&out);
        hs.push(thread::spawn(move || {
            if g < m {
                let local = world.comm_from_ranks(pid, &prod, g);
                let io = world.comm_from_ranks(ioid, &prod, g);
                let mut vol = Vol::new(local.clone(), workdir);
                vol.set_io_comm(Some(io));
                let ic = table
                    .any_memory()
                    .then(|| InterComm::new(local, chid, cons.clone()));
                vol.add_out_channel(OutChannel::new(ic, "f.h5", table));
                for t in 0..steps {
                    vol.file_create("f.h5").unwrap();
                    for (di, d) in DSETS.iter().enumerate() {
                        vol.dataset_create("f.h5", d, DType::U64, &[rows]).unwrap();
                        let slab = split_rows(&[rows], m)[g].clone();
                        let vals: Vec<u8> = (slab.offset[0]..slab.offset[0] + slab.count[0])
                            .flat_map(|i| {
                                (i * 7 + t * 1000 + di as u64 * 100_000).to_le_bytes()
                            })
                            .collect();
                        vol.dataset_write("f.h5", d, slab, vals).unwrap();
                    }
                    vol.file_close("f.h5").unwrap();
                }
                vol.finalize_producer().unwrap();
            } else {
                let local = world.comm_from_ranks(cid, &cons, g - m);
                let mut vol = Vol::new(local.clone(), workdir);
                let ic = table
                    .any_memory()
                    .then(|| InterComm::new(local, chid, prod.clone()));
                vol.add_in_channel(InChannel::new(ic, "f.h5", table));
                let mut opened = 0u64;
                loop {
                    let name = match vol.file_open("f.h5") {
                        Ok(name) => name,
                        Err(wilkins::WilkinsError::EndOfStream) => break,
                        Err(e) => panic!("open: {e}"),
                    };
                    for d in vol.consumer_file(&name).unwrap().dataset_names() {
                        let meta = vol.dataset_meta(&name, &d).unwrap();
                        let bytes = vol
                            .dataset_read(&name, &d, &Hyperslab::whole(&meta.dims))
                            .unwrap();
                        out.lock().unwrap().push(((g - m, opened, d), bytes));
                    }
                    vol.file_close(&name).unwrap();
                    opened += 1;
                }
                vol.finalize_consumer().unwrap();
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let mut reads = Arc::try_unwrap(out).unwrap().into_inner().unwrap();
    reads.sort_by(|a, b| a.0.cmp(&b.0));
    reads
}

#[test]
fn prop_mixed_routes_match_all_memory_baseline() {
    // The satellite equivalence: whatever per-dataset routes a channel
    // uses (memory / file / write-through, in any combination), every
    // consumer rank must read bit-identical bytes to the all-memory
    // baseline — transport routing is a placement decision, never a
    // data decision.
    run_prop("mixed-routes", 10, |rng| {
        let m = rng.usize(1, 3);
        let n = rng.usize(1, 3);
        let rows = rng.range(4, 24);
        let steps = rng.usize(1, 3) as u64;
        let all = [Route::Memory, Route::File, Route::Both];
        let routes = [
            *rng.choose(&all),
            *rng.choose(&all),
            *rng.choose(&all),
        ];
        let mixed = run_routed_coupling(routes, m, n, rows, steps);
        let baseline =
            run_routed_coupling([Route::Memory; 3], m, n, rows, steps);
        assert_eq!(
            mixed.len(),
            baseline.len(),
            "routes {routes:?} changed the number of reads (m={m}, n={n}, steps={steps})"
        );
        for (a, b) in mixed.iter().zip(&baseline) {
            assert_eq!(a.0, b.0, "read order diverged under routes {routes:?}");
            assert_eq!(
                a.1, b.1,
                "bytes diverged for {:?} under routes {routes:?}",
                a.0
            );
        }
    });
}
