//! Observability end-to-end: the merged Chrome trace and JSON report
//! coming out of a real 2-worker `wilkins up`, and the wire-frame tap
//! (`WILKINS_TRACE_WIRE=1`) recording real frames in every process of
//! the pool.

use std::process::Command;

fn wilkins() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wilkins"))
}

fn repo(p: &str) -> String {
    format!("{}/{p}", env!("CARGO_MANIFEST_DIR"))
}

/// Split a Chrome-trace document into per-event chunks. The exporter
/// always writes `ph` first in each event object, so splitting on that
/// prefix recovers event boundaries without a JSON parser.
fn events(doc: &str) -> Vec<String> {
    doc.split("{\"ph\":\"")
        .skip(1)
        .map(|s| format!("{{\"ph\":\"{s}"))
        .collect()
}

#[test]
fn up_two_workers_writes_merged_chrome_trace_and_json_report() {
    let dir = std::env::temp_dir().join("wilkins-obs-up");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.json");
    let json = dir.join("report.json");
    let out = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent", // synthetic workflow needs no engine
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--trace",
            trace.to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("chrome trace written to"), "{s}");
    assert!(s.contains("json report written to"), "{s}");

    let doc = std::fs::read_to_string(&trace).unwrap();
    assert!(doc.starts_with("{\"traceEvents\":["), "bad trace envelope: {doc}");
    // The exporter clamps reversed spans; a negative duration anywhere
    // means a clock-offset bug slipped through the merge.
    assert!(!doc.contains("\"dur\":-"), "negative span duration: {doc}");
    let evs = events(&doc);
    for w in 0..2u64 {
        assert!(
            evs.iter().any(|e| {
                e.contains("process_name") && e.contains(&format!("\"worker {w}\""))
            }),
            "no process_name track for worker {w}: {doc}"
        );
        assert!(
            evs.iter().any(|e| {
                e.starts_with("{\"ph\":\"X\"") && e.contains(&format!("\"pid\":{w},"))
            }),
            "no complete spans on worker {w}'s track: {doc}"
        );
    }

    let rep = std::fs::read_to_string(&json).unwrap();
    assert!(rep.contains("\"schema\":\"wilkins.run_report/1\""), "{rep}");
    assert!(rep.contains("\"telemetry\":"), "{rep}");
    assert!(rep.contains("\"counters\":"), "{rep}");
    assert!(rep.contains("\"faults\":"), "{rep}");
}

#[test]
fn run_single_process_writes_trace_and_json() {
    let dir = std::env::temp_dir().join("wilkins-obs-run");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.json");
    let json = dir.join("report.json");
    let out = wilkins()
        .args([
            "run",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--trace",
            trace.to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let doc = std::fs::read_to_string(&trace).unwrap();
    assert!(doc.contains("\"wilkins run\""), "{doc}");
    assert!(events(&doc).iter().any(|e| e.starts_with("{\"ph\":\"X\"")), "{doc}");
    assert!(!doc.contains("\"dur\":-"), "{doc}");
    let rep = std::fs::read_to_string(&json).unwrap();
    assert!(rep.contains("\"schema\":\"wilkins.run_report/1\""), "{rep}");
}

#[test]
fn wire_tap_records_frames_in_every_pool_process() {
    let dir = std::env::temp_dir().join("wilkins-obs-wtap");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let out = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
        ])
        .env("WILKINS_TRACE_WIRE", "1")
        .env("WILKINS_TRACE_DIR", dir.to_str().unwrap())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let logs: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "wtap"))
        .collect();
    // Coordinator + 2 spawned workers, one per-process log each.
    assert_eq!(logs.len(), 3, "expected 3 wtap logs, got {logs:?}");
    let mut total = 0usize;
    for log in &logs {
        let tap = wilkins::obs::wiretap::read_log(log).unwrap();
        assert_eq!(tap.version, 1, "WILKINS_TRACE_WIRE=1 writes header-only v1 logs");
        assert!(!tap.truncated, "clean shutdown must not tear the log tail in {log:?}");
        let recs = tap.records;
        let mut last = 0u64;
        for r in &recs {
            assert!(r.t_us >= last, "tap timestamps must be monotone in {log:?}");
            last = r.t_us;
            // 1..=13 spans K_HELLO through K_SHM_ACK (net::proto).
            assert!(
                (1..=13).contains(&r.kind),
                "unknown frame kind {} in {log:?}",
                r.kind
            );
        }
        total += recs.len();
    }
    assert!(total > 0, "no frames tapped across {logs:?}");
}
