//! Science use-case integration tests (need `make artifacts`).
//! Skipped with a note when artifacts are missing.

use std::path::PathBuf;

use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn engine() -> Option<Engine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping science test: run `make artifacts` first");
        return None;
    }
    Some(Engine::start(&dir).unwrap())
}

#[test]
fn materials_science_nxn_ensemble() {
    let Some(engine) = engine() else { return };
    // Scaled-down Listing 4: 2 ensemble instances, 4+2 procs, 2 dumps.
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: freeze
    taskCount: 2
    nprocs: 4
    nwriters: 1
    params: { dumps: 2, execs_per_dump: 1 }
    outports:
      - filename: dump-h5md.h5
        dsets: [ { name: /particles/* } ]
  - func: detector
    taskCount: 2
    nprocs: 2
    stateless: 1
    inports:
      - filename: dump-h5md.h5
        dsets: [ { name: /particles/* } ]
",
        builtin_registry(),
    )
    .unwrap()
    .with_engine(engine.handle())
    .run()
    .unwrap();
    for i in 0..2 {
        let f = report.node(&format!("freeze[{i}]")).unwrap();
        assert_eq!(f.files_served, 2, "freeze[{i}]");
        let d = report.node(&format!("detector[{i}]")).unwrap();
        assert_eq!(d.files_opened, 2, "detector[{i}]");
        // Each dump moves 4096*3*4 bytes of positions.
        assert!(d.bytes_read >= 2 * 4096 * 3 * 4);
    }
}

#[test]
fn cosmology_nyx_reeber_with_flow_control() {
    let Some(engine) = engine() else { return };
    // Scaled-down Listing 6: nyx double-close pattern + some(2) flow.
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: nyx
    nprocs: 4
    actions: [\"actions\", \"nyx\"]
    params: { snapshots: 4, steps_per_snapshot: 1 }
    outports:
      - filename: plt*.h5
        dsets: [ { name: /level_0/density } ]
  - func: reeber
    nprocs: 2
    params: { analysis_rounds: 2, threshold: 1.5 }
    inports:
      - filename: plt*.h5
        io_freq: 2
        dsets: [ { name: /level_0/density } ]
",
        builtin_registry(),
    )
    .unwrap()
    .with_engine(engine.handle())
    .run()
    .unwrap();
    let nyx = report.node("nyx").unwrap();
    // 4 snapshots, io_freq 2 -> 2 served, 2 skipped.
    assert_eq!(nyx.files_served, 2);
    assert_eq!(nyx.serves_skipped, 2);
    let reeber = report.node("reeber").unwrap();
    assert_eq!(reeber.files_opened, 2);
    // Each snapshot moves a full 64^3 f32 grid.
    assert!(reeber.bytes_read >= 2 * 64 * 64 * 64 * 4);
}

#[test]
fn cosmology_all_strategy_serves_everything() {
    let Some(engine) = engine() else { return };
    let report = Wilkins::from_yaml_str(
        "\
tasks:
  - func: nyx
    nprocs: 2
    actions: [\"actions\", \"nyx\"]
    params: { snapshots: 3 }
    outports:
      - filename: plt*.h5
        dsets: [ { name: /level_0/density } ]
  - func: reeber
    nprocs: 2
    params: { analysis_rounds: 1 }
    inports:
      - filename: plt*.h5
        dsets: [ { name: /level_0/density } ]
",
        builtin_registry(),
    )
    .unwrap()
    .with_engine(engine.handle())
    .run()
    .unwrap();
    assert_eq!(report.node("nyx").unwrap().files_served, 3);
    assert_eq!(report.node("reeber").unwrap().files_opened, 3);
}
