//! CLI smoke tests: the `wilkins` binary end-to-end on the shipped
//! configs (validate / graph / run / gantt export).

use std::process::Command;

fn wilkins() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wilkins"))
}

fn repo(p: &str) -> String {
    format!("{}/{p}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn help_lists_commands() {
    let out = wilkins().arg("help").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("run") && s.contains("validate") && s.contains("graph"));
    assert!(s.contains("ensemble") && s.contains("--budget") && s.contains("--policy"));
}

#[test]
fn list_tasks_shows_builtins() {
    let out = wilkins().arg("list-tasks").output().unwrap();
    let s = String::from_utf8_lossy(&out.stdout);
    for name in ["producer", "consumer", "freeze", "detector", "nyx", "reeber"] {
        assert!(s.contains(name), "missing {name} in: {s}");
    }
}

#[test]
fn validate_all_shipped_configs() {
    for cfg in [
        "configs/listing1_3task.yaml",
        "configs/listing2_ensemble_fanin.yaml",
        "configs/listing4_materials.yaml",
        "configs/listing6_cosmology.yaml",
        "configs/flow_control.yaml",
    ] {
        let out = wilkins().args(["validate", &repo(cfg)]).output().unwrap();
        assert!(out.status.success(), "{cfg}: {}", String::from_utf8_lossy(&out.stderr));
        assert!(String::from_utf8_lossy(&out.stdout).starts_with("OK:"));
    }
}

#[test]
fn graph_describes_listing2() {
    let out = wilkins()
        .args(["graph", &repo("configs/listing2_ensemble_fanin.yaml")])
        .output()
        .unwrap();
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("producer[3]"));
    assert!(s.contains("consumer[1]"));
    assert!(s.contains("channel"));
}

#[test]
fn validate_rejects_bad_config() {
    let dir = std::env::temp_dir().join("wilkins-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.yaml");
    std::fs::write(&bad, "tasks:\n  - func: p\n    nprocs: 0\n").unwrap();
    let out = wilkins().args(["validate", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn ensemble_runs_shipped_spec_with_merged_gantt() {
    let dir = std::env::temp_dir().join("wilkins-cli-ensemble");
    std::fs::create_dir_all(&dir).unwrap();
    let gantt = dir.join("merged.csv");
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/ensemble_pipeline.yaml"),
            "--artifacts",
            "/nonexistent", // synthetic instances need no engine
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--gantt",
            gantt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("ensemble completed"), "{s}");
    assert!(s.contains("pipe[0]") && s.contains("pipe[1]") && s.contains("pipe[2]"), "{s}");
    let csv = std::fs::read_to_string(&gantt).unwrap();
    assert!(csv.starts_with("instance,rank,kind,label"));
    assert!(csv.contains("pipe[1]"));
}

#[test]
fn ensemble_rejects_budget_narrower_than_an_instance() {
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/ensemble_pipeline.yaml"),
            "--budget",
            "1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("budget") || err.contains("ranks"), "{err}");
}

#[test]
fn run_listing1_with_gantt_export() {
    let dir = std::env::temp_dir().join("wilkins-cli-run");
    std::fs::create_dir_all(&dir).unwrap();
    let gantt = dir.join("trace.csv");
    let out = wilkins()
        .args([
            "run",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent", // synthetic workflow needs no engine
            "--gantt",
            gantt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("workflow completed"));
    let csv = std::fs::read_to_string(&gantt).unwrap();
    assert!(csv.starts_with("rank,kind,label"));
    assert!(csv.contains("idle") || csv.contains("transfer"));
}
