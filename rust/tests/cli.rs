//! CLI smoke tests: the `wilkins` binary end-to-end on the shipped
//! configs (validate / graph / run / gantt export).

use std::process::Command;

fn wilkins() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wilkins"))
}

fn repo(p: &str) -> String {
    format!("{}/{p}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn help_lists_commands() {
    let out = wilkins().arg("help").output().unwrap();
    assert!(out.status.success());
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("run") && s.contains("validate") && s.contains("graph"));
    assert!(s.contains("ensemble") && s.contains("--budget") && s.contains("--policy"));
    assert!(s.contains("up") && s.contains("--workers") && s.contains("--dry-run"));
    assert!(s.contains("worker") && s.contains("--connect"));
}

#[test]
fn list_tasks_shows_builtins() {
    let out = wilkins().arg("list-tasks").output().unwrap();
    let s = String::from_utf8_lossy(&out.stdout);
    for name in ["producer", "consumer", "freeze", "detector", "nyx", "reeber"] {
        assert!(s.contains(name), "missing {name} in: {s}");
    }
}

#[test]
fn validate_all_shipped_configs() {
    for cfg in [
        "configs/listing1_3task.yaml",
        "configs/listing2_ensemble_fanin.yaml",
        "configs/listing4_materials.yaml",
        "configs/listing6_cosmology.yaml",
        "configs/flow_control.yaml",
        "configs/mixed_transport.yaml",
    ] {
        let out = wilkins().args(["validate", &repo(cfg)]).output().unwrap();
        assert!(out.status.success(), "{cfg}: {}", String::from_utf8_lossy(&out.stderr));
        assert!(String::from_utf8_lossy(&out.stdout).starts_with("OK:"));
    }
}

#[test]
fn graph_describes_listing2() {
    let out = wilkins()
        .args(["graph", &repo("configs/listing2_ensemble_fanin.yaml")])
        .output()
        .unwrap();
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("producer[3]"));
    assert!(s.contains("consumer[1]"));
    assert!(s.contains("channel"));
}

#[test]
fn validate_rejects_bad_config() {
    let dir = std::env::temp_dir().join("wilkins-cli-test");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.yaml");
    std::fs::write(&bad, "tasks:\n  - func: p\n    nprocs: 0\n").unwrap();
    let out = wilkins().args(["validate", bad.to_str().unwrap()]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn ensemble_runs_shipped_spec_with_merged_gantt() {
    let dir = std::env::temp_dir().join("wilkins-cli-ensemble");
    std::fs::create_dir_all(&dir).unwrap();
    let gantt = dir.join("merged.csv");
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/ensemble_pipeline.yaml"),
            "--artifacts",
            "/nonexistent", // synthetic instances need no engine
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--gantt",
            gantt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("ensemble completed"), "{s}");
    assert!(s.contains("pipe[0]") && s.contains("pipe[1]") && s.contains("pipe[2]"), "{s}");
    let csv = std::fs::read_to_string(&gantt).unwrap();
    assert!(csv.starts_with("instance,rank,kind,label"));
    assert!(csv.contains("pipe[1]"));
}

#[test]
fn ensemble_rejects_budget_narrower_than_an_instance() {
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/ensemble_pipeline.yaml"),
            "--budget",
            "1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("budget") || err.contains("ranks"), "{err}");
}

#[test]
fn run_listing1_with_gantt_export() {
    let dir = std::env::temp_dir().join("wilkins-cli-run");
    std::fs::create_dir_all(&dir).unwrap();
    let gantt = dir.join("trace.csv");
    let out = wilkins()
        .args([
            "run",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent", // synthetic workflow needs no engine
            "--gantt",
            gantt.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("workflow completed"));
    let csv = std::fs::read_to_string(&gantt).unwrap();
    assert!(csv.starts_with("rank,kind,label"));
    assert!(csv.contains("idle") || csv.contains("transfer"));
}

/// Task stat rows (first 7 columns: name, procs, served, skipped,
/// bytes_out, opened, bytes_in) from a CLI workflow report. The two
/// timing columns are dropped — wall-clock legitimately differs
/// between substrates; the counters must not.
fn report_rows(stdout: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut in_report = false;
    for line in stdout.lines() {
        if line.starts_with("workflow completed") {
            in_report = true;
            continue;
        }
        if !in_report || line.starts_with("task ") {
            continue;
        }
        let cols: Vec<String> = line.split_whitespace().take(7).map(str::to_string).collect();
        if cols.len() == 7 {
            rows.push(cols);
        }
    }
    rows
}

#[test]
fn up_two_workers_matches_single_process_run() {
    let dir = std::env::temp_dir().join("wilkins-cli-up");
    std::fs::create_dir_all(&dir).unwrap();
    let single = wilkins()
        .args([
            "run",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("single").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(single.status.success(), "{}", String::from_utf8_lossy(&single.stderr));
    let multi = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("multi").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(multi.status.success(), "{}", String::from_utf8_lossy(&multi.stderr));

    let s1 = String::from_utf8_lossy(&single.stdout);
    let s2 = String::from_utf8_lossy(&multi.stdout);
    assert!(s2.contains("process-per-node"), "{s2}");
    assert!(s2.contains("workflow completed"), "{s2}");

    // Per-task step counts (files served/opened) and byte totals must
    // be identical across the two substrates.
    let rows1 = report_rows(&s1);
    let rows2 = report_rows(&s2);
    assert_eq!(rows1.len(), 3, "three tasks in listing 1: {s1}");
    assert_eq!(rows1, rows2, "per-task stats must not depend on placement");
    // Wire-level totals are no longer placement-invariant: the zero-
    // copy data plane hands same-process serves through the shared
    // registry, so the single-process run moves far fewer mailbox
    // bytes than the 2-worker mesh. What it must report instead is a
    // fully engaged fast path.
    assert!(s1.contains("dataplane: bytes_shared="), "{s1}");
}

#[test]
fn mixed_transport_runs_on_both_substrates() {
    // The routed data plane end-to-end through the CLI: per-dataset
    // memory/file/write-through routing must produce identical
    // per-task counters single-process and across a 2-worker `up`
    // mesh (verify=1 is the task default, so the consumers
    // element-check every byte on both substrates).
    let dir = std::env::temp_dir().join("wilkins-cli-mixed");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let single = wilkins()
        .args([
            "run",
            &repo("configs/mixed_transport.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("single").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(single.status.success(), "{}", String::from_utf8_lossy(&single.stderr));
    let multi = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/mixed_transport.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("multi").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(multi.status.success(), "{}", String::from_utf8_lossy(&multi.stderr));
    let s1 = String::from_utf8_lossy(&single.stdout);
    let s2 = String::from_utf8_lossy(&multi.stdout);
    assert_eq!(
        report_rows(&s1),
        report_rows(&s2),
        "mixed-route counters must not depend on placement"
    );
    // Single process: the write-through grid is served zero-copy.
    assert!(s1.contains("dataplane: bytes_shared="), "{s1}");
    // Both substrates archived the file-routed datasets.
    for sub in ["single", "multi"] {
        let l5 = std::fs::read_dir(dir.join(sub))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".l5"))
            .count();
        assert!(l5 > 0, "no .l5 artifact under {sub}");
    }
}

#[test]
fn up_fans_ensemble_instances_across_worker_pool() {
    let dir = std::env::temp_dir().join("wilkins-cli-up-ens");
    std::fs::create_dir_all(&dir).unwrap();
    let out = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/ensemble_pipeline.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("process-per-instance"), "{s}");
    assert!(s.contains("ensemble completed"), "{s}");
    assert!(s.contains("on 2 workers"), "{s}");
    for inst in ["pipe[0]", "pipe[1]", "pipe[2]", "slow"] {
        assert!(s.contains(inst), "missing {inst} in: {s}");
    }
}

#[test]
fn ensemble_dry_run_prints_packing_plan_without_running() {
    let out = wilkins()
        .args(["ensemble", &repo("configs/ensemble_pipeline.yaml"), "--dry-run"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("packing plan: 4 instances"), "{s}");
    assert!(s.contains("wave 1"), "{s}");
    assert!(s.contains("pipe[0]"), "{s}");
    assert!(s.contains("all 4 instances placed"), "{s}");
    assert!(!s.contains("ensemble completed"), "dry run must not launch: {s}");

    // Worker slots reshape the plan: with one slot, waves are single
    // admissions and the placement line says so.
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/ensemble_pipeline.yaml"),
            "--dry-run",
            "--workers",
            "1",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("on 1 workers"), "{s}");
}

#[test]
fn shipped_placement_spec_parses_and_plans() {
    // configs/ensemble_placement.yaml carries the process-placement
    // keys; a dry run must honor its `workers: 2` without any flags.
    let out = wilkins()
        .args(["ensemble", &repo("configs/ensemble_placement.yaml"), "--dry-run"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("packing plan: 4 instances"), "{s}");
    assert!(s.contains("process-per-instance on 2 workers"), "{s}");
}

#[test]
fn worker_requires_connect_and_id() {
    let out = wilkins().arg("worker").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--connect"), "{err}");
}
