//! Deterministic wire replay verification (docs/replay.md).
//!
//! Records real multi-process runs with full payload capture
//! (`WILKINS_TRACE_WIRE=full`), then re-runs them in this process:
//!
//! * a 2-worker chaos ensemble (worker 0 hard-killed mid-campaign)
//!   replayed 100 consecutive times, every replay bit-identical to
//!   the first and — on the deterministic surface — to the recorded
//!   report itself;
//! * a 2-worker `up` world replayed both ways: the coordinator
//!   schedule into the merged `RunReport`, and worker 0's actual rank
//!   code re-executed against its recorded inbound frames;
//! * the same double replay for a run whose payloads rode the
//!   shared-memory descriptor plane (`K_DATA_SHM`), reconstructed
//!   purely from the segment images the full tap captured;
//! * the wiretap reader's torn-tail tolerance at every byte offset a
//!   kill can tear the final record;
//! * a worker killed at the `LaunchWorld` seam failing the run loudly
//!   with `WilkinsError::WorkerLost` naming the worker.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use wilkins::lowfive::VolStats;
use wilkins::net::proto::{WorldDone, K_DATA_SHM, K_WORLD_DONE};
use wilkins::net::{
    run_workflow_distributed_on, worker_main_with, FaultPlan, HeartbeatConfig, UpOpts,
    WorkerOpts, WorkerPool,
};
use wilkins::obs::replay::{self, RecordedRun, RunKind};
use wilkins::obs::wiretap::{read_log, Dir, WireLog};
use wilkins::WilkinsError;

fn wilkins() -> Command {
    Command::new(env!("CARGO_BIN_EXE_wilkins"))
}

fn repo(p: &str) -> String {
    format!("{}/{p}", env!("CARGO_MANIFEST_DIR"))
}

/// Fresh scratch dir per test (tests share one process, so the tag
/// does the disambiguation).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wilkins-replay-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Fast liveness cadence (same rationale as `tests/faults.rs`): quick
/// detection, deadline wide enough for CI scheduler jitter.
fn fast_hb() -> HeartbeatConfig {
    HeartbeatConfig {
        interval: Duration::from_millis(25),
        deadline: Duration::from_millis(400),
    }
}

/// Host `n` emulated workers on threads of this process
/// (integration-test binaries cannot re-exec themselves in worker
/// mode); `fault_specs[id]` is worker `id`'s injected fault plan.
fn host_pool(n: usize, hb: HeartbeatConfig, fault_specs: &[&str]) -> Arc<WorkerPool> {
    let plans: Vec<String> = (0..n)
        .map(|id| fault_specs.get(id).copied().unwrap_or("").to_string())
        .collect();
    let pool = WorkerPool::host(n, hb, |addr, id| {
        let addr = addr.to_string();
        let plan = FaultPlan::parse(&plans[id]).expect("fault spec parses");
        let beat = hb.interval;
        std::thread::Builder::new()
            .name(format!("replay-wk-{id}"))
            .spawn(move || {
                let _ = worker_main_with(
                    &addr,
                    id,
                    WorkerOpts { heartbeat: beat, faults: plan },
                );
            })
            .expect("spawn emulated worker");
    })
    .expect("host pool");
    Arc::new(pool)
}

/// The headline acceptance test: record a 2-worker chaos campaign
/// (worker 0 hard-killed on its first instance, so the recording
/// contains a real loss + re-dispatch), then replay it 100
/// consecutive times. Replay #1 must match the recorded report on the
/// deterministic surface; replays #2..#100 must be bit-identical to
/// replay #1 — raw JSON, no normalization.
#[test]
fn recorded_chaos_ensemble_replays_bit_identically_100_times() {
    let dir = scratch("chaos");
    let json = dir.join("report.json");
    let out = wilkins()
        .args([
            "ensemble",
            &repo("configs/chaos_ensemble.yaml"),
            "--workers",
            "2",
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .env("WILKINS_FAULT", "kill@0:after=0")
        .env("WILKINS_FAULT_HARD", "1")
        .env("WILKINS_TRACE_WIRE", "full")
        .env("WILKINS_TRACE_DIR", dir.to_str().unwrap())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let recorded = std::fs::read_to_string(&json).unwrap();
    assert!(recorded.contains("\"lost_workers\":1"), "no loss recorded: {recorded}");
    assert!(recorded.contains("\"dup_done\":0"), "{recorded}");

    let run = RecordedRun::load(&dir).unwrap();
    assert_eq!(run.kind, RunKind::Ensemble);
    assert_eq!(run.workers.len(), 2, "expected logs from both pool workers");

    let first = replay::replay(&run).unwrap().to_json();
    assert!(first.contains("\"lost_workers\":1"), "{first}");
    assert_eq!(
        replay::normalize_report_json(&first).unwrap(),
        replay::normalize_report_json(&recorded).unwrap(),
        "replay diverged from the recorded report\nreplayed: {first}\nrecorded: {recorded}"
    );

    for i in 1..100 {
        let run = RecordedRun::load(&dir).unwrap();
        let json_i = replay::replay(&run).unwrap().to_json();
        assert_eq!(json_i, first, "replay {i} not bit-identical to replay 0");
    }

    // CLI surface: `wilkins replay <dir>` defaults its diff baseline
    // to <dir>/report.json and must declare the runs identical.
    let out = wilkins().args(["replay", dir.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let s = String::from_utf8_lossy(&out.stdout);
    assert!(s.contains("report diff: identical"), "{s}");
}

/// A clean 2-worker `up` world replays both ways: the coordinator
/// schedule reproduces the merged report, and execution replay
/// re-runs worker 0's actual rank code against the recorded inbound
/// frames, landing on the same stable per-node counters worker 0
/// shipped back in its `WorldDone`.
#[test]
fn recorded_world_up_replays_and_reexecutes_worker_ranks() {
    let dir = scratch("world");
    let json = dir.join("report.json");
    let out = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/listing1_3task.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .env("WILKINS_TRACE_WIRE", "full")
        .env("WILKINS_TRACE_DIR", dir.to_str().unwrap())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let recorded = std::fs::read_to_string(&json).unwrap();

    let run = RecordedRun::load(&dir).unwrap();
    assert_eq!(run.kind, RunKind::World);
    assert_eq!(run.workers.len(), 2);
    assert!(!run.truncated, "clean shutdown must not leave torn logs");

    let rep = replay::replay(&run).unwrap();
    assert_eq!(
        replay::normalize_report_json(&rep.to_json()).unwrap(),
        replay::normalize_report_json(&recorded).unwrap(),
        "world replay diverged from the recorded report"
    );

    // Worker 0's recorded WorldDone is the ground truth for what its
    // ranks did; merge its per-rank stats per node the same way the
    // report builder does.
    let done0 = run
        .coordinator
        .iter()
        .find(|r| r.dir == Dir::Rx && r.kind == K_WORLD_DONE && r.link == 0)
        .expect("coordinator log holds worker 0's WorldDone");
    let done0 = WorldDone::decode(&done0.payload).unwrap();
    assert!(done0.error.is_empty(), "{}", done0.error);
    let mut expected: BTreeMap<usize, VolStats> = BTreeMap::new();
    for o in &done0.outcomes {
        expected.entry(o.node as usize).or_default().merge_from(&o.stats);
    }
    assert!(!expected.is_empty(), "worker 0 hosted no ranks?");

    let partial = replay::replay_worker_ranks(&run, 0, &dir.join("re-exec")).unwrap();
    // Only the wall-clock-free counters can be compared: the replay
    // never stalls on flow credits (they are pre-injected), so the
    // wait/stall/queue-depth gauges legitimately differ.
    for (node, exp) in &expected {
        for name in ["files_served", "bytes_served", "files_opened", "bytes_read"] {
            assert_eq!(
                partial.nodes[*node].stats.counter(name),
                exp.counter(name),
                "node {node} ({}) counter {name} diverged from the recording",
                partial.nodes[*node].name
            );
        }
    }
}

/// The shm-plane analogue of the world replay above: the fixture's
/// 256 KiB grid travels as `K_DATA_SHM` descriptor frames whose
/// payloads live in shared-memory segments the wire never carried
/// (the tap stores descriptor + segment image). By replay time the
/// segment files are unlinked, so both the coordinator-schedule
/// replay and worker 0's re-execution must reproduce the recording
/// from the captured images alone.
#[test]
fn recorded_shm_world_replays_from_captured_segment_images() {
    let dir = scratch("shm-world");
    let json = dir.join("report.json");
    let out = wilkins()
        .args([
            "up",
            "--workers",
            "2",
            &repo("configs/shm_replay.yaml"),
            "--artifacts",
            "/nonexistent",
            "--workdir",
            dir.join("work").to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ])
        .env("WILKINS_TRACE_WIRE", "full")
        .env("WILKINS_TRACE_DIR", dir.to_str().unwrap())
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let recorded = std::fs::read_to_string(&json).unwrap();

    let run = RecordedRun::load(&dir).unwrap();
    assert_eq!(run.kind, RunKind::World);
    assert_eq!(run.workers.len(), 2);
    assert!(!run.truncated, "clean shutdown must not leave torn logs");
    // The fixture is sized so the grid rides the shm plane; a
    // recording with no descriptor frames would silently demote this
    // test to a second copy of the inline-path one above.
    let shm_frames = run
        .workers
        .iter()
        .flat_map(|(_, recs)| recs.iter())
        .filter(|r| r.kind == K_DATA_SHM)
        .count();
    assert!(shm_frames > 0, "recorded run carried no K_DATA_SHM frames");

    let rep = replay::replay(&run).unwrap();
    assert_eq!(
        replay::normalize_report_json(&rep.to_json()).unwrap(),
        replay::normalize_report_json(&recorded).unwrap(),
        "shm-plane replay diverged from the recorded report"
    );

    // Re-execute worker 0's ranks (producer or consumer, whichever
    // placement put there) against the captured images; the consumer
    // re-verifies every grid value, so a corrupt image fails the run
    // itself, not just the counter diff.
    let done0 = run
        .coordinator
        .iter()
        .find(|r| r.dir == Dir::Rx && r.kind == K_WORLD_DONE && r.link == 0)
        .expect("coordinator log holds worker 0's WorldDone");
    let done0 = WorldDone::decode(&done0.payload).unwrap();
    assert!(done0.error.is_empty(), "{}", done0.error);
    let mut expected: BTreeMap<usize, VolStats> = BTreeMap::new();
    for o in &done0.outcomes {
        expected.entry(o.node as usize).or_default().merge_from(&o.stats);
    }
    assert!(!expected.is_empty(), "worker 0 hosted no ranks?");

    let partial = replay::replay_worker_ranks(&run, 0, &dir.join("re-exec")).unwrap();
    for (node, exp) in &expected {
        for name in ["files_served", "bytes_served", "files_opened", "bytes_read"] {
            assert_eq!(
                partial.nodes[*node].stats.counter(name),
                exp.counter(name),
                "node {node} ({}) counter {name} diverged from the recording",
                partial.nodes[*node].name
            );
        }
    }
}

/// Torn-tail tolerance, exhaustively: truncate a v2 log at *every*
/// byte offset of its final record. Exactly at the previous record's
/// boundary is a clean (shorter) log; one byte further through the
/// end-minus-one is a torn tail — complete prefix plus the
/// `truncated` flag, never an error.
#[test]
fn read_log_tolerates_truncation_at_every_byte_of_the_last_record() {
    let dir = scratch("torn");
    let path = dir.join("t.wtap");
    {
        let mut log = WireLog::create_full(&path).unwrap();
        log.record_parts(7, Dir::Tx, 4, &[b"alpha"]).unwrap();
        log.record_parts(7, Dir::Rx, 5, &[b"bravo-", b"charlie"]).unwrap();
        log.record_parts(9, Dir::Tx, 3, &[b"x"]).unwrap();
    }
    let full = read_log(&path).unwrap();
    assert_eq!(full.version, 2);
    assert!(!full.truncated);
    assert_eq!(full.records.len(), 3);
    assert_eq!(full.records[1].payload, b"bravo-charlie".to_vec());

    let bytes = std::fs::read(&path).unwrap();
    // head (18) + capture-length word (4) + 1 payload byte.
    let last_len = 18 + 4 + 1;
    let boundary = bytes.len() - last_len;
    for cut in boundary..bytes.len() {
        let torn = dir.join(format!("cut-{cut}.wtap"));
        std::fs::write(&torn, &bytes[..cut]).unwrap();
        let log = read_log(&torn).unwrap();
        assert_eq!(log.records.len(), 2, "cut at byte {cut}");
        assert_eq!(&log.records[..], &full.records[..2], "cut at byte {cut}");
        assert_eq!(
            log.truncated,
            cut != boundary,
            "truncated flag wrong for cut at byte {cut}"
        );
    }
}

/// Header-only (v1) recordings cannot be replayed; the loader must
/// say so and point at the fix. An empty directory gets the
/// how-to-record hint too.
#[test]
fn loader_rejects_v1_logs_and_empty_dirs_with_recording_hints() {
    let dir = scratch("v1-reject");
    {
        let mut log = WireLog::create(&dir.join("w.wtap")).unwrap();
        log.record(0, Dir::Tx, 4, 32).unwrap();
    }
    let msg = RecordedRun::load(&dir).unwrap_err().to_string();
    assert!(msg.contains("WILKINS_TRACE_WIRE=full"), "unhelpful error: {msg}");

    let empty = scratch("empty");
    let msg = RecordedRun::load(&empty).unwrap_err().to_string();
    assert!(msg.contains("no .wtap logs"), "{msg}");
    assert!(msg.contains("WILKINS_TRACE_WIRE=full"), "unhelpful error: {msg}");
}

/// `process-per-node` worker loss: a worker killed at the
/// `LaunchWorld` seam (before its ranks ever run) must fail the run
/// loudly with `WorkerLost` naming the worker — not hang the
/// coordinator, not report a partial world.
#[test]
fn worker_killed_mid_launch_world_fails_loudly_with_worker_lost() {
    let src = std::fs::read_to_string(repo("configs/listing1_3task.yaml")).unwrap();
    let pool = host_pool(2, fast_hb(), &["kill@0:at=launch"]);
    let opts = UpOpts {
        workers: 2,
        time_scale: 1.0,
        workdir: Some(scratch("launch-loss")),
        artifacts: None,
        heartbeat: fast_hb(),
    };
    let err = run_workflow_distributed_on(&pool, &src, &opts).unwrap_err();
    match err {
        WilkinsError::WorkerLost(m) => {
            assert!(m.contains("worker 0"), "loss message must name the worker: {m}")
        }
        other => panic!("want WorkerLost naming worker 0, got {other:?}"),
    }
}
