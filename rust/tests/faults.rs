//! Fault-tolerance verification suite (docs/fault-tolerance.md).
//!
//! Drives the `net::faults` injection seam against real pools of
//! emulated workers — `worker_main_with` on threads of this process,
//! hosted by [`WorkerPool::host`] — in three phases:
//!
//! * **Phase 1 — liveness**: a wedged worker (alive socket, no
//!   heartbeats, no replies) is declared dead within the configured
//!   deadline instead of blocking the coordinator forever.
//! * **Phase 2 — requeue**: a worker killed mid-campaign loses its
//!   slot and its in-flight instance completes on a survivor; the
//!   campaign finishes with every instance exactly once and the
//!   engagement counters visible in the merged report.
//! * **Phase 3 — idempotency**: duplicated and dropped `InstanceDone`
//!   acknowledgements are absorbed by the per-dispatch idempotency
//!   keys — nothing is double-counted.
//!
//! Plus a determinism regression: the same campaign under the same
//! injected kill, 20 times, must produce bit-identical results (all
//! timing-dependent fields excluded).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use wilkins::ensemble::{Ensemble, EnsembleReport};
use wilkins::net::proto::RunInstance;
use wilkins::net::{worker_main_with, FaultPlan, HeartbeatConfig, WorkerOpts, WorkerPool};
use wilkins::tasks::builtin_registry;
use wilkins::WilkinsError;

/// Fast cadence so liveness tests resolve in milliseconds, with a
/// deadline wide enough (16 intervals) that scheduler jitter on a
/// loaded CI machine cannot kill a healthy link.
fn fast_hb() -> HeartbeatConfig {
    HeartbeatConfig {
        interval: Duration::from_millis(25),
        deadline: Duration::from_millis(400),
    }
}

/// Host a pool of `n` emulated workers on threads of this process
/// (integration-test binaries cannot re-exec themselves in worker
/// mode). `fault_specs[id]` is worker `id`'s `WILKINS_FAULT`-grammar
/// plan; missing entries mean no faults.
fn host_pool(n: usize, hb: HeartbeatConfig, fault_specs: &[&str]) -> Arc<WorkerPool> {
    let plans: Vec<String> = (0..n)
        .map(|id| fault_specs.get(id).copied().unwrap_or("").to_string())
        .collect();
    let pool = WorkerPool::host(n, hb, |addr, id| {
        let addr = addr.to_string();
        let plan = FaultPlan::parse(&plans[id]).expect("fault spec parses");
        let beat = hb.interval;
        std::thread::Builder::new()
            .name(format!("faults-wk-{id}"))
            .spawn(move || {
                let _ = worker_main_with(
                    &addr,
                    id,
                    WorkerOpts { heartbeat: beat, faults: plan },
                );
            })
            .expect("spawn emulated worker");
    })
    .expect("host pool");
    Arc::new(pool)
}

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wilkins-faults-{}-{tag}", std::process::id()))
}

/// A small producer→consumer campaign: each instance is 2 ranks, the
/// budget admits two at a time, and the counters are exact (2 serves
/// and 2 opens per instance) so "completed exactly once" is checkable
/// per instance.
fn campaign_spec(count: usize) -> String {
    format!(
        "\
ensemble:
  max_ranks: 4
  policy: fifo
  tasks:
    - func: producer
      nprocs: 1
      params: {{ steps: 2, grid_per_proc: 200, particles_per_proc: 200 }}
      outports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
    - func: consumer
      nprocs: 1
      inports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  instances:
    - name: ins
      count: {count}
",
    )
}

/// Run `campaign_spec(count)` on `pool` in its own scratch workdir.
fn run_campaign(
    pool: &Arc<WorkerPool>,
    count: usize,
    tag: &str,
) -> wilkins::Result<EnsembleReport> {
    let spec = campaign_spec(count);
    let ens = Ensemble::from_yaml_str(&spec, builtin_registry())
        .unwrap()
        .with_workdir(scratch(tag));
    ens.run_on_pool(Arc::clone(pool), &spec, Path::new("."), None)
}

/// Every instance ran to completion exactly once: the per-node
/// counters are exact, so a skipped or doubled run would show.
fn assert_each_instance_exactly_once(report: &EnsembleReport, count: usize) {
    assert_eq!(report.instances.len(), count);
    for i in 0..count {
        let inst = report
            .instance(&format!("ins[{i}]"))
            .unwrap_or_else(|| panic!("missing instance ins[{i}]"));
        assert_eq!(
            inst.report.node("producer").unwrap().files_served,
            2,
            "ins[{i}] producer did not serve every step exactly once"
        );
        assert_eq!(
            inst.report.node("consumer").unwrap().files_opened,
            2,
            "ins[{i}] consumer did not open every step exactly once"
        );
    }
}

// ---------------------------------------------------------------- phase 1

/// A wedged worker — socket open, heartbeats stopped, no reply coming
/// — is the failure plain EOF detection can never see. The liveness
/// deadline must surface it as `WorkerLost` instead of blocking the
/// dispatch forever.
#[test]
fn phase1_wedged_worker_declared_dead_within_deadline() {
    let hb = fast_hb();
    let pool = host_pool(1, hb, &["wedge@0"]);
    let req = RunInstance {
        spec_src: campaign_spec(1),
        base_dir: ".".to_string(),
        instance_idx: 0,
        workdir: scratch("phase1").display().to_string(),
        artifacts: String::new(),
        time_scale: 1.0,
        idem_key: 1,
    };

    let t0 = Instant::now();
    let err = pool.run_instance(0, &req).expect_err("wedged worker must not reply");
    let waited = t0.elapsed();

    assert!(
        matches!(err, WilkinsError::WorkerLost(_)),
        "expected WorkerLost, got: {err}"
    );
    assert!(pool.is_dead(0), "the wedged worker must be marked dead");
    assert_eq!(pool.alive(), 0);
    assert!(
        waited >= hb.deadline,
        "declared dead after {waited:?}, before the {:?} deadline",
        hb.deadline
    );
    assert!(
        waited < hb.deadline * 20,
        "detection took {waited:?} — the deadline is not bounding the wait"
    );
    assert!(
        pool.heartbeat_misses() >= 1,
        "the silent stretch before the deadline must be counted as misses"
    );

    // A dead worker fails fast forever after — no second deadline wait.
    let t1 = Instant::now();
    let err = pool.run_instance(0, &req).expect_err("dead workers stay dead");
    assert!(matches!(err, WilkinsError::WorkerLost(_)), "got: {err}");
    assert!(t1.elapsed() < hb.deadline, "fail-fast must not wait out the deadline again");
}

// ---------------------------------------------------------------- phase 2

/// Kill one of two workers on its first instance: the campaign must
/// still drain, the lost worker's instance completing on the survivor
/// under a fresh idempotency key, with the engagement visible in the
/// report counters and the rendered `faults:` line.
#[test]
fn phase2_killed_workers_instances_requeue_onto_survivors() {
    let hb = fast_hb();
    let pool = host_pool(2, hb, &["kill@0"]);
    let report = run_campaign(&pool, 4, "phase2").expect("campaign must survive one kill");

    assert_eq!(report.faults.lost_workers, 1, "exactly one worker died");
    assert_eq!(report.faults.retries, 1, "exactly one instance was re-dispatched");
    assert_eq!(pool.alive(), 1, "the survivor keeps serving");
    assert_each_instance_exactly_once(&report, 4);

    let rendered = report.render();
    assert!(rendered.contains("faults:"), "no faults line in:\n{rendered}");
    assert!(rendered.contains("lost_workers=1"), "no lost_workers in:\n{rendered}");
    assert!(rendered.contains("retries=1"), "no retries in:\n{rendered}");
}

/// Losing every worker is the one unsurvivable case — it must be a
/// loud campaign error, not a hang.
#[test]
fn phase2_losing_every_worker_fails_the_campaign() {
    let hb = fast_hb();
    let pool = host_pool(1, hb, &["kill@0"]);
    let err = run_campaign(&pool, 2, "phase2-total").expect_err("no survivors, no campaign");
    let msg = err.to_string();
    assert!(msg.contains("lost every worker"), "unexpected error: {msg}");
    assert_eq!(pool.alive(), 0);
}

// ---------------------------------------------------------------- phase 3

/// A worker that acknowledges twice: the stale duplicate must be
/// dropped by the idempotency-key check and counted, never recorded
/// as a second completion.
#[test]
fn phase3_duplicate_instance_done_is_deduplicated() {
    let hb = fast_hb();
    let pool = host_pool(1, hb, &["dup-done@0"]);
    let report = run_campaign(&pool, 2, "phase3-dup").expect("duplicates must be harmless");

    assert_eq!(report.faults.lost_workers, 0);
    assert_eq!(report.faults.retries, 0);
    assert_eq!(
        report.faults.dup_done, 1,
        "the duplicated acknowledgement must be counted exactly once"
    );
    assert_eq!(pool.dup_done(), 1);
    assert_each_instance_exactly_once(&report, 2);
}

/// A worker that completes the work but loses the acknowledgement
/// (then wedges): the instance is re-dispatched to a survivor and the
/// merged report counts it once even though it physically ran twice.
#[test]
fn phase3_dropped_reply_requeues_without_double_count() {
    let hb = fast_hb();
    let pool = host_pool(2, hb, &["drop-done@0"]);
    let report = run_campaign(&pool, 3, "phase3-drop").expect("dropped ack must be survivable");

    assert_eq!(report.faults.lost_workers, 1, "the silent worker counts as lost");
    assert_eq!(report.faults.retries, 1);
    assert_eq!(pool.alive(), 1);
    assert_each_instance_exactly_once(&report, 3);
}

// ------------------------------------------------------------- baseline

/// With no fault plan armed, a heartbeating pool behaves exactly like
/// the pre-liveness one: no losses, no retries, no duplicates.
#[test]
fn healthy_pool_runs_clean_with_heartbeats_on() {
    let hb = fast_hb();
    let pool = host_pool(2, hb, &[]);
    let report = run_campaign(&pool, 3, "healthy").expect("healthy campaign");

    assert_eq!(report.faults.lost_workers, 0);
    assert_eq!(report.faults.retries, 0);
    assert_eq!(report.faults.dup_done, 0);
    assert_eq!(pool.alive(), 2);
    assert_each_instance_exactly_once(&report, 3);
}

// ---------------------------------------------------------- determinism

/// Everything about a report that must not depend on timing, worker
/// fates, or recovery paths: instance identity and every per-node
/// counter, plus the deterministic fault counters. Wall-clock fields
/// and `heartbeat_misses` (a jitter observation) are excluded.
fn fingerprint(report: &EnsembleReport) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(
        s,
        "budget={} lost={} retries={} dup={}",
        report.budget, report.faults.lost_workers, report.faults.retries, report.faults.dup_done
    );
    for inst in &report.instances {
        let _ = write!(s, "{} ranks={}", inst.name, inst.ranks);
        for node in &inst.report.nodes {
            let _ = write!(
                s,
                " | {} served={} skipped={} dropped={} bytes_out={} opened={} bytes_in={}",
                node.name,
                node.files_served,
                node.serves_skipped,
                node.serves_dropped,
                node.bytes_served,
                node.files_opened,
                node.bytes_read
            );
        }
        let _ = writeln!(s);
    }
    s
}

/// The same campaign under the same mid-campaign kill, 20 times: the
/// merged results must be bit-identical. Fault recovery is allowed to
/// cost wall-clock, never to perturb what the workflows computed.
#[test]
fn determinism_20_runs_under_injected_worker_kill() {
    let mut prints = std::collections::BTreeSet::new();
    for run in 0..20 {
        let hb = fast_hb();
        let pool = host_pool(2, hb, &["kill@0"]);
        let report = run_campaign(&pool, 3, &format!("det-{run}"))
            .unwrap_or_else(|e| panic!("run {run} failed: {e}"));
        assert_eq!(report.faults.lost_workers, 1, "run {run}");
        prints.insert(fingerprint(&report));
        pool.shutdown();
    }
    assert_eq!(
        prints.len(),
        1,
        "fault recovery perturbed the merged results:\n{}",
        prints.into_iter().collect::<Vec<_>>().join("----\n")
    );
}
