//! Offline stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! The Wilkins runtime codes against the xla-rs API surface:
//! [`PjRtClient::cpu`], [`HloModuleProto::from_text_file`],
//! [`PjRtClient::compile`], [`PjRtLoadedExecutable::execute`] and the
//! [`Literal`] conversions. The offline toolchain has no
//! `xla_extension` shared library, so this shim provides the same
//! types with [`PjRtClient::cpu`] failing cleanly — the engine thread
//! (`wilkins::runtime`) already degrades every request into a readable
//! runtime error when the client is unavailable, and synthetic
//! workflows never touch it.
//!
//! To run the real AOT payloads, replace the `xla` path dependency in
//! the root `Cargo.toml` with the actual xla-rs crate; no Wilkins code
//! changes.

use std::fmt;

/// Error type matching `xla::Error`'s role in the real bindings.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub() -> Error {
        Error(
            "xla stub: PJRT unavailable in this build (link the real xla-rs crate \
             to execute AOT artifacts)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub has no backing runtime, so [`cpu`]
/// always fails; callers are expected to degrade gracefully.
///
/// [`cpu`]: PjRtClient::cpu
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// Parsed HLO module (text form in the real crate).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Mirrors xla-rs: one buffer list per device; callers index
    /// `[0][0]` on a single-device client.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A host-side literal value.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly() {
        let err = match PjRtClient::cpu() {
            Err(e) => e.to_string(),
            Ok(_) => panic!("stub must not produce a client"),
        };
        assert!(err.contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(Literal::vec1(&[1.0]).to_vec::<f32>().is_err());
    }
}
