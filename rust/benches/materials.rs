//! Figure 10: completion time of the materials-science workflow
//! (LAMMPS + diamond detector) vs number of NxN ensemble instances.
//!
//! Paper setup: 32 procs per LAMMPS instance + 8 per detector, 1 to 64
//! instances, 1M MD steps with analysis every 10K. Result: completion
//! time is flat — 64 instances cost only 1.2% more than one.
//!
//! Substitutions: the LAMMPS proxy runs the AOT md_step payload
//! (N=4096 LJ atoms) on rank 0 with `nwriters: 1` (the paper's
//! subset-writers feature); procs per instance are 4+2 by default and
//! instance counts 1,2,4,8 (16 under WILKINS_BENCH_FULL=1) — the PJRT
//! engine serializes the MD work, so per-instance compute is the
//! scaling limit, not Wilkins. Requires `make artifacts`.

use std::path::PathBuf;

use wilkins::bench_util::{full_scale, mean, time_trials, Table};
use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn run(engine: &Engine, instances: usize) -> f64 {
    let yaml = format!(
        "\
tasks:
  - func: freeze
    taskCount: {instances}
    nprocs: 4
    nwriters: 1
    params: {{ dumps: 2, execs_per_dump: 1 }}
    outports:
      - filename: dump-h5md.h5
        dsets: [ {{ name: /particles/* }} ]
  - func: detector
    taskCount: {instances}
    nprocs: 2
    stateless: 1
    inports:
      - filename: dump-h5md.h5
        dsets: [ {{ name: /particles/* }} ]
",
    );
    let w = Wilkins::from_yaml_str(&yaml, builtin_registry())
        .unwrap()
        .with_engine(engine.handle());
    w.run().unwrap().elapsed.as_secs_f64()
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        println!("SKIP: artifacts missing; run `make artifacts` first");
        return;
    }
    let engine = Engine::start(&dir).unwrap();
    let counts: Vec<usize> = if full_scale() {
        vec![1, 2, 4, 8, 16]
    } else {
        vec![1, 2, 4, 8]
    };
    let trials = 3;
    println!("== Figure 10: materials-science NxN ensemble scaling ==");
    println!("(freeze 4 procs (1 writer) + detector 2 procs per instance; avg of {trials})\n");

    let mut table = Table::new(&["instances", "completion (s)", "vs 1 instance"]);
    let mut times = Vec::new();
    for &c in &counts {
        let t = mean(&time_trials(trials, true, || {
            run(&engine, c);
        }));
        times.push(t);
        table.row(&[
            c.to_string(),
            format!("{t:.3}"),
            format!("{:+.1}%", (t - times[0]) / times[0] * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!("\npaper: 64 instances within 1.2% of a single instance (NxN is flat)");
    println!("note: our single shared PJRT CPU engine serializes MD compute, so");
    println!("completion grows with the *compute*, unlike the paper's per-node");
    println!("simulations; the Wilkins *coordination* cost per instance is what");
    println!("must stay small. We check transport/coordination scaling via the");
    println!("per-instance overhead after subtracting serialized compute.");

    // Shape check: cost per instance must not blow up — the workflow
    // layer adds at most a modest factor over perfectly-serialized
    // compute (time/instances roughly constant or decreasing).
    let per_instance: Vec<f64> = times
        .iter()
        .zip(&counts)
        .map(|(t, &c)| t / c as f64)
        .collect();
    let first = per_instance[0];
    let last = *per_instance.last().unwrap();
    assert!(
        last <= first * 1.5,
        "per-instance cost grew: {per_instance:?}"
    );
    println!("OK: per-instance cost flat or improving (Figure 10 shape holds)");
}
