//! Figures 7, 8, 9: scaling of ensembles in fan-out, fan-in and NxN
//! topologies.
//!
//! Paper setup: 2 ranks per producer/consumer instance; instance
//! counts 1, 4, 16, 64, 256. Results: fan-out and fan-in grow ~linearly
//! with the instance count (the single peer serves/reads each instance
//! sequentially: 0.6 s @16 -> 8.2 s @256 for fan-out); NxN stays
//! nearly flat (1:1 pairs are independent).
//!
//! Default sweep stops at 64 instances (130 rank threads); set
//! WILKINS_BENCH_FULL=1 for 256.
//!
//! Testbed caveat (DESIGN.md): this machine exposes a SINGLE core, so
//! independent NxN pairs serialize and wall-clock necessarily grows
//! with the instance count. The paper-equivalent observable here is
//! the *per-instance* cost: flat per-instance cost means zero
//! cross-pair coordination interference, which on Bebop's >=N nodes
//! is exactly what produces Figure 9's flat wall-clock. Fan-out and
//! fan-in are inherently serial at the shared endpoint, so their
//! per-instance cost stays constant too — but their wall-clock
//! linearity is intrinsic (it matches the paper's Figures 7/8 even on
//! parallel hardware).

use wilkins::bench_util::{
    assert_monotonic_increase, assert_roughly_flat, full_scale, mean, time_trials, Table,
};
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const PER_PROC: u64 = 5_000;

fn run(topology: &str, instances: usize) -> f64 {
    let (pcount, ccount) = match topology {
        "fanout" => (1, instances),
        "fanin" => (instances, 1),
        "nxn" => (instances, instances),
        _ => unreachable!(),
    };
    let yaml = format!(
        "\
tasks:
  - func: producer
    taskCount: {pcount}
    nprocs: 2
    params: {{ steps: 1, grid_per_proc: {PER_PROC}, particles_per_proc: {PER_PROC}, verify: 0 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    taskCount: {ccount}
    nprocs: 2
    params: {{ verify: 0 }}
    inports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
    );
    let w = Wilkins::from_yaml_str(&yaml, builtin_registry()).unwrap();
    w.run().unwrap().elapsed.as_secs_f64()
}

fn main() {
    let counts: Vec<usize> = if full_scale() {
        vec![1, 4, 16, 64, 256]
    } else {
        vec![1, 4, 16, 64]
    };
    let trials = 3;
    println!("== Figures 7/8/9: ensemble topology scaling ==");
    println!("(2 ranks per instance, {PER_PROC} elems/proc, avg of {trials} trials)\n");

    let mut series: Vec<(&str, Vec<f64>)> = Vec::new();
    for topo in ["fanout", "fanin", "nxn"] {
        let mut times = Vec::new();
        for &c in &counts {
            let t = mean(&time_trials(trials, true, || {
                run(topo, c);
            }));
            times.push(t);
        }
        series.push((topo, times));
    }

    let mut table = Table::new(&[
        "instances",
        "fan-out (s)",
        "fan-in (s)",
        "NxN (s)",
        "NxN per-inst (s)",
    ]);
    for (i, &c) in counts.iter().enumerate() {
        table.row(&[
            c.to_string(),
            format!("{:.4}", series[0].1[i]),
            format!("{:.4}", series[1].1[i]),
            format!("{:.4}", series[2].1[i]),
            format!("{:.5}", series[2].1[i] / c as f64),
        ]);
    }
    print!("{}", table.render());
    println!("\npaper: fan-out/fan-in grow ~linearly (producer serves each consumer");
    println!("sequentially); NxN stays nearly flat (independent 1:1 pairs).");
    println!("testbed: 1 core serializes independent pairs, so the NxN observable");
    println!("here is flat *per-instance* cost (== flat wall-clock on >=N nodes).");

    // Shape checks over the tail of the sweep (small counts are
    // launch-cost dominated).
    let fanout = &series[0].1;
    let fanin = &series[1].1;
    let nxn = &series[2].1;
    assert_monotonic_increase("fan-out", &fanout[1..], 0.15);
    assert_monotonic_increase("fan-in", &fanin[1..], 0.15);
    let n = counts.len();
    assert!(
        fanout[n - 1] / fanout[1] > (counts[n - 1] / counts[1]) as f64 * 0.2,
        "fan-out should grow roughly with instance count: {fanout:?}"
    );
    // NxN: per-instance cost flat across the sweep tail — no
    // cross-pair interference from the workflow layer.
    let nxn_per: Vec<f64> = nxn
        .iter()
        .zip(&counts)
        .map(|(t, &c)| t / c as f64)
        .collect();
    assert_roughly_flat("NxN per-instance", &nxn_per[1..], 3.0);

    // Paper-scale projection (sim::NetModel, reporting aid): what the
    // measured per-instance cost implies on Bebop-like hardware where
    // every NxN pair gets its own node.
    let per_inst = nxn_per[counts.len() - 1];
    println!("\nprojection (sim/): NxN completion with nodes >= instances:");
    for &c in &counts {
        let t = wilkins::sim::ensemble_completion(c as u64, per_inst, c as u64);
        println!("  {c:>4} instances -> {t:.4}s (flat, Figure 9's shape)");
    }
    println!("OK: ensemble scaling shape holds (Figures 7/8/9)");
}
