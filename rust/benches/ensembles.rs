//! Figures 7, 8, 9: scaling of ensembles in fan-out, fan-in and NxN
//! topologies — driven through the `ensemble` co-scheduling subsystem.
//!
//! Paper setup: 2 ranks per producer/consumer instance; instance
//! counts 1, 4, 16, 64, 256. Results: fan-out and fan-in grow ~linearly
//! with the instance count (the single peer serves/reads each instance
//! sequentially: 0.6 s @16 -> 8.2 s @256 for fan-out); NxN stays
//! nearly flat (1:1 pairs are independent).
//!
//! Topology mapping onto the ensemble layer: fan-out (1:N) and fan-in
//! (N:1) share one endpoint, so each is ONE workflow instance whose
//! `taskCount` spans the ensemble — exactly the paper's YAML. NxN is N
//! independent 1:1 pipelines, so it becomes N co-scheduled instances
//! (`count: N`) under an unbounded rank budget. A final section packs
//! the same NxN instances onto HALF the ranks and compares the fifo
//! and round-robin policies.
//!
//! Default sweep stops at 64 instances (130 rank threads); set
//! WILKINS_BENCH_FULL=1 for 256.
//!
//! Testbed caveat (DESIGN.md): this machine exposes a SINGLE core, so
//! independent NxN pairs serialize and wall-clock necessarily grows
//! with the instance count. The paper-equivalent observable here is
//! the *per-instance* cost: flat per-instance cost means zero
//! cross-pair coordination interference, which on Bebop's >=N nodes
//! is exactly what produces Figure 9's flat wall-clock. Fan-out and
//! fan-in are inherently serial at the shared endpoint, so their
//! per-instance cost stays constant too — but their wall-clock
//! linearity is intrinsic (it matches the paper's Figures 7/8 even on
//! parallel hardware).

use std::sync::Arc;

use wilkins::bench_util::{
    assert_monotonic_increase, assert_roughly_flat, full_scale, mean, time_trials, Table,
};
use wilkins::ensemble::Ensemble;
use wilkins::net::WorkerPool;
use wilkins::tasks::builtin_registry;

const PER_PROC: u64 = 5_000;

/// Spec for a fan topology: one instance, `taskCount` inside.
fn fan_spec(pcount: usize, ccount: usize) -> String {
    format!(
        "\
ensemble:
  tasks:
    - func: producer
      taskCount: {pcount}
      nprocs: 2
      params: {{ steps: 1, grid_per_proc: {PER_PROC}, particles_per_proc: {PER_PROC}, verify: 0 }}
      outports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
    - func: consumer
      taskCount: {ccount}
      nprocs: 2
      params: {{ verify: 0 }}
      inports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  instances:
    - name: fan
"
    )
}

/// Spec for NxN: N co-scheduled instances of an independent 1:1
/// pipeline, optionally on a bounded budget.
fn nxn_spec(instances: usize, budget: usize, policy: &str) -> String {
    format!(
        "\
ensemble:
  max_ranks: {budget}
  policy: {policy}
  tasks:
    - func: producer
      nprocs: 2
      params: {{ steps: 1, grid_per_proc: {PER_PROC}, particles_per_proc: {PER_PROC}, verify: 0 }}
      outports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
    - func: consumer
      nprocs: 2
      params: {{ verify: 0 }}
      inports:
        - filename: outfile.h5
          dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  instances:
    - name: pipe
      count: {instances}
"
    )
}

fn run(topology: &str, instances: usize) -> f64 {
    let spec = match topology {
        "fanout" => fan_spec(1, instances),
        "fanin" => fan_spec(instances, 1),
        // Budget 0 = fully concurrent (all N pairs at once).
        "nxn" => nxn_spec(instances, 0, "fifo"),
        _ => unreachable!(),
    };
    let ens = Ensemble::from_yaml_str(&spec, builtin_registry()).unwrap();
    ens.run().unwrap().elapsed.as_secs_f64()
}

fn main() {
    // `WorkerPool::spawn` re-executes the *current binary* with a
    // leading `worker` argument; route that to the worker serve loop
    // so this bench hosts its own process pool.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("worker") {
        let opt = |name: &str| -> Option<String> {
            argv.iter()
                .position(|a| a == name)
                .and_then(|i| argv.get(i + 1).cloned())
        };
        let connect = opt("--connect").expect("worker mode needs --connect");
        let id: usize = opt("--id")
            .expect("worker mode needs --id")
            .parse()
            .expect("bad --id");
        wilkins::net::worker_main(&connect, id).expect("worker serve loop");
        return;
    }

    let counts: Vec<usize> = if full_scale() {
        vec![1, 4, 16, 64, 256]
    } else {
        vec![1, 4, 16, 64]
    };
    let trials = 3;
    println!("== Figures 7/8/9: ensemble topology scaling (ensemble subsystem) ==");
    println!("(2 ranks per instance, {PER_PROC} elems/proc, avg of {trials} trials)\n");

    let mut series: Vec<(&str, Vec<f64>)> = Vec::new();
    for topo in ["fanout", "fanin", "nxn"] {
        let mut times = Vec::new();
        for &c in &counts {
            let t = mean(&time_trials(trials, true, || {
                run(topo, c);
            }));
            times.push(t);
        }
        series.push((topo, times));
    }

    let mut table = Table::new(&[
        "instances",
        "fan-out (s)",
        "fan-in (s)",
        "NxN (s)",
        "NxN per-inst (s)",
    ]);
    for (i, &c) in counts.iter().enumerate() {
        table.row(&[
            c.to_string(),
            format!("{:.4}", series[0].1[i]),
            format!("{:.4}", series[1].1[i]),
            format!("{:.4}", series[2].1[i]),
            format!("{:.5}", series[2].1[i] / c as f64),
        ]);
    }
    print!("{}", table.render());
    println!("\npaper: fan-out/fan-in grow ~linearly (producer serves each consumer");
    println!("sequentially); NxN stays nearly flat (independent 1:1 pairs).");
    println!("testbed: 1 core serializes independent pairs, so the NxN observable");
    println!("here is flat *per-instance* cost (== flat wall-clock on >=N nodes).");

    // Shape checks over the tail of the sweep (small counts are
    // launch-cost dominated).
    let fanout = &series[0].1;
    let fanin = &series[1].1;
    let nxn = &series[2].1;
    assert_monotonic_increase("fan-out", &fanout[1..], 0.15);
    assert_monotonic_increase("fan-in", &fanin[1..], 0.15);
    let n = counts.len();
    assert!(
        fanout[n - 1] / fanout[1] > (counts[n - 1] / counts[1]) as f64 * 0.2,
        "fan-out should grow roughly with instance count: {fanout:?}"
    );
    // NxN: per-instance cost flat across the sweep tail — no
    // cross-pair interference from the workflow layer.
    let nxn_per: Vec<f64> = nxn
        .iter()
        .zip(&counts)
        .map(|(t, &c)| t / c as f64)
        .collect();
    assert_roughly_flat("NxN per-instance", &nxn_per[1..], 3.0);

    // Co-scheduling on a bounded budget: the same NxN instances packed
    // onto HALF the ranks, fifo vs round-robin. Both must drain the
    // whole ensemble without ever exceeding the budget; the scheduler
    // runs the pairs in two waves.
    let pairs = 16;
    let budget = pairs * 4 / 2;
    println!("\n== co-scheduling {pairs} pipelines on {budget}/{} ranks ==", pairs * 4);
    let mut ptable = Table::new(&["policy", "time (s)", "peak ranks", "rounds"]);
    for policy in ["fifo", "round-robin"] {
        let ens = Ensemble::from_yaml_str(&nxn_spec(pairs, budget, policy), builtin_registry())
            .unwrap();
        let report = ens.run().unwrap();
        assert!(
            report.peak_ranks <= budget,
            "{policy}: peak {} exceeded budget {budget}",
            report.peak_ranks
        );
        assert_eq!(report.instances.len(), pairs, "{policy}: all instances ran");
        ptable.row(&[
            policy.to_string(),
            format!("{:.4}", report.elapsed.as_secs_f64()),
            report.peak_ranks.to_string(),
            report.rounds.to_string(),
        ]);
    }
    print!("{}", ptable.render());

    // == worker-pool trajectory: process-per-instance placement ==
    //
    // The net:: substrate exists to break the one-core serialization
    // caveat: N independent instances on a pool of N worker PROCESSES
    // should approach flat wall-clock on a multi-core host. Record a
    // 1-worker vs N-worker comparison of the same ensemble so
    // BENCH_ensembles.json accumulates the trajectory across PRs
    // (speedup ~1.0 on a single-core box is expected and recorded,
    // not asserted away).
    let pool_pairs = 4usize;
    let pool_spec = nxn_spec(pool_pairs, 0, "fifo");
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let wide = host.clamp(1, pool_pairs);
    println!("\n== process placement: {pool_pairs} pipelines, 1 vs {wide} worker processes ==");
    let mut pool_times: Vec<(usize, f64)> = Vec::new();
    for &w in &[1usize, wide] {
        let pool = Arc::new(WorkerPool::spawn(w).expect("spawn worker pool"));
        let spec_src = pool_spec.clone();
        let t = mean(&time_trials(trials, true, || {
            let ens = Ensemble::from_yaml_str(&spec_src, builtin_registry()).unwrap();
            let report = ens
                .run_on_pool(Arc::clone(&pool), &spec_src, std::path::Path::new("."), None)
                .unwrap();
            assert_eq!(report.instances.len(), pool_pairs);
        }));
        pool.shutdown();
        pool_times.push((w, t));
        println!("  {w} worker(s): {t:.4}s");
    }
    let (one_w, one_t) = pool_times[0];
    let (n_w, n_t) = pool_times[pool_times.len() - 1];
    assert_eq!(one_w, 1);
    let speedup = one_t / n_t;
    println!(
        "  speedup {speedup:.2}x on {host}-core host ({n_w} workers; 1.0x expected on 1 core)"
    );

    // Paper-scale projection (sim::NetModel, reporting aid): what the
    // measured per-instance cost implies on Bebop-like hardware where
    // every NxN pair gets its own node.
    let per_inst = nxn_per[counts.len() - 1];
    println!("\nprojection (sim/): NxN completion with nodes >= instances:");
    for &c in &counts {
        let t = wilkins::sim::ensemble_completion(c as u64, per_inst, c as u64);
        println!("  {c:>4} instances -> {t:.4}s (flat, Figure 9's shape)");
    }

    // == BENCH_ensembles.json: the accumulating trajectory record ==
    let json_arr = |xs: &[f64]| -> String {
        let items: Vec<String> = xs.iter().map(|x| format!("{x:.6}")).collect();
        format!("[{}]", items.join(", "))
    };
    let counts_arr: Vec<String> = counts.iter().map(usize::to_string).collect();
    let json = format!(
        "{{\n  \"bench\": \"ensembles\",\n  \"instance_counts\": [{}],\n  \"fanout_s\": {},\n  \"fanin_s\": {},\n  \"nxn_s\": {},\n  \"nxn_per_instance_s\": {},\n  \"placement\": {{\n    \"instances\": {pool_pairs},\n    \"ranks_per_instance\": 4,\n    \"host_cores\": {host},\n    \"one_worker_s\": {one_t:.6},\n    \"n_workers\": {n_w},\n    \"n_workers_s\": {n_t:.6},\n    \"speedup\": {speedup:.4}\n  }}\n}}\n",
        counts_arr.join(", "),
        json_arr(&series[0].1),
        json_arr(&series[1].1),
        json_arr(nxn),
        json_arr(&nxn_per),
    );
    let out_dir = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let out_path = std::path::Path::new(&out_dir).join("BENCH_ensembles.json");
    std::fs::write(&out_path, json).expect("write BENCH_ensembles.json");
    println!("\nbench record written to {}", out_path.display());
    println!("OK: ensemble scaling shape holds (Figures 7/8/9)");
}
