//! Table 3: completion time of the cosmology workflow (Nyx + Reeber)
//! under different flow-control strategies.
//!
//! Paper setup: Nyx 1024 procs (256^3 grid, 20 snapshots) + Reeber 64
//! procs, Reeber slowed 100x by recomputing halos. Results: all 5421 s;
//! some n=2 2754 s; n=5 1084 s; n=10 702 s — up to 7.7x savings.
//!
//! Substitutions: Nyx proxy 8 procs on a 64^3 grid, 10 snapshots,
//! Reeber proxy 4 procs slowed by `analysis_rounds` (default 12;
//! paper's 100 under WILKINS_BENCH_FULL=1 with 20 snapshots). The Nyx
//! double-open/close custom action (Listing 5) is active throughout.
//! Requires `make artifacts`.

use std::path::PathBuf;

use wilkins::bench_util::{assert_speedup, full_scale, Table};
use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn run(engine: &Engine, snapshots: u64, rounds: i64, io_freq: i64) -> f64 {
    let yaml = format!(
        "\
tasks:
  - func: nyx
    nprocs: 8
    actions: [\"actions\", \"nyx\"]
    params: {{ snapshots: {snapshots}, steps_per_snapshot: 2 }}
    outports:
      - filename: plt*.h5
        dsets: [ {{ name: /level_0/density }} ]
  - func: reeber
    nprocs: 4
    params: {{ analysis_rounds: {rounds}, threshold: 1.5 }}
    inports:
      - filename: plt*.h5
        io_freq: {io_freq}
        dsets: [ {{ name: /level_0/density }} ]
",
    );
    let w = Wilkins::from_yaml_str(&yaml, builtin_registry())
        .unwrap()
        .with_engine(engine.handle());
    w.run().unwrap().elapsed.as_secs_f64()
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        println!("SKIP: artifacts missing; run `make artifacts` first");
        return;
    }
    let engine = Engine::start(&dir).unwrap();
    let (snapshots, rounds) = if full_scale() { (20, 100) } else { (10, 12) };

    println!("== Table 3: cosmology workflow flow control ==");
    println!("(nyx 8 procs + reeber 4 procs slowed {rounds}x, {snapshots} snapshots)\n");
    let mut table = Table::new(&["strategy", "completion (s)", "savings vs all"]);
    let t_all = run(&engine, snapshots, rounds, 1);
    table.row(&["all".into(), format!("{t_all:.2}"), "1.0x".into()]);
    let mut times = vec![("all", t_all)];
    for n in [2i64, 5, 10] {
        let t = run(&engine, snapshots, rounds, n);
        table.row(&[
            format!("some (n={n})"),
            format!("{t:.2}"),
            format!("{:.1}x", t_all / t),
        ]);
        times.push(("some", t));
    }
    print!("{}", table.render());
    println!("\npaper: all 5421s; some n=2 2754s; n=5 1084s; n=10 702s (7.7x savings)");

    // Shape checks: savings increase with n; some(10) is a large win.
    let t2 = times[1].1;
    let t5 = times[2].1;
    let t10 = times[3].1;
    assert!(t2 < t_all, "some(2) must beat all: {t2} vs {t_all}");
    assert!(t5 < t2, "some(5) must beat some(2): {t5} vs {t2}");
    assert!(t10 <= t5 * 1.05, "some(10) must not lose to some(5)");
    assert_speedup("some(10) vs all", t_all, t10, 2.0);
    println!("OK: cosmology flow-control shape holds (Table 3)");
}
