//! Wire hot-path bench: what the pooled, scatter-gather data plane
//! buys over the historical owned-`Vec` path.
//!
//! Three harnesses, each run pooled vs ablated
//! (`Vol::set_pooling(false)`, which also flips the process-wide
//! transport switch):
//!
//! 1. **1-proc serve loop** — a 1→1 coupling over the in-memory
//!    transport with the zero-copy registry ablated, so every round
//!    takes the encode → mailbox → decode path. The pooled win here
//!    is allocation discipline: steady-state rounds must report
//!    `alloc_rounds == 0` beyond warm-up.
//! 2. **2-worker socket mesh** — two `World`s joined over loopback
//!    TCP inside this process (exactly what two worker processes
//!    hold), so the global copy meter sees both ends of the wire.
//!    Reported as bytes-copied-per-byte-delivered; the acceptance
//!    bar is a ≥2x reduction at the 16 MiB payload, where the old
//!    path pays the chunk-split / frame-concat / decode-copy tax in
//!    full.
//! 3. **2-worker `wilkins up`** — real worker processes (this bench
//!    binary self-hosts its pool), wall-clock + the report's
//!    alloc_rounds, with the ablation arm exported to the children
//!    through `WILKINS_POOLING=0`.
//!
//! The mesh harness also carries a 64 B tiny-frame row: at that size
//! the cost is pure per-frame overhead (syscalls, wakeups), which is
//! what the event-loop transport's small-frame coalescing targets.
//! Each row reports how many `write` syscalls the staging buffers
//! absorbed (the `frames_coalesced` counter), and the 64KiB mesh
//! row's frames/sec is gated against the newest archived record in
//! `ci/bench-archive/` so small-frame throughput cannot silently
//! regress.
//!
//! The shm section measures the shared-memory payload plane against
//! the inline socket path over a raw 2-worker mesh (direct
//! `Comm::send_owned` rounds, no lowfive pipeline): bytes *moved* per
//! byte delivered, where moved = user-space memcpys + wire tx bytes +
//! 2x wire rx bytes — the rx double honestly counts the nonblocking
//! reader's lease zero-fill, a real per-byte RAM write the shm path
//! never pays and `note_copied` never sees. The acceptance bar is
//! >= 2x fewer moved bytes at 1 MiB and 16 MiB. The legacy
//! pooled-vs-ablation matrix above runs with the shm plane disabled
//! so it keeps measuring the inline wire it always did.
//!
//! Emits BENCH_wire.json so the trajectory accumulates across PRs.

use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread;
use std::time::Instant;

use wilkins::comm::{buf, InterComm, World};
use wilkins::coordinator::RunReport;
use wilkins::lowfive::{DType, Hyperslab, InChannel, OutChannel, RouteTable, Vol, VolStats};
use wilkins::net::proto::LaunchWorld;
use wilkins::net::rendezvous::{build_mesh_world, MeshWorld};
use wilkins::net::{self, UpOpts};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "wilkins-wire-{}-{}-{}",
        tag,
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// What one serve-loop arm measured.
struct Arm {
    elapsed_s: f64,
    /// Wire-path memcpy'd bytes per payload byte delivered.
    copies_per_byte: f64,
    /// Serve rounds per second (one file close+open+read per round).
    frames_per_sec: f64,
    producer: VolStats,
}

/// Drive one 1→1 coupling for `steps` rounds of `payload` bytes over
/// the given pair of worlds/comm-id layout. The two closures build
/// the producer- and consumer-side (world, workdir) pairs.
fn drive(
    payload: usize,
    steps: u64,
    producer_world: World,
    consumer_world: World,
    zero_copy: bool,
) -> Arm {
    let elems = (payload / 8) as u64;
    let workdir = fresh_dir("serve");
    let copied0 = buf::bytes_copied_total();
    let t0 = Instant::now();
    let wp = {
        let world = producer_world;
        let workdir = workdir.clone();
        thread::spawn(move || {
            let local = world.comm_from_ranks(90, &[0], 0);
            let io = world.comm_from_ranks(92, &[0], 0);
            let mut vol = Vol::new(local.clone(), workdir);
            vol.set_io_comm(Some(io));
            let ic = InterComm::new(local, 93, vec![1]);
            vol.add_out_channel(OutChannel::new(Some(ic), "f.h5", RouteTable::memory()));
            vol.set_zero_copy(zero_copy);
            let data = vec![7u8; payload];
            for _ in 0..steps {
                vol.file_create("f.h5").unwrap();
                vol.dataset_create("f.h5", "/d", DType::U64, &[elems]).unwrap();
                vol.dataset_write("f.h5", "/d", Hyperslab::whole(&[elems]), data.clone())
                    .unwrap();
                vol.file_close("f.h5").unwrap();
            }
            vol.finalize_producer().unwrap();
            vol.stats.clone()
        })
    };
    let wc = {
        let world = consumer_world;
        thread::spawn(move || {
            let local = world.comm_from_ranks(91, &[1], 0);
            let mut vol = Vol::new(local.clone(), fresh_dir("consumer"));
            let ic = InterComm::new(local, 93, vec![0]);
            vol.add_in_channel(InChannel::new(Some(ic), "f.h5", RouteTable::memory()));
            for _ in 0..steps {
                let name = vol.file_open("f.h5").unwrap();
                let bytes = vol
                    .dataset_read(&name, "/d", &Hyperslab::whole(&[elems]))
                    .unwrap();
                assert_eq!(bytes.len(), payload);
                assert_eq!(bytes[payload / 2], 7, "payload must survive the wire");
                vol.file_close(&name).unwrap();
            }
            vol.finalize_consumer().unwrap();
        })
    };
    let producer = wp.join().unwrap();
    wc.join().unwrap();
    let elapsed_s = t0.elapsed().as_secs_f64();
    let copied = (buf::bytes_copied_total() - copied0) as f64;
    let delivered = (payload as u64 * steps) as f64;
    Arm {
        elapsed_s,
        copies_per_byte: copied / delivered,
        frames_per_sec: steps as f64 / elapsed_s,
        producer,
    }
}

/// One-process arm: both ranks are threads of one in-memory world.
/// The zero-copy registry is ablated so the serve takes the encode
/// path this bench measures.
fn serve_local(payload: usize, steps: u64, pooled: bool) -> Arm {
    buf::set_pooling(pooled);
    let world = World::new(2);
    drive(payload, steps, world.clone(), world, false)
}

/// Two-worker arm: two independent socket-meshed worlds in this
/// process (thread-per-rank, loopback TCP between them), so the copy
/// meter covers sender and receiver.
fn serve_mesh(payload: usize, steps: u64, pooled: bool) -> Arm {
    buf::set_pooling(pooled);
    let (side0, side1) = mesh_pair();
    let arm = drive(payload, steps, side0.world.clone(), side1.world.clone(), true);
    side0.shutdown();
    side1.shutdown();
    arm
}

/// Two mesh sides — two worker processes' worth of state — joined
/// over loopback; rank 0 lives on side 0, rank 1 on side 1.
fn mesh_pair() -> (MeshWorld, MeshWorld) {
    let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
    let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let endpoints = vec![
        l0.local_addr().unwrap().to_string(),
        l1.local_addr().unwrap().to_string(),
    ];
    let msg = LaunchWorld {
        config_src: String::new(),
        workdir: String::new(),
        artifacts: String::new(),
        time_scale: 1.0,
        total_ranks: 2,
        endpoints,
        owner_of: vec![0, 1],
        // Liveness off: beats would perturb the wire byte counters
        // this bench compares.
        heartbeat_ms: 0,
        heartbeat_deadline_ms: 0,
    };
    let m0 = msg.clone();
    let h = thread::spawn(move || build_mesh_world(0, &l0, &m0).unwrap());
    let side1 = build_mesh_world(1, &l1, &msg).unwrap();
    let side0 = h.join().unwrap();
    (side0, side1)
}

/// One arm of the shm-vs-inline comparison: `steps` rounds of
/// `payload` bytes sent rank 0 → rank 1 over a fresh 2-worker mesh
/// via `Comm::send_owned` (the lowfive pipeline's symmetric
/// encode/fill copies would dilute the transport-layer difference
/// this row isolates). Returns (moved bytes per delivered byte,
/// elapsed seconds); see the module docs for the moved-bytes
/// definition.
fn mesh_moved_per_byte(payload: usize, steps: u64, shm_on: bool) -> (f64, f64) {
    use wilkins::net::shm;
    use wilkins::obs::Ctr;
    buf::set_pooling(true);
    shm::set_enabled(shm_on);
    let (side0, side1) = mesh_pair();
    let copied0 = buf::bytes_copied_total();
    let (tx0, rx0) = (Ctr::BytesSentWire.get(), Ctr::BytesRecvWire.get());
    let (shm0, fb0) = (Ctr::BytesShm.get(), Ctr::ShmFallbacks.get());
    let t0 = Instant::now();
    let consumer = {
        let world = side1.world.clone();
        thread::spawn(move || {
            let comm = world.comm_world(1);
            for step in 0..steps {
                let (src, bytes) = comm.recv(0, step).unwrap();
                assert_eq!(src, 0);
                assert_eq!(bytes.len(), payload);
                assert_eq!(bytes[payload / 2], 0xa5, "payload must survive the plane");
                // Dropping `bytes` here releases the last view: on the
                // shm arm that stages the segment ack.
            }
        })
    };
    {
        let comm = side0.world.comm_world(0);
        let data = vec![0xa5u8; payload];
        for step in 0..steps {
            comm.send_owned(1, step, data.clone());
        }
    }
    consumer.join().unwrap();
    let elapsed_s = t0.elapsed().as_secs_f64();
    let copied = (buf::bytes_copied_total() - copied0) as f64;
    let tx = (Ctr::BytesSentWire.get() - tx0) as f64;
    let rx = (Ctr::BytesRecvWire.get() - rx0) as f64;
    let via_shm = Ctr::BytesShm.get() - shm0;
    let fallbacks = Ctr::ShmFallbacks.get() - fb0;
    side0.shutdown();
    side1.shutdown();
    let delivered = payload as u64 * steps;
    if shm_on {
        assert_eq!(
            via_shm, delivered,
            "shm arm must carry every payload byte through the shm plane"
        );
        assert_eq!(fallbacks, 0, "shm arm must not fall back to the socket path");
    } else {
        assert_eq!(via_shm, 0, "inline arm must not touch the shm plane");
    }
    ((copied + tx + 2.0 * rx) / delivered as f64, elapsed_s)
}

fn up_yaml() -> String {
    "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 4, grid_per_proc: 50000, particles_per_proc: 50000, verify: 0 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 2
    params: { verify: 0 }
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
"
    .to_string()
}

/// Run the shipped producer/consumer workflow over a real 2-worker
/// pool; the pooling arm reaches the worker processes via the
/// `WILKINS_POOLING` environment variable they inherit.
fn run_up(pooled: bool) -> (f64, RunReport) {
    std::env::set_var("WILKINS_POOLING", if pooled { "1" } else { "0" });
    buf::set_pooling(pooled);
    let opts = UpOpts {
        workers: 2,
        time_scale: 1.0,
        workdir: None,
        artifacts: None,
        heartbeat: Default::default(),
    };
    let t0 = Instant::now();
    let report = net::run_workflow_distributed(&up_yaml(), &opts).unwrap();
    (t0.elapsed().as_secs_f64(), report)
}

const SIZES: [(&str, usize); 4] = [
    ("64B", 64),
    ("64KiB", 1 << 16),
    ("1MiB", 1 << 20),
    ("16MiB", 1 << 24),
];

/// Newest archived wire record under `ci/bench-archive/` (populated
/// by every `ci/check.sh` run), with the pooled 2-worker-mesh
/// `frames_per_sec` of the smallest size every record carries
/// (64KiB — the archive predates the 64B row).
fn archived_mesh_small_fps() -> Option<(std::path::PathBuf, f64)> {
    let root = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let dir = std::path::Path::new(&root).join("ci").join("bench-archive");
    let mut newest: Option<(std::time::SystemTime, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(&dir).ok()?.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !(name.starts_with("BENCH_wire.") && name.ends_with(".json")) {
            continue;
        }
        let Some(mtime) = entry.metadata().ok().and_then(|m| m.modified().ok()) else {
            continue;
        };
        if newest.as_ref().map_or(true, |(t, _)| mtime > *t) {
            newest = Some((mtime, entry.path()));
        }
    }
    let (_, path) = newest?;
    // A baseline that exists but cannot be read or parsed is a broken
    // gate, not a missing one — fail loudly instead of silently
    // skipping the no-regress check.
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("unreadable bench baseline {}: {e}", path.display()));
    let fps = extract_pooled_fps(&text, "mesh", "64KiB").unwrap_or_else(|| {
        panic!(
            "bench baseline {} has no mesh/64KiB pooled frames_per_sec — \
             the archive format drifted from this gate's parser",
            path.display()
        )
    });
    Some((path, fps))
}

/// Hand-rolled scan for the `frames_per_sec` of the pooled arm of
/// `section.label` in an emitted record — the bench stays
/// dependency-free, and the emission format below is ours to match.
fn extract_pooled_fps(text: &str, section: &str, label: &str) -> Option<f64> {
    let rest = &text[text.find(&format!("\"{section}\""))?..];
    let rest = &rest[rest.find(&format!("\"{label}\""))?..];
    let rest = &rest[rest.find("\"pooled\"")?..];
    let key = "\"frames_per_sec\":";
    let rest = rest[rest.find(key)? + key.len()..].trim_start();
    let end = rest.find(|c: char| c == ',' || c == '}')?;
    rest[..end].trim().parse().ok()
}

fn main() {
    // `WorkerPool::spawn` re-executes the *current binary* with a
    // leading `worker` argument; route that to the worker serve loop
    // so this bench hosts its own process pool.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("worker") {
        let opt = |name: &str| -> Option<String> {
            argv.iter()
                .position(|a| a == name)
                .and_then(|i| argv.get(i + 1).cloned())
        };
        let connect = opt("--connect").expect("worker mode needs --connect");
        let id: usize = opt("--id")
            .expect("worker mode needs --id")
            .parse()
            .expect("bad --id");
        wilkins::net::worker_main(&connect, id).expect("worker serve loop");
        return;
    }

    let steps = 6u64;
    println!("== wire hot path: pooled scatter-gather vs owned-Vec ablation ==\n");

    // Observability guard: with WILKINS_TRACE_WIRE unset, the frame
    // tap hook every codec read/write now calls must cost one atomic
    // load + branch. The budget is generous (50 ns/call, ~25x the
    // expected cost) so machine noise can't flake CI, but a lock or
    // syscall sneaking onto this path blows straight through it.
    use wilkins::obs::wiretap;
    assert!(
        !wiretap::enabled(),
        "this bench must run with the wire tap off (unset WILKINS_TRACE_WIRE)"
    );
    let tap_reps = 10_000_000u64;
    let t0 = Instant::now();
    for i in 0..tap_reps {
        wiretap::frame(
            wiretap::Dir::Tx,
            std::hint::black_box((i & 0xff) as u8),
            std::hint::black_box(64),
        );
    }
    let tap_ns = t0.elapsed().as_nanos() as f64 / tap_reps as f64;
    println!("disabled wire tap: {tap_ns:.2} ns/frame over {tap_reps} calls\n");
    assert!(
        tap_ns < 50.0,
        "disabled wire tap must stay out of the hot path, got {tap_ns:.2} ns/frame"
    );

    use wilkins::obs::Ctr;
    // The pooled-vs-ablation matrix measures the *inline* socket
    // plane; with the shm plane at its default-on the >= 64 KiB rows
    // would route around the very path under test. The shm plane gets
    // its own section below.
    wilkins::net::shm::set_enabled(false);
    let mut mesh_rows = Vec::new();
    let mut local_rows = Vec::new();
    let mut coalesced_rows = Vec::new();
    for (label, payload) in SIZES {
        let old_local = serve_local(payload, steps, false);
        let new_local = serve_local(payload, steps, true);
        let coal0 = Ctr::FramesCoalesced.get();
        let old_mesh = serve_mesh(payload, steps, false);
        let new_mesh = serve_mesh(payload, steps, true);
        // Every coalesced frame is a `write` syscall the staging
        // buffers absorbed across both mesh arms (the envelope +
        // flow-control chatter rides this path at every size; at 64B
        // the data frames themselves do too).
        let coalesced = Ctr::FramesCoalesced.get() - coal0;
        println!(
            "{label:>6}  1-proc: {:.2} -> {:.2} copies/B ({:.0} -> {:.0} frames/s)   \
             2-worker mesh: {:.2} -> {:.2} copies/B ({:.0} -> {:.0} frames/s)   \
             {coalesced} writes coalesced away",
            old_local.copies_per_byte,
            new_local.copies_per_byte,
            old_local.frames_per_sec,
            new_local.frames_per_sec,
            old_mesh.copies_per_byte,
            new_mesh.copies_per_byte,
            old_mesh.frames_per_sec,
            new_mesh.frames_per_sec,
        );

        // Allocation discipline: beyond pool warm-up, every encode on
        // the pooled arm must be a pool hit; the ablation arm pays an
        // allocation every round. The 64B row is exempt — it exists
        // to measure tiny-frame syscall throughput, and sub-KiB
        // leases sit below the pool's recycling classes.
        if payload >= 1 << 16 {
            assert!(
                new_local.producer.alloc_rounds <= 1,
                "{label}: pooled 1-proc arm allocated on {} rounds (warm-up budget is 1)",
                new_local.producer.alloc_rounds
            );
            assert!(
                new_mesh.producer.alloc_rounds <= 1,
                "{label}: pooled mesh arm allocated on {} rounds (warm-up budget is 1)",
                new_mesh.producer.alloc_rounds
            );
            assert_eq!(
                old_mesh.producer.alloc_rounds, steps,
                "{label}: ablation arm must allocate every round"
            );
            assert!(
                new_mesh.producer.bytes_pooled > 0,
                "{label}: pooled arm must encode into recycled buffers"
            );
        }

        mesh_rows.push((label, old_mesh, new_mesh));
        local_rows.push((label, old_local, new_local));
        coalesced_rows.push((label, coalesced));
    }

    // Small-frame throughput must not regress against the newest
    // archived record (ci/check.sh copies every emitted BENCH_wire.json
    // into ci/bench-archive/). The 0.8x floor absorbs wall-clock noise
    // on shared hosts; a transport regression (frames stalling behind
    // the event loop's timers, a lost flush wake) shows up as a
    // multiple, not 20%.
    let small_fps = mesh_rows
        .iter()
        .find(|(l, _, _)| *l == "64KiB")
        .map(|(_, _, new)| new.frames_per_sec)
        .unwrap();
    match archived_mesh_small_fps() {
        Some((path, baseline)) => {
            println!(
                "\nsmall-frame no-regress: {small_fps:.0} frames/s vs archived {baseline:.0} \
                 ({:.2}x, {})",
                small_fps / baseline,
                path.display()
            );
            assert!(
                small_fps >= 0.8 * baseline,
                "small-frame mesh throughput regressed: {small_fps:.0} frames/s vs archived \
                 {baseline:.0} ({})",
                path.display()
            );
        }
        None => println!("\nsmall-frame no-regress: no archived BENCH_wire record; skipping"),
    }

    // The acceptance criterion: at 16 MiB, where the old path pays
    // the chunk-split/frame-concat/decode-copy tax in full, the
    // pooled plane must at least halve bytes-copied-per-byte.
    let (_, old_big, new_big) = mesh_rows.last().unwrap();
    let reduction = old_big.copies_per_byte / new_big.copies_per_byte;
    assert!(
        reduction >= 2.0,
        "copies/byte at 16MiB must drop >= 2x over the mesh, got {reduction:.2}x \
         ({:.2} -> {:.2})",
        old_big.copies_per_byte,
        new_big.copies_per_byte
    );

    // The tentpole criterion: over the same mesh, the shm plane must
    // move >= 2x fewer bytes per delivered byte than the inline
    // socket path, at 1 MiB (one K_DATA frame inline) and at 16 MiB
    // (chunked inline — shm never chunks, the segment holds the whole
    // payload).
    println!("\n== shm payload plane vs inline socket path (2-worker mesh) ==\n");
    let mut shm_rows = Vec::new();
    for (label, payload) in [("1MiB", 1usize << 20), ("16MiB", 1usize << 24)] {
        let (inline_mpb, inline_s) = mesh_moved_per_byte(payload, steps, false);
        let (shm_mpb, shm_s) = mesh_moved_per_byte(payload, steps, true);
        let ratio = inline_mpb / shm_mpb;
        println!(
            "{label:>6}  inline: {inline_mpb:.2} moved/B ({inline_s:.3}s)   \
             shm: {shm_mpb:.2} moved/B ({shm_s:.3}s)   {ratio:.2}x fewer"
        );
        assert!(
            ratio >= 2.0,
            "{label}: shm plane must move >= 2x fewer bytes/byte than the inline path, \
             got {ratio:.2}x ({inline_mpb:.2} -> {shm_mpb:.2})"
        );
        shm_rows.push((label, inline_mpb, shm_mpb, ratio));
    }
    // Back to the process default before the up runs (worker children
    // read WILKINS_SHM themselves; this is for hygiene in-process).
    wilkins::net::shm::set_enabled(true);

    println!("\n== 2-worker `up` (real worker processes) ==\n");
    let (up_old_s, up_old_rep) = run_up(false);
    let (up_new_s, up_new_rep) = run_up(true);
    std::env::set_var("WILKINS_POOLING", "1");
    let up_old_p = up_old_rep.node("producer").unwrap();
    let up_new_p = up_new_rep.node("producer").unwrap();
    println!(
        "ablation: {up_old_s:.3}s (alloc_rounds {})   pooled: {up_new_s:.3}s (alloc_rounds {}, bytes_pooled {})",
        up_old_p.alloc_rounds, up_new_p.alloc_rounds, up_new_p.bytes_pooled
    );
    assert!(
        up_new_p.alloc_rounds < up_old_p.alloc_rounds,
        "pooled up run must allocate on fewer rounds than the ablation \
         ({} vs {})",
        up_new_p.alloc_rounds,
        up_old_p.alloc_rounds
    );

    let arm_json = |a: &Arm| {
        format!(
            "{{ \"copies_per_byte\": {:.3}, \"frames_per_sec\": {:.1}, \"elapsed_s\": {:.4}, \"alloc_rounds\": {}, \"bytes_pooled\": {} }}",
            a.copies_per_byte, a.frames_per_sec, a.elapsed_s, a.producer.alloc_rounds, a.producer.bytes_pooled
        )
    };
    let section = |rows: &[(&str, Arm, Arm)]| {
        rows.iter()
            .map(|(label, old, new)| {
                format!(
                    "      \"{label}\": {{ \"ablation\": {}, \"pooled\": {} }}",
                    arm_json(old),
                    arm_json(new)
                )
            })
            .collect::<Vec<_>>()
            .join(",\n")
    };
    // Writes the coalescing buffers absorbed per size (both mesh
    // arms): each one is a `write(2)` the kernel never saw.
    let coalesced_json = coalesced_rows
        .iter()
        .map(|(label, n)| format!("\"{label}\": {n}"))
        .collect::<Vec<_>>()
        .join(", ");
    // Moved-bytes-per-byte of the shm plane vs the inline socket path
    // (see the module docs for the metric).
    let shm_json = shm_rows
        .iter()
        .map(|(label, inline, shm, ratio)| {
            format!(
                "\"{label}\": {{ \"inline_moved_per_byte\": {inline:.3}, \
                 \"shm_moved_per_byte\": {shm:.3}, \"reduction\": {ratio:.2} }}"
            )
        })
        .collect::<Vec<_>>()
        .join(", ");
    let json = format!(
        "{{\n  \"bench\": \"wire\",\n  \"steps\": {steps},\n  \"copy_reduction_16mib_mesh\": {reduction:.2},\n  \"tap_disabled_ns_per_frame\": {tap_ns:.2},\n  \"mesh_writes_coalesced\": {{ {coalesced_json} }},\n  \"shm_mesh\": {{ {shm_json} }},\n  \"serve\": {{\n    \"local\": {{\n{}\n    }},\n    \"mesh\": {{\n{}\n    }}\n  }},\n  \"up\": {{ \"ablation_s\": {up_old_s:.3}, \"pooled_s\": {up_new_s:.3}, \"ablation_alloc_rounds\": {}, \"pooled_alloc_rounds\": {} }}\n}}\n",
        section(&local_rows),
        section(&mesh_rows),
        up_old_p.alloc_rounds,
        up_new_p.alloc_rounds
    );
    let out_dir = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let out_path = std::path::Path::new(&out_dir).join("BENCH_wire.json");
    std::fs::write(&out_path, json).expect("write BENCH_wire.json");
    println!("\nbench record written to {}", out_path.display());
    println!("OK: pooled data plane halves bytes-copied-per-byte-delivered at 16 MiB");
}
