//! Table 2 + Figure 5: flow control with slow consumers.
//!
//! Paper setup: 512+512 procs, producer computes 2 s/step for 10
//! steps; consumers are 2x/5x/10x slower (4/10/20 s). Strategies:
//! all, some(N matched to the slowdown), latest. Paper result: some
//! and latest save up to 4.7x / 4.6x, growing with consumer slowness;
//! Figure 5 shows the producer's idle time vanishing.
//!
//! Substitutions: ranks 32+32 by default (512+512 under
//! WILKINS_BENCH_FULL=1) and paper-seconds scaled by 0.01 (2 s ->
//! 20 ms). Completion-time *ratios* are scale-invariant and are the
//! asserted shape. The Gantt chart for the 5x consumer is rendered in
//! ASCII from the span recorder (Figure 5).

use wilkins::bench_util::{assert_speedup, full_scale, Table};
use wilkins::metrics::SpanKind;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const TIME_SCALE: f64 = 0.01;
const STEPS: u64 = 10;
const PRODUCER_S: f64 = 2.0;

fn run(
    nprocs: usize,
    consumer_sleep_s: f64,
    io_freq: i64,
    gantt: bool,
) -> (f64, Option<String>) {
    let yaml = format!(
        "\
tasks:
  - func: producer
    nprocs: {nprocs}
    params: {{ steps: {STEPS}, grid_per_proc: 1000, particles_per_proc: 1000, sleep_s: {PRODUCER_S}, verify: 0 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    nprocs: {nprocs}
    params: {{ sleep_s: {consumer_sleep_s}, verify: 0 }}
    inports:
      - filename: outfile.h5
        io_freq: {io_freq}
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
    );
    let w = Wilkins::from_yaml_str(&yaml, builtin_registry())
        .unwrap()
        .with_time_scale(TIME_SCALE);
    let recorder = w.recorder();
    let report = w.run().unwrap();
    // Scale measured wall time back up to paper-seconds.
    let paper_secs = report.elapsed.as_secs_f64() / TIME_SCALE;
    let chart = gantt.then(|| {
        // Rank 0 of producer and rank 0 of consumer (global nprocs).
        let ranks = [0usize, report.nodes[0].nprocs];
        let mut s = recorder.gantt_ascii(&ranks, 100);
        let (c, i, t, st) = recorder.totals(0);
        s.push_str(&format!(
            "producer rank 0 totals: compute {:.2}s idle {:.2}s transfer {:.2}s stall {:.2}s (paper-s: x{})\n",
            c,
            i,
            t,
            st,
            1.0 / TIME_SCALE
        ));
        let _ = SpanKind::Compute;
        s
    });
    (paper_secs, chart)
}

fn main() {
    let nprocs = if full_scale() { 512 } else { 32 };
    println!("== Table 2: flow-control completion times (paper-seconds) ==");
    println!(
        "(producer {PRODUCER_S}s/step x {STEPS} steps, {nprocs}+{nprocs} ranks, time scale {TIME_SCALE})\n"
    );

    let mut table = Table::new(&["strategy", "2x slow", "5x slow", "10x slow"]);
    let slowdowns = [(2.0, 2i64), (5.0, 5), (10.0, 10)];
    let mut all_times = Vec::new();
    let mut some_times = Vec::new();
    let mut latest_times = Vec::new();
    for &(factor, _) in &slowdowns {
        let (t, _) = run(nprocs, PRODUCER_S * factor, 1, false);
        all_times.push(t);
    }
    for &(factor, n) in &slowdowns {
        let (t, _) = run(nprocs, PRODUCER_S * factor, n, false);
        some_times.push(t);
    }
    for &(factor, _) in &slowdowns {
        let (t, _) = run(nprocs, PRODUCER_S * factor, -1, false);
        latest_times.push(t);
    }
    let fmt = |xs: &[f64]| xs.iter().map(|t| format!("{t:.1}s")).collect::<Vec<_>>();
    let f_all = fmt(&all_times);
    let f_some = fmt(&some_times);
    let f_latest = fmt(&latest_times);
    table.row(&[
        "all".into(),
        f_all[0].clone(),
        f_all[1].clone(),
        f_all[2].clone(),
    ]);
    table.row(&[
        "some".into(),
        f_some[0].clone(),
        f_some[1].clone(),
        f_some[2].clone(),
    ]);
    table.row(&[
        "latest".into(),
        f_latest[0].clone(),
        f_latest[1].clone(),
        f_latest[2].clone(),
    ]);
    print!("{}", table.render());
    println!(
        "\nsavings vs all:  some {:.1}x/{:.1}x/{:.1}x   latest {:.1}x/{:.1}x/{:.1}x",
        all_times[0] / some_times[0],
        all_times[1] / some_times[1],
        all_times[2] / some_times[2],
        all_times[0] / latest_times[0],
        all_times[1] / latest_times[1],
        all_times[2] / latest_times[2],
    );
    println!("paper: all 51/111.7/211.7s; some 31.2/35/44.9s (up to 4.7x); latest 33.5/38/45.8s (up to 4.6x)");

    // Shape checks: savings grow with consumer slowness; both
    // strategies beat `all` substantially for the 5x/10x consumers.
    assert_speedup("some vs all (5x)", all_times[1], some_times[1], 1.8);
    assert_speedup("some vs all (10x)", all_times[2], some_times[2], 2.5);
    assert_speedup("latest vs all (5x)", all_times[1], latest_times[1], 1.8);
    assert_speedup("latest vs all (10x)", all_times[2], latest_times[2], 2.5);
    assert!(
        all_times[2] / some_times[2] > all_times[0] / some_times[0],
        "savings must grow with consumer slowness"
    );

    println!("\n== Figure 5: Gantt charts, producer + 5x slow consumer ==\n");
    for (label, freq) in [("all", 1i64), ("some N=5", 5), ("latest", -1)] {
        let (_, chart) = run(4, PRODUCER_S * 5.0, freq, true);
        println!("--- strategy: {label} ---");
        print!("{}", chart.unwrap());
        println!();
    }
    println!("OK: flow-control shape holds (Table 2 + Figure 5)");
}
