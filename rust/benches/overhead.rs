//! Table 1 + Figure 4: overhead of Wilkins compared with LowFive
//! standalone, weak scaling.
//!
//! The paper couples one producer and one consumer (3:1 rank split),
//! scaling from 4 to 1,024 MPI processes with 10^6..10^8 elements per
//! process, and reports the write/read time of LowFive alone vs under
//! Wilkins — overhead at 1K procs is ~2%.
//!
//! Testbed substitutions (DESIGN.md): ranks are threads; default sweep
//! is 4..64 procs with 10^3..10^5 elements/proc so `cargo bench`
//! finishes in minutes. `WILKINS_BENCH_FULL=1` extends to 256/1024
//! procs. The *relative* overhead is the reproduced quantity.

use wilkins::baseline::{run_standalone, SyntheticSize};
use wilkins::bench_util::{full_scale, mean, time_trials, Table};
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

fn wilkins_run(m: usize, n: usize, size: SyntheticSize) -> f64 {
    let yaml = format!(
        "\
tasks:
  - func: producer
    nprocs: {m}
    params: {{ steps: {steps}, grid_per_proc: {g}, particles_per_proc: {p}, verify: 0 }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    nprocs: {n}
    params: {{ verify: 0 }}
    inports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
        steps = size.steps,
        g = size.grid_per_proc,
        p = size.particles_per_proc,
    );
    let w = Wilkins::from_yaml_str(&yaml, builtin_registry()).unwrap();
    let report = w.run().unwrap();
    report.elapsed.as_secs_f64()
}

fn main() {
    let trials = 3; // paper: average of 3 trials
    let procs: Vec<usize> = if full_scale() {
        vec![4, 16, 64, 256, 1024]
    } else {
        vec![4, 16, 64]
    };
    let sizes: Vec<u64> = if full_scale() {
        vec![10_000, 100_000]
    } else {
        vec![1_000, 10_000, 100_000]
    };

    println!("== Table 1 / Figure 4: Wilkins overhead vs LowFive standalone ==");
    println!("(weak scaling; 3:1 producer:consumer ranks; avg of {trials} trials)\n");
    let mut table = Table::new(&[
        "procs", "elems/proc", "total MiB", "lowfive (s)", "wilkins (s)", "overhead %",
    ]);
    let mut overheads = Vec::new();
    for &np in &procs {
        let m = np * 3 / 4;
        let n = np - m;
        for &per in &sizes {
            let size = SyntheticSize {
                grid_per_proc: per,
                particles_per_proc: per,
                steps: 1,
            };
            let base = mean(&time_trials(trials, true, || {
                run_standalone(m, n, size).unwrap();
            }));
            let wk = mean(&time_trials(trials, true, || {
                wilkins_run(m, n, size);
            }));
            let overhead = (wk - base) / base * 100.0;
            overheads.push(overhead);
            let mib = (per * 20 * m as u64) as f64 / (1024.0 * 1024.0);
            table.row(&[
                np.to_string(),
                per.to_string(),
                format!("{mib:.2}"),
                format!("{base:.4}"),
                format!("{wk:.4}"),
                format!("{overhead:+.1}"),
            ]);
        }
    }
    print!("{}", table.render());
    let largest = *overheads.last().unwrap();
    println!("\npaper: overhead negligible for all sizes, ~2% at 1K procs");
    println!("measured overhead at largest configuration: {largest:+.1}%");
    // Shape check on the *largest* configuration (small ones are
    // launch-cost dominated): Wilkins must track the hand-written
    // coupling closely.
    assert!(
        largest < 30.0,
        "Wilkins overhead {largest:.1}% at the largest size is far beyond the paper's ~2%"
    );
    println!("OK: overhead bounded (paper shape holds)");
}
