//! §Perf probe: fixed large transport workload for optimization A/B
//! measurements (not a paper figure). 12 producer + 4 consumer ranks,
//! 3 steps of 400k grid + 400k particles per producer rank.
use wilkins::baseline::{run_standalone, SyntheticSize};
use wilkins::bench_util::{mean, stddev, time_trials};

fn main() {
    let size = SyntheticSize { grid_per_proc: 400_000, particles_per_proc: 400_000, steps: 3 };
    let xs = time_trials(5, true, || { run_standalone(12, 4, size).unwrap(); });
    println!("perf_probe: {:.4}s +- {:.4}s  ({:?})", mean(&xs), stddev(&xs), xs.iter().map(|x| (x*1000.0).round()/1000.0).collect::<Vec<_>>());
}
