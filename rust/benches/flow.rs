//! Credit-based flow control (paper Sec. 3.6) on a fast-producer /
//! slow-consumer pair: block (synchronous, the paper's *all*) vs a
//! bounded credit window (block depth=3) vs latest (keep-newest),
//! each at 1 process and across a 2-worker `wilkins up` world.
//!
//! Asserted shape:
//! * the bounded window beats synchronous block on end-to-end
//!   makespan (the producer overlaps compute with the consumer's
//!   reads instead of stalling every step);
//! * latest beats both (it sheds rounds instead of queueing) and
//!   reports a nonzero dropped count;
//! * under `block`, per-task counters are identical between the
//!   in-memory transport and the 2-worker socket world, and the
//!   consumers' element-exact verification passes on both — the
//!   "byte-identical results across transports" criterion.
//!
//! Emits BENCH_flow.json with the measured makespans and flow
//! counters so the trajectory accumulates across PRs.

use wilkins::bench_util::assert_speedup;
use wilkins::coordinator::RunReport;
use wilkins::net::{self, UpOpts};
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const TIME_SCALE: f64 = 0.02;
const STEPS: u64 = 10;
const PRODUCER_S: f64 = 3.0;
const CONSUMER_S: f64 = 6.0;

fn workflow_yaml(flow: &str) -> String {
    format!(
        "\
tasks:
  - func: producer
    nprocs: 1
    params: {{ steps: {STEPS}, grid_per_proc: 2000, particles_per_proc: 2000, sleep_s: {PRODUCER_S} }}
    outports:
      - filename: outfile.h5
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
  - func: consumer
    nprocs: 1
    params: {{ hold_s: {CONSUMER_S} }}
    inports:
      - filename: outfile.h5
        {flow}
        dsets: [ {{ name: /group1/grid }}, {{ name: /group1/particles }} ]
",
    )
}

/// Makespan in paper-seconds plus the producer's flow counters.
struct Outcome {
    paper_s: f64,
    dropped: u64,
    stalled_s: f64,
    max_queue_depth: u64,
    report: RunReport,
}

fn outcome(report: RunReport) -> Outcome {
    let p = report.node("producer").expect("producer row").clone();
    Outcome {
        paper_s: report.elapsed.as_secs_f64() / TIME_SCALE,
        dropped: p.serves_dropped,
        stalled_s: p.stall_wait.as_secs_f64() / TIME_SCALE,
        max_queue_depth: p.max_queue_depth,
        report,
    }
}

fn run_single(flow: &str) -> Outcome {
    let w = Wilkins::from_yaml_str(&workflow_yaml(flow), builtin_registry())
        .unwrap()
        .with_time_scale(TIME_SCALE);
    outcome(w.run().unwrap())
}

fn run_distributed(flow: &str) -> Outcome {
    let opts = UpOpts {
        workers: 2,
        time_scale: TIME_SCALE,
        workdir: None,
        artifacts: None,
        heartbeat: Default::default(),
    };
    outcome(net::run_workflow_distributed(&workflow_yaml(flow), &opts).unwrap())
}

/// The placement-invariant per-task counters of a report.
fn counters(r: &RunReport) -> Vec<(String, u64, u64, u64, u64, u64, u64)> {
    r.nodes
        .iter()
        .map(|n| {
            (
                n.name.clone(),
                n.files_served,
                n.serves_skipped,
                n.serves_dropped,
                n.bytes_served,
                n.files_opened,
                n.bytes_read,
            )
        })
        .collect()
}

fn main() {
    // `WorkerPool::spawn` re-executes the *current binary* with a
    // leading `worker` argument; route that to the worker serve loop
    // so this bench hosts its own process pool.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.first().map(String::as_str) == Some("worker") {
        let opt = |name: &str| -> Option<String> {
            argv.iter()
                .position(|a| a == name)
                .and_then(|i| argv.get(i + 1).cloned())
        };
        let connect = opt("--connect").expect("worker mode needs --connect");
        let id: usize = opt("--id")
            .expect("worker mode needs --id")
            .parse()
            .expect("bad --id");
        wilkins::net::worker_main(&connect, id).expect("worker serve loop");
        return;
    }

    println!("== flow control: fast producer ({PRODUCER_S}s/step x {STEPS}) vs slow consumer ({CONSUMER_S}s) ==");
    println!("(1+1 ranks, time scale {TIME_SCALE}; paper-seconds reported)\n");

    let policies: [(&str, &str); 3] = [
        ("block", "io_freq: 1"),
        ("bounded", "flow: { policy: block, depth: 3 }"),
        ("latest", "flow: latest"),
    ];

    let mut rows = Vec::new();
    for (name, flow) in policies {
        let single = run_single(flow);
        let multi = run_distributed(flow);
        println!(
            "{name:>8}: single {:.1}s (dropped {}, stalled {:.1}s, maxq {})   2-worker up {:.1}s (dropped {})",
            single.paper_s,
            single.dropped,
            single.stalled_s,
            single.max_queue_depth,
            multi.paper_s,
            multi.dropped
        );
        rows.push((name, single, multi));
    }

    let block = &rows[0].1;
    let bounded = &rows[1].1;
    let latest = &rows[2].1;

    // Shape assertions (single-process timings; the distributed runs
    // add pool overhead and are recorded, not asserted).
    assert_speedup("bounded depth=3 vs block", block.paper_s, bounded.paper_s, 1.15);
    assert_speedup("latest vs block", block.paper_s, latest.paper_s, 1.5);
    assert!(latest.dropped > 0, "latest must drop rounds under a slow consumer");
    assert!(rows[2].2.dropped > 0, "latest must drop rounds under `up` too");
    assert_eq!(block.dropped, 0, "block never drops");
    assert!(
        block.stalled_s > bounded.stalled_s,
        "the credit window must cut producer stall time ({:.1}s vs {:.1}s)",
        block.stalled_s,
        bounded.stalled_s
    );

    // Transport equivalence under block: every counter identical, and
    // both consumers verified every element (verify=1 is the task
    // default) — results are byte-identical across transports.
    assert_eq!(
        counters(&rows[0].1.report),
        counters(&rows[0].2.report),
        "block: per-task counters must not depend on the transport"
    );

    let json = format!(
        "{{\n  \"bench\": \"flow\",\n  \"steps\": {STEPS},\n  \"producer_s\": {PRODUCER_S},\n  \"consumer_s\": {CONSUMER_S},\n  \"policies\": {{\n{}\n  }}\n}}\n",
        rows.iter()
            .map(|(name, s, m)| format!(
                "    \"{name}\": {{ \"single_s\": {:.3}, \"workers2_s\": {:.3}, \"dropped\": {}, \"stalled_s\": {:.3}, \"max_queue_depth\": {} }}",
                s.paper_s, m.paper_s, s.dropped, s.stalled_s, s.max_queue_depth
            ))
            .collect::<Vec<_>>()
            .join(",\n")
    );
    let out_dir = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let out_path = std::path::Path::new(&out_dir).join("BENCH_flow.json");
    std::fs::write(&out_path, json).expect("write BENCH_flow.json");
    println!("\nbench record written to {}", out_path.display());
    println!("OK: credit-window flow control beats synchronous block; latest sheds load");
}
