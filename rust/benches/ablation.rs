//! Ablation bench (§Perf, DESIGN.md): measures the effect of the two
//! L3 transport design choices EXPERIMENTS.md credits:
//!
//! 1. **Pipelined data requests** — a consumer rank sends DataReqs to
//!    every owning producer rank before collecting replies, so the
//!    producers extract/serve in overlap. Ablated against lockstep
//!    request/await per rank.
//! 2. **Contiguous-run region copies** — `copy_region` moves the
//!    innermost dimension as a single memcpy run. Ablated against an
//!    element-at-a-time copy.

use std::sync::Arc;
use std::thread;
use std::time::Instant;

use wilkins::bench_util::{mean, time_trials, Table};
use wilkins::comm::{InterComm, World};
use wilkins::lowfive::hyperslab::copy_region;
use wilkins::lowfive::{
    split_rows, DType, Hyperslab, InChannel, OutChannel, RouteTable, Vol,
};

/// M producers serve one dataset to N consumers; consumers read their
/// row split with pipelined or lockstep requests.
fn mxn_read(m: usize, n: usize, elems_per_proc: u64, lockstep: bool) -> f64 {
    let world = World::new(m + n);
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let ioid = world.alloc_comm_id();
    let chid = world.alloc_comm_id();
    let prod: Vec<usize> = (0..m).collect();
    let cons: Vec<usize> = (m..m + n).collect();
    let dims = Arc::new(vec![elems_per_proc * m as u64]);
    let t0 = Instant::now();
    let mut hs = Vec::new();
    for g in 0..m + n {
        let world = world.clone();
        let prod = prod.clone();
        let cons = cons.clone();
        let dims = Arc::clone(&dims);
        hs.push(thread::spawn(move || {
            let workdir = std::env::temp_dir().join("wilkins-ablation");
            if g < m {
                let local = world.comm_from_ranks(pid, &prod, g);
                let io = world.comm_from_ranks(ioid, &prod, g);
                let mut vol = Vol::new(local.clone(), workdir);
                vol.set_io_comm(Some(io));
                let ic = InterComm::new(local, chid, cons.clone());
                vol.add_out_channel(OutChannel::new(Some(ic), "f.h5", RouteTable::memory()));
                vol.file_create("f.h5").unwrap();
                vol.dataset_create("f.h5", "/d", DType::U64, &dims).unwrap();
                let slab = split_rows(&dims, m)[g].clone();
                let vals: Vec<u8> = (0..slab.count[0])
                    .flat_map(|i| (slab.offset[0] + i).to_le_bytes())
                    .collect();
                vol.dataset_write("f.h5", "/d", slab, vals).unwrap();
                vol.file_close("f.h5").unwrap();
                vol.finalize_producer().unwrap();
            } else {
                let local = world.comm_from_ranks(cid, &cons, g - m);
                let mut vol = Vol::new(local.clone(), workdir);
                let ic = InterComm::new(local, chid, prod.clone());
                vol.add_in_channel(InChannel::new(Some(ic), "f.h5", RouteTable::memory()));
                vol.set_lockstep_reads(lockstep);
                let name = vol.file_open("f.h5").unwrap();
                let want = split_rows(&dims, n)[g - m].clone();
                vol.dataset_read(&name, "/d", &want).unwrap();
                vol.file_close(&name).unwrap();
                vol.finalize_consumer().unwrap();
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

/// Element-wise copy (the ablated arm of copy_region).
fn copy_elementwise(
    src_slab: &Hyperslab,
    src: &[u8],
    dst_slab: &Hyperslab,
    dst: &mut [u8],
    region: &Hyperslab,
    esize: usize,
) {
    // Walk every coordinate of the region, one element per copy.
    let total = region.element_count();
    for idx in 0..total {
        let mut rem = idx;
        let mut coord = vec![0u64; region.dims()];
        for d in (0..region.dims()).rev() {
            coord[d] = region.offset[d] + rem % region.count[d];
            rem /= region.count[d];
        }
        let lin = |slab: &Hyperslab, coord: &[u64]| -> usize {
            let mut stride = 1u64;
            let mut off = 0u64;
            for d in (0..slab.dims()).rev() {
                off += (coord[d] - slab.offset[d]) * stride;
                stride *= slab.count[d];
            }
            off as usize
        };
        let si = lin(src_slab, &coord) * esize;
        let di = lin(dst_slab, &coord) * esize;
        dst[di..di + esize].copy_from_slice(&src[si..si + esize]);
    }
}

fn main() {
    println!("== Ablation: L3 transport design choices ==\n");

    // --- 1. pipelined vs lockstep data requests -------------------------
    let trials = 3;
    let mut t = Table::new(&["M x N", "elems/proc", "lockstep (s)", "pipelined (s)", "speedup"]);
    let mut speedups = Vec::new();
    for (m, n, per) in [(8, 4, 200_000u64), (16, 4, 100_000), (16, 8, 100_000)] {
        let lock = mean(&time_trials(trials, true, || {
            mxn_read(m, n, per, true);
        }));
        let pipe = mean(&time_trials(trials, true, || {
            mxn_read(m, n, per, false);
        }));
        speedups.push(lock / pipe);
        t.row(&[
            format!("{m}x{n}"),
            per.to_string(),
            format!("{lock:.4}"),
            format!("{pipe:.4}"),
            format!("{:.2}x", lock / pipe),
        ]);
    }
    print!("{}", t.render());

    // --- 2. contiguous-run vs element-wise region copy -------------------
    let dims = [512u64, 512, 8];
    let src_slab = Hyperslab::whole(&dims);
    let dst_slab = Hyperslab::new(&[128, 128, 0], &[256, 256, 8]);
    let region = dst_slab.clone();
    let src = vec![7u8; (dims.iter().product::<u64>() * 8) as usize];
    let mut dst = vec![0u8; (dst_slab.element_count() * 8) as usize];
    let reps = 50;
    let run_t = mean(&time_trials(3, true, || {
        for _ in 0..reps {
            copy_region(&src_slab, &src, &dst_slab, &mut dst, &region, 8);
        }
    }));
    let elem_t = mean(&time_trials(3, true, || {
        for _ in 0..reps {
            copy_elementwise(&src_slab, &src, &dst_slab, &mut dst, &region, 8);
        }
    }));
    let mib = dst.len() as f64 / (1024.0 * 1024.0);
    println!("\ncopy_region ({mib:.1} MiB x {reps}): contiguous {run_t:.4}s vs element-wise {elem_t:.4}s = {:.1}x", elem_t / run_t);

    // Shape assertions: both optimizations must actually pay.
    let avg_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    assert!(
        avg_speedup > 1.05,
        "pipelining should help on M x N reads, got {speedups:?}"
    );
    assert!(
        elem_t / run_t > 2.0,
        "contiguous runs should be much faster than element-wise"
    );
    println!("\nOK: both transport design choices measurably pay off");
}
