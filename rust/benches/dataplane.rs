//! Routed data plane bench: what the zero-copy same-process serve
//! path buys, and what mixed per-dataset routing costs.
//!
//! Part 1 — serve throughput, copied vs zero-copy, at 1/4/16 MiB
//! payloads: a 1→1 coupling serves a u64 grid per step; the copied
//! arm (`Vol::set_zero_copy(false)`) pays encode → mailbox → decode
//! (two full payload copies plus an allocation); the zero-copy arm
//! hands the snapshot `Arc` through the shared registry and copies
//! once, straight into the reader's buffer.
//!
//! Part 2 — workflow wall-clock: the shipped mixed-routing scenario
//! (write-through grid + file-only particles) against the all-memory
//! baseline, at identical sizes.
//!
//! Asserted shape: zero-copy beats copied at the 16 MiB payload (the
//! acceptance criterion); the mixed run moves nonzero bytes_shared
//! and nonzero disk bytes. Emits BENCH_dataplane.json.

use std::sync::atomic::{AtomicU64, Ordering};
use std::thread;
use std::time::Instant;

use wilkins::bench_util::{assert_speedup, mean, time_trials, Table};
use wilkins::comm::{InterComm, World};
use wilkins::coordinator::RunReport;
use wilkins::lowfive::{DType, Hyperslab, InChannel, OutChannel, RouteTable, Vol};
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn fresh_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "wilkins-dataplane-{}-{}-{}",
        tag,
        std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// One 1→1 coupling serving `steps` files of `payload` bytes each;
/// returns elapsed seconds.
fn serve_run(payload: usize, steps: u64, zero_copy: bool) -> f64 {
    let elems = (payload / 8) as u64;
    let world = World::new(2);
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let ioid = world.alloc_comm_id();
    let chid = world.alloc_comm_id();
    let workdir = fresh_dir("serve");
    let t0 = Instant::now();
    let wp = {
        let world = world.clone();
        let workdir = workdir.clone();
        thread::spawn(move || {
            let local = world.comm_from_ranks(pid, &[0], 0);
            let io = world.comm_from_ranks(ioid, &[0], 0);
            let mut vol = Vol::new(local.clone(), workdir);
            vol.set_io_comm(Some(io));
            let ic = InterComm::new(local, chid, vec![1]);
            vol.add_out_channel(OutChannel::new(Some(ic), "f.h5", RouteTable::memory()));
            vol.set_zero_copy(zero_copy);
            let data = vec![7u8; payload];
            for _ in 0..steps {
                vol.file_create("f.h5").unwrap();
                vol.dataset_create("f.h5", "/d", DType::U64, &[elems]).unwrap();
                vol.dataset_write("f.h5", "/d", Hyperslab::whole(&[elems]), data.clone())
                    .unwrap();
                vol.file_close("f.h5").unwrap();
            }
            vol.finalize_producer().unwrap();
            // The asserted split: every byte took exactly one path.
            let total = payload as u64 * steps;
            if zero_copy {
                assert_eq!(vol.stats.bytes_shared, total);
                assert_eq!(vol.stats.bytes_copied, 0);
            } else {
                assert_eq!(vol.stats.bytes_copied, total);
                assert_eq!(vol.stats.bytes_shared, 0);
            }
        })
    };
    let wc = {
        let world = world.clone();
        thread::spawn(move || {
            let local = world.comm_from_ranks(cid, &[1], 0);
            let mut vol = Vol::new(local.clone(), workdir);
            let ic = InterComm::new(local, chid, vec![0]);
            vol.add_in_channel(InChannel::new(Some(ic), "f.h5", RouteTable::memory()));
            for _ in 0..steps {
                let name = vol.file_open("f.h5").unwrap();
                let bytes = vol
                    .dataset_read(&name, "/d", &Hyperslab::whole(&[elems]))
                    .unwrap();
                assert_eq!(bytes.len(), payload);
                vol.file_close(&name).unwrap();
            }
            vol.finalize_consumer().unwrap();
        })
    };
    wp.join().unwrap();
    wc.join().unwrap();
    t0.elapsed().as_secs_f64()
}

const SIZES: [(&str, usize); 3] = [
    ("1MiB", 1 << 20),
    ("4MiB", 1 << 22),
    ("16MiB", 1 << 24),
];

fn workflow_yaml(mixed: bool) -> String {
    let (grid, particles) = if mixed {
        (
            "{ name: /group1/grid, memory: 1, file: 1 }",
            "{ name: /group1/particles, file: 1, memory: 0 }",
        )
    } else {
        ("{ name: /group1/grid }", "{ name: /group1/particles }")
    };
    format!(
        "\
tasks:
  - func: producer
    nprocs: 2
    params: {{ steps: 4, grid_per_proc: 50000, particles_per_proc: 50000, verify: 0 }}
    outports:
      - filename: outfile.h5
        dsets: [ {grid}, {particles} ]
  - func: consumer
    nprocs: 2
    params: {{ verify: 0 }}
    inports:
      - filename: outfile.h5
        dsets: [ {grid}, {particles} ]
",
    )
}

fn run_workflow(mixed: bool) -> (f64, RunReport) {
    let w = Wilkins::from_yaml_str(&workflow_yaml(mixed), builtin_registry())
        .unwrap()
        .with_workdir(fresh_dir(if mixed { "mixed" } else { "mem" }));
    let t0 = Instant::now();
    let report = w.run().unwrap();
    (t0.elapsed().as_secs_f64(), report)
}

fn main() {
    println!("== routed data plane: copied vs zero-copy serve throughput ==\n");
    let steps = 8u64;
    let mut table = Table::new(&["payload", "copied MB/s", "zero-copy MB/s", "speedup"]);
    let mut rows = Vec::new();
    for (label, payload) in SIZES {
        let trials = if payload >= (1 << 24) { 3 } else { 5 };
        let copied_s = mean(&time_trials(trials, true, || {
            serve_run(payload, steps, false);
        }));
        let shared_s = mean(&time_trials(trials, true, || {
            serve_run(payload, steps, true);
        }));
        let mb = (payload as f64 * steps as f64) / (1024.0 * 1024.0);
        let copied_mbps = mb / copied_s;
        let shared_mbps = mb / shared_s;
        table.row(&[
            label.to_string(),
            format!("{copied_mbps:.0}"),
            format!("{shared_mbps:.0}"),
            format!("{:.2}x", copied_s / shared_s),
        ]);
        rows.push((label, copied_mbps, shared_mbps, copied_s, shared_s));
    }
    print!("{}", table.render());

    // The acceptance criterion: at the largest payload, where copy
    // cost dominates protocol overhead, zero-copy must win.
    let big = rows.last().unwrap();
    assert_speedup("zero-copy vs copied @16MiB", big.3, big.4, 1.05);

    println!("\n== mixed routing vs all-memory workflow wall-clock ==\n");
    let (mem_s, mem_rep) = run_workflow(false);
    let (mix_s, mix_rep) = run_workflow(true);
    let mem_p = mem_rep.node("producer").unwrap();
    let mix_p = mix_rep.node("producer").unwrap();
    println!(
        "all-memory: {mem_s:.3}s (shared {} B)   mixed: {mix_s:.3}s (shared {} B, served {} B)",
        mem_p.bytes_shared, mix_p.bytes_shared, mix_p.bytes_served
    );
    assert!(mix_p.bytes_shared > 0, "mixed run must share the write-through grid");
    assert!(
        mix_p.bytes_served > mix_p.bytes_shared + mix_p.bytes_copied,
        "mixed run must also move disk bytes"
    );
    assert_eq!(
        mem_rep.node("consumer").unwrap().files_opened,
        mix_rep.node("consumer").unwrap().files_opened,
        "routing must not change how many files the consumer sees"
    );

    let json = format!(
        "{{\n  \"bench\": \"dataplane\",\n  \"steps\": {steps},\n  \"serve\": {{\n{}\n  }},\n  \"workflow\": {{ \"all_memory_s\": {mem_s:.3}, \"mixed_s\": {mix_s:.3}, \"mixed_bytes_shared\": {}, \"mixed_bytes_served\": {} }}\n}}\n",
        rows.iter()
            .map(|(label, c, z, _, _)| format!(
                "    \"{label}\": {{ \"copied_mbps\": {c:.1}, \"zero_copy_mbps\": {z:.1} }}"
            ))
            .collect::<Vec<_>>()
            .join(",\n"),
        mix_p.bytes_shared,
        mix_p.bytes_served
    );
    let out_dir = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    let out_path = std::path::Path::new(&out_dir).join("BENCH_dataplane.json");
    std::fs::write(&out_path, json).expect("write BENCH_dataplane.json");
    println!("\nbench record written to {}", out_path.display());
    println!("OK: zero-copy serve path beats the encode/decode round-trip");
}
