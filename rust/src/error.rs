//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the Wilkins workflow system.
#[derive(Error, Debug)]
pub enum WilkinsError {
    /// YAML syntax errors from the in-repo parser.
    #[error("yaml parse error at line {line}: {msg}")]
    Yaml { line: usize, msg: String },

    /// Workflow configuration is syntactically valid YAML but violates
    /// the Wilkins schema (missing fields, bad values, ...).
    #[error("workflow config error: {0}")]
    Config(String),

    /// Port matching produced an unusable graph (dangling inport, ...).
    #[error("workflow graph error: {0}")]
    Graph(String),

    /// Virtual-MPI communicator misuse or teardown races.
    #[error("comm error: {0}")]
    Comm(String),

    /// LowFive data-transport errors (unknown dataset, bad hyperslab...).
    #[error("lowfive error: {0}")]
    LowFive(String),

    /// The producer closed the stream: no more files will arrive on
    /// this channel. Consumers use this to terminate cleanly.
    #[error("end of stream")]
    EndOfStream,

    /// Task-code registry / execution errors.
    #[error("task error: {0}")]
    Task(String),

    /// PJRT runtime errors (artifact missing, shape mismatch, ...).
    #[error("runtime error: {0}")]
    Runtime(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error(transparent)]
    Xla(#[from] xla::Error),
}

pub type Result<T> = std::result::Result<T, WilkinsError>;
