//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the offline
//! toolchain ships no proc-macro crates, and the error surface is
//! small enough that the derive would only save a few lines.

use std::fmt;

/// Errors produced by the Wilkins workflow system.
#[derive(Debug)]
pub enum WilkinsError {
    /// YAML syntax errors from the in-repo parser.
    Yaml { line: usize, msg: String },

    /// Workflow configuration is syntactically valid YAML but violates
    /// the Wilkins schema (missing fields, bad values, ...).
    Config(String),

    /// Port matching produced an unusable graph (dangling inport, ...).
    Graph(String),

    /// Virtual-MPI communicator misuse or teardown races.
    Comm(String),

    /// LowFive data-transport errors (unknown dataset, bad hyperslab...).
    LowFive(String),

    /// The producer closed the stream: no more files will arrive on
    /// this channel. Consumers use this to terminate cleanly.
    EndOfStream,

    /// Task-code registry / execution errors.
    Task(String),

    /// A pool worker died or stopped heartbeating while the
    /// coordinator waited on it. Distinguished from `Comm` so the
    /// ensemble driver can requeue the lost worker's in-flight
    /// instance instead of failing the campaign.
    WorkerLost(String),

    /// PJRT runtime errors (artifact missing, shape mismatch, ...).
    Runtime(String),

    /// Filesystem errors (transparent wrapper).
    Io(std::io::Error),

    /// XLA/PJRT binding errors (transparent wrapper).
    Xla(xla::Error),
}

impl fmt::Display for WilkinsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WilkinsError::Yaml { line, msg } => {
                write!(f, "yaml parse error at line {line}: {msg}")
            }
            WilkinsError::Config(m) => write!(f, "workflow config error: {m}"),
            WilkinsError::Graph(m) => write!(f, "workflow graph error: {m}"),
            WilkinsError::Comm(m) => write!(f, "comm error: {m}"),
            WilkinsError::LowFive(m) => write!(f, "lowfive error: {m}"),
            WilkinsError::EndOfStream => write!(f, "end of stream"),
            WilkinsError::Task(m) => write!(f, "task error: {m}"),
            WilkinsError::WorkerLost(m) => write!(f, "worker lost: {m}"),
            WilkinsError::Runtime(m) => write!(f, "runtime error: {m}"),
            // Transparent, like thiserror's #[error(transparent)].
            WilkinsError::Io(e) => e.fmt(f),
            WilkinsError::Xla(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for WilkinsError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Transparent wrappers forward source() to the *inner*
            // error's source (thiserror `#[error(transparent)]`
            // semantics) — returning the inner error itself would
            // print its message twice in "caused by" chains, since
            // Display is already forwarded to it.
            WilkinsError::Io(e) => e.source(),
            WilkinsError::Xla(e) => e.source(),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WilkinsError {
    fn from(e: std::io::Error) -> WilkinsError {
        WilkinsError::Io(e)
    }
}

impl From<xla::Error> for WilkinsError {
    fn from(e: xla::Error) -> WilkinsError {
        WilkinsError::Xla(e)
    }
}

pub type Result<T> = std::result::Result<T, WilkinsError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_paper_repo_conventions() {
        assert_eq!(
            WilkinsError::Yaml { line: 3, msg: "bad indent".into() }.to_string(),
            "yaml parse error at line 3: bad indent"
        );
        assert_eq!(
            WilkinsError::Config("missing `tasks:`".into()).to_string(),
            "workflow config error: missing `tasks:`"
        );
        assert_eq!(WilkinsError::EndOfStream.to_string(), "end of stream");
    }

    #[test]
    fn io_errors_are_transparent() {
        // Display forwards to the wrapped error; source() skips to
        // the wrapped error's own cause so "caused by" chains never
        // repeat the message.
        let e = WilkinsError::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert_eq!(e.to_string(), "gone");
        let kind_only =
            WilkinsError::from(std::io::Error::from(std::io::ErrorKind::NotFound));
        assert!(
            std::error::Error::source(&kind_only).is_none(),
            "kind-only io errors have no source to forward"
        );
    }
}
