//! User-defined custom actions (S8, paper Sec. 3.5.2 + Listing 5).
//!
//! In real Wilkins users drop a <25-line Python callback script next to
//! the YAML (`actions: ["script", "func"]`) and the runtime wires it
//! into LowFive's callback slots. Our equivalent keeps the declarative
//! interface identical — the YAML field is unchanged — and resolves the
//! (script, func) pair against an [`ActionRegistry`] of Rust callbacks
//! of the same size and shape. Applications register their own actions
//! exactly like task codes.
//!
//! Built-ins reproduce the paper's two examples:
//! * `("actions", "nyx")` — Listing 5: the Nyx double-open/close I/O
//!   pattern (rank 0 writes metadata solo, everyone re-opens for bulk
//!   writes; serve only on the second close; broadcast in between).
//! * `("actions", "every_second_write")` — Listing 3: delay the data
//!   transfer until every second dataset write.

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{Result, WilkinsError};
use crate::lowfive::Vol;

/// An action: applied once per rank to its Vol before the task starts.
/// Receives the Vol and the rank within the task.
pub type ActionFn = Arc<dyn Fn(&mut Vol, usize) + Send + Sync>;

#[derive(Default, Clone)]
pub struct ActionRegistry {
    map: HashMap<(String, String), ActionFn>,
}

impl ActionRegistry {
    /// Registry preloaded with the paper's built-in actions.
    pub fn with_builtins() -> ActionRegistry {
        let mut r = ActionRegistry::default();
        r.register("actions", "nyx", Arc::new(nyx_action));
        r.register("actions", "every_second_write", Arc::new(every_second_write));
        r
    }

    pub fn register(&mut self, script: &str, func: &str, f: ActionFn) {
        self.map.insert((script.to_string(), func.to_string()), f);
    }

    pub fn get(&self, script: &str, func: &str) -> Result<ActionFn> {
        self.map
            .get(&(script.to_string(), func.to_string()))
            .cloned()
            .ok_or_else(|| {
                WilkinsError::Config(format!(
                    "action [{script:?}, {func:?}] not registered"
                ))
            })
    }
}

/// Listing 5: the Nyx custom I/O pattern.
///
/// Nyx closes each plotfile twice: once from rank 0 alone (small
/// metadata writes) and once collectively (bulk data). The default
/// serve-on-every-close would fire at the wrong time, so:
/// * default serve is suppressed;
/// * rank != 0: serve + clear on (its only) close;
/// * rank 0: broadcast file state to the other ranks on odd closes
///   (the metadata close), serve + clear on even closes;
/// * rank != 0: receive the broadcast before re-opening the file.
pub fn nyx_action(vol: &mut Vol, rank: usize) {
    vol.set_before_file_close(Box::new(|vol, _name| {
        vol.skip_serve();
    }));
    vol.set_after_file_close(Box::new(move |vol, _name| {
        if rank != 0 {
            vol.serve_all().expect("nyx action: serve failed");
            vol.clear_files();
        } else if vol.file_close_counter % 2 == 0 {
            vol.serve_all().expect("nyx action: serve failed");
            vol.clear_files();
        } else {
            // First (metadata) close: share rank 0's file state.
            vol.broadcast_files().expect("nyx action: broadcast failed");
        }
    }));
    vol.set_before_file_open(Box::new(move |vol, _name| {
        if rank != 0 {
            vol.broadcast_files().expect("nyx action: broadcast failed");
        }
    }));
}

/// Listing 3: transfer only after every second dataset write (e.g. the
/// consumer wants positions but the producer also writes times).
pub fn every_second_write(vol: &mut Vol, _rank: usize) {
    vol.set_before_file_close(Box::new(|vol, _name| {
        vol.skip_serve();
    }));
    vol.set_after_dataset_write(Box::new(|vol, _dset| {
        // Writes are counted per file via the close-independent
        // dataset-write counter below.
        vol.note_dataset_write();
        if vol.dataset_writes() % 2 == 0 {
            vol.serve_all().expect("every_second_write: serve failed");
        }
    }));
}
