//! Interconnect/time model (S12): projects testbed measurements to
//! paper-scale hardware for reporting.
//!
//! The paper's experiments ran on Bebop (36-core Broadwell nodes,
//! Intel Omni-Path). This testbed is one core of one machine, so the
//! benches measure scaled-down workloads; this module carries the cost
//! model used in EXPERIMENTS.md to sanity-check that the measured
//! *shapes* extrapolate: an alpha-beta (latency-bandwidth) transfer
//! model plus a node-parallelism model for ensemble layouts.

/// Alpha-beta interconnect model: time = alpha + bytes / beta.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Bandwidth (bytes/second).
    pub beta: f64,
}

/// Intel Omni-Path (Bebop): ~1 us MPI latency, ~100 Gbit/s.
pub const OMNI_PATH: NetModel = NetModel { alpha: 1.0e-6, beta: 12.5e9 };

/// This testbed's intra-process channel transport, fit from the
/// overhead bench (memcpy-speed bandwidth, mailbox-lock latency).
pub const TESTBED: NetModel = NetModel { alpha: 2.0e-6, beta: 6.0e9 };

impl NetModel {
    /// Time to move one message of `bytes`.
    pub fn xfer(&self, bytes: u64) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }

    /// Time for `count` messages sent *sequentially* from one endpoint
    /// (the fan-out/fan-in serialization of Figs. 7/8).
    pub fn sequential(&self, count: u64, bytes_each: u64) -> f64 {
        count as f64 * self.xfer(bytes_each)
    }

    /// Time for `count` transfers spread over `parallelism` independent
    /// paths (the NxN regime of Fig. 9).
    pub fn parallel(&self, count: u64, bytes_each: u64, parallelism: u64) -> f64 {
        let waves = count.div_ceil(parallelism.max(1));
        waves as f64 * self.xfer(bytes_each)
    }
}

/// Project a measured testbed series onto paper-scale hardware: scale
/// transfer terms by the bandwidth ratio and evaluate what fraction of
/// the measured time survives. Used for the EXPERIMENTS.md projection
/// tables — a reporting aid, not a claim of absolute accuracy.
pub fn project(measured_s: f64, bytes_moved: u64, from: NetModel, to: NetModel) -> f64 {
    let xfer_from = from.xfer(bytes_moved);
    let non_transfer = (measured_s - xfer_from).max(0.0);
    non_transfer + to.xfer(bytes_moved)
}

/// Ensemble-layout model: completion time of `instances` independent
/// pairs each costing `per_instance_s`, on `nodes` nodes (Fig. 9/10
/// shape: flat once nodes >= instances).
pub fn ensemble_completion(instances: u64, per_instance_s: f64, nodes: u64) -> f64 {
    let waves = instances.div_ceil(nodes.max(1));
    waves as f64 * per_instance_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_is_alpha_plus_size_over_beta() {
        let m = NetModel { alpha: 1e-6, beta: 1e9 };
        let t = m.xfer(1_000_000);
        assert!((t - (1e-6 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn sequential_scales_linearly() {
        let m = OMNI_PATH;
        let t1 = m.sequential(16, 19 << 20);
        let t2 = m.sequential(256, 19 << 20);
        let ratio = t2 / t1;
        assert!((ratio - 16.0).abs() < 1e-9);
        // Paper Fig. 7: 0.6s @16 -> 8.2s @256 is 13.7x, close to the
        // 16x pure-serialization model (the gap is overlap/caching).
        assert!(ratio > 13.0);
    }

    #[test]
    fn parallel_is_flat_when_enough_nodes() {
        let m = OMNI_PATH;
        let t16 = m.parallel(16, 19 << 20, 256);
        let t256 = m.parallel(256, 19 << 20, 256);
        assert!((t16 - t256).abs() < 1e-12, "NxN flat when nodes >= instances");
    }

    #[test]
    fn ensemble_completion_flat_then_waves() {
        assert_eq!(ensemble_completion(64, 2.0, 64), 2.0);
        assert_eq!(ensemble_completion(64, 2.0, 1), 128.0);
        assert_eq!(ensemble_completion(65, 2.0, 64), 4.0);
    }

    #[test]
    fn projection_reduces_transfer_term() {
        let slow = NetModel { alpha: 1e-6, beta: 1e8 };
        let fast = NetModel { alpha: 1e-6, beta: 1e10 };
        // 1 GB at 100 MB/s = 10s measured, 1s compute on top.
        let measured = 11.0;
        let projected = project(measured, 1_000_000_000, slow, fast);
        assert!(projected < 1.2 && projected > 1.0);
    }
}
