//! Bench harness (S15): the offline toolchain has no criterion, so the
//! `cargo bench` targets (harness = false) use this small substitute —
//! repeated trials, simple statistics, and paper-style tables printed
//! to stdout. Each bench also *asserts the shape* of the paper's
//! result (who wins, monotonicity, flatness) so `cargo bench` fails if
//! the reproduction regresses.

use std::time::Instant;

/// Run `f` for `trials` trials (after one warmup when `warmup`), return
/// seconds per trial.
pub fn time_trials<F: FnMut()>(trials: usize, warmup: bool, mut f: F) -> Vec<f64> {
    if warmup {
        f();
    }
    (0..trials)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Simple fixed-width table printer for the bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

/// Bench scale from the environment: `WILKINS_BENCH_FULL=1` runs the
/// larger sweeps (closer to paper scale), default keeps CI-friendly.
pub fn full_scale() -> bool {
    std::env::var("WILKINS_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Shape assertion helpers: loud failures when the reproduction loses
/// the paper's qualitative result.
pub fn assert_monotonic_increase(label: &str, xs: &[f64], tolerance: f64) {
    for w in xs.windows(2) {
        assert!(
            w[1] >= w[0] * (1.0 - tolerance),
            "{label}: expected non-decreasing series, got {xs:?}"
        );
    }
}

pub fn assert_roughly_flat(label: &str, xs: &[f64], max_ratio: f64) {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(0.0_f64, f64::max);
    assert!(
        hi / lo.max(1e-12) <= max_ratio,
        "{label}: expected flat series (ratio <= {max_ratio}), got {xs:?}"
    );
}

pub fn assert_speedup(label: &str, baseline: f64, improved: f64, min_ratio: f64) {
    assert!(
        baseline / improved >= min_ratio,
        "{label}: expected >= {min_ratio}x speedup, got {:.2}x ({baseline:.3}s -> {improved:.3}s)",
        baseline / improved
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0];
        assert!((mean(&xs) - 2.0).abs() < 1e-12);
        assert!((stddev(&xs) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        let out = t.render();
        assert!(out.contains("long_header"));
        assert_eq!(out.lines().count(), 3);
    }

    #[test]
    fn shape_assertions() {
        assert_monotonic_increase("x", &[1.0, 2.0, 3.0], 0.05);
        assert_roughly_flat("y", &[1.0, 1.1, 0.95], 1.3);
    }

    #[test]
    #[should_panic]
    fn monotonic_fails_on_decrease() {
        assert_monotonic_increase("x", &[3.0, 1.0], 0.05);
    }
}
