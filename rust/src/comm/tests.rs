//! Unit tests for the virtual-MPI substrate.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::wire::{Reader, Writer};
use super::{InterComm, World, ANY_SOURCE};

/// Run `f(rank, comm)` on `n` rank threads over a fresh world.
fn spmd<F>(n: usize, f: F)
where
    F: Fn(usize, super::Comm) + Send + Sync + 'static,
{
    let world = World::new(n);
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let comm = world.comm_world(r);
            let f = Arc::clone(&f);
            thread::spawn(move || f(r, comm))
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn p2p_roundtrip() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            comm.send(1, 7, b"hello");
            let (src, msg) = comm.recv(1, 8).unwrap();
            assert_eq!((src, msg.as_slice()), (1, b"world".as_slice()));
        } else {
            let (src, msg) = comm.recv(0, 7).unwrap();
            assert_eq!((src, msg.as_slice()), (0, b"hello".as_slice()));
            comm.send(0, 8, b"world");
        }
    });
}

#[test]
fn tag_matching_out_of_order() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            comm.send(1, 1, b"first");
            comm.send(1, 2, b"second");
        } else {
            // Receive in reverse tag order: matching must dig past the
            // queued tag-1 message.
            let (_, b) = comm.recv(0, 2).unwrap();
            assert_eq!(b, b"second");
            let (_, a) = comm.recv(0, 1).unwrap();
            assert_eq!(a, b"first");
        }
    });
}

#[test]
fn any_source_receives_from_all() {
    spmd(4, |rank, comm| {
        if rank == 0 {
            let mut seen = vec![false; 4];
            for _ in 0..3 {
                let (src, _) = comm.recv(ANY_SOURCE, 5).unwrap();
                seen[src] = true;
            }
            assert_eq!(&seen[1..], &[true, true, true]);
        } else {
            comm.send(0, 5, &[rank as u8]);
        }
    });
}

#[test]
fn recv_timeout_fires() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            let err = comm.recv_timeout(1, 99, Duration::from_millis(50));
            assert!(err.is_err());
        }
        // rank 1 sends nothing
    });
}

#[test]
fn barrier_synchronizes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static BEFORE: AtomicUsize = AtomicUsize::new(0);
    BEFORE.store(0, Ordering::SeqCst);
    spmd(8, |_, comm| {
        BEFORE.fetch_add(1, Ordering::SeqCst);
        comm.barrier().unwrap();
        // After the barrier every rank must observe all 8 increments.
        assert_eq!(BEFORE.load(Ordering::SeqCst), 8);
    });
}

#[test]
fn bcast_from_nonzero_root() {
    spmd(5, |rank, comm| {
        let data = if rank == 3 { Some(&b"payload"[..]) } else { None };
        let got = comm.bcast(3, data).unwrap();
        assert_eq!(got, b"payload");
    });
}

#[test]
fn gather_collects_in_rank_order() {
    spmd(4, |rank, comm| {
        let mine = vec![rank as u8; rank + 1];
        let out = comm.gather(0, &mine).unwrap();
        if rank == 0 {
            let parts = out.unwrap();
            for (r, p) in parts.iter().enumerate() {
                assert_eq!(p, &vec![r as u8; r + 1]);
            }
        } else {
            assert!(out.is_none());
        }
    });
}

#[test]
fn allgather_everywhere() {
    spmd(3, |rank, comm| {
        let parts = comm.allgather(&[rank as u8 * 10]).unwrap();
        assert_eq!(parts, vec![vec![0u8], vec![10u8], vec![20u8]]);
    });
}

#[test]
fn allreduce_sums() {
    spmd(6, |rank, comm| {
        assert_eq!(comm.allreduce_sum_u64(rank as u64).unwrap(), 15);
        let f = comm.allreduce_sum_f64(0.5).unwrap();
        assert!((f - 3.0).abs() < 1e-12);
        assert_eq!(comm.allreduce_max_u64(rank as u64).unwrap(), 5);
    });
}

#[test]
fn subset_comm_is_isolated() {
    spmd(4, |rank, comm| {
        // Ranks {1, 3} form a sub-communicator with id 42.
        if rank == 1 || rank == 3 {
            let sub = comm.subset(42, &[1, 3]).unwrap();
            assert_eq!(sub.size(), 2);
            let me = sub.rank();
            let peer = 1 - me;
            sub.send(peer, 0, &[me as u8]);
            let (_, got) = sub.recv(peer, 0).unwrap();
            assert_eq!(got, vec![peer as u8]);
            sub.barrier().unwrap();
        } else {
            assert!(comm.subset(42, &[1, 3]).is_none());
        }
    });
}

#[test]
fn subset_messages_do_not_leak_to_world() {
    spmd(2, |rank, comm| {
        let sub = comm.subset(9, &[0, 1]).unwrap();
        if rank == 0 {
            sub.send(1, 3, b"subonly");
        } else {
            // Same tag on the world comm must NOT see it.
            assert!(comm.recv_timeout(0, 3, Duration::from_millis(50)).is_err());
            let (_, m) = sub.recv(0, 3).unwrap();
            assert_eq!(m, b"subonly");
        }
    });
}

#[test]
fn intercomm_crosses_groups() {
    // World of 5: producers {0,1,2}, consumers {3,4}.
    let world = World::new(5);
    let wid = world.alloc_comm_id();
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let _ = wid;
    let mut handles = Vec::new();
    for g in 0..5usize {
        let world = world.clone();
        handles.push(thread::spawn(move || {
            let producers = [0usize, 1, 2];
            let consumers = [3usize, 4];
            if g < 3 {
                let local = world.comm_from_ranks(pid, &producers, g);
                let ic = InterComm::new(local, 77, consumers.to_vec());
                // Producer rank g sends to consumer rank g % 2.
                ic.send(g % 2, 4, &[g as u8]);
            } else {
                let local = world.comm_from_ranks(cid, &consumers, g - 3);
                let ic = InterComm::new(local, 77, producers.to_vec());
                let me = g - 3;
                let expect: Vec<u8> =
                    (0..3).filter(|p| p % 2 == me).map(|p| p as u8).collect();
                let mut got = Vec::new();
                for _ in 0..expect.len() {
                    let (src, m) = ic.recv_any(4).unwrap();
                    assert_eq!(m, vec![src as u8]);
                    got.push(m[0]);
                }
                got.sort();
                assert_eq!(got, expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn intercomm_iprobe() {
    let world = World::new(2);
    let a = world.comm_from_ranks(1, &[0], 0);
    let b = world.comm_from_ranks(2, &[1], 0);
    let ia = InterComm::new(a, 50, vec![1]);
    let ib = InterComm::new(b, 50, vec![0]);
    assert!(!ib.iprobe(6));
    ia.send(0, 6, b"x");
    assert!(ib.iprobe(6));
    let (_, m) = ib.recv_any(6).unwrap();
    assert_eq!(m, b"x");
    assert!(!ib.iprobe(6));
}

#[test]
fn byte_counters_track_traffic() {
    let world = World::new(2);
    let w2 = world.clone();
    let t = thread::spawn(move || {
        let c = w2.comm_world(0);
        c.send(1, 0, &[0u8; 1000]);
    });
    let c = world.comm_world(1);
    let (_, m) = c.recv(0, 0).unwrap();
    assert_eq!(m.len(), 1000);
    t.join().unwrap();
    assert_eq!(world.bytes_sent(), 1000);
    assert_eq!(world.msgs_sent(), 1);
}

#[test]
fn wire_roundtrip() {
    let mut w = Writer::new();
    w.put_u8(9);
    w.put_u32(70_000);
    w.put_u64(1 << 40);
    w.put_i64(-5);
    w.put_f32(1.5);
    w.put_f64(-2.25);
    w.put_str("grid");
    w.put_u64_slice(&[3, 1, 4]);
    w.put_bytes(&[1, 2, 3]);
    let buf = w.into_vec();
    let mut r = Reader::new(&buf);
    assert_eq!(r.get_u8().unwrap(), 9);
    assert_eq!(r.get_u32().unwrap(), 70_000);
    assert_eq!(r.get_u64().unwrap(), 1 << 40);
    assert_eq!(r.get_i64().unwrap(), -5);
    assert_eq!(r.get_f32().unwrap(), 1.5);
    assert_eq!(r.get_f64().unwrap(), -2.25);
    assert_eq!(r.get_str().unwrap(), "grid");
    assert_eq!(r.get_u64_vec().unwrap(), vec![3, 1, 4]);
    assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
    assert_eq!(r.remaining(), 0);
}

#[test]
fn wire_underrun_is_error() {
    let mut r = Reader::new(&[1, 2]);
    assert!(r.get_u64().is_err());
}

#[test]
fn large_world_fan_in() {
    // 64 ranks all send to 0; exercises mailbox contention.
    spmd(64, |rank, comm| {
        if rank == 0 {
            let mut sum = 0u64;
            for _ in 0..63 {
                let (_, m) = comm.recv_any(1).unwrap();
                sum += m[0] as u64;
            }
            assert_eq!(sum, (1..64).sum::<u64>());
        } else {
            comm.send(0, 1, &[rank as u8]);
        }
    });
}

/// Every value kind the wire protocol can carry, for the codec
/// property sweep below.
#[derive(Debug, Clone, PartialEq)]
enum WireValue {
    U8(u8),
    U32(u32),
    U64(u64),
    I64(i64),
    F32(f32),
    F64(f64),
    Bytes(Vec<u8>),
    Str(String),
    U64s(Vec<u64>),
}

impl WireValue {
    fn random(rng: &mut crate::proptest_lite::Rng) -> WireValue {
        match rng.usize(0, 9) {
            0 => WireValue::U8(rng.next_u64() as u8),
            1 => WireValue::U32(rng.next_u64() as u32),
            2 => WireValue::U64(rng.next_u64()),
            3 => WireValue::I64(rng.next_u64() as i64),
            4 => WireValue::F32(rng.f32()),
            5 => WireValue::F64(rng.f32() as f64 * 1e9),
            6 => {
                let n = rng.usize(0, 300);
                WireValue::Bytes((0..n).map(|_| rng.next_u64() as u8).collect())
            }
            7 => {
                let n = rng.usize(0, 40);
                let alphabet = b"abcdefgh /._-#[]";
                WireValue::Str(
                    (0..n)
                        .map(|_| *rng.choose(alphabet) as char)
                        .collect(),
                )
            }
            _ => {
                let n = rng.usize(0, 20);
                WireValue::U64s((0..n).map(|_| rng.next_u64()).collect())
            }
        }
    }

    fn put(&self, w: &mut Writer) {
        match self {
            WireValue::U8(v) => w.put_u8(*v),
            WireValue::U32(v) => w.put_u32(*v),
            WireValue::U64(v) => w.put_u64(*v),
            WireValue::I64(v) => w.put_i64(*v),
            WireValue::F32(v) => w.put_f32(*v),
            WireValue::F64(v) => w.put_f64(*v),
            WireValue::Bytes(v) => w.put_bytes(v),
            WireValue::Str(v) => w.put_str(v),
            WireValue::U64s(v) => w.put_u64_slice(v),
        }
    }

    fn check(&self, r: &mut Reader) {
        match self {
            WireValue::U8(v) => assert_eq!(r.get_u8().unwrap(), *v),
            WireValue::U32(v) => assert_eq!(r.get_u32().unwrap(), *v),
            WireValue::U64(v) => assert_eq!(r.get_u64().unwrap(), *v),
            WireValue::I64(v) => assert_eq!(r.get_i64().unwrap(), *v),
            WireValue::F32(v) => assert_eq!(r.get_f32().unwrap().to_bits(), v.to_bits()),
            WireValue::F64(v) => assert_eq!(r.get_f64().unwrap().to_bits(), v.to_bits()),
            WireValue::Bytes(v) => assert_eq!(r.get_bytes().unwrap(), v.as_slice()),
            WireValue::Str(v) => assert_eq!(&r.get_str().unwrap(), v),
            WireValue::U64s(v) => assert_eq!(&r.get_u64_vec().unwrap(), v),
        }
    }
}

/// Property: random sequences of every wire value kind, framed through
/// the socket codec and fed back through the incremental decoder at
/// random split points (including splits inside headers and bodies),
/// reproduce every frame and every value bit-exactly, in order.
#[test]
fn prop_wire_values_roundtrip_through_frame_codec() {
    use crate::net::codec::FrameDecoder;
    use crate::proptest_lite::run_prop;

    run_prop("wire-through-codec", 150, |rng| {
        let nframes = rng.usize(1, 5);
        let mut stream: Vec<u8> = Vec::new();
        let mut expected: Vec<(u8, Vec<WireValue>)> = Vec::new();
        for _ in 0..nframes {
            let kind = rng.next_u64() as u8;
            let nvals = rng.usize(0, 12);
            let vals: Vec<WireValue> =
                (0..nvals).map(|_| WireValue::random(rng)).collect();
            let mut w = Writer::new();
            for v in &vals {
                v.put(&mut w);
            }
            crate::net::codec::write_frame(&mut stream, kind, &w.into_vec()).unwrap();
            expected.push((kind, vals));
        }

        // Feed the byte stream in random-size chunks; a frame may be
        // split anywhere, including inside its header.
        let mut dec = FrameDecoder::new();
        let mut got: Vec<(u8, Vec<u8>)> = Vec::new();
        let mut pos = 0usize;
        while pos < stream.len() {
            let step = rng.usize(1, 18).min(stream.len() - pos);
            let before = got.len();
            dec.feed(&stream[pos..pos + step]);
            while let Some(f) = dec.next_frame().unwrap() {
                got.push(f);
            }
            // Partial feeds must never invent frames out of thin air.
            assert!(got.len() >= before);
            pos += step;
        }
        assert_eq!(dec.pending(), 0, "no trailing bytes after the last frame");
        assert_eq!(got.len(), expected.len());
        for ((kind, body), (ekind, evals)) in got.iter().zip(&expected) {
            assert_eq!(kind, ekind);
            let mut r = Reader::new(body);
            for v in evals {
                v.check(&mut r);
            }
            assert_eq!(r.remaining(), 0, "frame body fully consumed");
        }
    });
}

/// Property: the blocking reader and the incremental decoder agree on
/// the same stream (same frames, same order, same clean-EOF point).
#[test]
fn prop_blocking_and_incremental_decode_agree() {
    use crate::net::codec::{read_frame, FrameDecoder};
    use crate::proptest_lite::run_prop;

    run_prop("codec-two-paths-agree", 100, |rng| {
        let nframes = rng.usize(0, 6);
        let mut stream: Vec<u8> = Vec::new();
        for _ in 0..nframes {
            let n = rng.usize(0, 200);
            let body: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
            crate::net::codec::write_frame(&mut stream, rng.next_u64() as u8, &body)
                .unwrap();
        }
        let mut blocking = Vec::new();
        let mut cur = std::io::Cursor::new(stream.clone());
        while let Some(f) = read_frame(&mut cur).unwrap() {
            blocking.push(f);
        }
        let mut dec = FrameDecoder::new();
        dec.feed(&stream);
        let mut incremental = Vec::new();
        while let Some(f) = dec.next_frame().unwrap() {
            incremental.push(f);
        }
        assert_eq!(blocking, incremental);
        assert_eq!(blocking.len(), nframes);
    });
}
