//! Unit tests for the virtual-MPI substrate.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::wire::{Reader, Writer};
use super::{InterComm, World, ANY_SOURCE};

/// Run `f(rank, comm)` on `n` rank threads over a fresh world.
fn spmd<F>(n: usize, f: F)
where
    F: Fn(usize, super::Comm) + Send + Sync + 'static,
{
    let world = World::new(n);
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|r| {
            let comm = world.comm_world(r);
            let f = Arc::clone(&f);
            thread::spawn(move || f(r, comm))
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn p2p_roundtrip() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            comm.send(1, 7, b"hello");
            let (src, msg) = comm.recv(1, 8).unwrap();
            assert_eq!((src, msg.as_slice()), (1, b"world".as_slice()));
        } else {
            let (src, msg) = comm.recv(0, 7).unwrap();
            assert_eq!((src, msg.as_slice()), (0, b"hello".as_slice()));
            comm.send(0, 8, b"world");
        }
    });
}

#[test]
fn tag_matching_out_of_order() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            comm.send(1, 1, b"first");
            comm.send(1, 2, b"second");
        } else {
            // Receive in reverse tag order: matching must dig past the
            // queued tag-1 message.
            let (_, b) = comm.recv(0, 2).unwrap();
            assert_eq!(b, b"second");
            let (_, a) = comm.recv(0, 1).unwrap();
            assert_eq!(a, b"first");
        }
    });
}

#[test]
fn any_source_receives_from_all() {
    spmd(4, |rank, comm| {
        if rank == 0 {
            let mut seen = vec![false; 4];
            for _ in 0..3 {
                let (src, _) = comm.recv(ANY_SOURCE, 5).unwrap();
                seen[src] = true;
            }
            assert_eq!(&seen[1..], &[true, true, true]);
        } else {
            comm.send(0, 5, &[rank as u8]);
        }
    });
}

#[test]
fn recv_timeout_fires() {
    spmd(2, |rank, comm| {
        if rank == 0 {
            let err = comm.recv_timeout(1, 99, Duration::from_millis(50));
            assert!(err.is_err());
        }
        // rank 1 sends nothing
    });
}

#[test]
fn barrier_synchronizes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static BEFORE: AtomicUsize = AtomicUsize::new(0);
    BEFORE.store(0, Ordering::SeqCst);
    spmd(8, |_, comm| {
        BEFORE.fetch_add(1, Ordering::SeqCst);
        comm.barrier().unwrap();
        // After the barrier every rank must observe all 8 increments.
        assert_eq!(BEFORE.load(Ordering::SeqCst), 8);
    });
}

#[test]
fn bcast_from_nonzero_root() {
    spmd(5, |rank, comm| {
        let data = if rank == 3 { Some(&b"payload"[..]) } else { None };
        let got = comm.bcast(3, data).unwrap();
        assert_eq!(got, b"payload");
    });
}

#[test]
fn gather_collects_in_rank_order() {
    spmd(4, |rank, comm| {
        let mine = vec![rank as u8; rank + 1];
        let out = comm.gather(0, &mine).unwrap();
        if rank == 0 {
            let parts = out.unwrap();
            for (r, p) in parts.iter().enumerate() {
                assert_eq!(p, &vec![r as u8; r + 1]);
            }
        } else {
            assert!(out.is_none());
        }
    });
}

#[test]
fn allgather_everywhere() {
    spmd(3, |rank, comm| {
        let parts = comm.allgather(&[rank as u8 * 10]).unwrap();
        assert_eq!(parts, vec![vec![0u8], vec![10u8], vec![20u8]]);
    });
}

#[test]
fn allreduce_sums() {
    spmd(6, |rank, comm| {
        assert_eq!(comm.allreduce_sum_u64(rank as u64).unwrap(), 15);
        let f = comm.allreduce_sum_f64(0.5).unwrap();
        assert!((f - 3.0).abs() < 1e-12);
        assert_eq!(comm.allreduce_max_u64(rank as u64).unwrap(), 5);
    });
}

#[test]
fn subset_comm_is_isolated() {
    spmd(4, |rank, comm| {
        // Ranks {1, 3} form a sub-communicator with id 42.
        if rank == 1 || rank == 3 {
            let sub = comm.subset(42, &[1, 3]).unwrap();
            assert_eq!(sub.size(), 2);
            let me = sub.rank();
            let peer = 1 - me;
            sub.send(peer, 0, &[me as u8]);
            let (_, got) = sub.recv(peer, 0).unwrap();
            assert_eq!(got, vec![peer as u8]);
            sub.barrier().unwrap();
        } else {
            assert!(comm.subset(42, &[1, 3]).is_none());
        }
    });
}

#[test]
fn subset_messages_do_not_leak_to_world() {
    spmd(2, |rank, comm| {
        let sub = comm.subset(9, &[0, 1]).unwrap();
        if rank == 0 {
            sub.send(1, 3, b"subonly");
        } else {
            // Same tag on the world comm must NOT see it.
            assert!(comm.recv_timeout(0, 3, Duration::from_millis(50)).is_err());
            let (_, m) = sub.recv(0, 3).unwrap();
            assert_eq!(m, b"subonly");
        }
    });
}

#[test]
fn intercomm_crosses_groups() {
    // World of 5: producers {0,1,2}, consumers {3,4}.
    let world = World::new(5);
    let wid = world.alloc_comm_id();
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let _ = wid;
    let mut handles = Vec::new();
    for g in 0..5usize {
        let world = world.clone();
        handles.push(thread::spawn(move || {
            let producers = [0usize, 1, 2];
            let consumers = [3usize, 4];
            if g < 3 {
                let local = world.comm_from_ranks(pid, &producers, g);
                let ic = InterComm::new(local, 77, consumers.to_vec());
                // Producer rank g sends to consumer rank g % 2.
                ic.send(g % 2, 4, &[g as u8]);
            } else {
                let local = world.comm_from_ranks(cid, &consumers, g - 3);
                let ic = InterComm::new(local, 77, producers.to_vec());
                let me = g - 3;
                let expect: Vec<u8> =
                    (0..3).filter(|p| p % 2 == me).map(|p| p as u8).collect();
                let mut got = Vec::new();
                for _ in 0..expect.len() {
                    let (src, m) = ic.recv_any(4).unwrap();
                    assert_eq!(m, vec![src as u8]);
                    got.push(m[0]);
                }
                got.sort();
                assert_eq!(got, expect);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn intercomm_iprobe() {
    let world = World::new(2);
    let a = world.comm_from_ranks(1, &[0], 0);
    let b = world.comm_from_ranks(2, &[1], 0);
    let ia = InterComm::new(a, 50, vec![1]);
    let ib = InterComm::new(b, 50, vec![0]);
    assert!(!ib.iprobe(6));
    ia.send(0, 6, b"x");
    assert!(ib.iprobe(6));
    let (_, m) = ib.recv_any(6).unwrap();
    assert_eq!(m, b"x");
    assert!(!ib.iprobe(6));
}

#[test]
fn byte_counters_track_traffic() {
    let world = World::new(2);
    let w2 = world.clone();
    let t = thread::spawn(move || {
        let c = w2.comm_world(0);
        c.send(1, 0, &[0u8; 1000]);
    });
    let c = world.comm_world(1);
    let (_, m) = c.recv(0, 0).unwrap();
    assert_eq!(m.len(), 1000);
    t.join().unwrap();
    assert_eq!(world.bytes_sent(), 1000);
    assert_eq!(world.msgs_sent(), 1);
}

#[test]
fn wire_roundtrip() {
    let mut w = Writer::new();
    w.put_u8(9);
    w.put_u32(70_000);
    w.put_u64(1 << 40);
    w.put_i64(-5);
    w.put_f32(1.5);
    w.put_f64(-2.25);
    w.put_str("grid");
    w.put_u64_slice(&[3, 1, 4]);
    w.put_bytes(&[1, 2, 3]);
    let buf = w.into_vec();
    let mut r = Reader::new(&buf);
    assert_eq!(r.get_u8().unwrap(), 9);
    assert_eq!(r.get_u32().unwrap(), 70_000);
    assert_eq!(r.get_u64().unwrap(), 1 << 40);
    assert_eq!(r.get_i64().unwrap(), -5);
    assert_eq!(r.get_f32().unwrap(), 1.5);
    assert_eq!(r.get_f64().unwrap(), -2.25);
    assert_eq!(r.get_str().unwrap(), "grid");
    assert_eq!(r.get_u64_vec().unwrap(), vec![3, 1, 4]);
    assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
    assert_eq!(r.remaining(), 0);
}

#[test]
fn wire_underrun_is_error() {
    let mut r = Reader::new(&[1, 2]);
    assert!(r.get_u64().is_err());
}

#[test]
fn large_world_fan_in() {
    // 64 ranks all send to 0; exercises mailbox contention.
    spmd(64, |rank, comm| {
        if rank == 0 {
            let mut sum = 0u64;
            for _ in 0..63 {
                let (_, m) = comm.recv_any(1).unwrap();
                sum += m[0] as u64;
            }
            assert_eq!(sum, (1..64).sum::<u64>());
        } else {
            comm.send(0, 1, &[rank as u8]);
        }
    });
}
