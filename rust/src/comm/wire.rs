//! Byte-level message encoding (serde substitute).
//!
//! All inter-rank protocol messages (LowFive metadata, requests,
//! dataset blocks) are encoded with this little-endian writer/reader
//! pair. Deliberately boring: length-prefixed bytes and fixed-width
//! integers only.

use crate::error::{Result, WilkinsError};

#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Length-prefixed bytes written in place: reserves `n` zeroed
    /// bytes and hands the slice to `f` to fill (§Perf: lets callers
    /// extract data straight into the wire buffer, no staging copy).
    pub fn put_bytes_via(&mut self, n: usize, f: impl FnOnce(&mut [u8])) {
        self.put_u64(n as u64);
        let start = self.buf.len();
        self.buf.resize(start + n, 0);
        f(&mut self.buf[start..]);
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    pub fn put_u64_slice(&mut self, xs: &[u64]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.put_u64(x);
        }
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(WilkinsError::Comm(format!(
                "wire underrun: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u64()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|e| WilkinsError::Comm(format!("wire: bad utf8: {e}")))
    }

    pub fn get_u64_vec(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}
