//! Byte-level message encoding (serde substitute).
//!
//! All inter-rank protocol messages (LowFive metadata, requests,
//! dataset blocks) are encoded with this little-endian writer/reader
//! pair. Deliberately boring: length-prefixed bytes and fixed-width
//! integers only.

use crate::error::{Result, WilkinsError};

use super::buf::{BufPool, Lease, Payload};

#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
    /// Set when the buffer was leased from a [`BufPool`]:
    /// [`Writer::finish`] attaches the pool back-link so the
    /// resulting [`Payload`] returns the buffer on its last drop.
    lease: Option<Lease>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new(), lease: None }
    }

    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap), lease: None }
    }

    /// A writer over a buffer leased from `pool` (§Perf: steady-state
    /// encodes recycle the same allocation round after round). Finish
    /// with [`Writer::finish`] to keep the buffer pooled.
    pub fn pooled(pool: &BufPool, cap: usize) -> Writer {
        let lease = pool.lease(cap);
        Writer { buf: Vec::new(), lease: Some(lease) }
    }

    /// Is this encode allocation-free so far: the backing buffer was
    /// recycled from its pool *and* has not been outgrown (no
    /// reallocation since lease time)? Always false for unpooled
    /// writers. Evaluate after encoding — growth can only be seen
    /// once the bytes are in.
    pub fn pool_hit(&self) -> bool {
        self.lease.as_ref().is_some_and(|l| l.was_hit() && !l.grew())
    }

    fn bytes_mut(&mut self) -> &mut Vec<u8> {
        match self.lease.as_mut() {
            Some(l) => l,
            None => &mut self.buf,
        }
    }

    fn bytes(&self) -> &Vec<u8> {
        match self.lease.as_ref() {
            Some(l) => l,
            None => &self.buf,
        }
    }

    /// Freeze the encoded bytes into a refcounted [`Payload`]. Pooled
    /// writers keep their pool link (the buffer is recycled when the
    /// last payload view drops); plain writers wrap their `Vec`
    /// without copying.
    pub fn finish(self) -> Payload {
        match self.lease {
            Some(l) => l.finish(),
            None => Payload::from(self.buf),
        }
    }

    pub fn put_u8(&mut self, v: u8) {
        self.bytes_mut().push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.bytes_mut().extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.bytes_mut().extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_i64(&mut self, v: i64) {
        self.bytes_mut().extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.bytes_mut().extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.bytes_mut().extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_u64(b.len() as u64);
        self.bytes_mut().extend_from_slice(b);
    }

    /// Append raw bytes with no length prefix (file magics, preframed
    /// sub-encodings).
    pub fn put_raw(&mut self, b: &[u8]) {
        self.bytes_mut().extend_from_slice(b);
    }

    /// Overwrite the u64 at byte offset `pos` (little-endian) — the
    /// backfill half of a reserve-then-encode-in-place length prefix.
    /// Panics if `pos..pos+8` was not already written.
    pub fn set_u64_at(&mut self, pos: usize, v: u64) {
        self.bytes_mut()[pos..pos + 8].copy_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed bytes written in place: reserves `n` zeroed
    /// bytes and hands the slice to `f` to fill (§Perf: lets callers
    /// extract data straight into the wire buffer, no staging copy).
    pub fn put_bytes_via(&mut self, n: usize, f: impl FnOnce(&mut [u8])) {
        self.put_u64(n as u64);
        let buf = self.bytes_mut();
        let start = buf.len();
        buf.resize(start + n, 0);
        f(&mut buf[start..]);
    }

    pub fn put_str(&mut self, s: &str) {
        self.put_bytes(s.as_bytes());
    }

    pub fn put_u64_slice(&mut self, xs: &[u64]) {
        self.put_u64(xs.len() as u64);
        for &x in xs {
            self.put_u64(x);
        }
    }

    /// Extract the raw encoded bytes. A pooled writer's contents are
    /// *copied out* here (the leased buffer goes back to its pool) —
    /// prefer [`Writer::finish`], which shares the buffer instead.
    pub fn into_vec(mut self) -> Vec<u8> {
        match self.lease.take() {
            Some(lease) => lease.finish().into_vec(),
            None => self.buf,
        }
    }

    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes().is_empty()
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(WilkinsError::Comm(format!(
                "wire underrun: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u64()? as usize;
        self.take(n)
    }

    /// Read a length-prefixed byte run and return it as a zero-copy
    /// slice of `src` — the payload this reader was constructed over
    /// (`Reader::new(&src)`). The one shared implementation of the
    /// "decode borrows the receive buffer" pattern: offsets are
    /// derived from the reader's own position and validated against
    /// `src`, so the five decode paths that slice instead of copying
    /// cannot drift apart.
    pub fn get_bytes_sliced(&mut self, src: &Payload) -> Result<Payload> {
        if src.len() != self.buf.len() || !std::ptr::eq(src.as_slice().as_ptr(), self.buf.as_ptr())
        {
            return Err(WilkinsError::Comm(
                "get_bytes_sliced: payload is not this reader's backing buffer".into(),
            ));
        }
        let n = self.get_bytes()?.len();
        let end = src.len() - self.remaining();
        src.slice(end - n..end)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        String::from_utf8(b.to_vec())
            .map_err(|e| WilkinsError::Comm(format!("wire: bad utf8: {e}")))
    }

    pub fn get_u64_vec(&mut self) -> Result<Vec<u64>> {
        let n = self.get_u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u64()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}
