//! Intercommunicators: a local group plus a remote group, used for the
//! producer↔consumer channels Wilkins creates per matched data object
//! (Sec. 3.2). Ranks address the *remote* group's local indices.

use std::sync::Arc;
use std::time::Duration;

use super::{Comm, Envelope, Payload, RECV_TIMEOUT};
use crate::error::{Result, WilkinsError};

/// An intercommunicator between a local and a remote rank group.
#[derive(Clone)]
pub struct InterComm {
    /// Our side's communicator (restricted world of this task).
    local: Comm,
    /// Channel id (shared by both sides; allocated by the coordinator).
    id: u64,
    /// Global ranks of the remote group, in remote-local-rank order.
    remote: Arc<Vec<usize>>,
}

impl InterComm {
    /// Coordinator-side constructor: both sides must use the same `id`
    /// and see each other's global rank lists in consistent order.
    pub fn new(local: Comm, id: u64, remote_global_ranks: Vec<usize>) -> InterComm {
        InterComm {
            local,
            id,
            remote: Arc::new(remote_global_ranks),
        }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Local rank within our side's group.
    pub fn local_rank(&self) -> usize {
        self.local.rank()
    }

    pub fn local_size(&self) -> usize {
        self.local.size()
    }

    pub fn remote_size(&self) -> usize {
        self.remote.len()
    }

    /// Is remote local rank `dst` hosted in this OS process? Gates the
    /// zero-copy serve fast path: an `Arc` handed through the shared
    /// registry only resolves inside one address space.
    pub fn remote_is_local(&self, dst: usize) -> bool {
        self.local.global_is_local(self.remote[dst])
    }

    /// Send to remote local rank `dst`.
    pub fn send(&self, dst: usize, tag: u64, data: &[u8]) {
        let dst_global = self.remote[dst];
        self.local.send_global(self.id, dst_global, tag, data);
    }

    /// Owned-buffer send (no payload copy); see [`Comm::send_owned`].
    /// Accepts a `Vec<u8>` or a pooled/sliced [`Payload`] view.
    pub fn send_owned(&self, dst: usize, tag: u64, data: impl Into<Payload>) {
        let dst_global = self.remote[dst];
        self.local.send_global_owned(self.id, dst_global, tag, data.into());
    }

    /// Blocking receive from remote local rank `src` (or ANY_SOURCE).
    /// Returns (remote local rank, payload).
    pub fn recv(&self, src: usize, tag: u64) -> Result<(usize, Payload)> {
        self.recv_timeout(src, tag, RECV_TIMEOUT)
    }

    pub fn recv_any(&self, tag: u64) -> Result<(usize, Payload)> {
        self.recv_timeout(super::ANY_SOURCE, tag, RECV_TIMEOUT)
    }

    pub fn recv_timeout(
        &self,
        src: usize,
        tag: u64,
        timeout: Duration,
    ) -> Result<(usize, Payload)> {
        let remote = Arc::clone(&self.remote);
        let id = self.id;
        let matcher = move |e: &Envelope| {
            e.comm_id == id
                && e.tag == tag
                && (src == super::ANY_SOURCE
                    || remote.get(src) == Some(&e.src_global))
        };
        let env = self.local.recv_matching(matcher, timeout)?;
        let src_local = self
            .remote
            .iter()
            .position(|&g| g == env.src_global)
            .ok_or_else(|| {
                WilkinsError::Comm("intercomm message from unknown remote rank".into())
            })?;
        Ok((src_local, env.payload))
    }

    /// Non-blocking receive from any remote rank: `None` when nothing
    /// is queued right now. Returns (remote local rank, payload).
    pub fn try_recv_any(&self, tag: u64) -> Option<(usize, Payload)> {
        self.try_recv_where(tag, |_| true)
    }

    /// Non-blocking *selective* receive: pop the first queued message
    /// on `tag` whose payload satisfies `pred`, leaving everything
    /// else queued. The flow pump uses a payload peek (the request
    /// discriminant byte) to answer data reads without absorbing
    /// protocol events that a coordinated section plan owns.
    pub fn try_recv_where(
        &self,
        tag: u64,
        pred: impl Fn(&[u8]) -> bool,
    ) -> Option<(usize, Payload)> {
        let remote = Arc::clone(&self.remote);
        let id = self.id;
        let matcher = move |e: &Envelope| {
            e.comm_id == id
                && e.tag == tag
                && remote.contains(&e.src_global)
                && pred(&e.payload)
        };
        let env = self.local.try_recv_matching(matcher)?;
        let src_local = self.remote.iter().position(|&g| g == env.src_global)?;
        Some((src_local, env.payload))
    }

    /// Non-blocking probe for a message from any remote rank.
    pub fn iprobe(&self, tag: u64) -> bool {
        let mb_rank = self.local.global_rank();
        let state = self.local.world_state();
        let queue = state.mailboxes.at(mb_rank).queue.lock().unwrap();
        queue
            .iter()
            .any(|e| e.comm_id == self.id && e.tag == tag && self.remote.contains(&e.src_global))
    }
}
