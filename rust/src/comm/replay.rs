//! Replay backend for the [`Transport`](super::Transport) seam: a
//! single-process world that re-hosts one recorded process's ranks
//! and feeds their inboxes from a wire log instead of live sockets.
//!
//! The live socket substrate splits every world into *hosted* ranks
//! (mailboxes in this process) and remote ranks (frames on a peer
//! link). Replay keeps that exact split: sends between two hosted
//! ranks are delivered live — they never crossed the wire in the
//! recorded run either — while sends to a rank the recorded process
//! did not host are *suppressed* (counted, dropped), because their
//! effect on this process, if any, came back as recorded inbound
//! frames which [`crate::obs::replay`] injects via
//! [`ReplayWorld::inject`] in log order.
//!
//! Mailbox matching is on (communicator id, tag, source) FIFO, so
//! pre-injecting the recorded inbound frames preserves exactly the
//! per-key arrival order the recorded run observed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::{Envelope, Mailboxes, Payload, Transport, World};

/// The replay transport: local delivery for hosted ranks, counted
/// suppression for everything else (see the module docs).
pub struct ReplayTransport {
    mailboxes: Arc<Mailboxes>,
    hosted: Vec<bool>,
    suppressed: AtomicU64,
}

impl Transport for ReplayTransport {
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    ) {
        if self.hosted.get(dst_global).copied().unwrap_or(false) {
            self.mailboxes.push(dst_global, Envelope { src_global, comm_id, tag, payload });
        } else {
            // The recorded process framed this onto a peer link; its
            // observable consequences are already in the inbound log.
            self.suppressed.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn is_local(&self, dst_global: usize) -> bool {
        self.hosted.get(dst_global).copied().unwrap_or(false)
    }
}

/// A world wired over a [`ReplayTransport`], plus the injection
/// handle the replay driver feeds recorded inbound frames through.
pub struct ReplayWorld {
    world: World,
    transport: Arc<ReplayTransport>,
}

impl ReplayWorld {
    /// Build a `size`-rank world where `hosted[r]` marks the ranks the
    /// recorded process ran locally (the replay re-hosts exactly
    /// those).
    pub fn new(size: usize, hosted: Vec<bool>) -> ReplayWorld {
        assert_eq!(hosted.len(), size, "hosted mask must cover every global rank");
        let mailboxes = Arc::new(Mailboxes::new(size));
        let transport = Arc::new(ReplayTransport {
            mailboxes: Arc::clone(&mailboxes),
            hosted,
            suppressed: AtomicU64::new(0),
        });
        let world = World::with_transport(size, mailboxes, Arc::clone(&transport) as _);
        ReplayWorld { world, transport }
    }

    /// The world to run hosted ranks against.
    pub fn world(&self) -> &World {
        &self.world
    }

    /// Inject one recorded inbound message into `dst_global`'s inbox —
    /// the replay analogue of the socket pump delivering a decoded
    /// data envelope. Call in log order; per-(comm, tag, src) FIFO
    /// then reproduces the recorded arrival interleaving.
    pub fn inject(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    ) {
        self.transport
            .mailboxes
            .push(dst_global, Envelope { src_global, comm_id, tag, payload });
    }

    /// How many outbound sends targeted non-hosted ranks (and were
    /// suppressed). Mirrors the recorded process's cross-process send
    /// count, so drivers can sanity-check replay coverage.
    pub fn suppressed(&self) -> u64 {
        self.transport.suppressed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hosted_sends_deliver_and_foreign_sends_suppress() {
        let rw = ReplayWorld::new(3, vec![true, true, false]);
        let c0 = rw.world().comm_world(0);
        let c1 = rw.world().comm_world(1);
        c0.send(1, 7, b"live");
        let (src, got) = c1.recv(0, 7).unwrap();
        assert_eq!((src, &got[..]), (0, &b"live"[..]));
        // Rank 2 is not hosted: the send must vanish, counted.
        c0.send(2, 7, b"gone");
        assert_eq!(rw.suppressed(), 1);
    }

    #[test]
    fn injected_frames_arrive_in_fifo_order() {
        let rw = ReplayWorld::new(2, vec![true, false]);
        let c0 = rw.world().comm_world(0);
        rw.inject(0, 1, 0, 9, Payload::copy_from_slice(b"first"));
        rw.inject(0, 1, 0, 9, Payload::copy_from_slice(b"second"));
        let (_, a) = c0.recv(1, 9).unwrap();
        let (_, b) = c0.recv(1, 9).unwrap();
        assert_eq!(&a[..], b"first");
        assert_eq!(&b[..], b"second");
    }
}
