//! Pooled buffers and refcounted payload views — the allocation
//! discipline of the wire hot path.
//!
//! Every remote serve used to copy its bytes 4–5 times between the
//! producer's block store and the consumer's hyperslab fill: encode
//! into a fresh `Vec`, concatenate a frame, split into owned chunks,
//! reassemble, and `to_vec` once more at decode. This module holds the
//! two primitives that delete those copies:
//!
//! * [`Payload`] — a refcounted byte buffer plus an `(offset, len)`
//!   view, like `bytes::Bytes` but dependency-free. Slicing is O(1)
//!   and allocation-free; clones share the backing buffer. A payload
//!   whose buffer came from a [`BufPool`] returns it to the pool when
//!   the last view drops, so steady-state serve rounds recycle the
//!   same allocations round after round.
//! * [`BufPool`] — a bounded, thread-safe free list of `Vec<u8>`
//!   buffers. Leases report whether they were pool *hits* (a recycled
//!   allocation) or *misses* (a fresh one); the producer engine folds
//!   that into [`VolStats::alloc_rounds`](crate::lowfive::VolStats)
//!   so "zero allocations at steady state" is a measurable claim, not
//!   a hope.
//!
//! The process-global [`pool()`] serves the transport layer (frame
//! reads, chunk reassembly) and the lowfive encode paths. The
//! [`set_pooling`]/[`pooling_enabled`] switch is the benchmark
//! ablation arm (`Vol::set_pooling(false)` routes through it): with
//! pooling off, the transport falls back to the historical
//! owned-`Vec` path so `benches/wire.rs` can measure exactly what the
//! pooled plane buys. [`note_copied`]/[`bytes_copied_total`] meter
//! every user-space memcpy of payload bytes on the wire path for the
//! bench's bytes-copied-per-byte-delivered figure.

use std::ops::{Deref, Range};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use crate::error::{Result, WilkinsError};

/// Upper bound on buffers parked in the global pool. Enough for every
/// pump thread and encode path of a many-worker process to hit the
/// pool concurrently; the byte budget
/// (`PoolShared::MAX_PARKED_TOTAL`) is what actually bounds idle
/// memory after a burst of giant rounds passes.
const GLOBAL_POOL_BUFFERS: usize = 64;

/// Process-wide ablation switch (see [`set_pooling`]). Defaults to on;
/// the `WILKINS_POOLING=0` environment variable disables it at startup
/// so spawned worker processes inherit the bench arm.
static POOLING: OnceLock<AtomicBool> = OnceLock::new();

/// Total payload bytes memcpy'd on the wire path (see [`note_copied`]).
static BYTES_COPIED: AtomicU64 = AtomicU64::new(0);

fn pooling_flag() -> &'static AtomicBool {
    POOLING.get_or_init(|| {
        let on = std::env::var("WILKINS_POOLING").map(|v| v != "0").unwrap_or(true);
        AtomicBool::new(on)
    })
}

/// Is the pooled/zero-copy wire plane enabled in this process?
pub fn pooling_enabled() -> bool {
    pooling_flag().load(Ordering::Relaxed)
}

/// Enable/disable the pooled wire plane process-wide (benchmark
/// ablation; prefer `Vol::set_pooling`, which routes here). Disabling
/// makes the transport take the historical owned-`Vec` path: frame
/// concatenation, owned chunk splits, `to_vec` at decode.
pub fn set_pooling(on: bool) {
    pooling_flag().store(on, Ordering::Relaxed);
}

/// Record `n` payload bytes memcpy'd on the wire path. Call sites are
/// the copy points themselves (encode fills, chunk splits, frame
/// concatenation, decode `to_vec`s, reassembly appends, hyperslab
/// fills) so `benches/wire.rs` can report bytes-copied-per-
/// byte-delivered without guessing.
#[inline]
pub fn note_copied(n: usize) {
    BYTES_COPIED.fetch_add(n as u64, Ordering::Relaxed);
}

/// Running total of [`note_copied`] bytes since process start.
pub fn bytes_copied_total() -> u64 {
    BYTES_COPIED.load(Ordering::Relaxed)
}

/// Shared state behind a [`BufPool`] (and behind the weak back-link
/// pooled [`Payload`]s carry so dropped payloads return their buffer).
struct PoolShared {
    bufs: Mutex<Vec<Vec<u8>>>,
    max_buffers: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_pooled: AtomicU64,
}

impl PoolShared {
    /// Largest single buffer the pool will park (64 MiB). Steady-state
    /// serve buffers (tens of MiB) recycle; a one-off giant reassembly
    /// is freed instead of pinning its peak size for the process
    /// lifetime — the same reclamation stance as the frame decoder's
    /// staging buffer, one layer down.
    const MAX_PARKED_CAPACITY: usize = 1 << 26;
    /// Byte budget across all parked buffers (256 MiB): a burst of
    /// many large rounds returns most of its memory to the allocator
    /// once the budget is full, instead of pinning
    /// buffers × MAX_PARKED_CAPACITY indefinitely.
    const MAX_PARKED_TOTAL: usize = 1 << 28;

    fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > Self::MAX_PARKED_CAPACITY {
            return;
        }
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap();
        let parked: usize = bufs.iter().map(Vec::capacity).sum();
        if bufs.len() < self.max_buffers
            && parked + buf.capacity() <= Self::MAX_PARKED_TOTAL
        {
            bufs.push(buf);
        }
    }
}

/// A bounded, thread-safe free list of reusable byte buffers.
///
/// `lease(cap)` hands back the most recently returned buffer (warm
/// caches) grown to at least `cap`, or a fresh allocation on a miss.
/// Buffers flow back either explicitly (`Lease` dropped unfinished)
/// or when the last [`Payload`] view over a finished lease drops.
pub struct BufPool {
    shared: Arc<PoolShared>,
}

impl BufPool {
    /// A pool keeping at most `max_buffers` idle buffers.
    pub fn new(max_buffers: usize) -> BufPool {
        BufPool {
            shared: Arc::new(PoolShared {
                bufs: Mutex::new(Vec::new()),
                max_buffers,
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                bytes_pooled: AtomicU64::new(0),
            }),
        }
    }

    /// Lease an empty buffer with capacity at least `cap`. Best-fit
    /// with an oversize guard: the smallest parked buffer that
    /// satisfies `cap` *without exceeding ~4× of it* is a *hit* (no
    /// allocation at all); a grossly oversized buffer is left parked
    /// for the size class it belongs to — a tiny request-frame lease
    /// must never hollow out the one big reply buffer and force the
    /// next big encode to allocate. With no fitting buffer, the
    /// largest under-sized one is grown (or a fresh one allocated)
    /// and the lease counts as a miss. Check [`Lease::was_hit`] to
    /// learn whether an allocation happened.
    pub fn lease(&self, cap: usize) -> Lease {
        let oversize = cap.saturating_mul(4).max(4096);
        let recycled = {
            let mut bufs = self.shared.bufs.lock().unwrap();
            let best = bufs
                .iter()
                .enumerate()
                .filter(|(_, b)| b.capacity() >= cap && b.capacity() <= oversize)
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .or_else(|| {
                    bufs.iter()
                        .enumerate()
                        .filter(|(_, b)| b.capacity() < cap)
                        .max_by_key(|(_, b)| b.capacity())
                        .map(|(i, _)| i)
                });
            best.map(|i| bufs.swap_remove(i))
        };
        let (mut buf, hit) = match recycled {
            Some(b) => {
                let fits = b.capacity() >= cap;
                (b, fits)
            }
            None => (Vec::new(), false),
        };
        if buf.capacity() < cap {
            buf.reserve_exact(cap - buf.len());
        }
        if hit {
            self.shared.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shared.misses.fetch_add(1, Ordering::Relaxed);
        }
        let leased_cap = buf.capacity();
        Lease { buf, shared: Some(Arc::clone(&self.shared)), hit, leased_cap }
    }

    /// Leases served from a recycled buffer since creation.
    pub fn hits(&self) -> u64 {
        self.shared.hits.load(Ordering::Relaxed)
    }

    /// Leases that had to allocate since creation.
    pub fn misses(&self) -> u64 {
        self.shared.misses.load(Ordering::Relaxed)
    }

    /// Total bytes finished into recycled (pool-hit) buffers.
    pub fn bytes_pooled(&self) -> u64 {
        self.shared.bytes_pooled.load(Ordering::Relaxed)
    }

    /// Idle buffers currently parked in the pool (tests/observability).
    pub fn idle(&self) -> usize {
        self.shared.bufs.lock().unwrap().len()
    }
}

/// The process-global buffer pool: transport pumps, chunk reassembly
/// and the lowfive encode paths all lease from here, so a handful of
/// steady-state buffers serve the whole process.
pub fn pool() -> &'static BufPool {
    static GLOBAL: OnceLock<BufPool> = OnceLock::new();
    GLOBAL.get_or_init(|| BufPool::new(GLOBAL_POOL_BUFFERS))
}

/// An exclusive, growable buffer checked out of a [`BufPool`] (or a
/// plain unpooled buffer behind the same interface — see
/// [`Lease::unpooled`]). Dereferences to its `Vec<u8>`; finish it
/// into a [`Payload`] to share it (a pooled buffer returns to its
/// pool when the last view drops), or just drop it to hand the
/// buffer straight back.
pub struct Lease {
    buf: Vec<u8>,
    shared: Option<Arc<PoolShared>>,
    hit: bool,
    /// Capacity at lease time: outgrowing it means a reallocation
    /// happened while encoding, which must not be reported as an
    /// allocation-free round.
    leased_cap: usize,
}

impl Lease {
    /// A plain `Vec`-backed lease with no pool attached (always a
    /// miss; the buffer is freed, not parked, when the payload
    /// drops). The ablation arm of the transport reassembles into
    /// these so the historical per-message allocation cost is really
    /// measured.
    pub fn unpooled(cap: usize) -> Lease {
        Lease { buf: Vec::with_capacity(cap), shared: None, hit: false, leased_cap: cap }
    }

    /// Did this lease recycle a pooled buffer (no allocation)?
    pub fn was_hit(&self) -> bool {
        self.hit
    }

    /// Did the buffer outgrow its leased capacity (a reallocation
    /// since lease time)?
    pub fn grew(&self) -> bool {
        self.buf.capacity() > self.leased_cap
    }

    /// Freeze the buffer into a shared [`Payload`] view of its full
    /// contents. Leases that were pool hits *and* never reallocated
    /// credit their final length to the pool's `bytes_pooled` meter —
    /// a hit that outgrew its buffer paid an allocation after all and
    /// must not read as allocation-free.
    pub fn finish(mut self) -> Payload {
        if self.hit && !self.grew() {
            if let Some(shared) = &self.shared {
                shared.bytes_pooled.fetch_add(self.buf.len() as u64, Ordering::Relaxed);
            }
        }
        // Taking the buffer leaves a zero-capacity carcass behind, so
        // the lease's own Drop returns nothing to the pool.
        let buf = std::mem::take(&mut self.buf);
        let len = buf.len();
        Payload {
            inner: Arc::new(PayloadInner {
                backing: Backing::Buf {
                    buf,
                    pool: self.shared.as_ref().map(Arc::downgrade),
                },
            }),
            off: 0,
            len,
        }
    }
}

impl Deref for Lease {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for Lease {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            shared.put(std::mem::take(&mut self.buf));
        }
    }
}

/// Borrowed-memory backing for a [`Payload`]: any region of immutable
/// bytes whose lifetime is managed by its owner rather than by a `Vec`
/// (a mapped shared-memory segment, for example). Dropping the last
/// `Payload` view drops the region, which is where owners hook their
/// reclamation (the shm plane sends its segment ack from that drop).
pub trait ByteRegion: Send + Sync {
    /// The full region this payload views.
    fn as_bytes(&self) -> &[u8];
}

/// The shared backing store of one or more [`Payload`] views.
enum Backing {
    /// An owned `Vec`, optionally on loan from a [`BufPool`].
    Buf {
        buf: Vec<u8>,
        /// Set for pooled buffers: the last view's drop returns the buffer.
        pool: Option<Weak<PoolShared>>,
    },
    /// Externally owned memory (e.g. a shm mapping).
    Region(Arc<dyn ByteRegion>),
}

struct PayloadInner {
    backing: Backing,
}

impl PayloadInner {
    fn bytes(&self) -> &[u8] {
        match &self.backing {
            Backing::Buf { buf, .. } => buf,
            Backing::Region(r) => r.as_bytes(),
        }
    }
}

impl Drop for PayloadInner {
    fn drop(&mut self) {
        if let Backing::Buf { buf, pool: Some(pool) } = &mut self.backing {
            if let Some(pool) = pool.upgrade() {
                pool.put(std::mem::take(buf));
            }
        }
    }
}

/// A refcounted, sliceable view of immutable bytes — the unit of
/// transfer of the wire hot path. Cloning and [`Payload::slice`]-ing
/// are O(1) and allocation-free; the backing buffer lives until the
/// last view drops (and returns to its [`BufPool`] if it came from
/// one). `Deref`s to `[u8]`, so existing `&[u8]` consumers work
/// unchanged.
#[derive(Clone)]
pub struct Payload {
    inner: Arc<PayloadInner>,
    off: usize,
    len: usize,
}

impl Payload {
    /// An empty payload (no backing allocation is shared).
    pub fn empty() -> Payload {
        Payload::from(Vec::new())
    }

    /// Copy `bytes` into a fresh unpooled payload.
    pub fn copy_from_slice(bytes: &[u8]) -> Payload {
        Payload::from(bytes.to_vec())
    }

    /// Length of this view in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is this view empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A payload viewing externally owned memory (a shm mapping, a
    /// static table): O(1), no copy. The view spans the whole region;
    /// [`Payload::slice`] narrows it as usual. The region drops — and
    /// runs its owner's reclamation — when the last view drops.
    pub fn from_region(region: Arc<dyn ByteRegion>) -> Payload {
        let len = region.as_bytes().len();
        Payload {
            inner: Arc::new(PayloadInner { backing: Backing::Region(region) }),
            off: 0,
            len,
        }
    }

    /// The viewed bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.inner.bytes()[self.off..self.off + self.len]
    }

    /// A sub-view of `range` (relative to this view): O(1), shares the
    /// backing buffer. Errors on any out-of-bounds or inverted range —
    /// wire offsets come off the network, so this is a checked seam,
    /// not a panic.
    pub fn slice(&self, range: Range<usize>) -> Result<Payload> {
        if range.start > range.end || range.end > self.len {
            return Err(WilkinsError::Comm(format!(
                "payload slice {}..{} out of bounds (len {})",
                range.start, range.end, self.len
            )));
        }
        Ok(Payload {
            inner: Arc::clone(&self.inner),
            off: self.off + range.start,
            len: range.end - range.start,
        })
    }

    /// Extract owned bytes. Zero-copy when this is the only view of a
    /// whole unpooled buffer; otherwise one copy (a stolen pooled
    /// buffer would never return to its pool, so pooled payloads
    /// always copy out).
    pub fn into_vec(self) -> Vec<u8> {
        let whole_plain_vec = matches!(
            &self.inner.backing,
            Backing::Buf { buf, pool: None } if self.off == 0 && self.len == buf.len()
        );
        if whole_plain_vec {
            match Arc::try_unwrap(self.inner) {
                // Plain Vec backing, sole view: take the buffer out and
                // skip the copy (`pool` is None, so the Drop that runs
                // on the emptied inner has nothing to return).
                Ok(mut inner) => {
                    if let Backing::Buf { buf, .. } = &mut inner.backing {
                        return std::mem::take(buf);
                    }
                    unreachable!("backing changed under into_vec");
                }
                Err(shared) => return shared.bytes().to_vec(),
            }
        }
        self.as_slice().to_vec()
    }
}

impl From<Vec<u8>> for Payload {
    fn from(buf: Vec<u8>) -> Payload {
        let len = buf.len();
        Payload {
            inner: Arc::new(PayloadInner { backing: Backing::Buf { buf, pool: None } }),
            off: 0,
            len,
        }
    }
}

impl Deref for Payload {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Payload {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Payload({} bytes @ {})", self.len, self.off)
    }
}

impl PartialEq for Payload {
    fn eq(&self, other: &Payload) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Payload {}

impl PartialEq<[u8]> for Payload {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Payload {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl<const N: usize> PartialEq<[u8; N]> for Payload {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Payload {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for Payload {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Payload> for Vec<u8> {
    fn eq(&self, other: &Payload) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_the_same_allocation_across_rounds() {
        let pool = BufPool::new(4);
        let mut lease = pool.lease(1024);
        lease.extend_from_slice(&[7u8; 1024]);
        let first_ptr = lease.as_ptr();
        assert!(!lease.was_hit(), "first lease must be a miss");
        let payload = lease.finish();
        assert_eq!(payload.len(), 1024);
        drop(payload); // last view: buffer returns to the pool

        // Steady state: the very same allocation comes back.
        let lease2 = pool.lease(512);
        assert!(lease2.was_hit(), "second lease must be a pool hit");
        assert_eq!(lease2.as_ptr(), first_ptr, "allocation must be recycled");
        assert_eq!(pool.hits(), 1);
        assert_eq!(pool.misses(), 1);
    }

    #[test]
    fn outgrown_lease_is_not_an_allocation_free_hit() {
        let pool = BufPool::new(4);
        drop(pool.lease(1024).finish()); // park a 1 KiB buffer
        let mut lease = pool.lease(16);
        assert!(lease.was_hit());
        assert!(!lease.grew());
        lease.extend_from_slice(&[1u8; 100_000]); // realloc past the lease
        assert!(lease.grew(), "outgrowing the leased capacity must be visible");
        let before = pool.bytes_pooled();
        drop(lease.finish());
        assert_eq!(
            pool.bytes_pooled(),
            before,
            "a hit that reallocated must not credit bytes_pooled"
        );
    }

    #[test]
    fn unpooled_lease_never_hits_and_parks_nothing() {
        let pool = BufPool::new(4);
        let mut lease = Lease::unpooled(64);
        assert!(!lease.was_hit());
        lease.extend_from_slice(b"abc");
        let p = lease.finish();
        assert_eq!(p, b"abc");
        drop(p);
        assert_eq!(pool.idle(), 0, "unpooled buffers are freed, not parked");
    }

    #[test]
    fn oversized_buffers_are_freed_not_parked() {
        let pool = BufPool::new(4);
        let lease = pool.lease(PoolShared::MAX_PARKED_CAPACITY + 1);
        drop(lease);
        assert_eq!(pool.idle(), 0, "a giant buffer must not pin its peak size");
    }

    #[test]
    fn unfinished_lease_returns_straight_to_the_pool() {
        let pool = BufPool::new(4);
        let mut lease = pool.lease(64);
        lease.push(1);
        drop(lease);
        assert_eq!(pool.idle(), 1);
        assert!(pool.lease(8).was_hit());
    }

    #[test]
    fn pooled_buffer_outlives_slices_until_last_view() {
        let pool = BufPool::new(4);
        let mut lease = pool.lease(16);
        lease.extend_from_slice(b"0123456789");
        let whole = lease.finish();
        let a = whole.slice(0..4).unwrap();
        let b = whole.slice(4..10).unwrap();
        drop(whole);
        assert_eq!(pool.idle(), 0, "buffer still referenced by slices");
        assert_eq!(&a[..], b"0123");
        drop(a);
        assert_eq!(pool.idle(), 0);
        assert_eq!(&b[..], b"456789");
        drop(b);
        assert_eq!(pool.idle(), 1, "last view returns the buffer");
    }

    #[test]
    fn slice_bounds_are_checked() {
        let p = Payload::from(vec![1, 2, 3, 4]);
        assert!(p.slice(0..5).is_err(), "end past len");
        assert!(p.slice(5..5).is_err(), "start past len");
        #[allow(clippy::reversed_empty_ranges)]
        {
            assert!(p.slice(3..2).is_err(), "inverted range");
        }
        let s = p.slice(1..3).unwrap();
        assert_eq!(s, vec![2u8, 3]);
        // Sub-slicing is relative to the view, and re-checked.
        assert_eq!(s.slice(1..2).unwrap(), vec![3u8]);
        assert!(s.slice(0..3).is_err());
    }

    #[test]
    fn into_vec_roundtrips() {
        let p = Payload::from(vec![9u8, 8, 7]);
        assert_eq!(p.clone().into_vec(), vec![9, 8, 7]);
        assert_eq!(p.slice(1..3).unwrap().into_vec(), vec![8, 7]);
        assert_eq!(Payload::empty().into_vec(), Vec::<u8>::new());
    }
}
