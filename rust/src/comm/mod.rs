//! Virtual MPI substrate (S4).
//!
//! The paper runs workflow tasks as MPI processes on the Bebop cluster;
//! this testbed has neither MPI nor a cluster, so Wilkins ships a
//! process substrate with MPI semantics: every *rank is an OS thread*,
//! point-to-point messages move real bytes through per-rank mailboxes,
//! and communicators can be split into the *restricted worlds* the
//! paper's execution model (Sec. 3.5) presents to task codes.
//!
//! The semantics the experiments rely on are reproduced exactly:
//! blocking sends/recvs serialize transfers (fan-out grows linearly,
//! Fig. 7), barriers really rendezvous, and probes let a producer ask
//! "is any consumer ready?" without blocking (the *latest* flow-control
//! strategy, Sec. 3.6).
//!
//! Addressing: every rank has a *global* id in the SPMD world. A
//! [`Comm`] is an ordered set of global ranks plus this thread's
//! position in it; an intercommunicator ([`InterComm`]) adds a remote
//! group. Message matching is on (communicator id, tag, source).

// The pooled-buffer layer is documented surface (DESIGN.md copy-
// discipline table): every public item must carry docs or the
// ci/check.sh doc/clippy gates fail.
#[warn(missing_docs)]
pub mod buf;
mod collectives;
mod intercomm;
#[warn(missing_docs)]
pub mod replay;
pub mod wire;

pub use buf::{BufPool, Payload};
pub use intercomm::InterComm;
pub use replay::{ReplayTransport, ReplayWorld};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Result, WilkinsError};

/// Wildcard source for [`Comm::recv_any`] / probes.
pub const ANY_SOURCE: usize = usize::MAX;

/// Default receive timeout: generous enough for loaded CI machines,
/// short enough that deadlocked tests fail rather than hang forever.
pub const RECV_TIMEOUT: Duration = Duration::from_secs(120);

#[derive(Debug)]
pub(crate) struct Envelope {
    pub(crate) src_global: usize,
    pub(crate) comm_id: u64,
    pub(crate) tag: u64,
    /// Refcounted view: local deliveries and the socket pump hand the
    /// same bytes from sender to receiver without an owning copy.
    pub(crate) payload: Payload,
}

#[derive(Default)]
pub(crate) struct Mailbox {
    pub(crate) queue: Mutex<VecDeque<Envelope>>,
    pub(crate) cv: Condvar,
}

/// The per-rank inbox array, shared between the receive path (ranks
/// block on their mailbox condvar) and whatever [`Transport`] delivers
/// into it. In distributed worlds only the locally-hosted ranks' boxes
/// are ever touched; the rest exist so global-rank indexing stays
/// uniform.
pub(crate) struct Mailboxes {
    boxes: Vec<Mailbox>,
}

impl Mailboxes {
    pub(crate) fn new(size: usize) -> Mailboxes {
        Mailboxes { boxes: (0..size).map(|_| Mailbox::default()).collect() }
    }

    pub(crate) fn at(&self, rank: usize) -> &Mailbox {
        &self.boxes[rank]
    }

    /// Deliver an envelope into `dst`'s inbox and wake its waiters.
    pub(crate) fn push(&self, dst: usize, env: Envelope) {
        let mb = &self.boxes[dst];
        mb.queue.lock().unwrap().push_back(env);
        mb.cv.notify_all();
    }
}

/// Where a sent message goes: the seam between the communicator API
/// and the execution substrate. The in-process backend
/// ([`MemoryTransport`]) pushes straight into the destination mailbox
/// — today's single-process behavior, bit for bit. The socket backend
/// (`net::SocketTransport`) does the same for locally-hosted ranks and
/// frames everything else onto the peer process that hosts the
/// destination.
pub trait Transport: Send + Sync {
    /// Deliver `payload` to global rank `dst_global`'s inbox, wherever
    /// that inbox lives. The payload is a refcounted view: in-process
    /// backends hand it over as-is (zero copies), socket backends
    /// write its bytes onto the peer link (vectored, no staging
    /// concatenation when pooling is enabled).
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    );

    /// Orderly teardown (flush and close sockets); a no-op in-process.
    fn shutdown(&self) {}

    /// A rank on this transport is about to block waiting for inbound
    /// data: backends that stage small outbound frames for coalescing
    /// should push them to the kernel now (the peer we are about to
    /// wait on may itself be blocked on one of those tiny frames —
    /// credit grants, flow `Done`s). A no-op for unbuffered backends.
    fn flush_hint(&self) {}

    /// Does `dst_global`'s inbox live in this OS process? Decides
    /// whether a serve may take the zero-copy shared-snapshot path
    /// (sharing an `Arc` only works inside one address space). The
    /// in-memory backend hosts every rank; the socket backend answers
    /// per its rank-ownership map. Defaults to `false` — a backend
    /// that forgets to override merely loses the optimization, instead
    /// of shipping un-resolvable registry tokens across processes.
    fn is_local(&self, _dst_global: usize) -> bool {
        false
    }
}

/// The in-process backend: every rank is a local thread, delivery is a
/// mailbox push under the destination's lock.
pub struct MemoryTransport {
    mailboxes: Arc<Mailboxes>,
}

impl MemoryTransport {
    pub(crate) fn new(mailboxes: Arc<Mailboxes>) -> MemoryTransport {
        MemoryTransport { mailboxes }
    }
}

impl Transport for MemoryTransport {
    fn deliver(
        &self,
        dst_global: usize,
        src_global: usize,
        comm_id: u64,
        tag: u64,
        payload: Payload,
    ) {
        self.mailboxes.push(dst_global, Envelope { src_global, comm_id, tag, payload });
    }

    fn is_local(&self, _dst_global: usize) -> bool {
        true // every rank is a thread of this process
    }
}

pub(crate) struct WorldState {
    size: usize,
    mailboxes: Arc<Mailboxes>,
    transport: Arc<dyn Transport>,
    next_comm_id: AtomicU64,
    /// Bytes pushed through send() — observability for the benches.
    bytes_sent: AtomicU64,
    msgs_sent: AtomicU64,
}

/// The SPMD world: create once, then [`World::comm_world`] per rank.
#[derive(Clone)]
pub struct World {
    state: Arc<WorldState>,
}

impl World {
    pub fn new(size: usize) -> World {
        let mailboxes = Arc::new(Mailboxes::new(size));
        let transport = Arc::new(MemoryTransport::new(Arc::clone(&mailboxes)));
        World::with_transport(size, mailboxes, transport)
    }

    /// Build a world over an explicit transport (the multi-process
    /// substrate in `net::` wires a [`Mailboxes`] it also hands to its
    /// socket pump threads). `World::new` is this with the in-memory
    /// backend.
    pub(crate) fn with_transport(
        size: usize,
        mailboxes: Arc<Mailboxes>,
        transport: Arc<dyn Transport>,
    ) -> World {
        assert!(size > 0, "world size must be positive");
        World {
            state: Arc::new(WorldState {
                size,
                mailboxes,
                transport,
                next_comm_id: AtomicU64::new(1),
                bytes_sent: AtomicU64::new(0),
                msgs_sent: AtomicU64::new(0),
            }),
        }
    }

    /// Orderly transport teardown (no-op for in-memory worlds).
    pub fn shutdown_transport(&self) {
        self.state.transport.shutdown();
    }

    pub fn size(&self) -> usize {
        self.state.size
    }

    /// The full-world communicator handle for a given global rank
    /// (comm id 0 == MPI_COMM_WORLD).
    pub fn comm_world(&self, global_rank: usize) -> Comm {
        assert!(global_rank < self.state.size);
        Comm {
            world: Arc::clone(&self.state),
            id: 0,
            ranks: Arc::new((0..self.state.size).collect()),
            my_index: global_rank,
        }
    }

    /// Allocate a fresh communicator id (coordinator-side; ids must be
    /// allocated identically across ranks, so the coordinator does it
    /// once before launch).
    pub fn alloc_comm_id(&self) -> u64 {
        self.state.next_comm_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Build a communicator over `ranks` (global ids) for the rank at
    /// `my_index` with a pre-allocated id. Used by the coordinator to
    /// carve restricted worlds deterministically.
    pub fn comm_from_ranks(&self, id: u64, ranks: &[usize], my_index: usize) -> Comm {
        assert!(my_index < ranks.len());
        Comm {
            world: Arc::clone(&self.state),
            id,
            ranks: Arc::new(ranks.to_vec()),
            my_index,
        }
    }

    /// Total payload bytes sent since creation.
    pub fn bytes_sent(&self) -> u64 {
        self.state.bytes_sent.load(Ordering::Relaxed)
    }

    pub fn msgs_sent(&self) -> u64 {
        self.state.msgs_sent.load(Ordering::Relaxed)
    }
}

/// A communicator: ordered global ranks + our position. Clone is cheap.
#[derive(Clone)]
pub struct Comm {
    world: Arc<WorldState>,
    id: u64,
    ranks: Arc<Vec<usize>>,
    my_index: usize,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.my_index
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn global_rank(&self) -> usize {
        self.ranks[self.my_index]
    }

    pub fn global_of(&self, local: usize) -> usize {
        self.ranks[local]
    }

    fn local_of_global(&self, global: usize) -> Option<usize> {
        self.ranks.iter().position(|&g| g == global)
    }

    /// Blocking send of `data` to local rank `dst` with `tag`.
    ///
    /// Buffered-eager semantics (MPI_Send with an unbounded buffer):
    /// the call never blocks, but the *bytes are copied now*, so large
    /// fan-outs pay the full serial copy cost like the paper's runs.
    pub fn send(&self, dst: usize, tag: u64, data: &[u8]) {
        self.send_on(self.id, dst, tag, data)
    }

    /// Owned-buffer send: moves the payload into the mailbox without
    /// copying. Preferred on reply paths that just built the buffer
    /// (§Perf iteration 1: removes one full payload copy per serve).
    /// Accepts anything convertible into a [`Payload`] — a `Vec<u8>`,
    /// or a pooled/sliced payload view (no copy either way).
    pub fn send_owned(&self, dst: usize, tag: u64, data: impl Into<Payload>) {
        let dst_global = self.ranks[dst];
        self.send_global_owned(self.id, dst_global, tag, data.into());
    }

    fn send_on(&self, comm_id: u64, dst: usize, tag: u64, data: &[u8]) {
        let dst_global = self.ranks[dst];
        self.send_global(comm_id, dst_global, tag, data);
    }

    pub(crate) fn send_global(&self, comm_id: u64, dst_global: usize, tag: u64, data: &[u8]) {
        self.send_global_owned(comm_id, dst_global, tag, Payload::copy_from_slice(data));
    }

    pub(crate) fn send_global_owned(
        &self,
        comm_id: u64,
        dst_global: usize,
        tag: u64,
        data: Payload,
    ) {
        self.world.bytes_sent.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.world.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.world
            .transport
            .deliver(dst_global, self.global_rank(), comm_id, tag, data);
    }

    /// Blocking receive from local rank `src` (or [`ANY_SOURCE`]).
    /// Returns (source local rank, payload). The payload is a
    /// refcounted view of the sender's bytes (or of the pooled
    /// receive buffer on socket transports) — call
    /// [`Payload::into_vec`] if owned bytes are really needed.
    pub fn recv(&self, src: usize, tag: u64) -> Result<(usize, Payload)> {
        self.recv_timeout(src, tag, RECV_TIMEOUT)
    }

    pub fn recv_any(&self, tag: u64) -> Result<(usize, Payload)> {
        self.recv_timeout(ANY_SOURCE, tag, RECV_TIMEOUT)
    }

    pub fn recv_timeout(
        &self,
        src: usize,
        tag: u64,
        timeout: Duration,
    ) -> Result<(usize, Payload)> {
        let matcher = |e: &Envelope| {
            e.comm_id == self.id
                && e.tag == tag
                && (src == ANY_SOURCE
                    || self.local_of_global(e.src_global) == Some(src))
        };
        let env = self.recv_matching(matcher, timeout)?;
        let src_local = self
            .local_of_global(env.src_global)
            .ok_or_else(|| WilkinsError::Comm("message from rank outside comm".into()))?;
        Ok((src_local, env.payload))
    }

    /// Non-blocking receive: pop the first queued envelope the matcher
    /// accepts, `None` when nothing matches right now. The flow-control
    /// pump uses this to drain available requests without committing
    /// to a blocking wait.
    pub(crate) fn try_recv_matching<F>(&self, matcher: F) -> Option<Envelope>
    where
        F: Fn(&Envelope) -> bool,
    {
        self.world.transport.flush_hint();
        let mb = self.world.mailboxes.at(self.global_rank());
        let mut queue = mb.queue.lock().unwrap();
        let idx = queue.iter().position(matcher)?;
        queue.remove(idx)
    }

    pub(crate) fn recv_matching<F>(&self, matcher: F, timeout: Duration) -> Result<Envelope>
    where
        F: Fn(&Envelope) -> bool,
    {
        // About to block: anything we staged may be exactly what our
        // counterpart needs before it can send what we wait for.
        self.world.transport.flush_hint();
        let mb = self.world.mailboxes.at(self.global_rank());
        let deadline = Instant::now() + timeout;
        let mut queue = mb.queue.lock().unwrap();
        loop {
            if let Some(idx) = queue.iter().position(&matcher) {
                return Ok(queue.remove(idx).unwrap());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(WilkinsError::Comm(format!(
                    "recv timeout on comm {} at global rank {}",
                    self.id,
                    self.global_rank()
                )));
            }
            let (q, res) = mb.cv.wait_timeout(queue, deadline - now).unwrap();
            queue = q;
            let _ = res;
        }
    }

    /// Non-blocking probe: is a matching message waiting?
    pub fn iprobe(&self, src: usize, tag: u64) -> bool {
        self.world.transport.flush_hint();
        let mb = self.world.mailboxes.at(self.global_rank());
        let queue = mb.queue.lock().unwrap();
        queue.iter().any(|e| {
            e.comm_id == self.id
                && e.tag == tag
                && (src == ANY_SOURCE
                    || self.local_of_global(e.src_global) == Some(src))
        })
    }

    /// Derive a sub-communicator deterministically (coordinator-side):
    /// `id` must be identical on all members; `members` are local ranks
    /// of `self` in the new comm's order.
    pub fn subset(&self, id: u64, members: &[usize]) -> Option<Comm> {
        let my_pos = members.iter().position(|&m| m == self.my_index)?;
        let ranks: Vec<usize> = members.iter().map(|&m| self.ranks[m]).collect();
        Some(Comm {
            world: Arc::clone(&self.world),
            id,
            ranks: Arc::new(ranks),
            my_index: my_pos,
        })
    }

    /// Is global rank `global`'s mailbox hosted in this process?
    /// (Zero-copy eligibility; see [`Transport::is_local`].)
    pub(crate) fn global_is_local(&self, global: usize) -> bool {
        self.world.transport.is_local(global)
    }

    pub(crate) fn world_state(&self) -> &Arc<WorldState> {
        &self.world
    }
}

#[cfg(test)]
mod tests;
