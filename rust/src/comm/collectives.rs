//! Collectives over the p2p substrate: barrier, bcast, gather,
//! allgather, reductions. All are built from send/recv with reserved
//! high tags so they never collide with user traffic.

use super::{Comm, Payload, Result};

/// Tag space reserved for collectives (user tags must stay below).
pub const COLL_TAG_BASE: u64 = u64::MAX - 16;
const TAG_BARRIER: u64 = COLL_TAG_BASE;
const TAG_BCAST: u64 = COLL_TAG_BASE + 1;
const TAG_GATHER: u64 = COLL_TAG_BASE + 2;
const TAG_REDUCE: u64 = COLL_TAG_BASE + 3;

impl Comm {
    /// Rendezvous barrier: fan-in to rank 0, fan-out release.
    pub fn barrier(&self) -> Result<()> {
        if self.size() == 1 {
            return Ok(());
        }
        if self.rank() == 0 {
            // Per-source receives: a fast rank's *next* barrier message
            // must not release the current one early.
            for r in 1..self.size() {
                self.recv(r, TAG_BARRIER)?;
            }
            for r in 1..self.size() {
                self.send(r, TAG_BARRIER, &[]);
            }
        } else {
            self.send(0, TAG_BARRIER, &[]);
            self.recv(0, TAG_BARRIER)?;
        }
        Ok(())
    }

    /// Broadcast `data` from `root`; returns the received bytes on all
    /// ranks (the root gets its own copy back). The sends share one
    /// refcounted payload, so an N-way fan-out copies the bytes once,
    /// not N times.
    pub fn bcast(&self, root: usize, data: Option<&[u8]>) -> Result<Payload> {
        if self.size() == 1 {
            return Ok(Payload::copy_from_slice(data.unwrap_or(&[])));
        }
        if self.rank() == root {
            let payload = Payload::copy_from_slice(data.expect("bcast root must supply data"));
            for r in 0..self.size() {
                if r != root {
                    self.send_owned(r, TAG_BCAST, payload.clone());
                }
            }
            Ok(payload)
        } else {
            Ok(self.recv(root, TAG_BCAST)?.1)
        }
    }

    /// Gather every rank's bytes at `root`; Some(vec indexed by rank)
    /// at the root, None elsewhere.
    pub fn gather(&self, root: usize, data: &[u8]) -> Result<Option<Vec<Payload>>> {
        if self.rank() == root {
            let mut out: Vec<Payload> = vec![Payload::empty(); self.size()];
            out[root] = Payload::copy_from_slice(data);
            // Per-source receives keep consecutive gathers from mixing
            // (recv_any could consume a racing rank's next-gather msg).
            for r in 0..self.size() {
                if r == root {
                    continue;
                }
                let (_, bytes) = self.recv(r, TAG_GATHER)?;
                out[r] = bytes;
            }
            Ok(Some(out))
        } else {
            self.send(root, TAG_GATHER, data);
            Ok(None)
        }
    }

    /// All ranks end up with every rank's contribution. Each returned
    /// part is a zero-copy slice of the one broadcast buffer.
    pub fn allgather(&self, data: &[u8]) -> Result<Vec<Payload>> {
        let gathered = self.gather(0, data)?;
        let packed = match gathered {
            Some(parts) => {
                let mut w = super::wire::Writer::new();
                w.put_u64(parts.len() as u64);
                for p in &parts {
                    w.put_bytes(p);
                }
                Some(w.into_vec())
            }
            None => None,
        };
        let bytes = self.bcast(0, packed.as_deref())?;
        let mut r = super::wire::Reader::new(&bytes);
        let n = r.get_u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(r.get_bytes_sliced(&bytes)?);
        }
        Ok(out)
    }

    /// Sum-allreduce for u64.
    pub fn allreduce_sum_u64(&self, value: u64) -> Result<u64> {
        let parts = self.reduce_parts(value.to_le_bytes().to_vec())?;
        let total: u64 = parts
            .iter()
            .map(|b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .sum();
        Ok(total)
    }

    /// Sum-allreduce for f64.
    pub fn allreduce_sum_f64(&self, value: f64) -> Result<f64> {
        let parts = self.reduce_parts(value.to_le_bytes().to_vec())?;
        let total: f64 = parts
            .iter()
            .map(|b| f64::from_le_bytes(b[..8].try_into().unwrap()))
            .sum();
        Ok(total)
    }

    /// Max-allreduce for u64 (used for "any rank saw X" style flags).
    pub fn allreduce_max_u64(&self, value: u64) -> Result<u64> {
        let parts = self.reduce_parts(value.to_le_bytes().to_vec())?;
        Ok(parts
            .iter()
            .map(|b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .max()
            .unwrap_or(value))
    }

    fn reduce_parts(&self, mine: Vec<u8>) -> Result<Vec<Payload>> {
        if self.size() == 1 {
            return Ok(vec![Payload::from(mine)]);
        }
        // Gather to 0, bcast the raw parts back (tag distinct from
        // gather/bcast so concurrent collectives of different kinds on
        // the same comm cannot interleave).
        if self.rank() == 0 {
            let mut parts: Vec<Payload> = vec![Payload::empty(); self.size()];
            parts[0] = Payload::from(mine);
            for r in 1..self.size() {
                let (_, bytes) = self.recv(r, TAG_REDUCE)?;
                parts[r] = bytes;
            }
            let mut w = super::wire::Writer::new();
            w.put_u64(parts.len() as u64);
            for p in &parts {
                w.put_bytes(p);
            }
            let packed = w.into_vec();
            for r in 1..self.size() {
                self.send(r, TAG_REDUCE, &packed);
            }
            Ok(parts)
        } else {
            self.send(0, TAG_REDUCE, &mine);
            let (_, bytes) = self.recv(0, TAG_REDUCE)?;
            let mut r = super::wire::Reader::new(&bytes);
            let n = r.get_u64()? as usize;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(r.get_bytes_sliced(&bytes)?);
            }
            Ok(out)
        }
    }
}
