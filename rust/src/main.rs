//! `wilkins` — the workflow launcher CLI (the `wilkins-master` entry
//! point of the paper).
//!
//! Usage:
//!   wilkins run <config.yaml> [--time-scale S] [--workdir DIR]
//!                             [--artifacts DIR] [--gantt FILE.csv]
//!                             [--trace FILE.json] [--json FILE.json]
//!   wilkins up <config-or-spec.yaml> [--workers N] [...]
//!   wilkins ensemble <spec.yaml> [--budget N] [--policy P] [--dry-run] [...]
//!   wilkins worker --connect ADDR --id K
//!   wilkins replay <trace-dir> [--against FILE.json] [--json FILE.json]
//!   wilkins validate <config.yaml>
//!   wilkins graph <config.yaml>
//!   wilkins list-tasks
//!   wilkins help

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;

use wilkins::config::WorkflowConfig;
use wilkins::ensemble::{Ensemble, Placement, Policy};
use wilkins::graph::WorkflowGraph;
use wilkins::net::{self, WorkerPool};
use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const HELP: &str = "\
wilkins — HPC in situ workflows made easy (paper reproduction)

USAGE:
    wilkins run <config.yaml> [OPTIONS]   launch a workflow (one process)
    wilkins up <config-or-spec.yaml> [OPTIONS]
                                          launch across worker PROCESSES:
                                          a workflow runs one distributed
                                          world (process-per-node); an
                                          ensemble spec fans instances out
                                          process-per-instance
    wilkins ensemble <spec.yaml> [OPTIONS]
                                          co-schedule N workflow instances
    wilkins worker --connect ADDR --id K  join a pool (spawned by `up`)
    wilkins replay <trace-dir> [OPTIONS]  re-run a recorded multi-process
                                          run from its .wtap wire logs,
                                          deterministically, in one
                                          process, and diff the report
    wilkins validate <config.yaml>        parse + validate only
    wilkins graph <config.yaml>           print the expanded task graph
    wilkins list-tasks                    list built-in task codes
    wilkins help                          this text

OPTIONS (run):
    --time-scale S     wall-seconds per emulated paper-second (default 1)
    --workdir DIR      directory for file-mode transports
    --artifacts DIR    AOT artifacts dir (default ./artifacts or
                       $WILKINS_ARTIFACTS); only workflows using the
                       science payloads need it
    --gantt FILE.csv   write the span trace as CSV after the run
    --trace FILE.json  write a merged Chrome trace (chrome://tracing /
                       Perfetto) after the run
    --json FILE.json   write the machine-readable run report

OPTIONS (up, in addition to the run options):
    --workers N        worker processes in the pool (default: host
                       parallelism, capped at the node/instance count)
    --budget N, --policy P     honored for ensemble specs
    (--trace merges every worker's spans onto the coordinator clock,
     one process track per worker, with flow arrows for cross-worker
     serves; set WILKINS_TRACE_WIRE=1 to also log every wire frame to
     a per-process .wtap file — see docs/observability.md)

OPTIONS (ensemble, in addition to the run options):
    --budget N         override the spec's max_ranks rank budget
    --policy P         override the spec's policy: fifo | round-robin
    --workers N        pool width when the spec asks for
                       placement: process-per-instance
    --dry-run          print the co-scheduler's packing plan and exit
    (--gantt writes the merged per-instance trace; --trace additionally
     paints WorkerLost/Requeue markers; one shared AOT engine serves
     every instance)

OPTIONS (replay):
    --against FILE     recorded report JSON to diff against (default:
                       <trace-dir>/report.json when present)
    --json FILE.json   write the replayed report JSON
    (record the run first: WILKINS_TRACE_WIRE=full
     WILKINS_TRACE_DIR=<trace-dir> wilkins up ... --json
     <trace-dir>/report.json — see docs/replay.md)
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> wilkins::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("up") => cmd_up(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("replay") => cmd_replay(&args[1..]),
        Some("ensemble") => cmd_ensemble(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("graph") => cmd_graph(&args[1..]),
        Some("list-tasks") => {
            for name in builtin_registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(wilkins::WilkinsError::Config(format!(
            "unknown command {other:?}; try `wilkins help`"
        ))),
    }
}

fn take_opt(args: &mut Vec<String>, name: &str) -> Option<String> {
    let idx = args.iter().position(|a| a == name)?;
    if idx + 1 >= args.len() {
        return None;
    }
    let v = args.remove(idx + 1);
    args.remove(idx);
    Some(v)
}

fn take_flag(args: &mut Vec<String>, name: &str) -> bool {
    match args.iter().position(|a| a == name) {
        Some(idx) => {
            args.remove(idx);
            true
        }
        None => false,
    }
}

fn take_usize_opt(args: &mut Vec<String>, name: &str) -> wilkins::Result<Option<usize>> {
    take_opt(args, name)
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|e| wilkins::WilkinsError::Config(format!("bad {name}: {e}")))
}

/// Pool-width default: the host's parallelism (this substrate exists
/// to use those cores).
fn host_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

fn config_path(args: &[String]) -> wilkins::Result<PathBuf> {
    args.iter()
        .find(|a| !a.starts_with("--"))
        .map(PathBuf::from)
        .ok_or_else(|| wilkins::WilkinsError::Config("missing <config.yaml>".into()))
}

fn cmd_validate(args: &[String]) -> wilkins::Result<()> {
    let path = config_path(args)?;
    let cfg = WorkflowConfig::from_yaml_str(&std::fs::read_to_string(&path)?)?;
    let graph = WorkflowGraph::build(&cfg)?;
    println!(
        "OK: {} tasks, {} instances, {} channels, {} ranks",
        cfg.tasks.len(),
        graph.nodes.len(),
        graph.channels.len(),
        graph.total_ranks
    );
    Ok(())
}

fn cmd_graph(args: &[String]) -> wilkins::Result<()> {
    let path = config_path(args)?;
    let cfg = WorkflowConfig::from_yaml_str(&std::fs::read_to_string(&path)?)?;
    print!("{}", WorkflowGraph::build(&cfg)?.describe());
    Ok(())
}

/// The options `run` and `ensemble` share.
struct RunOpts {
    time_scale: f64,
    workdir: Option<PathBuf>,
    artifacts: PathBuf,
    gantt: Option<PathBuf>,
    trace: Option<PathBuf>,
    json: Option<PathBuf>,
}

fn take_run_opts(args: &mut Vec<String>) -> wilkins::Result<RunOpts> {
    Ok(RunOpts {
        time_scale: take_opt(args, "--time-scale")
            .map(|s| s.parse::<f64>())
            .transpose()
            .map_err(|e| wilkins::WilkinsError::Config(format!("bad --time-scale: {e}")))?
            .unwrap_or(1.0),
        workdir: take_opt(args, "--workdir").map(PathBuf::from),
        artifacts: take_opt(args, "--artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(Engine::default_dir),
        gantt: take_opt(args, "--gantt").map(PathBuf::from),
        trace: take_opt(args, "--trace").map(PathBuf::from),
        json: take_opt(args, "--json").map(PathBuf::from),
    })
}

/// Write an exporter artifact and tell the user where it landed.
fn write_artifact(path: &Path, what: &str, content: &str) -> wilkins::Result<()> {
    std::fs::write(path, content)?;
    println!("{what} written to {}", path.display());
    Ok(())
}

/// Chrome trace for a single-process run: one process track, one
/// thread per rank, every span on the run clock (no offsets).
fn chrome_of_run(spans: &[wilkins::metrics::Span]) -> String {
    let mut t = wilkins::obs::ChromeTrace::new();
    t.process_name(0, "wilkins run");
    let mut ranks: Vec<usize> = spans.iter().map(|s| s.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in ranks {
        t.thread_name(0, r as u64, &format!("rank {r}"));
    }
    for s in spans {
        t.add_span(0, s, 0.0);
    }
    t.to_json()
}

/// Chrome trace for a distributed `up` run: one process track per
/// worker, each worker's spans shifted by its telemetry clock offset,
/// plus flow arrows pairing cross-worker serves with their opens.
fn chrome_of_dist(dist: &net::DistTrace) -> String {
    let mut t = wilkins::obs::ChromeTrace::new();
    for tr in &dist.tracks {
        t.process_name(tr.worker as u64, &format!("worker {}", tr.worker));
        let mut ranks: Vec<usize> = tr.spans.iter().map(|s| s.rank).collect();
        ranks.sort_unstable();
        ranks.dedup();
        for r in ranks {
            t.thread_name(tr.worker as u64, r as u64, &format!("rank {r}"));
        }
        for s in &tr.spans {
            t.add_span(tr.worker as u64, s, tr.offset_s);
        }
    }
    let flat: Vec<(u64, &wilkins::metrics::Span, f64)> = dist
        .tracks
        .iter()
        .flat_map(|tr| tr.spans.iter().map(|s| (tr.worker as u64, s, tr.offset_s)))
        .collect();
    wilkins::obs::add_serve_open_flows(&mut t, &flat);
    t.to_json()
}

/// Chrome trace for an ensemble: one process track per instance (pid
/// in first-seen order, coordinator on pid 0), the merged trace's
/// spans already on the ensemble clock, and the coordinator's
/// WorkerLost/Requeue markers as instant events.
fn chrome_of_ensemble(report: &wilkins::ensemble::EnsembleReport) -> String {
    let mut t = wilkins::obs::ChromeTrace::new();
    t.process_name(0, "coordinator");
    let mut instances: Vec<String> = Vec::new();
    for s in report.trace.spans() {
        let pid = match instances.iter().position(|n| n == &s.instance) {
            Some(i) => i as u64 + 1,
            None => {
                instances.push(s.instance.clone());
                let pid = instances.len() as u64;
                t.process_name(pid, &s.instance);
                pid
            }
        };
        t.span((pid, s.rank as u64), &s.label, s.kind.name(), s.start, s.end, &[]);
    }
    for e in &report.events {
        t.instant(0, e.rank as u64, &e.name, e.t, &e.attrs);
    }
    t.to_json()
}

fn cmd_run(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let RunOpts { time_scale, workdir, artifacts, gantt, trace, json } =
        take_run_opts(&mut args)?;
    let path = config_path(&args)?;

    let mut w = Wilkins::from_yaml_file(&path, builtin_registry())?
        .with_time_scale(time_scale);
    if let Some(d) = workdir {
        w = w.with_workdir(d);
    }
    // The engine is optional: synthetic workflows run without it.
    let _engine;
    if artifacts.join("manifest.tsv").exists() {
        let engine = Engine::start(&artifacts)?;
        w = w.with_engine(engine.handle());
        _engine = Some(engine);
    } else {
        _engine = None;
    }
    println!("{}", w.graph().describe());
    let recorder = w.recorder();
    let report = w.run()?;
    print!("{}", report.render());
    if let Some(path) = gantt {
        std::fs::write(&path, recorder.to_csv())?;
        println!("gantt trace written to {}", path.display());
    }
    if let Some(path) = trace {
        write_artifact(&path, "chrome trace", &chrome_of_run(&recorder.spans()))?;
    }
    if let Some(path) = json {
        write_artifact(&path, "json report", &report.to_json())?;
    }
    Ok(())
}

fn cmd_ensemble(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let RunOpts { time_scale, workdir, artifacts, gantt, trace, json } =
        take_run_opts(&mut args)?;
    let budget = take_usize_opt(&mut args, "--budget")?;
    let policy = take_opt(&mut args, "--policy")
        .map(|s| Policy::parse(&s))
        .transpose()?;
    let workers_opt = take_usize_opt(&mut args, "--workers")?;
    let dry_run = take_flag(&mut args, "--dry-run");
    let path = config_path(&args)?;

    let mut ens =
        Ensemble::from_yaml_file(&path, builtin_registry())?.with_time_scale(time_scale);
    if let Some(d) = workdir {
        ens = ens.with_workdir(d);
    }
    if let Some(b) = budget {
        // Same convention as the spec's `max_ranks`: 0 = no cap (run
        // everything concurrently).
        let b = if b == 0 { ens.spec().total_ranks() } else { b };
        ens = ens.with_budget(b);
    }
    if let Some(p) = policy {
        ens = ens.with_policy(p);
    }

    // Pool width, if process placement is in play: CLI flag > spec
    // `workers:` > host parallelism, never wider than the ensemble.
    let n_inst = ens.spec().instances.len();
    let pool_width = workers_opt
        .or(ens.spec().workers)
        .unwrap_or_else(host_parallelism)
        .clamp(1, n_inst);

    if dry_run {
        let workers = match ens.spec().placement {
            Placement::ProcessPerInstance => Some(pool_width),
            Placement::Threads => workers_opt.map(|w| w.clamp(1, n_inst)),
        };
        print!("{}", ens.plan(workers)?);
        return Ok(());
    }

    let spec = ens.spec();
    println!(
        "ensemble: {} instances, {} total ranks, budget {}, policy {}, placement {}",
        spec.instances.len(),
        spec.total_ranks(),
        spec.max_ranks,
        spec.policy,
        spec.placement
    );
    for inst in &spec.instances {
        println!(
            "  instance {:<20} {} ranks, admission {}",
            inst.name,
            inst.ranks(),
            inst.admission
        );
    }

    let report = if ens.spec().placement == Placement::ProcessPerInstance {
        // Fan instances out across worker processes; each worker
        // attaches its own engine when the artifacts exist.
        let spec_src = std::fs::read_to_string(&path)?;
        let base_dir = path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."));
        let pool = Arc::new(WorkerPool::spawn_with(pool_width, ens.spec().heartbeat)?);
        let art = artifacts.join("manifest.tsv").exists().then_some(artifacts.as_path());
        ens.run_on_pool(pool, &spec_src, &base_dir, art)?
    } else {
        // One shared engine for the whole ensemble: identical
        // artifacts compile and load once across instances.
        if artifacts.join("manifest.tsv").exists() {
            ens = ens.with_shared_artifacts(&artifacts)?;
        }
        ens.run()?
    };
    print!("{}", report.render());
    if let Some(path) = gantt {
        std::fs::write(&path, report.trace.to_csv())?;
        println!("merged gantt trace written to {}", path.display());
    }
    if let Some(path) = trace {
        write_artifact(&path, "chrome trace", &chrome_of_ensemble(&report))?;
    }
    if let Some(path) = json {
        write_artifact(&path, "json report", &report.to_json())?;
    }
    Ok(())
}

/// `wilkins up`: run across worker processes. A workflow file becomes
/// one distributed world (process-per-node); an ensemble spec fans
/// instances out process-per-instance.
fn cmd_up(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let RunOpts { time_scale, workdir, artifacts, gantt, trace, json } =
        take_run_opts(&mut args)?;
    let workers_opt = take_usize_opt(&mut args, "--workers")?;
    let budget = take_usize_opt(&mut args, "--budget")?;
    let policy = take_opt(&mut args, "--policy")
        .map(|s| Policy::parse(&s))
        .transpose()?;
    let path = config_path(&args)?;
    let src = std::fs::read_to_string(&path)?;
    let doc = wilkins::configyaml::parse(&src)?;

    if doc.get("ensemble").is_some() {
        let mut ens =
            Ensemble::from_yaml_file(&path, builtin_registry())?.with_time_scale(time_scale);
        if let Some(d) = workdir {
            ens = ens.with_workdir(d);
        }
        if let Some(b) = budget {
            let b = if b == 0 { ens.spec().total_ranks() } else { b };
            ens = ens.with_budget(b);
        }
        if let Some(p) = policy {
            ens = ens.with_policy(p);
        }
        let n_inst = ens.spec().instances.len();
        let workers = workers_opt
            .or(ens.spec().workers)
            .unwrap_or_else(host_parallelism)
            .clamp(1, n_inst);
        println!(
            "up: {} instances across {} worker processes (process-per-instance)",
            n_inst, workers
        );
        let base_dir = path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from("."));
        let pool = Arc::new(WorkerPool::spawn_with(workers, ens.spec().heartbeat)?);
        let art = artifacts.join("manifest.tsv").exists().then_some(artifacts.as_path());
        let report = ens.run_on_pool(pool, &src, &base_dir, art)?;
        print!("{}", report.render());
        if let Some(p) = gantt {
            std::fs::write(&p, report.trace.to_csv())?;
            println!("merged gantt trace written to {}", p.display());
        }
        if let Some(p) = trace {
            write_artifact(&p, "chrome trace", &chrome_of_ensemble(&report))?;
        }
        if let Some(p) = json {
            write_artifact(&p, "json report", &report.to_json())?;
        }
        return Ok(());
    }

    let cfg = WorkflowConfig::from_yaml_str(&src)?;
    let graph = WorkflowGraph::build(&cfg)?;
    let workers = workers_opt
        .unwrap_or_else(host_parallelism)
        .clamp(1, graph.nodes.len());
    println!("{}", graph.describe());
    println!(
        "up: {} ranks across {} worker processes (process-per-node)",
        graph.total_ranks, workers
    );
    let opts = wilkins::net::UpOpts {
        workers,
        time_scale,
        workdir,
        artifacts: Some(artifacts),
        heartbeat: wilkins::net::HeartbeatConfig::default(),
    };
    let (report, dist) = net::run_workflow_distributed_traced(&src, &opts)?;
    print!("{}", report.render());
    if let Some(p) = gantt {
        // Workers ship their spans home in `WorldDone`; shift each
        // track by its clock offset so one CSV covers the whole world.
        let mut all: Vec<wilkins::metrics::Span> = Vec::new();
        for tr in &dist.tracks {
            all.extend(tr.spans.iter().map(|s| {
                let mut s = s.clone();
                s.start += tr.offset_s;
                s.end += tr.offset_s;
                s
            }));
        }
        std::fs::write(&p, wilkins::metrics::csv_of(&all))?;
        println!("gantt trace written to {}", p.display());
    }
    if let Some(p) = trace {
        write_artifact(&p, "chrome trace", &chrome_of_dist(&dist))?;
    }
    if let Some(p) = json {
        write_artifact(&p, "json report", &report.to_json())?;
    }
    Ok(())
}

/// `wilkins replay`: load the `.wtap` wire logs a recorded run left
/// in a trace dir, re-drive the coordinator bookkeeping from them in
/// this one process, and diff the reassembled report against the
/// recorded one. Exits non-zero on any deterministic-surface
/// divergence.
fn cmd_replay(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let against_opt = take_opt(&mut args, "--against").map(PathBuf::from);
    let json = take_opt(&mut args, "--json").map(PathBuf::from);
    let dir = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .map(PathBuf::from)
        .ok_or_else(|| wilkins::WilkinsError::Config("missing <trace-dir>".into()))?;

    let run = wilkins::obs::replay::RecordedRun::load(&dir)?;
    println!(
        "replaying {}: {} coordinator records, {} worker log(s)",
        dir.display(),
        run.coordinator.len(),
        run.workers.len()
    );
    if run.truncated {
        println!("note: a log ends mid-record (its process died writing); replaying the complete prefix");
    }
    let replayed = wilkins::obs::replay::replay(&run)?;
    print!("{}", replayed.render());
    if let Some(p) = &json {
        write_artifact(p, "replayed json report", &replayed.to_json())?;
    }

    let against = against_opt.unwrap_or_else(|| dir.join("report.json"));
    if !against.exists() {
        println!(
            "no recorded report at {} — skipping diff (record with --json, or pass --against)",
            against.display()
        );
        return Ok(());
    }
    let recorded = wilkins::obs::replay::normalize_report_json(&std::fs::read_to_string(&against)?)?;
    let ours = wilkins::obs::replay::normalize_report_json(&replayed.to_json())?;
    match wilkins::obs::replay::diff_reports(&recorded, &ours) {
        None => {
            println!("report diff: identical (vs {})", against.display());
            Ok(())
        }
        Some(d) => Err(wilkins::WilkinsError::Task(format!(
            "replay diverged from {}: {d}",
            against.display()
        ))),
    }
}

/// `wilkins worker`: one member of an `up` pool (never invoked by
/// hand — the coordinator spawns these).
fn cmd_worker(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let connect = take_opt(&mut args, "--connect").ok_or_else(|| {
        wilkins::WilkinsError::Config("worker needs --connect ADDR".into())
    })?;
    let id = take_usize_opt(&mut args, "--id")?.ok_or_else(|| {
        wilkins::WilkinsError::Config("worker needs --id K".into())
    })?;
    let mut opts = net::WorkerOpts::from_env()?;
    if let Some(ms) = take_usize_opt(&mut args, "--heartbeat-ms")? {
        // The coordinator prescribes the beat cadence it will listen
        // for (0 = liveness off).
        opts.heartbeat = std::time::Duration::from_millis(ms as u64);
    }
    net::worker_main_with(&connect, id, opts)
}
