//! `wilkins` — the workflow launcher CLI (the `wilkins-master` entry
//! point of the paper).
//!
//! Usage:
//!   wilkins run <config.yaml> [--time-scale S] [--workdir DIR]
//!                             [--artifacts DIR] [--gantt FILE.csv]
//!   wilkins ensemble <spec.yaml> [--budget N] [--policy P] [...]
//!   wilkins validate <config.yaml>
//!   wilkins graph <config.yaml>
//!   wilkins list-tasks
//!   wilkins help

use std::path::PathBuf;
use std::process::ExitCode;

use wilkins::config::WorkflowConfig;
use wilkins::ensemble::{Ensemble, Policy};
use wilkins::graph::WorkflowGraph;
use wilkins::runtime::Engine;
use wilkins::tasks::builtin_registry;
use wilkins::Wilkins;

const HELP: &str = "\
wilkins — HPC in situ workflows made easy (paper reproduction)

USAGE:
    wilkins run <config.yaml> [OPTIONS]   launch a workflow
    wilkins ensemble <spec.yaml> [OPTIONS]
                                          co-schedule N workflow instances
    wilkins validate <config.yaml>        parse + validate only
    wilkins graph <config.yaml>           print the expanded task graph
    wilkins list-tasks                    list built-in task codes
    wilkins help                          this text

OPTIONS (run):
    --time-scale S     wall-seconds per emulated paper-second (default 1)
    --workdir DIR      directory for file-mode transports
    --artifacts DIR    AOT artifacts dir (default ./artifacts or
                       $WILKINS_ARTIFACTS); only workflows using the
                       science payloads need it
    --gantt FILE.csv   write the span trace as CSV after the run

OPTIONS (ensemble, in addition to the run options):
    --budget N         override the spec's max_ranks rank budget
    --policy P         override the spec's policy: fifo | round-robin
    (--gantt writes the merged per-instance trace; one shared AOT
     engine serves every instance)
";

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> wilkins::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("ensemble") => cmd_ensemble(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("graph") => cmd_graph(&args[1..]),
        Some("list-tasks") => {
            for name in builtin_registry().names() {
                println!("{name}");
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => Err(wilkins::WilkinsError::Config(format!(
            "unknown command {other:?}; try `wilkins help`"
        ))),
    }
}

fn take_opt(args: &mut Vec<String>, name: &str) -> Option<String> {
    let idx = args.iter().position(|a| a == name)?;
    if idx + 1 >= args.len() {
        return None;
    }
    let v = args.remove(idx + 1);
    args.remove(idx);
    Some(v)
}

fn config_path(args: &[String]) -> wilkins::Result<PathBuf> {
    args.iter()
        .find(|a| !a.starts_with("--"))
        .map(PathBuf::from)
        .ok_or_else(|| wilkins::WilkinsError::Config("missing <config.yaml>".into()))
}

fn cmd_validate(args: &[String]) -> wilkins::Result<()> {
    let path = config_path(args)?;
    let cfg = WorkflowConfig::from_yaml_str(&std::fs::read_to_string(&path)?)?;
    let graph = WorkflowGraph::build(&cfg)?;
    println!(
        "OK: {} tasks, {} instances, {} channels, {} ranks",
        cfg.tasks.len(),
        graph.nodes.len(),
        graph.channels.len(),
        graph.total_ranks
    );
    Ok(())
}

fn cmd_graph(args: &[String]) -> wilkins::Result<()> {
    let path = config_path(args)?;
    let cfg = WorkflowConfig::from_yaml_str(&std::fs::read_to_string(&path)?)?;
    print!("{}", WorkflowGraph::build(&cfg)?.describe());
    Ok(())
}

/// The options `run` and `ensemble` share.
struct RunOpts {
    time_scale: f64,
    workdir: Option<PathBuf>,
    artifacts: PathBuf,
    gantt: Option<PathBuf>,
}

fn take_run_opts(args: &mut Vec<String>) -> wilkins::Result<RunOpts> {
    Ok(RunOpts {
        time_scale: take_opt(args, "--time-scale")
            .map(|s| s.parse::<f64>())
            .transpose()
            .map_err(|e| wilkins::WilkinsError::Config(format!("bad --time-scale: {e}")))?
            .unwrap_or(1.0),
        workdir: take_opt(args, "--workdir").map(PathBuf::from),
        artifacts: take_opt(args, "--artifacts")
            .map(PathBuf::from)
            .unwrap_or_else(Engine::default_dir),
        gantt: take_opt(args, "--gantt").map(PathBuf::from),
    })
}

fn cmd_run(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let RunOpts { time_scale, workdir, artifacts, gantt } = take_run_opts(&mut args)?;
    let path = config_path(&args)?;

    let mut w = Wilkins::from_yaml_file(&path, builtin_registry())?
        .with_time_scale(time_scale);
    if let Some(d) = workdir {
        w = w.with_workdir(d);
    }
    // The engine is optional: synthetic workflows run without it.
    let _engine;
    if artifacts.join("manifest.tsv").exists() {
        let engine = Engine::start(&artifacts)?;
        w = w.with_engine(engine.handle());
        _engine = Some(engine);
    } else {
        _engine = None;
    }
    println!("{}", w.graph().describe());
    let recorder = w.recorder();
    let report = w.run()?;
    print!("{}", report.render());
    if let Some(path) = gantt {
        std::fs::write(&path, recorder.to_csv())?;
        println!("gantt trace written to {}", path.display());
    }
    Ok(())
}

fn cmd_ensemble(args: &[String]) -> wilkins::Result<()> {
    let mut args = args.to_vec();
    let RunOpts { time_scale, workdir, artifacts, gantt } = take_run_opts(&mut args)?;
    let budget = take_opt(&mut args, "--budget")
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|e| wilkins::WilkinsError::Config(format!("bad --budget: {e}")))?;
    let policy = take_opt(&mut args, "--policy")
        .map(|s| Policy::parse(&s))
        .transpose()?;
    let path = config_path(&args)?;

    let mut ens =
        Ensemble::from_yaml_file(&path, builtin_registry())?.with_time_scale(time_scale);
    if let Some(d) = workdir {
        ens = ens.with_workdir(d);
    }
    if let Some(b) = budget {
        // Same convention as the spec's `max_ranks`: 0 = no cap (run
        // everything concurrently).
        let b = if b == 0 { ens.spec().total_ranks() } else { b };
        ens = ens.with_budget(b);
    }
    if let Some(p) = policy {
        ens = ens.with_policy(p);
    }
    // One shared engine for the whole ensemble: identical artifacts
    // compile and load once across instances.
    if artifacts.join("manifest.tsv").exists() {
        ens = ens.with_shared_artifacts(&artifacts)?;
    }
    let spec = ens.spec();
    println!(
        "ensemble: {} instances, {} total ranks, budget {}, policy {}",
        spec.instances.len(),
        spec.total_ranks(),
        spec.max_ranks,
        spec.policy
    );
    for inst in &spec.instances {
        println!(
            "  instance {:<20} {} ranks, admission {}",
            inst.name,
            inst.ranks(),
            inst.admission
        );
    }
    let report = ens.run()?;
    print!("{}", report.render());
    if let Some(path) = gantt {
        std::fs::write(&path, report.trace.to_csv())?;
        println!("merged gantt trace written to {}", path.display());
    }
    Ok(())
}
