//! Metrics + Gantt tracing (substrate S11).
//!
//! Every rank records timestamped spans — compute, idle (blocked on a
//! coupled task) and transfer — against a shared origin. As of the
//! observability plane ([`crate::obs`]) the span store itself lives in
//! [`obs::TraceRecorder`](crate::obs::TraceRecorder); this module is
//! the *Gantt/CSV view* over that trace: [`Recorder`] wraps a
//! `TraceRecorder` and renders the paper's Figure-5-style charts,
//! [`Span`] and [`SpanKind`] are re-exports of the obs types.
//!
//! For ensembles (see [`crate::ensemble`]) every workflow instance has
//! its own [`Recorder`]; a [`MergedTrace`] stitches the per-instance
//! traces back onto the shared ensemble clock so co-scheduling can be
//! inspected in one Gantt chart.

use std::time::Instant;

use crate::obs::TraceRecorder;

pub use crate::obs::{Span, SpanKind};

/// Shared, thread-safe span recorder: a Gantt/CSV view over an
/// [`obs::TraceRecorder`](crate::obs::TraceRecorder).
#[derive(Default)]
pub struct Recorder {
    inner: TraceRecorder,
}

impl Recorder {
    /// A recorder whose clock origin is now.
    pub fn new() -> Recorder {
        Recorder { inner: TraceRecorder::new() }
    }

    /// The structured trace under this view (for instant events,
    /// attrs, and the run clock).
    pub fn trace(&self) -> &TraceRecorder {
        &self.inner
    }

    /// The origin instant of the recorder's run-relative clock (for
    /// rebasing spans onto another clock in the same process).
    pub fn origin_instant(&self) -> Instant {
        self.inner.clock().origin()
    }

    /// Record one span.
    pub fn record(&self, rank: usize, kind: SpanKind, label: &str, t0: Instant, t1: Instant) {
        self.inner.span(rank, kind, label, t0, t1);
    }

    /// [`Recorder::record`] with key=value attributes.
    pub fn record_with(
        &self,
        rank: usize,
        kind: SpanKind,
        label: &str,
        t0: Instant,
        t1: Instant,
        attrs: Vec<(String, String)>,
    ) {
        self.inner.span_with(rank, kind, label, t0, t1, attrs);
    }

    /// Convenience: time a closure as a Compute span.
    pub fn compute<T>(&self, rank: usize, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(rank, SpanKind::Compute, label, t0, Instant::now());
        out
    }

    /// Snapshot of all spans recorded so far.
    pub fn spans(&self) -> Vec<Span> {
        self.inner.spans()
    }

    /// Total seconds per kind for one rank:
    /// (compute, idle, transfer, stall).
    pub fn totals(&self, rank: usize) -> (f64, f64, f64, f64) {
        let mut c = 0.0;
        let mut i = 0.0;
        let mut t = 0.0;
        let mut st = 0.0;
        for s in self.inner.spans().iter().filter(|s| s.rank == rank) {
            let d = s.end - s.start;
            match s.kind {
                SpanKind::Compute => c += d,
                SpanKind::Idle => i += d,
                SpanKind::Transfer => t += d,
                SpanKind::Stall => st += d,
            }
        }
        (c, i, t, st)
    }

    /// CSV export: rank,kind,label,start,end.
    pub fn to_csv(&self) -> String {
        csv_of(&self.spans())
    }

    /// ASCII Gantt chart over the given ranks (one row per rank),
    /// `width` columns spanning [0, max end]. Later spans overwrite
    /// earlier ones in a cell; transfer > idle > compute on ties.
    pub fn gantt_ascii(&self, ranks: &[usize], width: usize) -> String {
        let spans = self.spans();
        let tmax = spans
            .iter()
            .filter(|s| ranks.contains(&s.rank))
            .map(|s| s.end)
            .fold(0.0_f64, f64::max);
        if tmax <= 0.0 {
            return String::from("(no spans)\n");
        }
        let mut out = String::new();
        out.push_str(&gantt_header("gantt", width, tmax));
        for &rank in ranks {
            let row = paint_gantt_row(
                spans.iter().filter(|s| s.rank == rank).map(|s| (s.kind, s.start, s.end)),
                width,
                tmax,
            );
            out.push_str(&format!("rank {rank:>4} |{row}|\n"));
        }
        out
    }
}

/// Render spans as `rank,kind,label,start_s,end_s` CSV, sorted by
/// (rank, start). Shared by [`Recorder::to_csv`] and the distributed
/// `wilkins up --gantt` path (which merges spans from many workers
/// before rendering).
pub fn csv_of(spans: &[Span]) -> String {
    let mut out = String::from("rank,kind,label,start_s,end_s\n");
    let mut spans = spans.to_vec();
    spans.sort_by(|a, b| (a.rank, a.start).partial_cmp(&(b.rank, b.start)).unwrap());
    for s in spans {
        out.push_str(&format!(
            "{},{},{},{:.6},{:.6}\n",
            s.rank,
            s.kind.name(),
            s.label.replace(',', ";"),
            s.start,
            s.end
        ));
    }
    out
}

/// The shared Gantt header line (legend + scale).
fn gantt_header(label: &str, width: usize, tmax: f64) -> String {
    format!(
        "{label}: {width} cols = {tmax:.3}s  [{}=compute {}=idle {}=transfer {}=stall]\n",
        SpanKind::Compute.glyph(),
        SpanKind::Idle.glyph(),
        SpanKind::Transfer.glyph(),
        SpanKind::Stall.glyph()
    )
}

/// Paint one Gantt lane: floor/ceil bucket mapping over [0, tmax],
/// every span at least one cell wide, transfer > idle > compute when
/// spans share a cell. Both [`Recorder::gantt_ascii`] and
/// [`MergedTrace::gantt_ascii`] render through this, so the two
/// charts can never diverge on cell rules.
fn paint_gantt_row(
    spans: impl Iterator<Item = (SpanKind, f64, f64)>,
    width: usize,
    tmax: f64,
) -> String {
    let mut row: Vec<char> = vec![' '; width];
    let mut prio: Vec<u8> = vec![0; width];
    for (kind, start, end) in spans {
        let a = ((start / tmax) * width as f64).floor() as usize;
        let b = (((end / tmax) * width as f64).ceil() as usize).min(width);
        let p = match kind {
            SpanKind::Compute => 1,
            SpanKind::Idle => 2,
            SpanKind::Transfer => 3,
            // Stalls paint over everything: backpressure is the
            // signal these charts exist to show.
            SpanKind::Stall => 4,
        };
        for x in a..b.max(a + 1).min(width) {
            if p >= prio[x] {
                row[x] = kind.glyph();
                prio[x] = p;
            }
        }
    }
    row.into_iter().collect()
}

/// One span of a merged ensemble trace: a [`Span`] tagged with the
/// workflow instance it came from, on the shared ensemble clock.
#[derive(Debug, Clone)]
pub struct MergedSpan {
    /// Instance name (lane group), e.g. `pipe[2]`.
    pub instance: String,
    /// Rank *within* the instance's restricted world.
    pub rank: usize,
    pub kind: SpanKind,
    pub label: String,
    /// Seconds since ensemble start.
    pub start: f64,
    pub end: f64,
}

/// A Gantt trace merged from several per-instance recorders.
///
/// Each instance's spans are shifted by the instance's admission
/// offset (its [`Recorder`] origin relative to the ensemble origin),
/// so one chart shows when the co-scheduler packed each instance onto
/// the rank budget and what every rank did once admitted.
#[derive(Debug, Clone, Default)]
pub struct MergedTrace {
    spans: Vec<MergedSpan>,
    /// Lane order: (instance, rank) pairs in insertion order.
    lanes: Vec<(String, usize)>,
}

impl MergedTrace {
    pub fn new() -> MergedTrace {
        MergedTrace::default()
    }

    /// Fold one instance's spans in, shifting them by `offset_s` (the
    /// instance's start time on the ensemble clock).
    pub fn add_instance(&mut self, instance: &str, offset_s: f64, spans: &[Span]) {
        let mut ranks: Vec<usize> = spans.iter().map(|s| s.rank).collect();
        ranks.sort_unstable();
        ranks.dedup();
        for r in ranks {
            self.lanes.push((instance.to_string(), r));
        }
        for s in spans {
            self.spans.push(MergedSpan {
                instance: instance.to_string(),
                rank: s.rank,
                kind: s.kind,
                label: s.label.clone(),
                start: s.start + offset_s,
                end: s.end + offset_s,
            });
        }
    }

    pub fn spans(&self) -> &[MergedSpan] {
        &self.spans
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Last span end on the ensemble clock (0 when empty).
    pub fn end_s(&self) -> f64 {
        self.spans.iter().map(|s| s.end).fold(0.0_f64, f64::max)
    }

    /// CSV export: instance,rank,kind,label,start_s,end_s.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("instance,rank,kind,label,start_s,end_s\n");
        let mut spans = self.spans.clone();
        spans.sort_by(|a, b| {
            (&a.instance, a.rank, a.start)
                .partial_cmp(&(&b.instance, b.rank, b.start))
                .unwrap()
        });
        for s in spans {
            out.push_str(&format!(
                "{},{},{},{},{:.6},{:.6}\n",
                s.instance.replace(',', ";"),
                s.rank,
                s.kind.name(),
                s.label.replace(',', ";"),
                s.start,
                s.end
            ));
        }
        out
    }

    /// ASCII Gantt over all lanes (one row per instance rank), `width`
    /// columns spanning [0, last end]. Same cell-priority rules as
    /// [`Recorder::gantt_ascii`].
    pub fn gantt_ascii(&self, width: usize) -> String {
        let tmax = self.end_s();
        if tmax <= 0.0 || width == 0 {
            return String::from("(no spans)\n");
        }
        let name_w = self
            .lanes
            .iter()
            .map(|(i, _)| i.len())
            .max()
            .unwrap_or(0)
            .max(8);
        let mut out = String::new();
        out.push_str(&gantt_header("ensemble gantt", width, tmax));
        for (inst, rank) in &self.lanes {
            let row = paint_gantt_row(
                self.spans
                    .iter()
                    .filter(|s| &s.instance == inst && s.rank == *rank)
                    .map(|s| (s.kind, s.start, s.end)),
                width,
                tmax,
            );
            out.push_str(&format!("{inst:>name_w$} r{rank:<3} |{row}|\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn totals_accumulate_per_kind() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "a", t0, t0 + Duration::from_millis(10));
        rec.record(0, SpanKind::Idle, "b", t0, t0 + Duration::from_millis(20));
        rec.record(1, SpanKind::Compute, "c", t0, t0 + Duration::from_millis(5));
        let (c, i, t, st) = rec.totals(0);
        assert!((c - 0.010).abs() < 1e-9);
        assert!((i - 0.020).abs() < 1e-9);
        assert_eq!(t, 0.0);
        assert_eq!(st, 0.0);
    }

    #[test]
    fn compute_helper_records() {
        let rec = Recorder::new();
        let v = rec.compute(3, "work", || 42);
        assert_eq!(v, 42);
        let spans = rec.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].rank, 3);
        assert_eq!(spans[0].kind, SpanKind::Compute);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Transfer, "x,y", t0, t0 + Duration::from_millis(1));
        let csv = rec.to_csv();
        assert!(csv.starts_with("rank,kind,label,start_s,end_s\n"));
        assert!(csv.contains("transfer"));
        assert!(csv.contains("x;y")); // comma escaped
    }

    #[test]
    fn gantt_renders_rows() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "a", t0, t0 + Duration::from_millis(8));
        rec.record(1, SpanKind::Idle, "b", t0 + Duration::from_millis(2), t0 + Duration::from_millis(10));
        let g = rec.gantt_ascii(&[0, 1], 40);
        assert!(g.contains("rank    0"));
        assert!(g.contains('#'));
        assert!(g.contains('.'));
    }

    #[test]
    fn empty_gantt() {
        let rec = Recorder::new();
        assert_eq!(rec.gantt_ascii(&[0], 10), "(no spans)\n");
    }

    #[test]
    fn merged_trace_shifts_by_instance_offset() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "a", t0, t0 + Duration::from_millis(10));
        let spans = rec.spans();
        let mut m = MergedTrace::new();
        m.add_instance("one", 0.0, &spans);
        m.add_instance("two", 1.5, &spans);
        assert_eq!(m.spans().len(), 2);
        let two = m.spans().iter().find(|s| s.instance == "two").unwrap();
        assert!(two.start >= 1.5 && two.end > two.start);
        assert!((m.end_s() - two.end).abs() < 1e-12);
    }

    #[test]
    fn merged_csv_and_gantt_render() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "c", t0, t0 + Duration::from_millis(4));
        rec.record(1, SpanKind::Idle, "i", t0, t0 + Duration::from_millis(8));
        let mut m = MergedTrace::new();
        m.add_instance("pipe[0]", 0.0, &rec.spans());
        m.add_instance("pipe[1]", 0.01, &rec.spans());
        let csv = m.to_csv();
        assert!(csv.starts_with("instance,rank,kind,label,start_s,end_s\n"));
        assert_eq!(csv.lines().count(), 1 + 4);
        assert!(csv.contains("pipe[1]"));
        let g = m.gantt_ascii(60);
        // One row per (instance, rank) lane.
        assert_eq!(g.lines().count(), 1 + 4);
        assert!(g.contains("pipe[0]") && g.contains('#') && g.contains('.'));
    }

    #[test]
    fn merged_trace_empty() {
        let m = MergedTrace::new();
        assert!(m.is_empty());
        assert_eq!(m.gantt_ascii(20), "(no spans)\n");
    }

    #[test]
    fn record_with_attrs_lands_in_trace() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record_with(
            0,
            SpanKind::Transfer,
            "serve d",
            t0,
            t0 + Duration::from_millis(1),
            vec![("bytes".into(), "8".into())],
        );
        let spans = rec.trace().spans();
        assert_eq!(spans[0].attrs.len(), 1);
    }
}
