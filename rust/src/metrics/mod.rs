//! Metrics + Gantt tracing (substrate S11).
//!
//! Every rank records timestamped spans — compute, idle (blocked on a
//! coupled task) and transfer — against a shared origin. The recorder
//! renders the paper's Figure-5-style Gantt charts as ASCII and CSV,
//! and aggregates idle/compute totals for the flow-control tables.

use std::sync::Mutex;
use std::time::Instant;

/// What a rank was doing during a span (Fig. 5 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Task computation (blue bars).
    Compute,
    /// Blocked waiting on a coupled task (red bars).
    Idle,
    /// Data transfer (orange bars).
    Transfer,
}

impl SpanKind {
    pub fn glyph(&self) -> char {
        match self {
            SpanKind::Compute => '#',
            SpanKind::Idle => '.',
            SpanKind::Transfer => '=',
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Idle => "idle",
            SpanKind::Transfer => "transfer",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Span {
    pub rank: usize,
    pub kind: SpanKind,
    pub label: String,
    /// Seconds since recorder origin.
    pub start: f64,
    pub end: f64,
}

/// Shared, thread-safe span recorder.
pub struct Recorder {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder { origin: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    pub fn record(&self, rank: usize, kind: SpanKind, label: &str, t0: Instant, t1: Instant) {
        let start = t0.duration_since(self.origin).as_secs_f64();
        let end = t1.duration_since(self.origin).as_secs_f64();
        self.spans.lock().unwrap().push(Span {
            rank,
            kind,
            label: label.to_string(),
            start,
            end,
        });
    }

    /// Convenience: time a closure as a Compute span.
    pub fn compute<T>(&self, rank: usize, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(rank, SpanKind::Compute, label, t0, Instant::now());
        out
    }

    pub fn spans(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }

    /// Total seconds per kind for one rank.
    pub fn totals(&self, rank: usize) -> (f64, f64, f64) {
        let spans = self.spans.lock().unwrap();
        let mut c = 0.0;
        let mut i = 0.0;
        let mut t = 0.0;
        for s in spans.iter().filter(|s| s.rank == rank) {
            let d = s.end - s.start;
            match s.kind {
                SpanKind::Compute => c += d,
                SpanKind::Idle => i += d,
                SpanKind::Transfer => t += d,
            }
        }
        (c, i, t)
    }

    /// CSV export: rank,kind,label,start,end.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("rank,kind,label,start_s,end_s\n");
        let mut spans = self.spans();
        spans.sort_by(|a, b| (a.rank, a.start).partial_cmp(&(b.rank, b.start)).unwrap());
        for s in spans {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6}\n",
                s.rank,
                s.kind.name(),
                s.label.replace(',', ";"),
                s.start,
                s.end
            ));
        }
        out
    }

    /// ASCII Gantt chart over the given ranks (one row per rank),
    /// `width` columns spanning [0, max end]. Later spans overwrite
    /// earlier ones in a cell; transfer > idle > compute on ties.
    pub fn gantt_ascii(&self, ranks: &[usize], width: usize) -> String {
        let spans = self.spans();
        let tmax = spans
            .iter()
            .filter(|s| ranks.contains(&s.rank))
            .map(|s| s.end)
            .fold(0.0_f64, f64::max);
        if tmax <= 0.0 {
            return String::from("(no spans)\n");
        }
        let mut out = String::new();
        out.push_str(&format!(
            "gantt: {width} cols = {tmax:.3}s  [{}=compute {}=idle {}=transfer]\n",
            SpanKind::Compute.glyph(),
            SpanKind::Idle.glyph(),
            SpanKind::Transfer.glyph()
        ));
        for &rank in ranks {
            let mut row: Vec<char> = vec![' '; width];
            let mut prio: Vec<u8> = vec![0; width];
            for s in spans.iter().filter(|s| s.rank == rank) {
                let a = ((s.start / tmax) * width as f64).floor() as usize;
                let b = (((s.end / tmax) * width as f64).ceil() as usize).min(width);
                let p = match s.kind {
                    SpanKind::Compute => 1,
                    SpanKind::Idle => 2,
                    SpanKind::Transfer => 3,
                };
                for x in a..b.max(a + 1).min(width) {
                    if p >= prio[x] {
                        row[x] = s.kind.glyph();
                        prio[x] = p;
                    }
                }
            }
            out.push_str(&format!("rank {rank:>4} |{}|\n", row.iter().collect::<String>()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn totals_accumulate_per_kind() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "a", t0, t0 + Duration::from_millis(10));
        rec.record(0, SpanKind::Idle, "b", t0, t0 + Duration::from_millis(20));
        rec.record(1, SpanKind::Compute, "c", t0, t0 + Duration::from_millis(5));
        let (c, i, t) = rec.totals(0);
        assert!((c - 0.010).abs() < 1e-9);
        assert!((i - 0.020).abs() < 1e-9);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn compute_helper_records() {
        let rec = Recorder::new();
        let v = rec.compute(3, "work", || 42);
        assert_eq!(v, 42);
        let spans = rec.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].rank, 3);
        assert_eq!(spans[0].kind, SpanKind::Compute);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Transfer, "x,y", t0, t0 + Duration::from_millis(1));
        let csv = rec.to_csv();
        assert!(csv.starts_with("rank,kind,label,start_s,end_s\n"));
        assert!(csv.contains("transfer"));
        assert!(csv.contains("x;y")); // comma escaped
    }

    #[test]
    fn gantt_renders_rows() {
        let rec = Recorder::new();
        let t0 = Instant::now();
        rec.record(0, SpanKind::Compute, "a", t0, t0 + Duration::from_millis(8));
        rec.record(1, SpanKind::Idle, "b", t0 + Duration::from_millis(2), t0 + Duration::from_millis(10));
        let g = rec.gantt_ascii(&[0, 1], 40);
        assert!(g.contains("rank    0"));
        assert!(g.contains('#'));
        assert!(g.contains('.'));
    }

    #[test]
    fn empty_gantt() {
        let rec = Recorder::new();
        assert_eq!(rec.gantt_ascii(&[0], 10), "(no spans)\n");
    }
}
