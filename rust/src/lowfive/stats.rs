//! Per-rank transport counters ([`VolStats`]) and the borrowed engine
//! context (`EngineCx`) the producer/consumer engines work against.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::Comm;
use crate::metrics::{Recorder, SpanKind};

/// Transport statistics (observability for the benches).
#[derive(Debug, Default, Clone)]
pub struct VolStats {
    /// Serve rounds actually consumed (memory completions and disk
    /// writes; see the producer engine's flow-stat folding).
    pub files_served: u64,
    /// Flow-control cadence skips (`every`-gated closes that never
    /// reached a channel's round buffer).
    pub serves_skipped: u64,
    /// Rounds discarded by a dropping flow policy (latest /
    /// drop-oldest / drop-newest) after admission pressure.
    pub serves_dropped: u64,
    /// Default serves suppressed by a before-close callback (custom
    /// I/O patterns like Nyx's double close).
    pub serves_suppressed: u64,
    /// Total payload bytes served (data replies + disk writes).
    pub bytes_served: u64,
    /// Serve bytes handed to same-process consumers through the
    /// zero-copy shared-snapshot path (no encode/decode round-trip).
    pub bytes_shared: u64,
    /// Serve bytes that took the classic encode → deliver → decode
    /// path (cross-process consumers, or the fast path disabled).
    pub bytes_copied: u64,
    /// Encoded serve rounds whose reply buffer had to be freshly
    /// allocated (a pool miss, or pooling disabled). Zero at steady
    /// state: after warm-up every data-reply encode leases a recycled
    /// buffer from the process pool.
    pub alloc_rounds: u64,
    /// Bytes encoded into recycled (pool-hit) buffers — serve replies
    /// and disk-archive encodes that cost no allocation.
    pub bytes_pooled: u64,
    /// Files opened on the consumer side.
    pub files_opened: u64,
    /// Payload bytes read on the consumer side (both transports).
    pub bytes_read: u64,
    /// Time the producer spent blocked inside serve rounds.
    pub serve_wait: Duration,
    /// Time the producer stalled waiting for flow credits (subset of
    /// `serve_wait` under blocking policies).
    pub stall_wait: Duration,
    /// High-water mark of any channel's round buffer.
    pub max_queue_depth: u64,
    /// Time the consumer spent blocked in file_open.
    pub open_wait: Duration,
}

/// The borrowed slice of a [`Vol`](super::Vol) the engines work
/// against: stats, the I/O communicator, the workdir and the
/// recorder, carved out so engine methods can mutate channel state
/// and counters without fighting the borrow checker over the whole
/// Vol.
pub(super) struct EngineCx<'a> {
    /// I/O-rank sub-communicator (None on non-I/O ranks).
    pub(super) io_comm: Option<&'a Comm>,
    /// Directory for file-routed transports.
    pub(super) workdir: &'a Path,
    /// The rank's transport counters.
    pub(super) stats: &'a mut VolStats,
    /// Gantt recorder + this rank's global label, when attached.
    pub(super) recorder: Option<&'a (Arc<Recorder>, usize)>,
    /// Ablation switch: serial DataReqs instead of pipelined.
    pub(super) lockstep_reads: bool,
    /// Zero-copy fast path enabled (default; benches ablate it).
    pub(super) zero_copy: bool,
    /// Pooled encode buffers enabled (default; benches ablate it via
    /// `Vol::set_pooling`, which also flips the process-wide
    /// transport pooling switch).
    pub(super) pooling: bool,
}

impl EngineCx<'_> {
    /// Record a span against this rank's Gantt timeline.
    pub(super) fn record_span(&self, kind: SpanKind, label: &str, t0: Instant) {
        if let Some((rec, rank)) = self.recorder {
            rec.record(*rank, kind, label, t0, Instant::now());
        }
    }
}
