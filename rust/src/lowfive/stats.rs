//! Per-rank transport counters ([`VolStats`]) and the borrowed engine
//! context (`EngineCx`) the producer/consumer engines work against.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::Comm;
use crate::metrics::{Recorder, SpanKind};
use crate::obs::CounterDef;

/// Transport statistics (observability for the benches).
#[derive(Debug, Default, Clone)]
pub struct VolStats {
    /// Serve rounds actually consumed (memory completions and disk
    /// writes; see the producer engine's flow-stat folding).
    pub files_served: u64,
    /// Flow-control cadence skips (`every`-gated closes that never
    /// reached a channel's round buffer).
    pub serves_skipped: u64,
    /// Rounds discarded by a dropping flow policy (latest /
    /// drop-oldest / drop-newest) after admission pressure.
    pub serves_dropped: u64,
    /// Default serves suppressed by a before-close callback (custom
    /// I/O patterns like Nyx's double close).
    pub serves_suppressed: u64,
    /// Total payload bytes served (data replies + disk writes).
    pub bytes_served: u64,
    /// Serve bytes handed to same-process consumers through the
    /// zero-copy shared-snapshot path (no encode/decode round-trip).
    pub bytes_shared: u64,
    /// Serve bytes that took the classic encode → deliver → decode
    /// path (cross-process consumers, or the fast path disabled).
    pub bytes_copied: u64,
    /// Encoded serve rounds whose reply buffer had to be freshly
    /// allocated (a pool miss, or pooling disabled). Zero at steady
    /// state: after warm-up every data-reply encode leases a recycled
    /// buffer from the process pool.
    pub alloc_rounds: u64,
    /// Bytes encoded into recycled (pool-hit) buffers — serve replies
    /// and disk-archive encodes that cost no allocation.
    pub bytes_pooled: u64,
    /// Files opened on the consumer side.
    pub files_opened: u64,
    /// Payload bytes read on the consumer side (both transports).
    pub bytes_read: u64,
    /// Time the producer spent blocked inside serve rounds.
    pub serve_wait: Duration,
    /// Time the producer stalled waiting for flow credits (subset of
    /// `serve_wait` under blocking policies).
    pub stall_wait: Duration,
    /// High-water mark of any channel's round buffer.
    pub max_queue_depth: u64,
    /// Time the consumer spent blocked in file_open.
    pub open_wait: Duration,
}

impl VolStats {
    /// The registered counter family, in wire/JSON order (append
    /// only). Merge semantics across the SPMD ranks of one node:
    /// byte totals `Sum`; per-rank round counts, waits and high-water
    /// marks `Max` (each rank of a node sees the whole story, so
    /// summing would double-count — exactly the old hand-written merge
    /// in `coordinator::report::build`, now declared once).
    pub const DEFS: &'static [CounterDef] = &[
        CounterDef::max("files_served"),
        CounterDef::max("serves_skipped"),
        CounterDef::max("serves_dropped"),
        CounterDef::max("serves_suppressed"),
        CounterDef::sum("bytes_served"),
        CounterDef::sum("bytes_shared"),
        CounterDef::sum("bytes_copied"),
        CounterDef::sum("alloc_rounds"),
        CounterDef::sum("bytes_pooled"),
        CounterDef::max("files_opened"),
        CounterDef::sum("bytes_read"),
        CounterDef::max("max_queue_depth"),
        CounterDef::max("serve_wait_ns"),
        CounterDef::max("stall_wait_ns"),
        CounterDef::max("open_wait_ns"),
    ];

    /// The family's values in [`VolStats::DEFS`] order (durations as
    /// nanoseconds, the wire/JSON representation).
    pub fn counter_values(&self) -> Vec<u64> {
        vec![
            self.files_served,
            self.serves_skipped,
            self.serves_dropped,
            self.serves_suppressed,
            self.bytes_served,
            self.bytes_shared,
            self.bytes_copied,
            self.alloc_rounds,
            self.bytes_pooled,
            self.files_opened,
            self.bytes_read,
            self.max_queue_depth,
            self.serve_wait.as_nanos() as u64,
            self.stall_wait.as_nanos() as u64,
            self.open_wait.as_nanos() as u64,
        ]
    }

    /// Rebuild from [`VolStats::DEFS`]-ordered values (inverse of
    /// [`VolStats::counter_values`]).
    pub fn from_counter_values(vals: &[u64]) -> VolStats {
        assert_eq!(vals.len(), Self::DEFS.len(), "VolStats counter count mismatch");
        VolStats {
            files_served: vals[0],
            serves_skipped: vals[1],
            serves_dropped: vals[2],
            serves_suppressed: vals[3],
            bytes_served: vals[4],
            bytes_shared: vals[5],
            bytes_copied: vals[6],
            alloc_rounds: vals[7],
            bytes_pooled: vals[8],
            files_opened: vals[9],
            bytes_read: vals[10],
            max_queue_depth: vals[11],
            serve_wait: Duration::from_nanos(vals[12]),
            stall_wait: Duration::from_nanos(vals[13]),
            open_wait: Duration::from_nanos(vals[14]),
        }
    }

    /// Merge another rank's counters into this one per the family's
    /// registered semantics.
    pub fn merge_from(&mut self, other: &VolStats) {
        let mut vals = self.counter_values();
        crate::obs::merge_values(&mut vals, &other.counter_values(), Self::DEFS);
        *self = VolStats::from_counter_values(&vals);
    }

    /// Look up one counter by its registered name (`None` for unknown
    /// names). Report renderers and JSON export go through this, so a
    /// counter added to [`VolStats::DEFS`] is automatically visible.
    pub fn counter(&self, name: &str) -> Option<u64> {
        let idx = Self::DEFS.iter().position(|d| d.name == name)?;
        Some(self.counter_values()[idx])
    }
}

/// The borrowed slice of a [`Vol`](super::Vol) the engines work
/// against: stats, the I/O communicator, the workdir and the
/// recorder, carved out so engine methods can mutate channel state
/// and counters without fighting the borrow checker over the whole
/// Vol.
pub(super) struct EngineCx<'a> {
    /// I/O-rank sub-communicator (None on non-I/O ranks).
    pub(super) io_comm: Option<&'a Comm>,
    /// Directory for file-routed transports.
    pub(super) workdir: &'a Path,
    /// The rank's transport counters.
    pub(super) stats: &'a mut VolStats,
    /// Gantt recorder + this rank's global label, when attached.
    pub(super) recorder: Option<&'a (Arc<Recorder>, usize)>,
    /// Ablation switch: serial DataReqs instead of pipelined.
    pub(super) lockstep_reads: bool,
    /// Zero-copy fast path enabled (default; benches ablate it).
    pub(super) zero_copy: bool,
    /// Pooled encode buffers enabled (default; benches ablate it via
    /// `Vol::set_pooling`, which also flips the process-wide
    /// transport pooling switch).
    pub(super) pooling: bool,
}

impl EngineCx<'_> {
    /// Record a span against this rank's timeline, with key=value
    /// attributes (dataset names, byte counts) for the structured
    /// trace.
    pub(super) fn record_span_with(
        &self,
        kind: SpanKind,
        label: &str,
        t0: Instant,
        attrs: Vec<(String, String)>,
    ) {
        if let Some((rec, rank)) = self.recorder {
            rec.record_with(*rank, kind, label, t0, Instant::now(), attrs);
        }
    }
}
