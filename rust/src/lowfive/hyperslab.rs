//! Hyperslab (block selection) algebra: the heart of LowFive's M-to-N
//! data redistribution. A hyperslab is an axis-aligned box — `offset` +
//! `count` per dimension — selecting a region of a dataset.
//!
//! Redistribution never materialises index lists: producer/consumer
//! block pairs exchange only the *intersection boxes*, and
//! [`copy_region`] moves bytes with contiguous innermost runs
//! (memcpy-speed for the common row-major decompositions).

use crate::comm::wire::{Reader, Writer};
use crate::error::Result;

/// An axis-aligned block selection of an n-dimensional dataset.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Hyperslab {
    /// Per-dimension start coordinate (global).
    pub offset: Vec<u64>,
    /// Per-dimension extent.
    pub count: Vec<u64>,
}

impl Hyperslab {
    /// A slab from per-dimension offsets and counts (equal rank).
    pub fn new(offset: &[u64], count: &[u64]) -> Hyperslab {
        assert_eq!(offset.len(), count.len(), "offset/count rank mismatch");
        Hyperslab { offset: offset.to_vec(), count: count.to_vec() }
    }

    /// The whole of a dataset with the given dims.
    pub fn whole(dims: &[u64]) -> Hyperslab {
        Hyperslab { offset: vec![0; dims.len()], count: dims.to_vec() }
    }

    /// 1-D convenience.
    pub fn range1d(offset: u64, count: u64) -> Hyperslab {
        Hyperslab { offset: vec![offset], count: vec![count] }
    }

    /// Dimensionality of the slab.
    pub fn dims(&self) -> usize {
        self.offset.len()
    }

    /// Total selected elements.
    pub fn element_count(&self) -> u64 {
        self.count.iter().product()
    }

    /// Does the slab select nothing (any zero count)?
    pub fn is_empty(&self) -> bool {
        self.count.iter().any(|&c| c == 0)
    }

    /// Does this slab fit inside a dataset of the given dims?
    pub fn fits_within(&self, dims: &[u64]) -> bool {
        self.dims() == dims.len()
            && self
                .offset
                .iter()
                .zip(&self.count)
                .zip(dims)
                .all(|((&o, &c), &d)| o + c <= d)
    }

    /// Box intersection; None when empty.
    pub fn intersect(&self, other: &Hyperslab) -> Option<Hyperslab> {
        if self.dims() != other.dims() {
            return None;
        }
        let mut offset = Vec::with_capacity(self.dims());
        let mut count = Vec::with_capacity(self.dims());
        for d in 0..self.dims() {
            let lo = self.offset[d].max(other.offset[d]);
            let hi = (self.offset[d] + self.count[d]).min(other.offset[d] + other.count[d]);
            if lo >= hi {
                return None;
            }
            offset.push(lo);
            count.push(hi - lo);
        }
        Some(Hyperslab { offset, count })
    }

    /// Does `other` overlap this slab?
    pub fn overlaps(&self, other: &Hyperslab) -> bool {
        self.intersect(other).is_some()
    }

    /// Row-major strides (in elements) for a buffer shaped like `self`.
    fn strides(&self) -> Vec<u64> {
        let mut s = vec![1u64; self.dims()];
        for d in (0..self.dims().saturating_sub(1)).rev() {
            s[d] = s[d + 1] * self.count[d + 1];
        }
        s
    }

    /// Element index within this slab's row-major buffer of the global
    /// coordinate `coord` (must lie inside the slab).
    fn element_index(&self, coord: &[u64], strides: &[u64]) -> u64 {
        coord
            .iter()
            .zip(&self.offset)
            .zip(strides)
            .map(|((&c, &o), &s)| (c - o) * s)
            .sum()
    }

    /// Append the wire form to `w`.
    pub fn encode(&self, w: &mut Writer) {
        w.put_u64_slice(&self.offset);
        w.put_u64_slice(&self.count);
    }

    /// Decode a slab from `r`.
    pub fn decode(r: &mut Reader) -> Result<Hyperslab> {
        let offset = r.get_u64_vec()?;
        let count = r.get_u64_vec()?;
        Ok(Hyperslab { offset, count })
    }
}

/// Copy the elements of `region` from `src_slab`'s buffer into
/// `dst_slab`'s buffer. `region` must be contained in both slabs.
/// Buffers are row-major over their slab's `count`; `esize` is the
/// element size in bytes. Rows of the innermost dimension are copied as
/// contiguous runs.
pub fn copy_region(
    src_slab: &Hyperslab,
    src: &[u8],
    dst_slab: &Hyperslab,
    dst: &mut [u8],
    region: &Hyperslab,
    esize: usize,
) {
    let nd = region.dims();
    if region.is_empty() {
        return;
    }
    let src_strides = src_slab.strides();
    let dst_strides = dst_slab.strides();

    if nd == 0 {
        dst[..esize].copy_from_slice(&src[..esize]);
        return;
    }

    // Iterate over all "rows": the outer nd-1 dims; copy the innermost
    // dim as one contiguous run of region.count[nd-1] elements.
    let run = region.count[nd - 1] as usize * esize;
    let mut coord = region.offset.clone();
    loop {
        let si = src_slab.element_index(&coord, &src_strides) as usize * esize;
        let di = dst_slab.element_index(&coord, &dst_strides) as usize * esize;
        dst[di..di + run].copy_from_slice(&src[si..si + run]);

        // Advance the outer dims odometer.
        let mut d = nd.wrapping_sub(2);
        loop {
            if d == usize::MAX {
                return; // odometer overflow => done
            }
            coord[d] += 1;
            if coord[d] < region.offset[d] + region.count[d] {
                break;
            }
            coord[d] = region.offset[d];
            d = d.wrapping_sub(1);
        }
    }
}

/// Split `dims` into `n` near-equal row-major chunks along axis 0 — the
/// canonical block decomposition the synthetic tasks and the paper's
/// weak-scaling setup use. Returns one slab per rank (possibly empty).
pub fn split_rows(dims: &[u64], n: usize) -> Vec<Hyperslab> {
    let rows = dims[0];
    let n64 = n as u64;
    let base = rows / n64;
    let extra = rows % n64;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for r in 0..n64 {
        let cnt = base + u64::from(r < extra);
        let mut offset = vec![0; dims.len()];
        let mut count = dims.to_vec();
        offset[0] = start;
        count[0] = cnt;
        out.push(Hyperslab { offset, count });
        start += cnt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intersect_basic() {
        let a = Hyperslab::new(&[0, 0], &[4, 4]);
        let b = Hyperslab::new(&[2, 2], &[4, 4]);
        let i = a.intersect(&b).unwrap();
        assert_eq!(i, Hyperslab::new(&[2, 2], &[2, 2]));
    }

    #[test]
    fn intersect_disjoint() {
        let a = Hyperslab::new(&[0], &[4]);
        let b = Hyperslab::new(&[4], &[4]);
        assert!(a.intersect(&b).is_none());
    }

    #[test]
    fn intersect_contained() {
        let a = Hyperslab::new(&[0, 0, 0], &[10, 10, 10]);
        let b = Hyperslab::new(&[3, 4, 5], &[1, 2, 3]);
        assert_eq!(a.intersect(&b).unwrap(), b);
        assert_eq!(b.intersect(&a).unwrap(), b);
    }

    #[test]
    fn fits_within_checks_bounds() {
        let s = Hyperslab::new(&[2], &[3]);
        assert!(s.fits_within(&[5]));
        assert!(!s.fits_within(&[4]));
        assert!(!s.fits_within(&[5, 5]));
    }

    #[test]
    fn copy_1d() {
        // src owns [2..6) of a 1-D dataset, dst wants [0..8).
        let src_slab = Hyperslab::range1d(2, 4);
        let dst_slab = Hyperslab::range1d(0, 8);
        let src: Vec<u8> = vec![10, 11, 12, 13];
        let mut dst = vec![0u8; 8];
        let region = src_slab.intersect(&dst_slab).unwrap();
        copy_region(&src_slab, &src, &dst_slab, &mut dst, &region, 1);
        assert_eq!(dst, vec![0, 0, 10, 11, 12, 13, 0, 0]);
    }

    #[test]
    fn copy_2d_subblock() {
        // 4x4 dataset; src owns rows 0..2, dst wants the centre 2x2.
        let src_slab = Hyperslab::new(&[0, 0], &[2, 4]);
        let dst_slab = Hyperslab::new(&[1, 1], &[2, 2]);
        let src: Vec<u8> = (0..8).collect(); // rows 0..2 of 4 cols
        let mut dst = vec![255u8; 4];
        let region = src_slab.intersect(&dst_slab).unwrap();
        assert_eq!(region, Hyperslab::new(&[1, 1], &[1, 2]));
        copy_region(&src_slab, &src, &dst_slab, &mut dst, &region, 1);
        // Global (1,1) and (1,2) = src row 1, cols 1..3 = values 5, 6.
        assert_eq!(dst, vec![5, 6, 255, 255]);
    }

    #[test]
    fn copy_multibyte_elements() {
        let src_slab = Hyperslab::range1d(0, 3);
        let dst_slab = Hyperslab::range1d(1, 2);
        let src: Vec<u8> = vec![1, 0, 0, 0, 2, 0, 0, 0, 3, 0, 0, 0]; // u32 LE
        let mut dst = vec![0u8; 8];
        let region = src_slab.intersect(&dst_slab).unwrap();
        copy_region(&src_slab, &src, &dst_slab, &mut dst, &region, 4);
        assert_eq!(dst, vec![2, 0, 0, 0, 3, 0, 0, 0]);
    }

    #[test]
    fn copy_3d_region() {
        // 2x2x2 src at origin of a 3x3x3 space; dst wants whole space.
        let src_slab = Hyperslab::new(&[0, 0, 0], &[2, 2, 2]);
        let dst_slab = Hyperslab::new(&[0, 0, 0], &[3, 3, 3]);
        let src: Vec<u8> = (0..8).collect();
        let mut dst = vec![99u8; 27];
        let region = src_slab.clone();
        copy_region(&src_slab, &src, &dst_slab, &mut dst, &region, 1);
        // (z,y,x) -> dst index 9z+3y+x ; src index 4z+2y+x
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..2 {
                    assert_eq!(dst[9 * z + 3 * y + x], (4 * z + 2 * y + x) as u8);
                }
            }
        }
        assert_eq!(dst[2], 99); // untouched
    }

    #[test]
    fn split_rows_covers_exactly() {
        let dims = [10u64, 3];
        let parts = split_rows(&dims, 4);
        assert_eq!(parts.len(), 4);
        let total: u64 = parts.iter().map(|s| s.count[0]).sum();
        assert_eq!(total, 10);
        // Counts are 3,3,2,2 and offsets stack.
        assert_eq!(parts[0].count[0], 3);
        assert_eq!(parts[2].offset[0], 6);
        for p in &parts {
            assert_eq!(p.count[1], 3);
            assert!(p.fits_within(&dims));
        }
    }

    #[test]
    fn split_rows_more_ranks_than_rows() {
        let parts = split_rows(&[2], 4);
        assert_eq!(parts.iter().filter(|p| p.is_empty()).count(), 2);
        let total: u64 = parts.iter().map(Hyperslab::element_count).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn scalar_slab() {
        let s = Hyperslab::new(&[], &[]);
        assert_eq!(s.element_count(), 1);
        let src = vec![7u8, 8, 9, 10];
        let mut dst = vec![0u8; 4];
        copy_region(&s, &src, &s, &mut dst, &s, 4);
        assert_eq!(dst, src);
    }

    #[test]
    fn wire_roundtrip() {
        let s = Hyperslab::new(&[1, 2, 3], &[4, 5, 6]);
        let mut w = Writer::new();
        s.encode(&mut w);
        let buf = w.into_vec();
        let mut r = Reader::new(&buf);
        assert_eq!(Hyperslab::decode(&mut r).unwrap(), s);
    }
}
