//! HDF5-like in-memory data model (the LowFive "data model
//! specification" half): files, path-named datasets, attributes, typed
//! elements and block-distributed storage.
//!
//! Groups are implicit: dataset names are full HDF5 paths such as
//! `/group1/grid`, exactly how the Wilkins YAML refers to them.

use std::collections::BTreeMap;

use crate::comm::wire::{Reader, Writer};
use crate::error::{Result, WilkinsError};

use super::hyperslab::Hyperslab;

/// Element datatypes supported by the transport (the paper's synthetic
/// benchmark uses u64 grids + f32 particles; the science payloads f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// Unsigned 8-bit integer.
    U8,
    /// Signed 32-bit integer.
    I32,
    /// Unsigned 64-bit integer (the synthetic grid).
    U64,
    /// 32-bit float (particles, science payloads).
    F32,
    /// 64-bit float.
    F64,
}

impl DType {
    /// Element size in bytes.
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::U8 => 1,
            DType::I32 | DType::F32 => 4,
            DType::U64 | DType::F64 => 8,
        }
    }

    /// Wire code of this dtype.
    pub fn code(&self) -> u8 {
        match self {
            DType::U8 => 0,
            DType::I32 => 1,
            DType::U64 => 2,
            DType::F32 => 3,
            DType::F64 => 4,
        }
    }

    /// Decode a wire dtype code.
    pub fn from_code(c: u8) -> Result<DType> {
        Ok(match c {
            0 => DType::U8,
            1 => DType::I32,
            2 => DType::U64,
            3 => DType::F32,
            4 => DType::F64,
            _ => return Err(WilkinsError::LowFive(format!("bad dtype code {c}"))),
        })
    }
}

/// Attribute values (HDF5 scalar attributes).
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    /// Integer scalar.
    Int(i64),
    /// Floating-point scalar.
    Float(f64),
    /// String scalar.
    Str(String),
}

impl AttrValue {
    /// Append the wire form to `w`.
    pub fn encode(&self, w: &mut Writer) {
        match self {
            AttrValue::Int(v) => {
                w.put_u8(0);
                w.put_i64(*v);
            }
            AttrValue::Float(v) => {
                w.put_u8(1);
                w.put_f64(*v);
            }
            AttrValue::Str(s) => {
                w.put_u8(2);
                w.put_str(s);
            }
        }
    }

    /// Decode one attribute value from `r`.
    pub fn decode(r: &mut Reader) -> Result<AttrValue> {
        Ok(match r.get_u8()? {
            0 => AttrValue::Int(r.get_i64()?),
            1 => AttrValue::Float(r.get_f64()?),
            2 => AttrValue::Str(r.get_str()?),
            c => return Err(WilkinsError::LowFive(format!("bad attr code {c}"))),
        })
    }

    /// The integer value, if this is an `Int`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            AttrValue::Int(v) => Some(*v),
            _ => None,
        }
    }
}

/// Dataset metadata: global shape + dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetMeta {
    /// Full HDF5-style path, e.g. `/group1/grid`.
    pub name: String,
    /// Element datatype.
    pub dtype: DType,
    /// Global shape.
    pub dims: Vec<u64>,
}

impl DatasetMeta {
    /// Total elements of the global shape.
    pub fn element_count(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Append the wire form to `w`.
    pub fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u8(self.dtype.code());
        w.put_u64_slice(&self.dims);
    }

    /// Decode dataset metadata from `r`.
    pub fn decode(r: &mut Reader) -> Result<DatasetMeta> {
        Ok(DatasetMeta {
            name: r.get_str()?,
            dtype: DType::from_code(r.get_u8()?)?,
            dims: r.get_u64_vec()?,
        })
    }
}

/// A locally-owned block of a dataset: the hyperslab this rank wrote
/// plus its bytes (row-major within the slab).
#[derive(Debug, Clone)]
pub struct OwnedBlock {
    /// The region this block covers (global coordinates).
    pub slab: Hyperslab,
    /// Row-major bytes within the slab.
    pub data: Vec<u8>,
}

/// A dataset as seen by one rank: global metadata + its local blocks.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Global metadata (shape + dtype).
    pub meta: DatasetMeta,
    /// This rank's owned blocks.
    pub blocks: Vec<OwnedBlock>,
}

impl Dataset {
    /// An empty dataset with the given metadata.
    pub fn new(meta: DatasetMeta) -> Dataset {
        Dataset { meta, blocks: Vec::new() }
    }

    /// Write `data` covering `slab` (must match slab element count).
    pub fn write_slab(&mut self, slab: Hyperslab, data: Vec<u8>) -> Result<()> {
        let expect = slab.element_count() as usize * self.meta.dtype.size_bytes();
        if data.len() != expect {
            return Err(WilkinsError::LowFive(format!(
                "dataset {}: slab {:?} needs {} bytes, got {}",
                self.meta.name, slab, expect, data.len()
            )));
        }
        if slab.dims() != self.meta.dims.len() {
            return Err(WilkinsError::LowFive(format!(
                "dataset {}: slab rank {} != dataset rank {}",
                self.meta.name,
                slab.dims(),
                self.meta.dims.len()
            )));
        }
        if !slab.fits_within(&self.meta.dims) {
            return Err(WilkinsError::LowFive(format!(
                "dataset {}: slab {:?} outside global dims {:?}",
                self.meta.name, slab, self.meta.dims
            )));
        }
        self.blocks.push(OwnedBlock { slab, data });
        Ok(())
    }

    /// Read the subset of `want` covered by local blocks into `out`
    /// (row-major for `want`). Returns number of elements filled.
    pub fn read_into(&self, want: &Hyperslab, out: &mut [u8]) -> u64 {
        let esize = self.meta.dtype.size_bytes();
        let mut filled = 0;
        for b in &self.blocks {
            if let Some(inter) = b.slab.intersect(want) {
                super::hyperslab::copy_region(
                    &b.slab, &b.data, want, out, &inter, esize,
                );
                filled += inter.element_count();
            }
        }
        filled
    }
}

/// An in-memory "HDF5 file": datasets by path + file attributes.
#[derive(Debug, Clone, Default)]
pub struct H5File {
    /// Filename (serves and polls match patterns against it).
    pub name: String,
    /// Datasets by full path.
    pub datasets: BTreeMap<String, Dataset>,
    /// File attributes.
    pub attrs: BTreeMap<String, AttrValue>,
}

impl H5File {
    /// A fresh, empty file.
    pub fn new(name: &str) -> H5File {
        H5File { name: name.to_string(), ..Default::default() }
    }

    /// Create a dataset; rejects duplicates.
    pub fn create_dataset(&mut self, name: &str, dtype: DType, dims: &[u64]) -> Result<()> {
        if self.datasets.contains_key(name) {
            return Err(WilkinsError::LowFive(format!(
                "dataset {name} already exists in {}",
                self.name
            )));
        }
        self.datasets.insert(
            name.to_string(),
            Dataset::new(DatasetMeta {
                name: name.to_string(),
                dtype,
                dims: dims.to_vec(),
            }),
        );
        Ok(())
    }

    /// Look up a dataset by path.
    pub fn dataset(&self, name: &str) -> Result<&Dataset> {
        self.datasets.get(name).ok_or_else(|| {
            WilkinsError::LowFive(format!("no dataset {name} in file {}", self.name))
        })
    }

    /// Mutable dataset lookup.
    pub fn dataset_mut(&mut self, name: &str) -> Result<&mut Dataset> {
        let fname = self.name.clone();
        self.datasets.get_mut(name).ok_or_else(|| {
            WilkinsError::LowFive(format!("no dataset {name} in file {fname}"))
        })
    }

    /// Names of the (implicit) groups, i.e. unique path prefixes.
    pub fn groups(&self) -> Vec<String> {
        let mut gs: Vec<String> = self
            .datasets
            .keys()
            .filter_map(|k| k.rfind('/').map(|i| k[..i].to_string()))
            .filter(|g| !g.is_empty())
            .collect();
        gs.sort();
        gs.dedup();
        gs
    }

    /// Total bytes of local block data (observability).
    pub fn local_bytes(&self) -> usize {
        self.datasets
            .values()
            .flat_map(|d| d.blocks.iter())
            .map(|b| b.data.len())
            .sum()
    }
}
