//! File-mode transport: the "traditional HDF5 files" path (YAML
//! `file: 1`). Producer I/O rank 0 writes one self-describing binary
//! file per close; consumers poll the workdir for a version they have
//! not consumed yet. An `.eof` marker ends the stream.
//!
//! The same encoding doubles as the payload of `Vol::broadcast_files`.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::comm::wire::{Reader, Writer};
use crate::error::{Result, WilkinsError};

use super::hyperslab::Hyperslab;
use super::model::{AttrValue, Dataset, DatasetMeta, H5File, OwnedBlock};
use super::pattern_matches;

const MAGIC: &[u8; 4] = b"WLF5";

/// Cap of the consumer poll loop's exponential backoff: waiting
/// consumers sleep 1 ms, 2 ms, 4 ms ... up to this, instead of
/// busy-spinning a core at a fixed 1 ms cadence.
const MAX_POLL_BACKOFF: Duration = Duration::from_millis(20);

/// How long consumer polls wait before declaring the producer dead:
/// `WILKINS_FILE_TIMEOUT_S` seconds when set to a positive integer,
/// else the comm layer's [`RECV_TIMEOUT`](crate::comm::RECV_TIMEOUT).
/// An unparsable value falls back to the default rather than erroring
/// — a consumer deep in a run has no good way to surface a config
/// error, and an unbounded wait would be worse.
pub fn poll_timeout() -> Duration {
    match std::env::var("WILKINS_FILE_TIMEOUT_S") {
        Ok(v) => match v.trim().parse::<u64>() {
            Ok(s) if s > 0 => Duration::from_secs(s),
            _ => crate::comm::RECV_TIMEOUT,
        },
        Err(_) => crate::comm::RECV_TIMEOUT,
    }
}

/// Capacity hint for encoding (a filtered view of) `file`: the data
/// bytes plus a generous per-item allowance for names, slab headers
/// and attrs, so pooled encode leases are not outgrown by
/// metadata-heavy files (an outgrown lease still encodes correctly —
/// it just pays a reallocation the accounting then reports).
pub fn encode_cap_hint(file: &H5File) -> usize {
    let items: usize = file
        .datasets
        .values()
        .map(|d| 1 + d.blocks.len())
        .sum::<usize>()
        + file.attrs.len();
    file.local_bytes() + 4096 + items * 256
}

/// Encode a set of files (used for disk files and broadcast_files).
/// Generic over the map's value ownership so the producer's shared
/// `Arc<H5File>` entries encode without a deep copy.
pub fn encode_files<F: std::borrow::Borrow<H5File>>(files: &HashMap<String, F>) -> Vec<u8> {
    let mut w = Writer::new();
    encode_files_to(&mut w, files);
    w.into_vec()
}

/// [`encode_files`] into a caller-supplied writer — the disk-write
/// path encodes straight into its pooled output buffer instead of
/// staging an owned body `Vec` first.
pub fn encode_files_to<F: std::borrow::Borrow<H5File>>(
    w: &mut Writer,
    files: &HashMap<String, F>,
) {
    w.put_u64(files.len() as u64);
    let mut names: Vec<&String> = files.keys().collect();
    names.sort();
    for name in names {
        let f: &H5File = files[name].borrow();
        encode_one_file(w, name, f, &|_| true);
    }
}

/// Encode one file keeping only the datasets `keep` accepts — the
/// disk write-through path filters file-routed datasets during
/// encoding instead of cloning them into a temporary file. The output
/// is byte-compatible with [`decode_files`] (a one-entry set).
pub fn encode_file_filtered(file: &H5File, keep: impl Fn(&str) -> bool) -> Vec<u8> {
    let mut w = Writer::new();
    encode_file_filtered_to(&mut w, file, keep);
    w.into_vec()
}

/// [`encode_file_filtered`] into a caller-supplied writer — the
/// producer engine hands in a pooled writer so the per-close archive
/// encode recycles its buffer instead of allocating per round.
pub fn encode_file_filtered_to(w: &mut Writer, file: &H5File, keep: impl Fn(&str) -> bool) {
    w.put_u64(1);
    encode_one_file(w, &file.name, file, &keep);
}

/// The single per-file encoder behind [`encode_files`] and
/// [`encode_file_filtered`]: one writer for the on-disk format, so the
/// filtered and unfiltered paths can never drift apart.
fn encode_one_file(w: &mut Writer, name: &str, f: &H5File, keep: &dyn Fn(&str) -> bool) {
    w.put_str(name);
    w.put_u64(f.attrs.len() as u64);
    for (k, v) in &f.attrs {
        w.put_str(k);
        v.encode(w);
    }
    let kept: Vec<&Dataset> = f.datasets.values().filter(|d| keep(&d.meta.name)).collect();
    w.put_u64(kept.len() as u64);
    for d in kept {
        d.meta.encode(w);
        w.put_u64(d.blocks.len() as u64);
        for b in &d.blocks {
            b.slab.encode(w);
            w.put_bytes(&b.data);
        }
    }
}

/// Decode a set of files encoded by [`encode_files`].
pub fn decode_files(bytes: &[u8]) -> Result<HashMap<String, H5File>> {
    let mut r = Reader::new(bytes);
    let nfiles = r.get_u64()? as usize;
    let mut out = HashMap::with_capacity(nfiles);
    for _ in 0..nfiles {
        let name = r.get_str()?;
        let mut f = H5File::new(&name);
        let nattrs = r.get_u64()? as usize;
        for _ in 0..nattrs {
            let k = r.get_str()?;
            f.attrs.insert(k, AttrValue::decode(&mut r)?);
        }
        let nds = r.get_u64()? as usize;
        for _ in 0..nds {
            let meta = DatasetMeta::decode(&mut r)?;
            f.create_dataset(&meta.name.clone(), meta.dtype, &meta.dims)?;
            let nblocks = r.get_u64()? as usize;
            let d = f.dataset_mut(&meta.name)?;
            for _ in 0..nblocks {
                let slab = Hyperslab::decode(&mut r)?;
                let data = r.get_bytes()?.to_vec();
                d.blocks.push(OwnedBlock { slab, data });
            }
        }
        out.insert(name, f);
    }
    Ok(out)
}

/// Merge `src` into `dst`: union of attrs, datasets and blocks.
pub fn merge_file(dst: &mut H5File, src: H5File) {
    for (k, v) in src.attrs {
        dst.attrs.entry(k).or_insert(v);
    }
    for (name, d) in src.datasets {
        match dst.datasets.get_mut(&name) {
            Some(existing) => existing.blocks.extend(d.blocks),
            None => {
                dst.datasets.insert(name, d);
            }
        }
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect()
}

fn disk_path(workdir: &Path, name: &str, version: u64) -> PathBuf {
    workdir.join(format!("{}.v{version}.l5", sanitize(name)))
}

fn eof_path(workdir: &Path, pattern: &str) -> PathBuf {
    workdir.join(format!("{}.eof", sanitize(pattern)))
}

/// Write one versioned disk file atomically (tmp + rename). The
/// on-disk image is assembled in one pooled buffer (magic + header +
/// body encoded in place — no staging `Vec` per close) that recycles
/// after the write. The body's length prefix is backfilled so the
/// body really is encoded in place.
pub fn write_file(workdir: &Path, file: &H5File, version: u64) -> Result<()> {
    fs::create_dir_all(workdir)?;
    // Sized from the file's own bytes plus per-item metadata slack
    // ([`encode_cap_hint`]) so the encode does not outgrow the lease.
    let mut w = if crate::comm::buf::pooling_enabled() {
        Writer::pooled(crate::comm::buf::pool(), encode_cap_hint(file))
    } else {
        Writer::new()
    };
    w.put_raw(MAGIC);
    w.put_u64(version);
    w.put_str(&file.name);
    // Body, length-prefixed: reserve the prefix slot, encode the body
    // in place (borrowing through the map — no deep copy of the
    // merged blocks, no staging Vec), then backfill the length.
    let len_at = w.len();
    w.put_u64(0);
    let body_start = w.len();
    encode_files_to(&mut w, &HashMap::from([(file.name.clone(), file)]));
    let body_len = (w.len() - body_start) as u64;
    w.set_u64_at(len_at, body_len);
    let final_path = disk_path(workdir, &file.name, version);
    let tmp = final_path.with_extension("tmp");
    fs::write(&tmp, &w.finish())?;
    fs::rename(&tmp, &final_path)?;
    Ok(())
}

/// Mark the stream for `pattern` finished.
pub fn write_eof(workdir: &Path, pattern: &str) -> Result<()> {
    fs::create_dir_all(workdir)?;
    fs::write(eof_path(workdir, pattern), b"eof")?;
    Ok(())
}

fn read_disk_file(path: &Path) -> Result<(String, u64, H5File)> {
    let bytes = fs::read(path)?;
    if bytes.len() < 4 || &bytes[..4] != MAGIC {
        return Err(WilkinsError::LowFive(format!(
            "bad magic in {}",
            path.display()
        )));
    }
    let mut r = Reader::new(&bytes[4..]);
    let version = r.get_u64()?;
    let name = r.get_str()?;
    let body = r.get_bytes()?;
    let files = decode_files(body)?;
    let file = files
        .into_iter()
        .next()
        .map(|(_, f)| f)
        .ok_or_else(|| WilkinsError::LowFive("empty disk file".into()))?;
    Ok((name, version, file))
}

/// Poll `workdir` for a file whose embedded name matches `pattern` and
/// whose version is >= `min_version`. Returns the lowest such version
/// (preserving timestep order), or None once the EOF marker exists and
/// nothing newer is available.
pub fn poll_file(
    workdir: &Path,
    pattern: &str,
    min_version: u64,
    deadline: Instant,
) -> Result<Option<(H5File, u64)>> {
    poll_matching(
        workdir,
        pattern,
        |v| v >= min_version,
        true,
        deadline,
        &format!("version >= {min_version}"),
    )
}

/// Poll `workdir` for the disk file of *exactly* `version` — the
/// mixed-route consumer path: the memory round names the version its
/// file-routed datasets were archived under
/// ([`route::DISK_VERSION_ATTR`](super::route)). The producer writes
/// the disk file before serving the round, so this normally returns
/// on the first pass; the deadline guards against a producer that
/// died in between.
pub fn poll_file_exact(
    workdir: &Path,
    pattern: &str,
    version: u64,
    deadline: Instant,
) -> Result<H5File> {
    poll_matching(
        workdir,
        pattern,
        |v| v == version,
        false,
        deadline,
        &format!("version == {version}"),
    )?
    .map(|(file, _)| file)
    .ok_or_else(|| {
        WilkinsError::LowFive(format!(
            "disk stream for {pattern} ended before version {version}"
        ))
    })
}

/// The single polling loop behind both consumer poll paths: scan the
/// workdir for the lowest `accept`ed version of `pattern`, sleeping
/// with exponential backoff between passes. `stop_on_eof` returns
/// `Ok(None)` once the stream's EOF marker exists (the sequential
/// consumer path); without it only the deadline ends the wait.
fn poll_matching(
    workdir: &Path,
    pattern: &str,
    accept: impl Fn(u64) -> bool,
    stop_on_eof: bool,
    deadline: Instant,
    what: &str,
) -> Result<Option<(H5File, u64)>> {
    let mut backoff = Duration::from_millis(1);
    loop {
        let mut best: Option<(u64, PathBuf)> = None;
        if workdir.is_dir() {
            for entry in fs::read_dir(workdir)? {
                let path = entry?.path();
                let fname = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
                if !fname.ends_with(".l5") {
                    continue;
                }
                if let Ok((name, version, _)) = read_header(&path) {
                    if accept(version)
                        && pattern_matches(pattern, &name)
                        && best.as_ref().map_or(true, |(v, _)| version < *v)
                    {
                        best = Some((version, path));
                    }
                }
            }
        }
        if let Some((_, path)) = best {
            let (_, version, file) = read_disk_file(&path)?;
            return Ok(Some((file, version)));
        }
        if stop_on_eof && eof_path(workdir, pattern).exists() {
            return Ok(None);
        }
        if Instant::now() >= deadline {
            return Err(WilkinsError::LowFive(format!(
                "timed out polling for {pattern} ({what})"
            )));
        }
        // Exponential backoff: waiting must not burn a core.
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(MAX_POLL_BACKOFF);
    }
}

/// Cheap header-only read (version + embedded name).
fn read_header(path: &Path) -> Result<(String, u64, ())> {
    use std::io::Read;
    let mut f = fs::File::open(path)?;
    let mut head = [0u8; 4 + 8];
    f.read_exact(&mut head)?;
    if &head[..4] != MAGIC {
        return Err(WilkinsError::LowFive("bad magic".into()));
    }
    let version = u64::from_le_bytes(head[4..12].try_into().unwrap());
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let nlen = u64::from_le_bytes(lenb) as usize;
    let mut nameb = vec![0u8; nlen];
    f.read_exact(&mut nameb)?;
    let name = String::from_utf8(nameb)
        .map_err(|e| WilkinsError::LowFive(format!("bad name: {e}")))?;
    Ok((name, version, ()))
}
