//! LowFive reimplementation (substrate S5): data model, hyperslab
//! redistribution, memory/file transports, callbacks.
//!
//! The real LowFive is an HDF5 Virtual Object Layer plugin; task codes
//! keep calling HDF5 and the plugin intercepts the I/O. Here the
//! equivalent seam is the [`Vol`] object's HDF5-like API
//! (`file_create` / `dataset_write` / `file_close` / `file_open` /
//! `dataset_read`): task codes call only this generic API and never see
//! workflow machinery, preserving the paper's "no task-code changes"
//! property in spirit.

pub mod filemode;
pub mod hyperslab;
pub mod model;
pub mod protocol;
mod vol;

pub use hyperslab::{split_rows, Hyperslab};
pub use model::{AttrValue, DType, DatasetMeta, H5File};
pub use vol::{Callbacks, ChannelMode, ConsumerFile, InChannel, OutChannel, Vol, VolStats};

/// Filename/dataset glob matching (`plt*.h5`, `/particles/*`, exact
/// names). Invalid patterns fall back to string equality.
pub fn pattern_matches(pattern: &str, name: &str) -> bool {
    if pattern == name {
        return true;
    }
    match glob::Pattern::new(pattern) {
        Ok(p) => p.matches(name),
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests;
