//! LowFive reimplementation (substrate S5): data model, hyperslab
//! redistribution, the routed data plane (per-dataset memory / file /
//! write-through transports), callbacks.
//!
//! The real LowFive is an HDF5 Virtual Object Layer plugin; task codes
//! keep calling HDF5 and the plugin intercepts the I/O. Here the
//! equivalent seam is the [`Vol`] object's HDF5-like API
//! (`file_create` / `dataset_write` / `file_close` / `file_open` /
//! `dataset_read`): task codes call only this generic API and never see
//! workflow machinery, preserving the paper's "no task-code changes"
//! property in spirit.
//!
//! Module map: the [`Vol`] facade is the task-facing API; [`producer`] and
//! [`consumer`] are the two engine halves behind it; [`route`] holds
//! the per-dataset transport routing; [`model`], [`hyperslab`],
//! [`protocol`] and [`filemode`] are the shared data model, block
//! algebra, wire protocol and disk format.

pub mod consumer;
pub mod filemode;
pub mod hyperslab;
pub mod model;
pub mod producer;
pub mod protocol;
pub mod route;
pub mod stats;
mod vol;

pub use consumer::{ConsumerFile, InChannel};
pub use hyperslab::{split_rows, Hyperslab};
pub use model::{AttrValue, DType, DatasetMeta, H5File};
pub use producer::OutChannel;
pub use route::{Route, RouteTable};
pub use stats::VolStats;
pub use vol::{Callbacks, Vol};

/// Filename/dataset glob matching (`plt*.h5`, `/particles/*`, exact
/// names). Invalid patterns fall back to string equality.
pub fn pattern_matches(pattern: &str, name: &str) -> bool {
    if pattern == name {
        return true;
    }
    match glob::Pattern::new(pattern) {
        Ok(p) => p.matches(name),
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests;
