//! Producer engine of the routed data plane: per-channel serve rounds
//! over the flow layer, per-dataset transport routing, disk
//! write-through, and the zero-copy fast path for same-process
//! consumers.
//!
//! One `ProducerEngine` lives inside each [`Vol`](super::Vol). A
//! producer file close becomes, per matching channel:
//!
//! * a **disk write** of the file/both-routed dataset union (one
//!   versioned file per close, shared by every file-mode consumer),
//! * a **memory round** admitted through the channel's [`LinkState`]
//!   per its flow policy. The round shares the producer's file `Arc`
//!   (no bytes move at admission); what a channel *delivers* is
//!   decided at metadata time — file-only datasets are never
//!   advertised, so consumers never request them over memory.
//!
//! Mixed channels stamp the disk version of the same close into the
//! round's delivered metadata (see
//! [`route::DISK_VERSION_ATTR`](super::route)), so the consumer
//! engine can fetch the file-routed datasets of exactly that round.
//!
//! Data requests from consumer ranks hosted in the *same OS process*
//! skip the encode/deliver/decode copies entirely: the snapshot `Arc`
//! is parked in the process-local registry and only a token crosses
//! the mailbox ([`VolStats::bytes_shared`] vs
//! [`VolStats::bytes_copied`]).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::InterComm;
use crate::error::{Result, WilkinsError};
use crate::flow::{ChannelPolicy, FlowControl, LinkState, Plan, PlanOp};
use crate::metrics::SpanKind;

use super::hyperslab::Hyperslab;
use super::model::{AttrValue, H5File};
use super::protocol::{
    encode_shared_reply, FileMeta, Reply, Request, REQ_DATA_DISCRIMINANT, TAG_REP, TAG_REQ,
};
use super::route::{self, RouteTable, DISK_VERSION_ATTR};
use super::stats::{EngineCx, VolStats};
use super::{filemode, pattern_matches};

/// Producer-side channel to one consumer task. Versions are monotonic
/// per channel (not per file) so globbed multi-file streams like
/// plt*.h5 stay ordered; the round buffer, credit window and drop
/// accounting live in the channel's [`LinkState`] (the flow layer).
pub struct OutChannel {
    /// Intercommunicator to the consumer task's ranks (None on
    /// non-I/O ranks and on pure file-mode channels).
    pub intercomm: Option<InterComm>,
    /// Producer-side filename pattern (what file closes serve on).
    pub pattern: String,
    /// Per-dataset transport routing of this channel.
    pub routes: RouteTable,
    /// Flow engine: bounded round buffer + credits (Sec. 3.6).
    /// Round snapshots are `Arc`s of the producer's in-memory file:
    /// admission is O(1), and the producer's next write to the file
    /// copy-on-writes (`Arc::make_mut`) only while a buffered round
    /// still references the old bytes.
    link: LinkState<Arc<H5File>>,
    /// MetaReqs pulled out of the mailbox that no buffered round can
    /// answer yet (fast consumer re-opened early, or everything it
    /// could read was dropped).
    deferred: VecDeque<(usize, Request)>,
    /// Round version → disk version written on the same close, for
    /// mixed channels (file-only datasets present): delivered
    /// metadata carries it so the consumer polls exactly the matching
    /// archive. Pruned as rounds retire.
    disk_of: HashMap<u64, u64>,
}

impl OutChannel {
    /// A fresh channel with the default (synchronous block) policy.
    pub fn new(intercomm: Option<InterComm>, pattern: &str, routes: RouteTable) -> OutChannel {
        let remote = intercomm.as_ref().map_or(0, |ic| ic.remote_size());
        OutChannel {
            intercomm,
            pattern: pattern.to_string(),
            routes,
            link: LinkState::new(ChannelPolicy::block(), remote),
            deferred: VecDeque::new(),
            disk_of: HashMap::new(),
        }
    }

    /// Set the channel's flow policy (resets the link's round buffer;
    /// call before the first serve).
    pub fn with_policy(mut self, policy: ChannelPolicy) -> OutChannel {
        let remote = self.intercomm.as_ref().map_or(0, |ic| ic.remote_size());
        self.link = LinkState::new(policy, remote);
        self.disk_of.clear();
        self
    }

    /// Legacy sugar: lower a three-mode strategy onto its policy.
    pub fn with_flow(self, flow: FlowControl) -> OutChannel {
        self.with_policy(flow.lower())
    }

    /// The channel's flow policy.
    pub fn policy(&self) -> ChannelPolicy {
        self.link.policy()
    }
}

/// The producer half of a [`Vol`](super::Vol): out-channels plus the
/// disk-write version counter.
#[derive(Default)]
pub(super) struct ProducerEngine {
    pub(super) channels: Vec<OutChannel>,
    /// Monotonic version for file-routed disk writes.
    disk_version: u64,
    /// File-mode serves (disk writes) completed, folded into
    /// `files_served` alongside the memory channels' completions.
    disk_serves: u64,
}

impl ProducerEngine {
    /// Are there pending (unanswered) consumer requests for files
    /// matching this name? Drives the *latest* flow-control strategy.
    pub(super) fn any_pending_requests(&self, filename: &str) -> bool {
        self.channels.iter().any(|ch| {
            ch.routes.any_memory()
                && pattern_matches(&ch.pattern, filename)
                && (!ch.deferred.is_empty()
                    || ch.intercomm.as_ref().is_some_and(|ic| ic.iprobe(TAG_REQ)))
        })
    }

    /// Serve one file close: write the file-routed dataset union to
    /// disk (once), then admit one memory round per matching channel,
    /// subject to each channel's flow policy (the decision lives in
    /// [`LinkState`], not here).
    pub(super) fn serve_file(
        &mut self,
        cx: &mut EngineCx<'_>,
        name: &str,
        file: &Arc<H5File>,
    ) -> Result<()> {
        let t0 = Instant::now();

        // Disk side: one versioned file per close carrying the union
        // of datasets any matching channel archives (file or both).
        let file_idx: Vec<usize> = (0..self.channels.len())
            .filter(|&i| {
                self.channels[i].routes.any_file()
                    && pattern_matches(&self.channels[i].pattern, name)
            })
            .collect();
        let mut disk_written = None;
        if !file_idx.is_empty() {
            let disk_dsets: Vec<String> = file
                .datasets
                .keys()
                .filter(|d| {
                    file_idx
                        .iter()
                        .any(|&i| self.channels[i].routes.archives_to_disk(d))
                })
                .cloned()
                .collect();
            // Every close of a file-routed channel writes a versioned
            // file, even when no dataset archives this close — an
            // attr-only close (the nyx metadata pattern) must still
            // reach file-mode consumers, exactly as it always did.
            self.disk_version += 1;
            let v = self.disk_version;
            write_disk_file(cx, file, v, &disk_dsets)?;
            self.disk_serves += 1;
            disk_written = Some(v);
        }

        // Memory side: one admission per matching channel. The round
        // shares the file Arc (zero-copy admission); delivered
        // metadata is filtered per the channel's routes, so file-only
        // datasets never travel over memory.
        let mem_idx: Vec<usize> = (0..self.channels.len())
            .filter(|&i| {
                self.channels[i].routes.any_memory()
                    && self.channels[i].intercomm.is_some()
                    && pattern_matches(&self.channels[i].pattern, name)
            })
            .collect();
        for idx in mem_idx {
            if !self.channels[idx].link.note_attempt() {
                continue; // `every`-gated close (counted by the link)
            }
            // Mixed channels must point their consumers at the disk
            // half of this very close; memory-only channels carry no
            // disk pointer.
            let disk = disk_written.filter(|_| self.channels[idx].routes.any_file_only());
            self.enqueue_round(cx, idx, Arc::clone(file), disk)?;
        }
        cx.stats.serve_wait += t0.elapsed();
        cx.record_span_with(
            SpanKind::Transfer,
            &format!("serve {name}"),
            t0,
            vec![
                ("file".into(), name.to_string()),
                ("bytes_served".into(), cx.stats.bytes_served.to_string()),
            ],
        );
        self.sync_flow_stats(cx.stats);
        Ok(())
    }

    /// Fold the per-link flow counters into the rank's `VolStats`
    /// (the links are the single source of truth).
    ///
    /// `files_served` counts rounds actually *consumed*: the busiest
    /// memory channel's completions (channels at different cadences
    /// overlap on the same closes, so summing would double-count) plus
    /// file-mode disk writes. Rounds a dropping policy discarded never
    /// count — they are `serves_dropped`.
    pub(super) fn sync_flow_stats(&self, stats: &mut VolStats) {
        let mut skipped = 0;
        let mut dropped = 0;
        let mut completed = 0;
        let mut stalled = Duration::ZERO;
        let mut maxq = 0;
        for ch in &self.channels {
            skipped += ch.link.stats.skipped;
            dropped += ch.link.stats.dropped;
            completed = completed.max(ch.link.stats.completed);
            stalled += ch.link.stats.stalled;
            maxq = maxq.max(ch.link.stats.max_queue_depth);
        }
        stats.files_served = self.disk_serves.max(completed);
        stats.serves_skipped = skipped;
        stats.serves_dropped = dropped;
        stats.stall_wait = stalled;
        stats.max_queue_depth = maxq;
    }

    /// Admit one round on one channel per its policy.
    ///
    /// Blocking policies need no cross-rank coordination (no drops;
    /// deliveries are a pure function of the buffer, which every
    /// writer rank mutates through the identical push sequence).
    /// Dropping policies are coordinated by I/O rank 0's section plan
    /// (see the [`crate::flow`] module docs).
    fn enqueue_round(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        snapshot: Arc<H5File>,
        disk: Option<u64>,
    ) -> Result<()> {
        if self.channels[idx].link.policy().mode.drops() {
            self.enqueue_dropping(cx, idx, snapshot, disk)
        } else {
            self.enqueue_block(cx, idx, snapshot, disk)
        }
    }

    /// Record the disk version of a freshly pushed round (mixed
    /// channels) and prune mappings of retired rounds.
    fn track_disk(&mut self, idx: usize, pushed: Option<u64>, disk: Option<u64>) {
        let ch = &mut self.channels[idx];
        let (link, disk_of) = (&ch.link, &mut ch.disk_of);
        if let (Some(v), Some(dv)) = (pushed, disk) {
            disk_of.insert(v, dv);
        }
        disk_of.retain(|v, _| link.round(*v).is_some());
    }

    fn enqueue_block(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        snapshot: Arc<H5File>,
        disk: Option<u64>,
    ) -> Result<()> {
        self.pump_available(cx, idx, None)?;
        let v = self.channels[idx].link.push(snapshot);
        self.track_disk(idx, Some(v), disk);
        self.answer_deferred(idx, None)?;
        let target = self.channels[idx].link.policy().depth.saturating_sub(1);
        if self.channels[idx].link.occupancy() > target {
            // Out of credits: stall until enough rounds complete.
            let t0 = Instant::now();
            while self.channels[idx].link.occupancy() > target {
                self.pump_one_blocking(cx, idx)?;
            }
            self.channels[idx].link.note_stall(t0.elapsed());
            cx.record_span_with(
                SpanKind::Stall,
                "flow stall",
                t0,
                vec![("channel".into(), idx.to_string())],
            );
        }
        Ok(())
    }

    fn enqueue_dropping(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        snapshot: Arc<H5File>,
        disk: Option<u64>,
    ) -> Result<()> {
        let io = cx
            .io_comm
            .ok_or_else(|| WilkinsError::LowFive("dropping flow policy on non-io rank".into()))?;
        if io.rank() == 0 {
            let mut plan = Plan::default();
            self.pump_available(cx, idx, Some(&mut plan))?;
            let admission = self.channels[idx].link.admit(snapshot);
            self.track_disk(idx, admission.pushed, disk);
            for v in &admission.dropped {
                plan.ops.push(PlanOp::Drop { version: *v });
            }
            match admission.pushed {
                Some(v) => plan.ops.push(PlanOp::Push { version: v }),
                None => plan.ops.push(PlanOp::DropIncoming),
            }
            self.answer_deferred(idx, Some(&mut plan))?;
            if io.size() > 1 {
                io.bcast(0, Some(&plan.encode()))?;
            }
        } else {
            let bytes = io.bcast(0, None)?;
            let plan = Plan::decode(&bytes)?;
            self.replay_plan(cx, idx, snapshot, plan, disk)?;
        }
        Ok(())
    }

    /// Absorb every request already waiting in the mailbox for channel
    /// `idx` (non-blocking). With `plan`, record the state-mutating
    /// events so other writer ranks can replay them.
    fn pump_available(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        mut plan: Option<&mut Plan>,
    ) -> Result<()> {
        loop {
            let Some(ic) = self.channels[idx].intercomm.clone() else {
                return Ok(());
            };
            let Some((src, bytes)) = ic.try_recv_any(TAG_REQ) else {
                return Ok(());
            };
            let req = Request::decode(&bytes)?;
            self.handle_request(cx, idx, src, req, plan.as_deref_mut())?;
        }
    }

    /// Block for one request on channel `idx` and process it.
    fn pump_one_blocking(&mut self, cx: &mut EngineCx<'_>, idx: usize) -> Result<()> {
        let ic = self.channels[idx].intercomm.as_ref().unwrap().clone();
        let (src, bytes) = ic.recv_any(TAG_REQ)?;
        let req = Request::decode(&bytes)?;
        self.handle_request(cx, idx, src, req, None)
    }

    /// Process one consumer request against channel `idx`.
    fn handle_request(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        src: usize,
        req: Request,
        plan: Option<&mut Plan>,
    ) -> Result<()> {
        match req {
            Request::MetaReq { pattern, min_version } => {
                match self.channels[idx].link.choose_deliver(src, min_version) {
                    Some(v) => {
                        self.deliver_meta(idx, src, v)?;
                        if let Some(p) = plan {
                            p.ops.push(PlanOp::Deliver { j: src as u64, version: v });
                        }
                    }
                    // No buffered round can answer yet: defer until a
                    // later push (or the EOF handshake).
                    None => self.channels[idx]
                        .deferred
                        .push_back((src, Request::MetaReq { pattern, min_version })),
                }
            }
            Request::DataReq { ref file, ref dset, ref slab } => {
                self.answer_data_req(cx, idx, src, file, dset, slab)?;
            }
            Request::Done { version } => {
                self.channels[idx].link.mark_done(version, src)?;
                if let Some(p) = plan {
                    p.ops.push(PlanOp::Done { j: src as u64, version });
                }
            }
            Request::EofAck => {
                self.channels[idx].link.mark_eof(src);
                if let Some(p) = plan {
                    p.ops.push(PlanOp::Eof { j: src as u64 });
                }
            }
        }
        Ok(())
    }

    /// Answer a MetaReq with buffered round `version` and mark it
    /// delivered to consumer rank `src`. The metadata is the
    /// channel's *routed* view of the round: file-only datasets are
    /// withheld, and mixed rounds carry the disk version the consumer
    /// must poll for them.
    fn deliver_meta(&mut self, idx: usize, src: usize, version: u64) -> Result<()> {
        let rep = {
            let ch = &self.channels[idx];
            let round = ch.link.round(version).ok_or_else(|| {
                WilkinsError::LowFive(format!("deliver of unknown round v{version}"))
            })?;
            let disk = ch.disk_of.get(&version).copied();
            Reply::Meta(snapshot_meta(&round.snapshot, version, &ch.routes, disk)).encode()
        };
        let ic = self.channels[idx].intercomm.as_ref().unwrap().clone();
        ic.send_owned(src, TAG_REP, rep);
        self.channels[idx].link.mark_delivered(version, src)
    }

    /// Answer a DataReq from the round consumer rank `src` has open.
    ///
    /// Same-process consumers take the zero-copy path: the snapshot
    /// `Arc` is parked in the shared registry and only a token crosses
    /// the mailbox; the consumer copies block regions straight out of
    /// the shared file. Remote (or fast-path-disabled) consumers get
    /// the classic encoded reply.
    fn answer_data_req(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        src: usize,
        file: &str,
        dset: &str,
        slab: &Hyperslab,
    ) -> Result<()> {
        let snapshot = {
            let round = self.channels[idx].link.open_round(src).ok_or_else(|| {
                WilkinsError::LowFive(format!(
                    "data request for {file} from rank {src} with no open round"
                ))
            })?;
            if round.snapshot.name != file {
                return Err(WilkinsError::LowFive(format!(
                    "data request for {file} against round of {}",
                    round.snapshot.name
                )));
            }
            Arc::clone(&round.snapshot)
        };
        let ic = self.channels[idx].intercomm.as_ref().unwrap();
        if cx.zero_copy && ic.remote_is_local(src) {
            let nbytes = shared_reply_bytes(&snapshot, dset, slab)?;
            let token = route::share_snapshot(snapshot);
            ic.send_owned(src, TAG_REP, encode_shared_reply(token));
            cx.stats.bytes_served += nbytes as u64;
            cx.stats.bytes_shared += nbytes as u64;
            return Ok(());
        }
        let (rep, nbytes, pool_hit) = encode_data_reply(&snapshot, dset, slab, cx.pooling)?;
        cx.stats.bytes_served += nbytes as u64;
        cx.stats.bytes_copied += nbytes as u64;
        if pool_hit {
            cx.stats.bytes_pooled += rep.len() as u64;
        } else {
            // A fresh allocation on the serve hot path: the warm-up
            // rounds and the ablation arm land here; steady state
            // must not (the acceptance bar benches/wire.rs asserts).
            cx.stats.alloc_rounds += 1;
        }
        ic.send_owned(src, TAG_REP, rep);
        Ok(())
    }

    /// Re-examine deferred MetaReqs: a newly pushed round may satisfy
    /// them. Answered requests are recorded into `plan` when given.
    fn answer_deferred(&mut self, idx: usize, mut plan: Option<&mut Plan>) -> Result<()> {
        let mut keep = VecDeque::new();
        while let Some((src, req)) = self.channels[idx].deferred.pop_front() {
            let min_version = match &req {
                Request::MetaReq { min_version, .. } => *min_version,
                _ => {
                    keep.push_back((src, req));
                    continue;
                }
            };
            match self.channels[idx].link.choose_deliver(src, min_version) {
                Some(v) => {
                    self.deliver_meta(idx, src, v)?;
                    if let Some(p) = plan.as_deref_mut() {
                        p.ops.push(PlanOp::Deliver { j: src as u64, version: v });
                    }
                }
                None => keep.push_back((src, req)),
            }
        }
        self.channels[idx].deferred = keep;
        Ok(())
    }

    /// Replay I/O rank 0's section plan against our own mailbox: apply
    /// buffer mutations verbatim and consume exactly the planned
    /// protocol events from each consumer rank's (FIFO) request
    /// stream, answering our own DataReqs along the way. See the
    /// [`crate::flow`] module docs for why this keeps writer ranks'
    /// buffers bit-identical.
    fn replay_plan(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        snapshot: Arc<H5File>,
        plan: Plan,
        disk: Option<u64>,
    ) -> Result<()> {
        let mut snapshot = Some(snapshot);
        self.drain_data_reqs(cx, idx)?;
        for op in plan.ops {
            match op {
                PlanOp::Drop { version } => {
                    self.channels[idx].link.drop_version(version)?;
                }
                PlanOp::Push { version } => {
                    let snap = snapshot
                        .take()
                        .ok_or_else(|| WilkinsError::LowFive("flow plan pushes twice".into()))?;
                    let v = self.channels[idx].link.push(snap);
                    if v != version {
                        return Err(WilkinsError::LowFive(format!(
                            "flow plan version skew: local v{v}, plan v{version}"
                        )));
                    }
                    self.track_disk(idx, Some(v), disk);
                }
                PlanOp::DropIncoming => {
                    snapshot.take();
                    self.channels[idx].link.note_drop_incoming();
                }
                PlanOp::Deliver { j, version } => {
                    self.replay_expect(cx, idx, j as usize, Expect::Meta(version))?;
                }
                PlanOp::Done { j, version } => {
                    self.replay_expect(cx, idx, j as usize, Expect::Done(version))?;
                }
                PlanOp::Eof { j } => {
                    self.replay_expect(cx, idx, j as usize, Expect::Eof)?;
                }
            }
        }
        self.drain_data_reqs(cx, idx)?;
        Ok(())
    }

    /// Consume consumer rank `j`'s request stream up to (and
    /// including) the expected protocol event, answering DataReqs
    /// encountered on the way.
    fn replay_expect(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        j: usize,
        expect: Expect,
    ) -> Result<()> {
        loop {
            let ic = self.channels[idx].intercomm.as_ref().unwrap().clone();
            let (_, bytes) = ic.recv(j, TAG_REQ)?;
            let req = Request::decode(&bytes)?;
            match (req, expect) {
                (Request::DataReq { ref file, ref dset, ref slab }, _) => {
                    self.answer_data_req(cx, idx, j, file, dset, slab)?;
                }
                (Request::MetaReq { .. }, Expect::Meta(v)) => {
                    return self.deliver_meta(idx, j, v);
                }
                (Request::Done { version }, Expect::Done(v)) if version == v => {
                    self.channels[idx].link.mark_done(v, j)?;
                    return Ok(());
                }
                (Request::EofAck, Expect::Eof) => {
                    self.channels[idx].link.mark_eof(j);
                    return Ok(());
                }
                (other, _) => {
                    return Err(WilkinsError::LowFive(format!(
                        "flow plan replay: expected {expect:?} from rank {j}, got {other:?}"
                    )));
                }
            }
        }
    }

    /// Answer every DataReq already queued for channel `idx` without
    /// absorbing any plan-owned protocol event (payload-discriminant
    /// selective receive). Lets non-leader writer ranks keep consumer
    /// reads flowing between coordinated sections.
    fn drain_data_reqs(&mut self, cx: &mut EngineCx<'_>, idx: usize) -> Result<()> {
        loop {
            let Some(ic) = self.channels[idx].intercomm.clone() else {
                return Ok(());
            };
            let Some((src, bytes)) =
                ic.try_recv_where(TAG_REQ, |p| p.first() == Some(&REQ_DATA_DISCRIMINANT))
            else {
                return Ok(());
            };
            match Request::decode(&bytes)? {
                Request::DataReq { ref file, ref dset, ref slab } => {
                    self.answer_data_req(cx, idx, src, file, dset, slab)?;
                }
                other => {
                    return Err(WilkinsError::LowFive(format!(
                        "selective DataReq receive returned {other:?}"
                    )));
                }
            }
        }
    }

    /// Producer finalize: drop the disk EOF marker for file-routed
    /// channels, flush every memory channel's round buffer (each
    /// buffered round is delivered and completed — dropping policies
    /// stop dropping at shutdown so consumers get the freshest data),
    /// then signal EOF and wait for every consumer rank to
    /// acknowledge. Idempotent. Mixed channels do both.
    pub(super) fn finalize(&mut self, cx: &mut EngineCx<'_>) -> Result<()> {
        for idx in 0..self.channels.len() {
            if self.channels[idx].routes.any_file() {
                let io = cx
                    .io_comm
                    .ok_or_else(|| WilkinsError::LowFive("file mode on non-io rank".into()))?;
                if io.rank() == 0 {
                    filemode::write_eof(cx.workdir, &self.channels[idx].pattern)?;
                }
            }
            if !self.channels[idx].routes.any_memory()
                || self.channels[idx].intercomm.is_none()
            {
                continue;
            }
            // 1. Flush: every buffered round must complete before EOF.
            //    Buffer mutations during flush are completions only,
            //    so writer ranks stay consistent without a section
            //    plan.
            while self.channels[idx].link.occupancy() > 0 {
                self.answer_deferred(idx, None)?;
                if self.channels[idx].link.occupancy() == 0 {
                    break;
                }
                self.pump_one_blocking(cx, idx)?;
            }
            // 2. EOF handshake: answer remaining open requests with
            //    Eof until every consumer rank acked.
            while self.channels[idx].link.acked_count() < self.channels[idx].link.nconsumers() {
                let (src, req) = match self.channels[idx].deferred.pop_front() {
                    Some(x) => x,
                    None => {
                        let ic = self.channels[idx].intercomm.as_ref().unwrap();
                        let (src, bytes) = ic.recv_any(TAG_REQ)?;
                        (src, Request::decode(&bytes)?)
                    }
                };
                match req {
                    Request::MetaReq { .. } => {
                        let ic = self.channels[idx].intercomm.as_ref().unwrap();
                        ic.send(src, TAG_REP, &Reply::Eof.encode());
                    }
                    Request::EofAck => {
                        self.channels[idx].link.mark_eof(src);
                    }
                    Request::Done { .. } => {} // stale, ignore
                    Request::DataReq { .. } => {
                        return Err(WilkinsError::LowFive(
                            "data request after finalize".into(),
                        ))
                    }
                }
            }
        }
        self.sync_flow_stats(cx.stats);
        Ok(())
    }
}

/// The protocol event a plan replay is waiting for.
#[derive(Debug, Clone, Copy)]
enum Expect {
    /// A MetaReq, to be answered with this round version.
    Meta(u64),
    /// A Done for this round version.
    Done(u64),
    /// An EofAck.
    Eof,
}

/// Gather every I/O rank's file/both-routed blocks to I/O rank 0,
/// which writes one versioned disk file (the "traditional HDF5 file"
/// path). Encoding filters datasets in place — no intermediate clone
/// of the block bytes.
fn write_disk_file(
    cx: &mut EngineCx<'_>,
    file: &H5File,
    version: u64,
    dsets: &[String],
) -> Result<()> {
    let io = cx
        .io_comm
        .ok_or_else(|| WilkinsError::LowFive("file mode on non-io rank".into()))?;
    // Disk encodes ride the pool too: the versioned-archive path runs
    // once per close, so steady state reuses one warm buffer. Sized
    // from the file's bytes plus per-item metadata slack so the
    // encode does not outgrow the lease (growth would be a hidden,
    // uncredited reallocation).
    let mut w = if cx.pooling {
        crate::comm::wire::Writer::pooled(
            crate::comm::buf::pool(),
            filemode::encode_cap_hint(file),
        )
    } else {
        crate::comm::wire::Writer::new()
    };
    filemode::encode_file_filtered_to(&mut w, file, |d| dsets.iter().any(|k| k == d));
    // Evaluated after encoding: a hit that had to reallocate mid-
    // encode does not count as pooled.
    let hit = w.pool_hit();
    let mine = w.finish();
    if hit {
        cx.stats.bytes_pooled += mine.len() as u64;
    }
    let gathered = io.gather(0, &mine)?;
    if let Some(parts) = gathered {
        let mut merged = H5File::new(&file.name);
        for part in parts {
            let files = filemode::decode_files(&part)?;
            for (_, f) in files {
                filemode::merge_file(&mut merged, f);
            }
        }
        let nbytes = merged.local_bytes();
        filemode::write_file(cx.workdir, &merged, version)?;
        cx.stats.bytes_served += nbytes as u64;
    }
    Ok(())
}

/// One writer rank's metadata view of a buffered round snapshot,
/// filtered to what this channel delivers over memory: file-only
/// datasets are withheld (consumers fetch them from the disk version
/// stamped into the attrs), everything else is advertised with this
/// rank's owned slabs.
fn snapshot_meta(
    f: &H5File,
    version: u64,
    routes: &RouteTable,
    disk_version: Option<u64>,
) -> FileMeta {
    let mut attrs: Vec<(String, AttrValue)> =
        f.attrs.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    if let Some(v) = disk_version {
        attrs.push((DISK_VERSION_ATTR.to_string(), AttrValue::Int(v as i64)));
    }
    FileMeta {
        filename: f.name.clone(),
        version,
        attrs,
        datasets: f
            .datasets
            .values()
            .filter(|d| routes.delivers_in_memory(&d.meta.name))
            .map(|d| {
                (
                    d.meta.clone(),
                    d.blocks.iter().map(|b| b.slab.clone()).collect(),
                )
            })
            .collect(),
    }
}

/// Payload bytes a shared (zero-copy) reply hands over: the size of
/// every block intersection with the wanted region. Pure arithmetic —
/// no bytes move here; the consumer copies straight from the shared
/// snapshot.
fn shared_reply_bytes(snapshot: &H5File, dset: &str, want: &Hyperslab) -> Result<usize> {
    let d = snapshot.dataset(dset)?;
    let esize = d.meta.dtype.size_bytes();
    Ok(d.blocks
        .iter()
        .filter_map(|b| b.slab.intersect(want))
        .map(|i| i.element_count() as usize * esize)
        .sum())
}

/// Encode a Reply::Data wire message for the blocks of `snapshot`
/// intersecting `want`, extracting each intersection *directly into*
/// the wire buffer (§Perf iteration 2: no staging buffer per block).
/// With `pooled`, the buffer is leased from the process pool —
/// steady-state serves recycle the same allocation every round.
/// Returns (encoded reply, payload bytes, pool hit).
fn encode_data_reply(
    snapshot: &H5File,
    dset: &str,
    want: &Hyperslab,
    pooled: bool,
) -> Result<(crate::comm::buf::Payload, usize, bool)> {
    let d = snapshot.dataset(dset)?;
    let esize = d.meta.dtype.size_bytes();
    let inters: Vec<(&super::model::OwnedBlock, Hyperslab)> = d
        .blocks
        .iter()
        .filter_map(|b| b.slab.intersect(want).map(|i| (b, i)))
        .collect();
    // Per-block budget: the intersection bytes plus the slab header
    // (two length-prefixed u64 slices, 16 + 16·ndims) and the bytes
    // prefix — an under-estimate would silently realloc mid-encode.
    let payload: usize = inters
        .iter()
        .map(|(_, i)| i.element_count() as usize * esize + 32 + 16 * i.offset.len())
        .sum();
    let mut w = if pooled {
        crate::comm::wire::Writer::pooled(crate::comm::buf::pool(), payload + 16)
    } else {
        crate::comm::wire::Writer::with_capacity(payload + 16)
    };
    w.put_u8(1); // Reply::Data discriminant
    w.put_u64(inters.len() as u64);
    let mut nbytes = 0;
    for (b, inter) in inters {
        inter.encode(&mut w);
        let n = inter.element_count() as usize * esize;
        nbytes += n;
        w.put_bytes_via(n, |dst| {
            super::hyperslab::copy_region(&b.slab, &b.data, &inter, dst, &inter, esize);
        });
        crate::comm::buf::note_copied(n);
    }
    // Evaluated after encoding: a pool hit that reallocated while
    // filling is not allocation-free and must not read as one.
    let hit = w.pool_hit();
    Ok((w.finish(), nbytes, hit))
}
