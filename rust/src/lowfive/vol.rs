//! The Vol object: our reimplementation of the LowFive HDF5 VOL plugin
//! (substrate S5). One Vol per rank; task codes talk to it through the
//! HDF5-like file/dataset API and never see the workflow system —
//! the paper's "no task code changes" property.
//!
//! Producer side: ranks buffer dataset writes in memory; closing a file
//! *serves* it to every matching channel (consumer task), sequentially,
//! one serve *round* per close. Versions (serve counters) keep rounds
//! from mixing when consumers run at different rates.
//!
//! Consumer side: opening a file sends `MetaReq` to every producer
//! I/O rank of the next matching channel (round-robin across channels,
//! which is how fan-in ensembles interleave their producers), then
//! dataset reads pull only the intersecting blocks (O(M+N) block-range
//! intersection, never O(M·N) element scans).

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comm::{Comm, InterComm};
use crate::error::{Result, WilkinsError};
use crate::flow::{ChannelPolicy, FlowControl, LinkState, Plan, PlanOp};
use crate::metrics::{Recorder, SpanKind};

use super::hyperslab::{copy_region, Hyperslab};
use super::model::{AttrValue, DType, DatasetMeta, H5File};
use super::protocol::{
    FileMeta, Reply, Request, REQ_DATA_DISCRIMINANT, TAG_REP, TAG_REQ,
};
use super::{filemode, pattern_matches};

/// Transport mode of a channel (YAML `memory: 1` vs `file: 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelMode {
    Memory,
    File,
}

/// Producer-side channel to one consumer task. Versions are monotonic
/// per channel (not per file) so globbed multi-file streams like
/// plt*.h5 stay ordered; the round buffer, credit window and drop
/// accounting live in the channel's [`LinkState`] (the flow layer).
pub struct OutChannel {
    pub intercomm: Option<InterComm>,
    pub pattern: String,
    pub mode: ChannelMode,
    /// Flow engine: bounded round buffer + credits (Sec. 3.6).
    /// Round snapshots are `Arc`s of the producer's in-memory file:
    /// admission is O(1), and the producer's next write to the file
    /// copy-on-writes (`Arc::make_mut`) only while a buffered round
    /// still references the old bytes.
    link: LinkState<Arc<H5File>>,
    /// MetaReqs pulled out of the mailbox that no buffered round can
    /// answer yet (fast consumer re-opened early, or everything it
    /// could read was dropped).
    deferred: VecDeque<(usize, Request)>,
}

impl OutChannel {
    pub fn new(intercomm: Option<InterComm>, pattern: &str, mode: ChannelMode) -> OutChannel {
        let remote = intercomm.as_ref().map_or(0, |ic| ic.remote_size());
        OutChannel {
            intercomm,
            pattern: pattern.to_string(),
            mode,
            link: LinkState::new(ChannelPolicy::block(), remote),
            deferred: VecDeque::new(),
        }
    }

    /// Set the channel's flow policy (resets the link's round buffer;
    /// call before the first serve).
    pub fn with_policy(mut self, policy: ChannelPolicy) -> OutChannel {
        let remote = self.intercomm.as_ref().map_or(0, |ic| ic.remote_size());
        self.link = LinkState::new(policy, remote);
        self
    }

    /// Legacy sugar: lower a three-mode strategy onto its policy.
    pub fn with_flow(self, flow: FlowControl) -> OutChannel {
        self.with_policy(flow.lower())
    }

    /// The channel's flow policy.
    pub fn policy(&self) -> ChannelPolicy {
        self.link.policy()
    }
}

/// Consumer-side channel from one producer task.
pub struct InChannel {
    pub intercomm: Option<InterComm>,
    pub pattern: String,
    pub mode: ChannelMode,
    /// Version of the last file consumed from this channel.
    last_version: u64,
    exhausted: bool,
    /// Did we already send EofAck to the producers?
    eof_acked: bool,
}

impl InChannel {
    pub fn new(intercomm: Option<InterComm>, pattern: &str, mode: ChannelMode) -> InChannel {
        InChannel {
            intercomm,
            pattern: pattern.to_string(),
            mode,
            last_version: 0,
            exhausted: false,
            eof_acked: false,
        }
    }
}

/// Where an opened (consumer) file's bytes come from.
enum FileSource {
    /// Remote producer ranks over the channel intercomm.
    Memory { channel: usize },
    /// Fully materialised from a disk file (file mode).
    Disk { file: H5File },
}

/// A consumer-side opened file: merged metadata + block locations.
pub struct ConsumerFile {
    pub filename: String,
    pub version: u64,
    pub attrs: Vec<(String, AttrValue)>,
    /// dataset -> (meta, per-remote-rank owned slabs)
    datasets: HashMap<String, (DatasetMeta, Vec<Vec<Hyperslab>>)>,
    source: FileSource,
}

impl ConsumerFile {
    pub fn dataset_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.datasets.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn attr(&self, key: &str) -> Option<&AttrValue> {
        self.attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// Callback slots (LowFive's custom-callback extension, Sec. 3.4).
/// Each receives the Vol and the filename (or dataset name) involved.
type FileCb = Box<dyn FnMut(&mut Vol, &str) + Send>;

#[derive(Default)]
pub struct Callbacks {
    pub before_file_open: Option<FileCb>,
    pub after_file_open: Option<FileCb>,
    pub before_file_close: Option<FileCb>,
    pub after_file_close: Option<FileCb>,
    pub after_dataset_write: Option<FileCb>,
}

/// Transport statistics (observability for the benches).
#[derive(Debug, Default, Clone)]
pub struct VolStats {
    pub files_served: u64,
    /// Flow-control cadence skips (`every`-gated closes that never
    /// reached a channel's round buffer).
    pub serves_skipped: u64,
    /// Rounds discarded by a dropping flow policy (latest /
    /// drop-oldest / drop-newest) after admission pressure.
    pub serves_dropped: u64,
    /// Default serves suppressed by a before-close callback (custom
    /// I/O patterns like Nyx's double close).
    pub serves_suppressed: u64,
    pub bytes_served: u64,
    pub files_opened: u64,
    pub bytes_read: u64,
    /// Time the producer spent blocked inside serve rounds.
    pub serve_wait: Duration,
    /// Time the producer stalled waiting for flow credits (subset of
    /// `serve_wait` under blocking policies).
    pub stall_wait: Duration,
    /// High-water mark of any channel's round buffer.
    pub max_queue_depth: u64,
    /// Time the consumer spent blocked in file_open.
    pub open_wait: Duration,
}

/// The per-rank LowFive object.
pub struct Vol {
    /// Restricted-world communicator of the owning task.
    local: Comm,
    /// I/O-rank sub-communicator (subset writers, Sec. 3.2.2). None on
    /// non-I/O ranks.
    io_comm: Option<Comm>,
    out_channels: Vec<OutChannel>,
    in_channels: Vec<InChannel>,
    /// Producer-side in-memory files (shared with buffered serve
    /// rounds; mutation copy-on-writes via [`Arc::make_mut`]).
    files: HashMap<String, Arc<H5File>>,
    /// Consumer-side opened files.
    consumer_files: HashMap<String, ConsumerFile>,
    /// Per-file close counts and the global counter (Listing 5).
    closes: HashMap<String, u64>,
    pub file_close_counter: u64,
    /// Monotonic version for file-mode disk writes.
    disk_version: u64,
    /// File-mode serves (disk writes) completed, folded into
    /// `files_served` alongside the memory channels' completions.
    disk_serves: u64,
    /// Dataset writes seen (drives Listing-3-style actions).
    dataset_write_counter: u64,
    callbacks: Callbacks,
    /// Set by before_file_close callbacks to skip the default serve
    /// (flow control and custom I/O patterns build on this).
    suppress_serve: bool,
    /// Round-robin cursor over in-channels (fan-in interleaving).
    in_cursor: usize,
    /// File pre-opened by the driver (stateless-consumer relaunch,
    /// Sec. 3.5.1): the task's next file_open consumes it.
    preopened: Option<String>,
    pub stats: VolStats,
    /// Directory for file-mode transports.
    workdir: PathBuf,
    /// Optional Gantt recorder (metrics S11): wait spans are recorded
    /// against this rank's timeline.
    recorder: Option<(std::sync::Arc<Recorder>, usize)>,
    /// Ablation switch (benches/ablation.rs): issue DataReqs one rank
    /// at a time instead of pipelining send-all-then-receive.
    lockstep_reads: bool,
}

impl Vol {
    pub fn new(local: Comm, workdir: PathBuf) -> Vol {
        Vol {
            local,
            io_comm: None,
            out_channels: Vec::new(),
            in_channels: Vec::new(),
            files: HashMap::new(),
            consumer_files: HashMap::new(),
            closes: HashMap::new(),
            file_close_counter: 0,
            disk_version: 0,
            disk_serves: 0,
            dataset_write_counter: 0,
            callbacks: Callbacks::default(),
            suppress_serve: false,
            in_cursor: 0,
            preopened: None,
            stats: VolStats::default(),
            workdir,
            recorder: None,
            lockstep_reads: false,
        }
    }

    /// Ablation only: disable read pipelining (see benches/ablation.rs).
    pub fn set_lockstep_reads(&mut self, v: bool) {
        self.lockstep_reads = v;
    }

    /// Driver-side pre-open (the paper's "query producers whether there
    /// are more data to consume"): blocks until a producer serves a
    /// file on any live in-channel, or every channel reports EOF.
    /// The opened file is stashed; the task code's next `file_open`
    /// returns it, keeping the task code workflow-oblivious.
    pub fn preopen_next(&mut self) -> Result<String> {
        if let Some(name) = &self.preopened {
            return Ok(name.clone());
        }
        let name = self.open_any()?;
        self.preopened = Some(name.clone());
        Ok(name)
    }

    /// Open the next served file from any live in-channel (round-robin).
    pub fn open_any(&mut self) -> Result<String> {
        let t0 = Instant::now();
        let n = self.in_channels.len();
        if n == 0 {
            return Err(WilkinsError::LowFive("no in-channels configured".into()));
        }
        loop {
            let mut all_exhausted = true;
            for k in 0..n {
                let idx = (self.in_cursor + k) % n;
                if self.in_channels[idx].exhausted {
                    continue;
                }
                all_exhausted = false;
                let pat = self.in_channels[idx].pattern.clone();
                if let Some(name) = self.open_on_channel(idx, &pat)? {
                    self.in_cursor = (idx + 1) % n;
                    self.stats.files_opened += 1;
                    self.stats.open_wait += t0.elapsed();
                    self.record_span(SpanKind::Idle, &format!("open {name}"), t0);
                    self.run_cb(|c| &mut c.after_file_open, &name);
                    return Ok(name);
                }
            }
            if all_exhausted {
                return Err(WilkinsError::EndOfStream);
            }
        }
    }

    /// Attach a Gantt recorder; `rank` is the global rank label used
    /// for this Vol's wait spans.
    pub fn set_recorder(&mut self, rec: std::sync::Arc<Recorder>, rank: usize) {
        self.recorder = Some((rec, rank));
    }

    fn record_span(&self, kind: SpanKind, label: &str, t0: Instant) {
        if let Some((rec, rank)) = &self.recorder {
            rec.record(*rank, kind, label, t0, Instant::now());
        }
    }

    pub fn rank(&self) -> usize {
        self.local.rank()
    }

    pub fn local_comm(&self) -> &Comm {
        &self.local
    }

    pub fn set_io_comm(&mut self, io: Option<Comm>) {
        self.io_comm = io;
    }

    pub fn io_comm(&self) -> Option<&Comm> {
        self.io_comm.as_ref()
    }

    /// Is this rank an I/O rank? (Always true unless subset writers
    /// are configured and this rank is excluded.)
    pub fn is_io_rank(&self) -> bool {
        self.io_comm.is_some()
    }

    pub fn add_out_channel(&mut self, ch: OutChannel) {
        self.out_channels.push(ch);
    }

    pub fn add_in_channel(&mut self, ch: InChannel) {
        self.in_channels.push(ch);
    }

    pub fn workdir(&self) -> &PathBuf {
        &self.workdir
    }

    // ---- callback registration (Listing 5 API) ----------------------------

    pub fn set_before_file_open(&mut self, cb: FileCb) {
        self.callbacks.before_file_open = Some(cb);
    }

    pub fn set_after_file_open(&mut self, cb: FileCb) {
        self.callbacks.after_file_open = Some(cb);
    }

    pub fn set_before_file_close(&mut self, cb: FileCb) {
        self.callbacks.before_file_close = Some(cb);
    }

    pub fn set_after_file_close(&mut self, cb: FileCb) {
        self.callbacks.after_file_close = Some(cb);
    }

    pub fn set_after_dataset_write(&mut self, cb: FileCb) {
        self.callbacks.after_dataset_write = Some(cb);
    }

    fn run_cb(&mut self, which: fn(&mut Callbacks) -> &mut Option<FileCb>, arg: &str) {
        if let Some(mut cb) = which(&mut self.callbacks).take() {
            cb(self, arg);
            let slot = which(&mut self.callbacks);
            if slot.is_none() {
                *slot = Some(cb);
            }
        }
    }

    /// Skip the default serve for the file being closed (callable from
    /// before_file_close callbacks: flow control, custom I/O patterns).
    pub fn skip_serve(&mut self) {
        self.suppress_serve = true;
    }

    /// Are there pending (unanswered) consumer requests for files
    /// matching this name? Drives the *latest* flow-control strategy.
    pub fn any_pending_requests(&self, filename: &str) -> bool {
        self.out_channels.iter().any(|ch| {
            ch.mode == ChannelMode::Memory
                && pattern_matches(&ch.pattern, filename)
                && (!ch.deferred.is_empty()
                    || ch.intercomm.as_ref().is_some_and(|ic| ic.iprobe(TAG_REQ)))
        })
    }

    /// How many times has `filename` been closed so far?
    pub fn closes_of(&self, filename: &str) -> u64 {
        self.closes.get(filename).copied().unwrap_or(0)
    }

    /// Counter for dataset writes (Listing-3-style custom actions).
    pub fn note_dataset_write(&mut self) {
        self.dataset_write_counter += 1;
    }

    pub fn dataset_writes(&self) -> u64 {
        self.dataset_write_counter
    }

    // ---- producer-side API -------------------------------------------------

    /// Create (or truncate) an in-memory file for writing.
    pub fn file_create(&mut self, name: &str) -> Result<()> {
        self.files.insert(name.to_string(), Arc::new(H5File::new(name)));
        Ok(())
    }

    /// Producer-side reopen of a locally written file (Nyx pattern).
    pub fn producer_file_exists(&self, name: &str) -> bool {
        self.files.contains_key(name)
    }

    /// Producer-side collective reopen (the second open of the Nyx
    /// double-open pattern). Runs the file-open callbacks — which is
    /// where the custom action receives rank 0's broadcast state —
    /// then checks the file exists locally.
    pub fn producer_file_open(&mut self, name: &str) -> Result<()> {
        self.run_cb(|c| &mut c.before_file_open, name);
        if !self.files.contains_key(name) {
            return Err(WilkinsError::LowFive(format!(
                "producer reopen of unknown file {name}"
            )));
        }
        self.run_cb(|c| &mut c.after_file_open, name);
        Ok(())
    }

    pub fn attr_write(&mut self, file: &str, key: &str, value: AttrValue) -> Result<()> {
        self.file_mut(file)?.attrs.insert(key.to_string(), value);
        Ok(())
    }

    pub fn dataset_create(
        &mut self,
        file: &str,
        dset: &str,
        dtype: DType,
        dims: &[u64],
    ) -> Result<()> {
        self.file_mut(file)?.create_dataset(dset, dtype, dims)
    }

    pub fn dataset_write(
        &mut self,
        file: &str,
        dset: &str,
        slab: Hyperslab,
        data: Vec<u8>,
    ) -> Result<()> {
        self.file_mut(file)?.dataset_mut(dset)?.write_slab(slab, data)?;
        self.run_cb(|c| &mut c.after_dataset_write, dset);
        Ok(())
    }

    fn file_mut(&mut self, name: &str) -> Result<&mut H5File> {
        self.files
            .get_mut(name)
            // Copy-on-write: clones the file only when a buffered
            // serve round still shares it (pipelining depth > 1 or a
            // dropping policy); the default synchronous path mutates
            // in place.
            .map(Arc::make_mut)
            .ok_or_else(|| WilkinsError::LowFive(format!("file {name} not open for writing")))
    }

    pub fn file(&self, name: &str) -> Result<&H5File> {
        self.files
            .get(name)
            .map(Arc::as_ref)
            .ok_or_else(|| WilkinsError::LowFive(format!("file {name} not open for writing")))
    }

    /// Close a file. On the producer this is where data serving
    /// happens (unless a callback suppressed it); on the consumer it
    /// sends the Done for the current serve round.
    pub fn file_close(&mut self, name: &str) -> Result<()> {
        if self.consumer_files.contains_key(name) {
            return self.consumer_file_close(name);
        }
        self.suppress_serve = false;
        self.run_cb(|c| &mut c.before_file_close, name);
        *self.closes.entry(name.to_string()).or_insert(0) += 1;
        self.file_close_counter += 1;
        if self.suppress_serve {
            self.suppress_serve = false;
            self.stats.serves_suppressed += 1;
        } else {
            self.serve_file(name)?;
        }
        self.run_cb(|c| &mut c.after_file_close, name);
        Ok(())
    }

    /// Serve `name` on every matching channel (Listing 5's serve_all
    /// serves every open file).
    pub fn serve_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.files.keys().cloned().collect();
        for name in names {
            self.serve_file(&name)?;
        }
        Ok(())
    }

    /// Drop all producer-side in-memory file state (Listing 5).
    pub fn clear_files(&mut self) {
        self.files.clear();
    }

    /// Broadcast rank 0's in-memory files to all ranks of the local
    /// communicator (the Nyx custom I/O pattern: rank 0 writes file
    /// metadata solo, then every rank needs a consistent view).
    pub fn broadcast_files(&mut self) -> Result<()> {
        let payload = if self.local.rank() == 0 {
            // `encode_files` borrows through the `Arc`s: no deep copy
            // of dataset bytes just to serialize them.
            Some(filemode::encode_files(&self.files))
        } else {
            None
        };
        let bytes = self.local.bcast(0, payload.as_deref())?;
        if self.local.rank() != 0 {
            let files = filemode::decode_files(&bytes)?;
            for (name, file) in files {
                self.files.insert(name, Arc::new(file));
            }
        }
        Ok(())
    }

    /// Serve one file: admit one round per matching out-channel,
    /// subject to each channel's flow policy (the decision lives in
    /// [`crate::flow::LinkState`], not here). Only I/O ranks
    /// participate.
    fn serve_file(&mut self, name: &str) -> Result<()> {
        if !self.files.contains_key(name) {
            return Ok(()); // nothing buffered (non-writer rank)
        }
        if !self.is_io_rank() {
            return Ok(());
        }
        let t0 = Instant::now();
        let mode_file = self
            .out_channels
            .iter()
            .any(|ch| ch.mode == ChannelMode::File && pattern_matches(&ch.pattern, name));
        if mode_file {
            self.disk_version += 1;
            let v = self.disk_version;
            self.write_disk_file(name, v)?;
            self.disk_serves += 1;
        }
        let mem_idx: Vec<usize> = (0..self.out_channels.len())
            .filter(|&i| {
                self.out_channels[i].mode == ChannelMode::Memory
                    && self.out_channels[i].intercomm.is_some()
                    && pattern_matches(&self.out_channels[i].pattern, name)
            })
            .collect();
        for idx in mem_idx {
            if !self.out_channels[idx].link.note_attempt() {
                continue; // `every`-gated close (counted by the link)
            }
            let snapshot = Arc::clone(self.files.get(name).unwrap());
            self.enqueue_round(idx, snapshot)?;
        }
        self.stats.serve_wait += t0.elapsed();
        self.record_span(SpanKind::Transfer, &format!("serve {name}"), t0);
        self.sync_flow_stats();
        Ok(())
    }

    /// Fold the per-link flow counters into this rank's `VolStats`
    /// (the links are the single source of truth).
    ///
    /// `files_served` counts rounds actually *consumed*: the busiest
    /// memory channel's completions (channels at different cadences
    /// overlap on the same closes, so summing would double-count) plus
    /// file-mode disk writes. Rounds a dropping policy discarded never
    /// count — they are `serves_dropped`.
    fn sync_flow_stats(&mut self) {
        let mut skipped = 0;
        let mut dropped = 0;
        let mut completed = 0;
        let mut stalled = Duration::ZERO;
        let mut maxq = 0;
        for ch in &self.out_channels {
            skipped += ch.link.stats.skipped;
            dropped += ch.link.stats.dropped;
            completed = completed.max(ch.link.stats.completed);
            stalled += ch.link.stats.stalled;
            maxq = maxq.max(ch.link.stats.max_queue_depth);
        }
        self.stats.files_served = self.disk_serves.max(completed);
        self.stats.serves_skipped = skipped;
        self.stats.serves_dropped = dropped;
        self.stats.stall_wait = stalled;
        self.stats.max_queue_depth = maxq;
    }

    /// Admit one round on one channel per its policy.
    ///
    /// Blocking policies need no cross-rank coordination (no drops;
    /// deliveries are a pure function of the buffer, which every
    /// writer rank mutates through the identical push sequence).
    /// Dropping policies are coordinated by I/O rank 0's section plan
    /// (see the [`crate::flow`] module docs).
    fn enqueue_round(&mut self, idx: usize, snapshot: Arc<H5File>) -> Result<()> {
        if self.out_channels[idx].link.policy().mode.drops() {
            self.enqueue_dropping(idx, snapshot)
        } else {
            self.enqueue_block(idx, snapshot)
        }
    }

    fn enqueue_block(&mut self, idx: usize, snapshot: Arc<H5File>) -> Result<()> {
        self.pump_available(idx, None)?;
        self.out_channels[idx].link.push(snapshot);
        self.answer_deferred(idx, None)?;
        let target = self.out_channels[idx].link.policy().depth.saturating_sub(1);
        if self.out_channels[idx].link.occupancy() > target {
            // Out of credits: stall until enough rounds complete.
            let t0 = Instant::now();
            while self.out_channels[idx].link.occupancy() > target {
                self.pump_one_blocking(idx)?;
            }
            self.out_channels[idx].link.note_stall(t0.elapsed());
            self.record_span(SpanKind::Stall, "flow stall", t0);
        }
        Ok(())
    }

    fn enqueue_dropping(&mut self, idx: usize, snapshot: Arc<H5File>) -> Result<()> {
        let io = self
            .io_comm
            .as_ref()
            .ok_or_else(|| {
                WilkinsError::LowFive("dropping flow policy on non-io rank".into())
            })?
            .clone();
        if io.rank() == 0 {
            let mut plan = Plan::default();
            self.pump_available(idx, Some(&mut plan))?;
            let admission = self.out_channels[idx].link.admit(snapshot);
            for v in &admission.dropped {
                plan.ops.push(PlanOp::Drop { version: *v });
            }
            match admission.pushed {
                Some(v) => plan.ops.push(PlanOp::Push { version: v }),
                None => plan.ops.push(PlanOp::DropIncoming),
            }
            self.answer_deferred(idx, Some(&mut plan))?;
            if io.size() > 1 {
                io.bcast(0, Some(&plan.encode()))?;
            }
        } else {
            let bytes = io.bcast(0, None)?;
            let plan = Plan::decode(&bytes)?;
            self.replay_plan(idx, snapshot, plan)?;
        }
        Ok(())
    }

    /// Absorb every request already waiting in the mailbox for channel
    /// `idx` (non-blocking). With `plan`, record the state-mutating
    /// events so other writer ranks can replay them.
    fn pump_available(&mut self, idx: usize, mut plan: Option<&mut Plan>) -> Result<()> {
        loop {
            let Some(ic) = self.out_channels[idx].intercomm.clone() else {
                return Ok(());
            };
            let Some((src, bytes)) = ic.try_recv_any(TAG_REQ) else {
                return Ok(());
            };
            let req = Request::decode(&bytes)?;
            self.handle_request(idx, src, req, plan.as_deref_mut())?;
        }
    }

    /// Block for one request on channel `idx` and process it.
    fn pump_one_blocking(&mut self, idx: usize) -> Result<()> {
        let ic = self.out_channels[idx].intercomm.as_ref().unwrap().clone();
        let (src, bytes) = ic.recv_any(TAG_REQ)?;
        let req = Request::decode(&bytes)?;
        self.handle_request(idx, src, req, None)
    }

    /// Process one consumer request against channel `idx`.
    fn handle_request(
        &mut self,
        idx: usize,
        src: usize,
        req: Request,
        plan: Option<&mut Plan>,
    ) -> Result<()> {
        match req {
            Request::MetaReq { pattern, min_version } => {
                match self.out_channels[idx].link.choose_deliver(src, min_version) {
                    Some(v) => {
                        self.deliver_meta(idx, src, v)?;
                        if let Some(p) = plan {
                            p.ops.push(PlanOp::Deliver { j: src as u64, version: v });
                        }
                    }
                    // No buffered round can answer yet: defer until a
                    // later push (or the EOF handshake).
                    None => self.out_channels[idx]
                        .deferred
                        .push_back((src, Request::MetaReq { pattern, min_version })),
                }
            }
            Request::DataReq { ref file, ref dset, ref slab } => {
                self.answer_data_req(idx, src, file, dset, slab)?;
            }
            Request::Done { version } => {
                self.out_channels[idx].link.mark_done(version, src)?;
                if let Some(p) = plan {
                    p.ops.push(PlanOp::Done { j: src as u64, version });
                }
            }
            Request::EofAck => {
                self.out_channels[idx].link.mark_eof(src);
                if let Some(p) = plan {
                    p.ops.push(PlanOp::Eof { j: src as u64 });
                }
            }
        }
        Ok(())
    }

    /// Answer a MetaReq with buffered round `version` and mark it
    /// delivered to consumer rank `src`.
    fn deliver_meta(&mut self, idx: usize, src: usize, version: u64) -> Result<()> {
        let rep = {
            let round = self.out_channels[idx].link.round(version).ok_or_else(|| {
                WilkinsError::LowFive(format!("deliver of unknown round v{version}"))
            })?;
            Reply::Meta(snapshot_meta(&round.snapshot, version)).encode()
        };
        let ic = self.out_channels[idx].intercomm.as_ref().unwrap().clone();
        ic.send_owned(src, TAG_REP, rep);
        self.out_channels[idx].link.mark_delivered(version, src)
    }

    /// Answer a DataReq from the round consumer rank `src` has open.
    fn answer_data_req(
        &mut self,
        idx: usize,
        src: usize,
        file: &str,
        dset: &str,
        slab: &Hyperslab,
    ) -> Result<()> {
        let (rep, nbytes) = {
            let round = self.out_channels[idx].link.open_round(src).ok_or_else(|| {
                WilkinsError::LowFive(format!(
                    "data request for {file} from rank {src} with no open round"
                ))
            })?;
            if round.snapshot.name != file {
                return Err(WilkinsError::LowFive(format!(
                    "data request for {file} against round of {}",
                    round.snapshot.name
                )));
            }
            encode_data_reply(&round.snapshot, dset, slab)?
        };
        self.stats.bytes_served += nbytes as u64;
        let ic = self.out_channels[idx].intercomm.as_ref().unwrap().clone();
        ic.send_owned(src, TAG_REP, rep);
        Ok(())
    }

    /// Re-examine deferred MetaReqs: a newly pushed round may satisfy
    /// them. Answered requests are recorded into `plan` when given.
    fn answer_deferred(&mut self, idx: usize, mut plan: Option<&mut Plan>) -> Result<()> {
        let mut keep = VecDeque::new();
        while let Some((src, req)) = self.out_channels[idx].deferred.pop_front() {
            let min_version = match &req {
                Request::MetaReq { min_version, .. } => *min_version,
                _ => {
                    keep.push_back((src, req));
                    continue;
                }
            };
            match self.out_channels[idx].link.choose_deliver(src, min_version) {
                Some(v) => {
                    self.deliver_meta(idx, src, v)?;
                    if let Some(p) = plan.as_deref_mut() {
                        p.ops.push(PlanOp::Deliver { j: src as u64, version: v });
                    }
                }
                None => keep.push_back((src, req)),
            }
        }
        self.out_channels[idx].deferred = keep;
        Ok(())
    }

    /// Replay I/O rank 0's section plan against our own mailbox: apply
    /// buffer mutations verbatim and consume exactly the planned
    /// protocol events from each consumer rank's (FIFO) request
    /// stream, answering our own DataReqs along the way. See the
    /// [`crate::flow`] module docs for why this keeps writer ranks'
    /// buffers bit-identical.
    fn replay_plan(&mut self, idx: usize, snapshot: Arc<H5File>, plan: Plan) -> Result<()> {
        let mut snapshot = Some(snapshot);
        self.drain_data_reqs(idx)?;
        for op in plan.ops {
            match op {
                PlanOp::Drop { version } => {
                    self.out_channels[idx].link.drop_version(version)?;
                }
                PlanOp::Push { version } => {
                    let snap = snapshot.take().ok_or_else(|| {
                        WilkinsError::LowFive("flow plan pushes twice".into())
                    })?;
                    let v = self.out_channels[idx].link.push(snap);
                    if v != version {
                        return Err(WilkinsError::LowFive(format!(
                            "flow plan version skew: local v{v}, plan v{version}"
                        )));
                    }
                }
                PlanOp::DropIncoming => {
                    snapshot.take();
                    self.out_channels[idx].link.note_drop_incoming();
                }
                PlanOp::Deliver { j, version } => {
                    self.replay_expect(idx, j as usize, Expect::Meta(version))?;
                }
                PlanOp::Done { j, version } => {
                    self.replay_expect(idx, j as usize, Expect::Done(version))?;
                }
                PlanOp::Eof { j } => {
                    self.replay_expect(idx, j as usize, Expect::Eof)?;
                }
            }
        }
        self.drain_data_reqs(idx)?;
        Ok(())
    }

    /// Consume consumer rank `j`'s request stream up to (and
    /// including) the expected protocol event, answering DataReqs
    /// encountered on the way.
    fn replay_expect(&mut self, idx: usize, j: usize, expect: Expect) -> Result<()> {
        loop {
            let ic = self.out_channels[idx].intercomm.as_ref().unwrap().clone();
            let (_, bytes) = ic.recv(j, TAG_REQ)?;
            let req = Request::decode(&bytes)?;
            match (req, expect) {
                (Request::DataReq { ref file, ref dset, ref slab }, _) => {
                    self.answer_data_req(idx, j, file, dset, slab)?;
                }
                (Request::MetaReq { .. }, Expect::Meta(v)) => {
                    return self.deliver_meta(idx, j, v);
                }
                (Request::Done { version }, Expect::Done(v)) if version == v => {
                    self.out_channels[idx].link.mark_done(v, j)?;
                    return Ok(());
                }
                (Request::EofAck, Expect::Eof) => {
                    self.out_channels[idx].link.mark_eof(j);
                    return Ok(());
                }
                (other, _) => {
                    return Err(WilkinsError::LowFive(format!(
                        "flow plan replay: expected {expect:?} from rank {j}, got {other:?}"
                    )));
                }
            }
        }
    }

    /// Answer every DataReq already queued for channel `idx` without
    /// absorbing any plan-owned protocol event (payload-discriminant
    /// selective receive). Lets non-leader writer ranks keep consumer
    /// reads flowing between coordinated sections.
    fn drain_data_reqs(&mut self, idx: usize) -> Result<()> {
        loop {
            let Some(ic) = self.out_channels[idx].intercomm.clone() else {
                return Ok(());
            };
            let Some((src, bytes)) =
                ic.try_recv_where(TAG_REQ, |p| p.first() == Some(&REQ_DATA_DISCRIMINANT))
            else {
                return Ok(());
            };
            match Request::decode(&bytes)? {
                Request::DataReq { ref file, ref dset, ref slab } => {
                    self.answer_data_req(idx, src, file, dset, slab)?;
                }
                other => {
                    return Err(WilkinsError::LowFive(format!(
                        "selective DataReq receive returned {other:?}"
                    )));
                }
            }
        }
    }

    fn write_disk_file(&mut self, name: &str, version: u64) -> Result<()> {
        // Gather every I/O rank's blocks to I/O rank 0, which writes
        // one file (the "traditional HDF5 file" path).
        let io = self
            .io_comm
            .as_ref()
            .ok_or_else(|| WilkinsError::LowFive("file mode on non-io rank".into()))?
            .clone();
        let f = self.file(name)?;
        let mine = filemode::encode_files(&HashMap::from([(name.to_string(), f.clone())]));
        let gathered = io.gather(0, &mine)?;
        if let Some(parts) = gathered {
            let mut merged = H5File::new(name);
            for part in parts {
                let files = filemode::decode_files(&part)?;
                for (_, file) in files {
                    filemode::merge_file(&mut merged, file);
                }
            }
            let nbytes = merged.local_bytes();
            filemode::write_file(&self.workdir, &merged, version)?;
            self.stats.bytes_served += nbytes as u64;
        }
        Ok(())
    }

    /// Producer finalize: flush every channel's round buffer (each
    /// buffered round is delivered and completed — dropping policies
    /// stop dropping at shutdown so consumers get the freshest data),
    /// then signal EOF and wait for every consumer rank to
    /// acknowledge. Idempotent.
    pub fn finalize_producer(&mut self) -> Result<()> {
        if !self.is_io_rank() {
            return Ok(());
        }
        for idx in 0..self.out_channels.len() {
            match self.out_channels[idx].mode {
                ChannelMode::File => {
                    let io = self.io_comm.as_ref().unwrap();
                    if io.rank() == 0 {
                        filemode::write_eof(&self.workdir, &self.out_channels[idx].pattern)?;
                    }
                }
                ChannelMode::Memory => {
                    if self.out_channels[idx].intercomm.is_none() {
                        continue;
                    }
                    // 1. Flush: every buffered round must complete
                    //    before EOF. Buffer mutations during flush are
                    //    completions only, so writer ranks stay
                    //    consistent without a section plan.
                    while self.out_channels[idx].link.occupancy() > 0 {
                        self.answer_deferred(idx, None)?;
                        if self.out_channels[idx].link.occupancy() == 0 {
                            break;
                        }
                        self.pump_one_blocking(idx)?;
                    }
                    // 2. EOF handshake: answer remaining open requests
                    //    with Eof until every consumer rank acked.
                    while self.out_channels[idx].link.acked_count()
                        < self.out_channels[idx].link.nconsumers()
                    {
                        let (src, req) =
                            match self.out_channels[idx].deferred.pop_front() {
                                Some(x) => x,
                                None => {
                                    let ic = self.out_channels[idx]
                                        .intercomm
                                        .as_ref()
                                        .unwrap();
                                    let (src, bytes) = ic.recv_any(TAG_REQ)?;
                                    (src, Request::decode(&bytes)?)
                                }
                            };
                        match req {
                            Request::MetaReq { .. } => {
                                let ic =
                                    self.out_channels[idx].intercomm.as_ref().unwrap();
                                ic.send(src, TAG_REP, &Reply::Eof.encode());
                            }
                            Request::EofAck => {
                                self.out_channels[idx].link.mark_eof(src);
                            }
                            Request::Done { .. } => {} // stale, ignore
                            Request::DataReq { .. } => {
                                return Err(WilkinsError::LowFive(
                                    "data request after finalize".into(),
                                ))
                            }
                        }
                    }
                }
            }
        }
        self.sync_flow_stats();
        Ok(())
    }

    // ---- consumer-side API -------------------------------------------------

    /// Open the next available file matching `pattern`. Blocks until a
    /// producer serves one; returns the actual filename. Round-robins
    /// across matching in-channels (fan-in). Err(EndOfStream) when all
    /// matching channels are exhausted.
    pub fn file_open(&mut self, pattern: &str) -> Result<String> {
        if let Some(name) = self.preopened.take() {
            if pattern_matches(pattern, &name) || pattern_matches(&name, pattern) {
                return Ok(name);
            }
            self.preopened = Some(name); // not what the task wants
        }
        self.run_cb(|c| &mut c.before_file_open, pattern);
        let t0 = Instant::now();
        let n = self.in_channels.len();
        if n == 0 {
            return Err(WilkinsError::LowFive("no in-channels configured".into()));
        }
        let mut tried = 0;
        let mut matched = false;
        while tried < n {
            let idx = (self.in_cursor + tried) % n;
            tried += 1;
            let matches = pattern_matches(&self.in_channels[idx].pattern, pattern)
                || pattern_matches(pattern, &self.in_channels[idx].pattern);
            if !matches {
                continue;
            }
            matched = true;
            if self.in_channels[idx].exhausted {
                continue;
            }
            match self.open_on_channel(idx, pattern)? {
                Some(name) => {
                    self.in_cursor = (idx + 1) % n;
                    self.stats.files_opened += 1;
                    self.stats.open_wait += t0.elapsed();
                    self.record_span(SpanKind::Idle, &format!("open {name}"), t0);
                    self.run_cb(|c| &mut c.after_file_open, &name);
                    return Ok(name);
                }
                None => continue, // hit EOF on this channel; try next
            }
        }
        if !matched {
            return Err(WilkinsError::LowFive(format!(
                "no in-channel matches pattern {pattern}"
            )));
        }
        Err(WilkinsError::EndOfStream)
    }

    /// Try to open on a specific channel. Ok(None) => channel EOF.
    fn open_on_channel(&mut self, idx: usize, pattern: &str) -> Result<Option<String>> {
        let min_version = self.in_channels[idx].last_version + 1;
        match self.in_channels[idx].mode {
            ChannelMode::File => {
                let deadline = Instant::now() + crate::comm::RECV_TIMEOUT;
                let found = filemode::poll_file(
                    &self.workdir,
                    &self.in_channels[idx].pattern,
                    min_version,
                    deadline,
                )?;
                match found {
                    Some((file, version)) => {
                        self.in_channels[idx].last_version = version;
                        let name = file.name.clone();
                        let cf = ConsumerFile {
                            filename: name.clone(),
                            version,
                            attrs: file
                                .attrs
                                .iter()
                                .map(|(k, v)| (k.clone(), v.clone()))
                                .collect(),
                            datasets: file
                                .datasets
                                .values()
                                .map(|d| {
                                    (
                                        d.meta.name.clone(),
                                        (
                                            d.meta.clone(),
                                            vec![d
                                                .blocks
                                                .iter()
                                                .map(|b| b.slab.clone())
                                                .collect()],
                                        ),
                                    )
                                })
                                .collect(),
                            source: FileSource::Disk { file },
                        };
                        self.consumer_files.insert(name.clone(), cf);
                        Ok(Some(name))
                    }
                    None => {
                        self.in_channels[idx].exhausted = true;
                        Ok(None)
                    }
                }
            }
            ChannelMode::Memory => {
                let ic = self.in_channels[idx]
                    .intercomm
                    .as_ref()
                    .ok_or_else(|| WilkinsError::LowFive("memory channel without intercomm".into()))?
                    .clone();
                let req = Request::MetaReq {
                    pattern: pattern.to_string(),
                    min_version,
                }
                .encode();
                for r in 0..ic.remote_size() {
                    ic.send(r, TAG_REQ, &req);
                }
                let mut metas: Vec<Option<FileMeta>> = (0..ic.remote_size()).map(|_| None).collect();
                let mut eof = false;
                for _ in 0..ic.remote_size() {
                    let (src, bytes) = ic.recv_any(TAG_REP)?;
                    match Reply::decode(&bytes)? {
                        Reply::Meta(m) => metas[src] = Some(m),
                        Reply::Eof => eof = true,
                        Reply::Data(_) => {
                            return Err(WilkinsError::LowFive(
                                "unexpected data reply during open".into(),
                            ))
                        }
                    }
                }
                if eof {
                    // SPMD producers answer consistently: all Eof.
                    self.in_channels[idx].exhausted = true;
                    if !self.in_channels[idx].eof_acked {
                        let ack = Request::EofAck.encode();
                        for r in 0..ic.remote_size() {
                            ic.send(r, TAG_REQ, &ack);
                        }
                        self.in_channels[idx].eof_acked = true;
                    }
                    return Ok(None);
                }
                let mut filename = String::new();
                let mut version = 0;
                let mut attrs = Vec::new();
                let mut datasets: HashMap<String, (DatasetMeta, Vec<Vec<Hyperslab>>)> =
                    HashMap::new();
                let nremote = ic.remote_size();
                for (src, m) in metas.into_iter().enumerate() {
                    let m = m.ok_or_else(|| {
                        WilkinsError::LowFive("missing metadata reply".into())
                    })?;
                    filename = m.filename;
                    version = m.version;
                    if src == 0 {
                        attrs = m.attrs;
                    }
                    for (meta, slabs) in m.datasets {
                        let entry = datasets
                            .entry(meta.name.clone())
                            .or_insert_with(|| (meta.clone(), vec![Vec::new(); nremote]));
                        entry.1[src] = slabs;
                    }
                }
                self.in_channels[idx].last_version = version;
                let cf = ConsumerFile {
                    filename: filename.clone(),
                    version,
                    attrs,
                    datasets,
                    source: FileSource::Memory { channel: idx },
                };
                self.consumer_files.insert(filename.clone(), cf);
                Ok(Some(filename))
            }
        }
    }

    pub fn consumer_file(&self, name: &str) -> Result<&ConsumerFile> {
        self.consumer_files.get(name).ok_or_else(|| {
            WilkinsError::LowFive(format!("file {name} not open for reading"))
        })
    }

    pub fn dataset_meta(&self, file: &str, dset: &str) -> Result<DatasetMeta> {
        let cf = self.consumer_file(file)?;
        cf.datasets
            .get(dset)
            .map(|(m, _)| m.clone())
            .ok_or_else(|| WilkinsError::LowFive(format!("no dataset {dset} in {file}")))
    }

    /// Read `want` of `dset` (global coordinates). Pulls only the
    /// intersecting blocks from the producer ranks that own them.
    pub fn dataset_read(&mut self, file: &str, dset: &str, want: &Hyperslab) -> Result<Vec<u8>> {
        let (meta, rank_slabs, src_channel) = {
            let cf = self.consumer_file(file)?;
            let (m, rs) = cf
                .datasets
                .get(dset)
                .ok_or_else(|| WilkinsError::LowFive(format!("no dataset {dset} in {file}")))?;
            let ch = match cf.source {
                FileSource::Memory { channel } => Some(channel),
                FileSource::Disk { .. } => None,
            };
            (m.clone(), rs.clone(), ch)
        };
        let esize = meta.dtype.size_bytes();
        let mut out = vec![0u8; want.element_count() as usize * esize];
        match src_channel {
            None => {
                // Disk file: blocks are local.
                let cf = self.consumer_files.get(file).unwrap();
                if let FileSource::Disk { file: f } = &cf.source {
                    f.dataset(dset)?.read_into(want, &mut out);
                }
            }
            Some(idx) => {
                let ic = self.in_channels[idx].intercomm.as_ref().unwrap().clone();
                let req = Request::DataReq {
                    file: file.to_string(),
                    dset: dset.to_string(),
                    slab: want.clone(),
                }
                .encode();
                // Only contact ranks whose owned slabs intersect the
                // wanted region (O(M+N) block-range intersection).
                let targets: Vec<usize> = rank_slabs
                    .iter()
                    .enumerate()
                    .filter(|(_, slabs)| slabs.iter().any(|s| s.overlaps(want)))
                    .map(|(r, _)| r)
                    .collect();
                if self.lockstep_reads {
                    // Ablation arm: request/await one rank at a time.
                    for &r in &targets {
                        ic.send(r, TAG_REQ, &req);
                        let (_, bytes) = ic.recv(r, TAG_REP)?;
                        self.apply_data_reply(&bytes, want, &mut out, esize)?;
                    }
                } else {
                    // Default: pipeline — send every request first,
                    // then collect, overlapping the producers' work.
                    for &r in &targets {
                        ic.send(r, TAG_REQ, &req);
                    }
                    for &r in &targets {
                        let (_, bytes) = ic.recv(r, TAG_REP)?;
                        self.apply_data_reply(&bytes, want, &mut out, esize)?;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Streaming parse of a Reply::Data message: block bytes are
    /// copied straight from the wire buffer into the caller's output
    /// (§Perf iteration 3: skips Reply::decode's per-block to_vec).
    fn apply_data_reply(
        &mut self,
        bytes: &[u8],
        want: &Hyperslab,
        out: &mut [u8],
        esize: usize,
    ) -> Result<()> {
        let mut r = crate::comm::wire::Reader::new(bytes);
        if r.get_u8()? != 1 {
            return Err(WilkinsError::LowFive("expected data reply".into()));
        }
        let nblocks = r.get_u64()? as usize;
        for _ in 0..nblocks {
            let region = Hyperslab::decode(&mut r)?;
            let data = r.get_bytes()?; // borrowed, no copy
            self.stats.bytes_read += data.len() as u64;
            copy_region(&region, data, want, out, &region, esize);
        }
        Ok(())
    }

    fn consumer_file_close(&mut self, name: &str) -> Result<()> {
        self.run_cb(|c| &mut c.before_file_close, name);
        if let Some(cf) = self.consumer_files.remove(name) {
            if let FileSource::Memory { channel } = cf.source {
                let ic = self.in_channels[channel].intercomm.as_ref().unwrap();
                let done = Request::Done { version: cf.version }.encode();
                for r in 0..ic.remote_size() {
                    ic.send(r, TAG_REQ, &done);
                }
            }
        }
        self.run_cb(|c| &mut c.after_file_close, name);
        Ok(())
    }

    /// Consumer finalize: tell producers on every non-exhausted memory
    /// channel that this rank will not request again. Idempotent.
    pub fn finalize_consumer(&mut self) -> Result<()> {
        for ch in &mut self.in_channels {
            if ch.mode == ChannelMode::Memory && !ch.eof_acked {
                if let Some(ic) = &ch.intercomm {
                    let ack = Request::EofAck.encode();
                    for r in 0..ic.remote_size() {
                        ic.send(r, TAG_REQ, &ack);
                    }
                }
                ch.eof_acked = true;
            }
        }
        Ok(())
    }

    /// Are any in-channels still live (not exhausted)?
    pub fn has_live_inputs(&self) -> bool {
        self.in_channels.iter().any(|c| !c.exhausted)
    }
}

/// The protocol event a plan replay is waiting for.
#[derive(Debug, Clone, Copy)]
enum Expect {
    /// A MetaReq, to be answered with this round version.
    Meta(u64),
    /// A Done for this round version.
    Done(u64),
    /// An EofAck.
    Eof,
}

/// One writer rank's metadata view of a buffered round snapshot.
fn snapshot_meta(f: &H5File, version: u64) -> FileMeta {
    FileMeta {
        filename: f.name.clone(),
        version,
        attrs: f.attrs.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        datasets: f
            .datasets
            .values()
            .map(|d| {
                (
                    d.meta.clone(),
                    d.blocks.iter().map(|b| b.slab.clone()).collect(),
                )
            })
            .collect(),
    }
}

/// Encode a Reply::Data wire message for the blocks of `snapshot`
/// intersecting `want`, extracting each intersection *directly into*
/// the wire buffer (§Perf iteration 2: no staging buffer per block).
/// Returns (encoded reply, payload bytes).
fn encode_data_reply(
    snapshot: &H5File,
    dset: &str,
    want: &Hyperslab,
) -> Result<(Vec<u8>, usize)> {
    let d = snapshot.dataset(dset)?;
    let esize = d.meta.dtype.size_bytes();
    let inters: Vec<(&super::model::OwnedBlock, Hyperslab)> = d
        .blocks
        .iter()
        .filter_map(|b| b.slab.intersect(want).map(|i| (b, i)))
        .collect();
    let payload: usize = inters
        .iter()
        .map(|(_, i)| i.element_count() as usize * esize + 64)
        .sum();
    let mut w = crate::comm::wire::Writer::with_capacity(payload + 16);
    w.put_u8(1); // Reply::Data discriminant
    w.put_u64(inters.len() as u64);
    let mut nbytes = 0;
    for (b, inter) in inters {
        inter.encode(&mut w);
        let n = inter.element_count() as usize * esize;
        nbytes += n;
        w.put_bytes_via(n, |dst| {
            copy_region(&b.slab, &b.data, &inter, dst, &inter, esize);
        });
    }
    Ok((w.into_vec(), nbytes))
}
