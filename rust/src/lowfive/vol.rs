//! The Vol object: our reimplementation of the LowFive HDF5 VOL plugin
//! (substrate S5), now a thin facade over the routed data plane. One
//! Vol per rank; task codes talk to it through the HDF5-like
//! file/dataset API and never see the workflow system — the paper's
//! "no task code changes" property.
//!
//! The transport machinery lives in two engines the Vol owns:
//!
//! * [`ProducerEngine`](super::producer) — buffers dataset writes in
//!   memory; closing a file *serves* it per the per-dataset
//!   [`RouteTable`](super::route::RouteTable) of every matching
//!   channel (memory rounds through the flow layer, file/both routes
//!   to versioned disk files, zero-copy handoff to same-process
//!   consumers).
//! * [`ConsumerEngine`](super::consumer) — opens served files
//!   (round-robin across channels, which is how fan-in ensembles
//!   interleave their producers), assembling each file's datasets
//!   from the memory metadata and/or the polled disk half; reads pull
//!   only the intersecting blocks (O(M+N) block-range intersection,
//!   never O(M·N) element scans).
//!
//! The Vol itself keeps what both halves and the task code share:
//! the in-memory files, callbacks, counters and stats.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::comm::Comm;
use crate::error::{Result, WilkinsError};
use crate::metrics::Recorder;

use super::consumer::{ConsumerEngine, ConsumerFile, InChannel};
use super::hyperslab::Hyperslab;
use super::model::{AttrValue, DType, DatasetMeta, H5File};
use super::producer::{OutChannel, ProducerEngine};
use super::stats::{EngineCx, VolStats};
use super::{filemode, pattern_matches};

/// Callback slots (LowFive's custom-callback extension, Sec. 3.4).
/// Each receives the Vol and the filename (or dataset name) involved.
type FileCb = Box<dyn FnMut(&mut Vol, &str) + Send>;

/// The registered custom callbacks of one Vol.
#[derive(Default)]
pub struct Callbacks {
    /// Runs before a consumer-side `file_open` blocks.
    pub before_file_open: Option<FileCb>,
    /// Runs after any successful `file_open`.
    pub after_file_open: Option<FileCb>,
    /// Runs before a `file_close` serves (may call `skip_serve`).
    pub before_file_close: Option<FileCb>,
    /// Runs after a `file_close` completes.
    pub after_file_close: Option<FileCb>,
    /// Runs after every producer-side `dataset_write`.
    pub after_dataset_write: Option<FileCb>,
}

/// Build an [`EngineCx`] from disjoint `Vol` fields (keeps the
/// engines' `&mut self` borrows separate from the context borrows).
macro_rules! engine_cx {
    ($self:ident) => {
        EngineCx {
            io_comm: $self.io_comm.as_ref(),
            workdir: &$self.workdir,
            stats: &mut $self.stats,
            recorder: $self.recorder.as_ref(),
            lockstep_reads: $self.lockstep_reads,
            zero_copy: $self.zero_copy,
            pooling: $self.pooling,
        }
    };
}

/// The per-rank LowFive object.
pub struct Vol {
    /// Restricted-world communicator of the owning task.
    local: Comm,
    /// I/O-rank sub-communicator (subset writers, Sec. 3.2.2). None on
    /// non-I/O ranks.
    io_comm: Option<Comm>,
    /// Producer half: out-channels, serve rounds, disk writes.
    producer: ProducerEngine,
    /// Consumer half: in-channels, opened files.
    consumer: ConsumerEngine,
    /// Producer-side in-memory files (shared with buffered serve
    /// rounds; mutation copy-on-writes via [`Arc::make_mut`]).
    files: HashMap<String, Arc<H5File>>,
    /// Per-file close counts and the global counter (Listing 5).
    closes: HashMap<String, u64>,
    /// Total file closes seen by this rank.
    pub file_close_counter: u64,
    /// Dataset writes seen (drives Listing-3-style actions).
    dataset_write_counter: u64,
    callbacks: Callbacks,
    /// Set by before_file_close callbacks to skip the default serve
    /// (flow control and custom I/O patterns build on this).
    suppress_serve: bool,
    /// File pre-opened by the driver (stateless-consumer relaunch,
    /// Sec. 3.5.1): the task's next file_open consumes it.
    preopened: Option<String>,
    /// This rank's transport counters.
    pub stats: VolStats,
    /// Directory for file-mode transports.
    workdir: PathBuf,
    /// Optional Gantt recorder (metrics S11): wait spans are recorded
    /// against this rank's timeline.
    recorder: Option<(Arc<Recorder>, usize)>,
    /// Ablation switch (benches/ablation.rs): issue DataReqs one rank
    /// at a time instead of pipelining send-all-then-receive.
    lockstep_reads: bool,
    /// Zero-copy fast path for same-process serves (default on;
    /// benches/dataplane.rs ablates it).
    zero_copy: bool,
    /// Pooled encode buffers for serve replies and disk archives
    /// (default on; benches/wire.rs ablates it).
    pooling: bool,
}

impl Vol {
    /// A fresh Vol over a restricted-world communicator.
    pub fn new(local: Comm, workdir: PathBuf) -> Vol {
        Vol {
            local,
            io_comm: None,
            producer: ProducerEngine::default(),
            consumer: ConsumerEngine::default(),
            files: HashMap::new(),
            closes: HashMap::new(),
            file_close_counter: 0,
            dataset_write_counter: 0,
            callbacks: Callbacks::default(),
            suppress_serve: false,
            preopened: None,
            stats: VolStats::default(),
            workdir,
            recorder: None,
            lockstep_reads: false,
            zero_copy: true,
            pooling: crate::comm::buf::pooling_enabled(),
        }
    }

    /// Ablation only: disable read pipelining (see benches/ablation.rs).
    pub fn set_lockstep_reads(&mut self, v: bool) {
        self.lockstep_reads = v;
    }

    /// Ablation only: disable the zero-copy same-process serve path
    /// (see benches/dataplane.rs), forcing every data reply through
    /// the encode/decode round-trip.
    pub fn set_zero_copy(&mut self, v: bool) {
        self.zero_copy = v;
    }

    /// Ablation only: disable the pooled wire plane (see
    /// benches/wire.rs) — serve replies and disk archives encode into
    /// fresh allocations, and the process-wide transport switch
    /// ([`crate::comm::buf::set_pooling`]) falls back to the
    /// historical concatenate/copy-out frame path.
    pub fn set_pooling(&mut self, v: bool) {
        self.pooling = v;
        crate::comm::buf::set_pooling(v);
    }

    /// Driver-side pre-open (the paper's "query producers whether there
    /// are more data to consume"): blocks until a producer serves a
    /// file on any live in-channel, or every channel reports EOF.
    /// The opened file is stashed; the task code's next `file_open`
    /// returns it, keeping the task code workflow-oblivious.
    pub fn preopen_next(&mut self) -> Result<String> {
        if let Some(name) = &self.preopened {
            return Ok(name.clone());
        }
        let name = self.open_any()?;
        self.preopened = Some(name.clone());
        Ok(name)
    }

    /// Open the next served file from any live in-channel (round-robin).
    pub fn open_any(&mut self) -> Result<String> {
        let name = {
            let mut cx = engine_cx!(self);
            self.consumer.open_any(&mut cx)?
        };
        self.run_cb(|c| &mut c.after_file_open, &name);
        Ok(name)
    }

    /// Attach a Gantt recorder; `rank` is the global rank label used
    /// for this Vol's wait spans.
    pub fn set_recorder(&mut self, rec: Arc<Recorder>, rank: usize) {
        self.recorder = Some((rec, rank));
    }

    /// This rank's index within the task's restricted world.
    pub fn rank(&self) -> usize {
        self.local.rank()
    }

    /// The task's restricted-world communicator.
    pub fn local_comm(&self) -> &Comm {
        &self.local
    }

    /// Install (or clear) the I/O-rank sub-communicator.
    pub fn set_io_comm(&mut self, io: Option<Comm>) {
        self.io_comm = io;
    }

    /// The I/O-rank sub-communicator, if this rank is a writer.
    pub fn io_comm(&self) -> Option<&Comm> {
        self.io_comm.as_ref()
    }

    /// Is this rank an I/O rank? (Always true unless subset writers
    /// are configured and this rank is excluded.)
    pub fn is_io_rank(&self) -> bool {
        self.io_comm.is_some()
    }

    /// Attach a producer-side channel.
    pub fn add_out_channel(&mut self, ch: OutChannel) {
        self.producer.channels.push(ch);
    }

    /// Attach a consumer-side channel.
    pub fn add_in_channel(&mut self, ch: InChannel) {
        self.consumer.channels.push(ch);
    }

    /// Directory file-routed transports read and write.
    pub fn workdir(&self) -> &PathBuf {
        &self.workdir
    }

    // ---- callback registration (Listing 5 API) ----------------------------

    /// Register the before-file-open callback.
    pub fn set_before_file_open(&mut self, cb: FileCb) {
        self.callbacks.before_file_open = Some(cb);
    }

    /// Register the after-file-open callback.
    pub fn set_after_file_open(&mut self, cb: FileCb) {
        self.callbacks.after_file_open = Some(cb);
    }

    /// Register the before-file-close callback.
    pub fn set_before_file_close(&mut self, cb: FileCb) {
        self.callbacks.before_file_close = Some(cb);
    }

    /// Register the after-file-close callback.
    pub fn set_after_file_close(&mut self, cb: FileCb) {
        self.callbacks.after_file_close = Some(cb);
    }

    /// Register the after-dataset-write callback.
    pub fn set_after_dataset_write(&mut self, cb: FileCb) {
        self.callbacks.after_dataset_write = Some(cb);
    }

    fn run_cb(&mut self, which: fn(&mut Callbacks) -> &mut Option<FileCb>, arg: &str) {
        if let Some(mut cb) = which(&mut self.callbacks).take() {
            cb(self, arg);
            let slot = which(&mut self.callbacks);
            if slot.is_none() {
                *slot = Some(cb);
            }
        }
    }

    /// Skip the default serve for the file being closed (callable from
    /// before_file_close callbacks: flow control, custom I/O patterns).
    pub fn skip_serve(&mut self) {
        self.suppress_serve = true;
    }

    /// Are there pending (unanswered) consumer requests for files
    /// matching this name? Drives the *latest* flow-control strategy.
    pub fn any_pending_requests(&self, filename: &str) -> bool {
        self.producer.any_pending_requests(filename)
    }

    /// How many times has `filename` been closed so far?
    pub fn closes_of(&self, filename: &str) -> u64 {
        self.closes.get(filename).copied().unwrap_or(0)
    }

    /// Counter for dataset writes (Listing-3-style custom actions).
    pub fn note_dataset_write(&mut self) {
        self.dataset_write_counter += 1;
    }

    /// Dataset writes seen so far.
    pub fn dataset_writes(&self) -> u64 {
        self.dataset_write_counter
    }

    // ---- producer-side API -------------------------------------------------

    /// Create (or truncate) an in-memory file for writing.
    pub fn file_create(&mut self, name: &str) -> Result<()> {
        self.files.insert(name.to_string(), Arc::new(H5File::new(name)));
        Ok(())
    }

    /// Producer-side reopen of a locally written file (Nyx pattern).
    pub fn producer_file_exists(&self, name: &str) -> bool {
        self.files.contains_key(name)
    }

    /// Producer-side collective reopen (the second open of the Nyx
    /// double-open pattern). Runs the file-open callbacks — which is
    /// where the custom action receives rank 0's broadcast state —
    /// then checks the file exists locally.
    pub fn producer_file_open(&mut self, name: &str) -> Result<()> {
        self.run_cb(|c| &mut c.before_file_open, name);
        if !self.files.contains_key(name) {
            return Err(WilkinsError::LowFive(format!(
                "producer reopen of unknown file {name}"
            )));
        }
        self.run_cb(|c| &mut c.after_file_open, name);
        Ok(())
    }

    /// Write a file attribute.
    pub fn attr_write(&mut self, file: &str, key: &str, value: AttrValue) -> Result<()> {
        self.file_mut(file)?.attrs.insert(key.to_string(), value);
        Ok(())
    }

    /// Create a dataset with a global shape.
    pub fn dataset_create(
        &mut self,
        file: &str,
        dset: &str,
        dtype: DType,
        dims: &[u64],
    ) -> Result<()> {
        self.file_mut(file)?.create_dataset(dset, dtype, dims)
    }

    /// Write this rank's hyperslab of a dataset.
    pub fn dataset_write(
        &mut self,
        file: &str,
        dset: &str,
        slab: Hyperslab,
        data: Vec<u8>,
    ) -> Result<()> {
        self.file_mut(file)?.dataset_mut(dset)?.write_slab(slab, data)?;
        self.run_cb(|c| &mut c.after_dataset_write, dset);
        Ok(())
    }

    fn file_mut(&mut self, name: &str) -> Result<&mut H5File> {
        self.files
            .get_mut(name)
            // Copy-on-write: clones the file only when a buffered
            // serve round still shares it (pipelining depth > 1 or a
            // dropping policy); the default synchronous path mutates
            // in place.
            .map(Arc::make_mut)
            .ok_or_else(|| WilkinsError::LowFive(format!("file {name} not open for writing")))
    }

    /// The producer-side in-memory file, if open for writing.
    pub fn file(&self, name: &str) -> Result<&H5File> {
        self.files
            .get(name)
            .map(Arc::as_ref)
            .ok_or_else(|| WilkinsError::LowFive(format!("file {name} not open for writing")))
    }

    /// Close a file. On the producer this is where data serving
    /// happens (unless a callback suppressed it); on the consumer it
    /// sends the Done for the current serve round.
    pub fn file_close(&mut self, name: &str) -> Result<()> {
        if self.consumer.has_file(name) {
            self.run_cb(|c| &mut c.before_file_close, name);
            self.consumer.file_close(name)?;
            self.run_cb(|c| &mut c.after_file_close, name);
            return Ok(());
        }
        self.suppress_serve = false;
        self.run_cb(|c| &mut c.before_file_close, name);
        *self.closes.entry(name.to_string()).or_insert(0) += 1;
        self.file_close_counter += 1;
        if self.suppress_serve {
            self.suppress_serve = false;
            self.stats.serves_suppressed += 1;
        } else {
            self.serve_file(name)?;
        }
        self.run_cb(|c| &mut c.after_file_close, name);
        Ok(())
    }

    /// Serve `name` on every matching channel (Listing 5's serve_all
    /// serves every open file).
    pub fn serve_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.files.keys().cloned().collect();
        for name in names {
            self.serve_file(&name)?;
        }
        Ok(())
    }

    /// Drop all producer-side in-memory file state (Listing 5).
    pub fn clear_files(&mut self) {
        self.files.clear();
    }

    /// Broadcast rank 0's in-memory files to all ranks of the local
    /// communicator (the Nyx custom I/O pattern: rank 0 writes file
    /// metadata solo, then every rank needs a consistent view).
    pub fn broadcast_files(&mut self) -> Result<()> {
        let payload = if self.local.rank() == 0 {
            // `encode_files` borrows through the `Arc`s: no deep copy
            // of dataset bytes just to serialize them.
            Some(filemode::encode_files(&self.files))
        } else {
            None
        };
        let bytes = self.local.bcast(0, payload.as_deref())?;
        if self.local.rank() != 0 {
            let files = filemode::decode_files(&bytes)?;
            for (name, file) in files {
                self.files.insert(name, Arc::new(file));
            }
        }
        Ok(())
    }

    /// Serve one file through the producer engine (route resolution,
    /// flow admission, disk write-through). Only I/O ranks
    /// participate.
    fn serve_file(&mut self, name: &str) -> Result<()> {
        let Some(file) = self.files.get(name) else {
            return Ok(()); // nothing buffered (non-writer rank)
        };
        if !self.is_io_rank() {
            return Ok(());
        }
        let file = Arc::clone(file);
        let mut cx = engine_cx!(self);
        self.producer.serve_file(&mut cx, name, &file)
    }

    /// Producer finalize: flush every channel's round buffer, write
    /// disk EOF markers, then run the memory EOF handshake.
    /// Idempotent.
    pub fn finalize_producer(&mut self) -> Result<()> {
        if !self.is_io_rank() {
            return Ok(());
        }
        let mut cx = engine_cx!(self);
        self.producer.finalize(&mut cx)
    }

    // ---- consumer-side API -------------------------------------------------

    /// Open the next available file matching `pattern`. Blocks until a
    /// producer serves one; returns the actual filename. Round-robins
    /// across matching in-channels (fan-in). Err(EndOfStream) when all
    /// matching channels are exhausted.
    pub fn file_open(&mut self, pattern: &str) -> Result<String> {
        if let Some(name) = self.preopened.take() {
            if pattern_matches(pattern, &name) || pattern_matches(&name, pattern) {
                return Ok(name);
            }
            self.preopened = Some(name); // not what the task wants
        }
        self.run_cb(|c| &mut c.before_file_open, pattern);
        let name = {
            let mut cx = engine_cx!(self);
            self.consumer.open_matching(&mut cx, pattern)?
        };
        self.run_cb(|c| &mut c.after_file_open, &name);
        Ok(name)
    }

    /// An opened consumer-side file.
    pub fn consumer_file(&self, name: &str) -> Result<&ConsumerFile> {
        self.consumer.file(name)
    }

    /// Metadata of a dataset of an opened file.
    pub fn dataset_meta(&self, file: &str, dset: &str) -> Result<DatasetMeta> {
        self.consumer.dataset_meta(file, dset)
    }

    /// Read `want` of `dset` (global coordinates). Pulls only the
    /// intersecting blocks from the producer ranks (or the disk half)
    /// that own them.
    pub fn dataset_read(&mut self, file: &str, dset: &str, want: &Hyperslab) -> Result<Vec<u8>> {
        let mut cx = engine_cx!(self);
        self.consumer.dataset_read(&mut cx, file, dset, want)
    }

    /// Consumer finalize: tell producers on every non-exhausted memory
    /// channel that this rank will not request again. Idempotent.
    pub fn finalize_consumer(&mut self) -> Result<()> {
        self.consumer.finalize()
    }

    /// Are any in-channels still live (not exhausted)?
    pub fn has_live_inputs(&self) -> bool {
        self.consumer.has_live_inputs()
    }
}
