//! Per-dataset transport routing (paper Sec. 4.2): the table that
//! decides, for every (channel, dataset) pair, whether bytes move
//! through memory, to a traditional file on disk, or both
//! (write-through), plus the process-local shared-snapshot registry
//! behind the zero-copy serve fast path.
//!
//! The LowFive layer selects the transport *per dataset*: different
//! datasets of one file can ride different transports, and a dataset
//! flagged `memory: 1, file: 1` is written through — served in situ
//! to the coupled consumer *and* archived as a versioned disk file on
//! the same close. The graph layer builds one [`RouteTable`] per
//! channel from the matched port flags (see `graph::match_ports`);
//! uniform tables ([`RouteTable::memory`] / [`RouteTable::file`])
//! reproduce the old single-mode channels exactly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use super::model::H5File;
use super::pattern_matches;

/// Where a dataset's bytes travel on a producer file close.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// In-memory transport over the channel intercommunicator (the
    /// default; the paper's in situ path).
    Memory,
    /// Traditional file transport: producer I/O ranks write a
    /// versioned disk file, consumers poll it back.
    File,
    /// Write-through: served over memory *and* archived to disk on the
    /// same close (YAML `memory: 1, file: 1`).
    Both,
}

impl Route {
    /// Is the dataset delivered to consumers over the memory channel?
    pub fn to_memory(self) -> bool {
        matches!(self, Route::Memory | Route::Both)
    }

    /// Is the dataset written to a disk file on close?
    pub fn to_file(self) -> bool {
        matches!(self, Route::File | Route::Both)
    }
}

impl std::fmt::Display for Route {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Route::Memory => "memory",
            Route::File => "file",
            Route::Both => "both",
        })
    }
}

/// A channel's per-dataset routing: ordered (dataset pattern, route)
/// entries (first match wins) plus a fallback route for datasets no
/// entry matches.
///
/// The fallback keeps the Listing-1 convention intact on *both*
/// transports: a channel that names only `/group1/grid` still moves
/// the whole file, so a consumer task may read sibling datasets the
/// ports never mentioned. On a channel with any memory side the
/// fallback is `Memory` (siblings ride the served metadata); on a
/// pure file-only channel it is `File` (siblings land in the disk
/// archive, exactly like the historical whole-file write).
#[derive(Debug, Clone, PartialEq)]
pub struct RouteTable {
    entries: Vec<(String, Route)>,
    fallback: Route,
}

impl RouteTable {
    /// Table from matched (dataset pattern, route) pairs; unmatched
    /// datasets fall back to memory — or to file when every entry is
    /// file-only (a pure file-mode channel has no memory side to
    /// carry them).
    pub fn new(entries: Vec<(String, Route)>) -> RouteTable {
        let fallback = if !entries.is_empty() && entries.iter().all(|(_, r)| *r == Route::File)
        {
            Route::File
        } else {
            Route::Memory
        };
        RouteTable { entries, fallback }
    }

    /// Uniform table: every dataset takes `route`.
    pub fn uniform(route: Route) -> RouteTable {
        RouteTable { entries: Vec::new(), fallback: route }
    }

    /// Uniform in-memory table (the old `ChannelMode::Memory`).
    pub fn memory() -> RouteTable {
        RouteTable::uniform(Route::Memory)
    }

    /// Uniform file-mode table (the old `ChannelMode::File`).
    pub fn file() -> RouteTable {
        RouteTable::uniform(Route::File)
    }

    /// The matched (pattern, route) entries, in match order.
    pub fn entries(&self) -> &[(String, Route)] {
        &self.entries
    }

    /// Resolve the route of a concrete dataset name: first matching
    /// entry wins, else the fallback.
    pub fn route_of(&self, dset: &str) -> Route {
        self.entries
            .iter()
            .find(|(pat, _)| pattern_matches(pat, dset))
            .map(|(_, r)| *r)
            .unwrap_or(self.fallback)
    }

    fn routes(&self) -> impl Iterator<Item = Route> + '_ {
        let fb = if self.entries.is_empty() {
            Some(self.fallback)
        } else {
            None
        };
        self.entries.iter().map(|(_, r)| *r).chain(fb)
    }

    /// Does any routed dataset travel over the memory channel?
    /// (Decides whether the channel needs an intercommunicator.)
    pub fn any_memory(&self) -> bool {
        self.routes().any(Route::to_memory)
    }

    /// Does any routed dataset land on disk? (Decides whether closes
    /// write a disk file and finalize drops an EOF marker.)
    pub fn any_file(&self) -> bool {
        self.routes().any(Route::to_file)
    }

    /// Does any dataset travel *only* via disk? (Decides whether a
    /// memory consumer must also poll the disk file of each round.)
    pub fn any_file_only(&self) -> bool {
        self.routes().any(|r| r == Route::File)
    }

    /// Is `dset` part of the memory snapshot served on this channel?
    /// Everything except explicitly file-only datasets is.
    pub fn delivers_in_memory(&self, dset: &str) -> bool {
        self.route_of(dset) != Route::File
    }

    /// Is `dset` archived to disk on close over this channel?
    pub fn archives_to_disk(&self, dset: &str) -> bool {
        self.route_of(dset).to_file()
    }
}

impl std::fmt::Display for RouteTable {
    /// Renders `memory`, `file`, or `[/grid:both, /particles:file]`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.entries.is_empty() {
            return write!(f, "{}", self.fallback);
        }
        write!(f, "[")?;
        for (i, (pat, r)) in self.entries.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{pat}:{r}")?;
        }
        write!(f, "]")
    }
}

/// Attribute smuggled into mixed-route memory snapshots: the disk
/// version written on the same close, so the consumer can poll the
/// file-routed datasets of exactly this round. Stripped from the
/// attrs a consumer task sees.
pub(super) const DISK_VERSION_ATTR: &str = "__wilkins_disk_version";

// ---- zero-copy shared-snapshot registry --------------------------------
//
// When a producer rank answers a DataReq from a consumer rank hosted
// in the *same OS process* (always, in-memory; for `wilkins up`
// whenever both ranks landed on one worker), encoding the blocks into
// a wire reply and decoding them back is pure copy overhead: both
// sides can see the same address space. The fast path parks an
// `Arc<H5File>` snapshot here under a process-unique token and sends
// only the token; the consumer takes the Arc out and copies each
// intersecting block region straight into its read buffer — one copy
// end to end instead of three (encode + deliver + decode).
//
// Tokens are allocated from one process-wide counter, so concurrent
// worlds (ensemble instances, benches) never collide. Every entry is
// taken out by the consumer's very next reply receive; the map is
// transient by construction. Entries are `Weak` so a consumer rank
// that dies between request and receive cannot pin the payload for
// the life of the process: the producer's round buffer holds the
// strong reference until the round completes (the consumer always
// reads before sending `Done`, so a live reader's upgrade never
// fails), and dead entries are pruned on the next share.

static SHARED: OnceLock<Mutex<HashMap<u64, Weak<H5File>>>> = OnceLock::new();
static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);

fn shared_map() -> &'static Mutex<HashMap<u64, Weak<H5File>>> {
    SHARED.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Park a snapshot for a same-process consumer; returns its token.
pub(super) fn share_snapshot(snapshot: Arc<H5File>) -> u64 {
    let token = NEXT_TOKEN.fetch_add(1, Ordering::Relaxed);
    let mut map = shared_map().lock().unwrap();
    // Opportunistic prune: a token whose round already retired can
    // never be taken (its consumer is gone) — drop the dead weaks so
    // failed ranks don't accumulate entries.
    map.retain(|_, w| w.strong_count() > 0);
    map.insert(token, Arc::downgrade(&snapshot));
    token
}

/// Take a parked snapshot out of the registry (consumer side).
pub(super) fn take_snapshot(token: u64) -> Option<Arc<H5File>> {
    shared_map().lock().unwrap().remove(&token).and_then(|w| w.upgrade())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowfive::model::{DType, H5File};

    #[test]
    fn uniform_tables_match_old_channel_modes() {
        let m = RouteTable::memory();
        assert!(m.any_memory() && !m.any_file() && !m.any_file_only());
        assert_eq!(m.route_of("/anything"), Route::Memory);
        let f = RouteTable::file();
        assert!(!f.any_memory() && f.any_file() && f.any_file_only());
        assert_eq!(f.route_of("/anything"), Route::File);
        assert_eq!(m.to_string(), "memory");
        assert_eq!(f.to_string(), "file");
    }

    #[test]
    fn mixed_table_routes_per_pattern() {
        let t = RouteTable::new(vec![
            ("/group1/grid".into(), Route::Both),
            ("/particles/*".into(), Route::File),
        ]);
        assert_eq!(t.route_of("/group1/grid"), Route::Both);
        assert_eq!(t.route_of("/particles/position"), Route::File);
        // Unmatched datasets fall back to memory (Listing-1 behavior).
        assert_eq!(t.route_of("/other"), Route::Memory);
        assert!(t.any_memory() && t.any_file() && t.any_file_only());
        assert!(t.delivers_in_memory("/group1/grid"));
        assert!(!t.delivers_in_memory("/particles/position"));
        assert!(t.archives_to_disk("/group1/grid"));
        assert!(!t.archives_to_disk("/other"));
    }

    #[test]
    fn first_matching_entry_wins() {
        let t = RouteTable::new(vec![
            ("/a/*".into(), Route::File),
            ("/a/special".into(), Route::Memory),
        ]);
        assert_eq!(t.route_of("/a/special"), Route::File);
    }

    #[test]
    fn route_flags() {
        assert!(Route::Memory.to_memory() && !Route::Memory.to_file());
        assert!(!Route::File.to_memory() && Route::File.to_file());
        assert!(Route::Both.to_memory() && Route::Both.to_file());
    }

    #[test]
    fn shared_registry_round_trip() {
        let f = Arc::new({
            let mut f = H5File::new("x.h5");
            f.create_dataset("/d", DType::U8, &[4]).unwrap();
            f
        });
        let t = share_snapshot(Arc::clone(&f));
        let got = take_snapshot(t).expect("token resolves once");
        assert!(Arc::ptr_eq(&f, &got));
        assert!(take_snapshot(t).is_none(), "tokens are single-use");
    }

    #[test]
    fn shared_registry_does_not_pin_dead_rounds() {
        // The registry holds weak refs: once the producer's round (the
        // strong owner) is gone, an orphaned token resolves to None
        // instead of leaking the payload.
        let t = share_snapshot(Arc::new(H5File::new("gone.h5")));
        assert!(take_snapshot(t).is_none(), "no strong owner left");
    }

    #[test]
    fn file_only_tables_default_siblings_to_file() {
        // A pure file-mode channel that names only /grid must still
        // archive sibling datasets (the historical whole-file write);
        // any memory side flips the fallback to memory.
        let t = RouteTable::new(vec![("/grid".into(), Route::File)]);
        assert_eq!(t.route_of("/sibling"), Route::File);
        assert!(t.archives_to_disk("/sibling"));
        let m = RouteTable::new(vec![
            ("/grid".into(), Route::File),
            ("/x".into(), Route::Both),
        ]);
        assert_eq!(m.route_of("/sibling"), Route::Memory);
    }
}
