//! Consumer engine of the routed data plane: opens served files
//! across in-channels (round-robin fan-in), assembles per-dataset
//! block tables from memory metadata and/or polled disk files, and
//! pulls only the intersecting blocks on reads — via the zero-copy
//! shared-snapshot path when the producer rank shares this process.
//!
//! One `ConsumerEngine` lives inside each [`Vol`](super::Vol). A
//! channel's [`RouteTable`] decides where each dataset's bytes come
//! from:
//!
//! * **memory / both** — the producer's served snapshot, read with
//!   `DataReq`s over the intercommunicator (remote blocks);
//! * **file** — the versioned disk file of the same close, polled by
//!   the disk version the memory round carries
//!   ([`route::DISK_VERSION_ATTR`](super::route)) — or, on a pure
//!   file-mode channel, the lowest unconsumed version.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::comm::InterComm;
use crate::error::{Result, WilkinsError};
use crate::metrics::SpanKind;

use super::hyperslab::{copy_region, Hyperslab};
use super::model::{AttrValue, DatasetMeta, H5File};
use super::protocol::{
    FileMeta, Reply, Request, REP_SHARED_DISCRIMINANT, TAG_REP, TAG_REQ,
};
use super::route::{self, RouteTable, DISK_VERSION_ATTR};
use super::stats::EngineCx;
use super::{filemode, pattern_matches};

/// Consumer-side channel from one producer task.
pub struct InChannel {
    /// Intercommunicator to the producer task's I/O ranks (None on
    /// pure file-mode channels).
    pub intercomm: Option<InterComm>,
    /// Consumer-side filename pattern (what opens request).
    pub pattern: String,
    /// Per-dataset transport routing of this channel.
    pub routes: RouteTable,
    /// Version of the last file consumed from this channel.
    last_version: u64,
    exhausted: bool,
    /// Did we already send EofAck to the producers?
    eof_acked: bool,
}

impl InChannel {
    /// A fresh consumer channel.
    pub fn new(intercomm: Option<InterComm>, pattern: &str, routes: RouteTable) -> InChannel {
        InChannel {
            intercomm,
            pattern: pattern.to_string(),
            routes,
            last_version: 0,
            exhausted: false,
            eof_acked: false,
        }
    }
}

/// Where one opened dataset's bytes come from.
enum DsetSource {
    /// Remote producer blocks: per-producer-rank owned slabs, pulled
    /// with DataReqs over the channel intercomm.
    Remote { rank_slabs: Vec<Vec<Hyperslab>> },
    /// Fully materialised in the file's local (disk-read) half.
    Local,
}

/// A consumer-side opened file: merged metadata + block locations,
/// possibly assembled from both transports (mixed routing).
pub struct ConsumerFile {
    /// The actual filename served (glob patterns resolve to this).
    pub filename: String,
    /// Serve-round version on the owning channel.
    pub version: u64,
    /// File attributes (rank 0's view).
    pub attrs: Vec<(String, AttrValue)>,
    /// dataset -> (meta, where its bytes live)
    datasets: HashMap<String, (DatasetMeta, DsetSource)>,
    /// Memory channel the file was opened on (None: pure disk file).
    channel: Option<usize>,
    /// Locally materialised disk half (file-routed datasets).
    local: Option<H5File>,
}

impl ConsumerFile {
    /// Sorted names of every dataset in the file, whichever transport
    /// carried it.
    pub fn dataset_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.datasets.keys().cloned().collect();
        names.sort();
        names
    }

    /// Look up a file attribute.
    pub fn attr(&self, key: &str) -> Option<&AttrValue> {
        self.attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// The consumer half of a [`Vol`](super::Vol): in-channels, opened
/// files and the fan-in round-robin cursor.
#[derive(Default)]
pub(super) struct ConsumerEngine {
    pub(super) channels: Vec<InChannel>,
    files: HashMap<String, ConsumerFile>,
    /// Round-robin cursor over in-channels (fan-in interleaving).
    cursor: usize,
}

impl ConsumerEngine {
    /// Is `name` currently open for reading?
    pub(super) fn has_file(&self, name: &str) -> bool {
        self.files.contains_key(name)
    }

    pub(super) fn file(&self, name: &str) -> Result<&ConsumerFile> {
        self.files.get(name).ok_or_else(|| {
            WilkinsError::LowFive(format!("file {name} not open for reading"))
        })
    }

    /// Are any in-channels still live (not exhausted)?
    pub(super) fn has_live_inputs(&self) -> bool {
        self.channels.iter().any(|c| !c.exhausted)
    }

    /// Open the next served file from any live in-channel
    /// (round-robin). Blocks until a producer serves one.
    pub(super) fn open_any(&mut self, cx: &mut EngineCx<'_>) -> Result<String> {
        let t0 = Instant::now();
        let n = self.channels.len();
        if n == 0 {
            return Err(WilkinsError::LowFive("no in-channels configured".into()));
        }
        loop {
            let mut all_exhausted = true;
            for k in 0..n {
                let idx = (self.cursor + k) % n;
                if self.channels[idx].exhausted {
                    continue;
                }
                all_exhausted = false;
                let pat = self.channels[idx].pattern.clone();
                if let Some(name) = self.open_on_channel(cx, idx, &pat)? {
                    self.cursor = (idx + 1) % n;
                    cx.stats.files_opened += 1;
                    cx.stats.open_wait += t0.elapsed();
                    cx.record_span_with(
                        SpanKind::Idle,
                        &format!("open {name}"),
                        t0,
                        vec![("file".into(), name.clone())],
                    );
                    return Ok(name);
                }
            }
            if all_exhausted {
                return Err(WilkinsError::EndOfStream);
            }
        }
    }

    /// Open the next available file matching `pattern` (the
    /// `file_open` body). Round-robins across matching in-channels
    /// (fan-in); Err(EndOfStream) when all matching channels are
    /// exhausted.
    pub(super) fn open_matching(
        &mut self,
        cx: &mut EngineCx<'_>,
        pattern: &str,
    ) -> Result<String> {
        let t0 = Instant::now();
        let n = self.channels.len();
        if n == 0 {
            return Err(WilkinsError::LowFive("no in-channels configured".into()));
        }
        let mut tried = 0;
        let mut matched = false;
        while tried < n {
            let idx = (self.cursor + tried) % n;
            tried += 1;
            let matches = pattern_matches(&self.channels[idx].pattern, pattern)
                || pattern_matches(pattern, &self.channels[idx].pattern);
            if !matches {
                continue;
            }
            matched = true;
            if self.channels[idx].exhausted {
                continue;
            }
            match self.open_on_channel(cx, idx, pattern)? {
                Some(name) => {
                    self.cursor = (idx + 1) % n;
                    cx.stats.files_opened += 1;
                    cx.stats.open_wait += t0.elapsed();
                    cx.record_span_with(
                        SpanKind::Idle,
                        &format!("open {name}"),
                        t0,
                        vec![("file".into(), name.clone())],
                    );
                    return Ok(name);
                }
                None => continue, // hit EOF on this channel; try next
            }
        }
        if !matched {
            return Err(WilkinsError::LowFive(format!(
                "no in-channel matches pattern {pattern}"
            )));
        }
        Err(WilkinsError::EndOfStream)
    }

    /// Try to open on a specific channel. Ok(None) => channel EOF.
    fn open_on_channel(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        pattern: &str,
    ) -> Result<Option<String>> {
        let min_version = self.channels[idx].last_version + 1;
        if !self.channels[idx].routes.any_memory() {
            return self.open_disk_only(cx, idx, min_version);
        }
        let ic = self.channels[idx]
            .intercomm
            .as_ref()
            .ok_or_else(|| WilkinsError::LowFive("memory channel without intercomm".into()))?
            .clone();
        let req = Request::MetaReq {
            pattern: pattern.to_string(),
            min_version,
        }
        .encode();
        for r in 0..ic.remote_size() {
            ic.send(r, TAG_REQ, &req);
        }
        let mut metas: Vec<Option<FileMeta>> = (0..ic.remote_size()).map(|_| None).collect();
        let mut eof = false;
        for _ in 0..ic.remote_size() {
            let (src, bytes) = ic.recv_any(TAG_REP)?;
            match Reply::decode_from(&bytes)? {
                Reply::Meta(m) => metas[src] = Some(m),
                Reply::Eof => eof = true,
                Reply::Data(_) => {
                    return Err(WilkinsError::LowFive(
                        "unexpected data reply during open".into(),
                    ))
                }
            }
        }
        if eof {
            // SPMD producers answer consistently: all Eof.
            self.channels[idx].exhausted = true;
            if !self.channels[idx].eof_acked {
                let ack = Request::EofAck.encode();
                for r in 0..ic.remote_size() {
                    ic.send(r, TAG_REQ, &ack);
                }
                self.channels[idx].eof_acked = true;
            }
            return Ok(None);
        }
        let mut filename = String::new();
        let mut version = 0;
        let mut attrs = Vec::new();
        let mut datasets: HashMap<String, (DatasetMeta, DsetSource)> = HashMap::new();
        let nremote = ic.remote_size();
        for (src, m) in metas.into_iter().enumerate() {
            let m =
                m.ok_or_else(|| WilkinsError::LowFive("missing metadata reply".into()))?;
            filename = m.filename;
            version = m.version;
            if src == 0 {
                attrs = m.attrs;
            }
            for (meta, slabs) in m.datasets {
                let entry = datasets.entry(meta.name.clone()).or_insert_with(|| {
                    (meta.clone(), DsetSource::Remote { rank_slabs: vec![Vec::new(); nremote] })
                });
                if let DsetSource::Remote { rank_slabs } = &mut entry.1 {
                    rank_slabs[src] = slabs;
                }
            }
        }
        // Mixed routing: the round carries the disk version holding
        // its file-only datasets; fetch and fold them in as local.
        let disk_version = attrs
            .iter()
            .find(|(k, _)| k == DISK_VERSION_ATTR)
            .and_then(|(_, v)| v.as_i64());
        attrs.retain(|(k, _)| k != DISK_VERSION_ATTR);
        let mut local = None;
        if let Some(v) = disk_version {
            let deadline = Instant::now() + filemode::poll_timeout();
            // On timeout, name the datasets this wait was for — "which
            // inport starved" is the first question a stuck-campaign
            // triage asks.
            let ch = &self.channels[idx];
            let file_only: Vec<&str> = ch
                .routes
                .entries()
                .iter()
                .map(|(name, _)| name.as_str())
                .filter(|n| ch.routes.archives_to_disk(n) && !ch.routes.delivers_in_memory(n))
                .collect();
            let file = filemode::poll_file_exact(
                cx.workdir,
                &self.channels[idx].pattern,
                v as u64,
                deadline,
            )
            .map_err(|e| {
                WilkinsError::LowFive(format!(
                    "file-routed dataset(s) [{}] of inport {}: {e}",
                    file_only.join(", "),
                    self.channels[idx].pattern
                ))
            })?;
            for d in file.datasets.values() {
                // Memory wins for write-through datasets present on
                // both transports; disk supplies the file-only rest.
                datasets
                    .entry(d.meta.name.clone())
                    .or_insert_with(|| (d.meta.clone(), DsetSource::Local));
            }
            local = Some(file);
        }
        self.channels[idx].last_version = version;
        let cf = ConsumerFile {
            filename: filename.clone(),
            version,
            attrs,
            datasets,
            channel: Some(idx),
            local,
        };
        self.files.insert(filename.clone(), cf);
        Ok(Some(filename))
    }

    /// Pure file-mode open: poll the workdir for the next unconsumed
    /// version of the channel's pattern.
    fn open_disk_only(
        &mut self,
        cx: &mut EngineCx<'_>,
        idx: usize,
        min_version: u64,
    ) -> Result<Option<String>> {
        let deadline = Instant::now() + filemode::poll_timeout();
        let found = filemode::poll_file(
            cx.workdir,
            &self.channels[idx].pattern,
            min_version,
            deadline,
        )
        .map_err(|e| {
            let ch = &self.channels[idx];
            let dsets: Vec<&str> =
                ch.routes.entries().iter().map(|(name, _)| name.as_str()).collect();
            WilkinsError::LowFive(format!(
                "file-mode inport {} (dataset(s) [{}]): {e}",
                ch.pattern,
                dsets.join(", ")
            ))
        })?;
        match found {
            Some((file, version)) => {
                self.channels[idx].last_version = version;
                let name = file.name.clone();
                let cf = ConsumerFile {
                    filename: name.clone(),
                    version,
                    attrs: file
                        .attrs
                        .iter()
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                    datasets: file
                        .datasets
                        .values()
                        .map(|d| (d.meta.name.clone(), (d.meta.clone(), DsetSource::Local)))
                        .collect(),
                    channel: None,
                    local: Some(file),
                };
                self.files.insert(name.clone(), cf);
                Ok(Some(name))
            }
            None => {
                self.channels[idx].exhausted = true;
                Ok(None)
            }
        }
    }

    /// Metadata of one dataset of an opened file.
    pub(super) fn dataset_meta(&self, file: &str, dset: &str) -> Result<DatasetMeta> {
        let cf = self.file(file)?;
        cf.datasets
            .get(dset)
            .map(|(m, _)| m.clone())
            .ok_or_else(|| WilkinsError::LowFive(format!("no dataset {dset} in {file}")))
    }

    /// Read `want` of `dset` (global coordinates). Remote datasets
    /// pull only the intersecting blocks from the producer ranks that
    /// own them; local (disk-routed) datasets copy from the polled
    /// file.
    pub(super) fn dataset_read(
        &mut self,
        cx: &mut EngineCx<'_>,
        file: &str,
        dset: &str,
        want: &Hyperslab,
    ) -> Result<Vec<u8>> {
        let (meta, remote_slabs, src_channel) = {
            let cf = self.file(file)?;
            let (m, s) = cf
                .datasets
                .get(dset)
                .ok_or_else(|| WilkinsError::LowFive(format!("no dataset {dset} in {file}")))?;
            let slabs = match s {
                DsetSource::Remote { rank_slabs } => Some(rank_slabs.clone()),
                DsetSource::Local => None,
            };
            (m.clone(), slabs, cf.channel)
        };
        let esize = meta.dtype.size_bytes();
        let mut out = vec![0u8; want.element_count() as usize * esize];
        match remote_slabs {
            None => {
                // Disk-routed: blocks are local to this process.
                let cf = self.files.get(file).unwrap();
                if let Some(f) = &cf.local {
                    let filled = f.dataset(dset)?.read_into(want, &mut out);
                    cx.stats.bytes_read += filled * esize as u64;
                }
            }
            Some(rank_slabs) => {
                let idx = src_channel.ok_or_else(|| {
                    WilkinsError::LowFive(format!("remote dataset {dset} without a channel"))
                })?;
                let ic = self.channels[idx].intercomm.as_ref().unwrap().clone();
                let req = Request::DataReq {
                    file: file.to_string(),
                    dset: dset.to_string(),
                    slab: want.clone(),
                }
                .encode();
                // Only contact ranks whose owned slabs intersect the
                // wanted region (O(M+N) block-range intersection).
                let targets: Vec<usize> = rank_slabs
                    .iter()
                    .enumerate()
                    .filter(|(_, slabs)| slabs.iter().any(|s| s.overlaps(want)))
                    .map(|(r, _)| r)
                    .collect();
                if cx.lockstep_reads {
                    // Ablation arm: request/await one rank at a time.
                    for &r in &targets {
                        ic.send(r, TAG_REQ, &req);
                        let (_, bytes) = ic.recv(r, TAG_REP)?;
                        apply_data_reply(cx, dset, &bytes, want, &mut out, esize)?;
                    }
                } else {
                    // Default: pipeline — send every request first,
                    // then collect, overlapping the producers' work.
                    for &r in &targets {
                        ic.send(r, TAG_REQ, &req);
                    }
                    for &r in &targets {
                        let (_, bytes) = ic.recv(r, TAG_REP)?;
                        apply_data_reply(cx, dset, &bytes, want, &mut out, esize)?;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Close an opened file: release the serve round (Done to every
    /// producer rank on memory channels).
    pub(super) fn file_close(&mut self, name: &str) -> Result<()> {
        if let Some(cf) = self.files.remove(name) {
            if let Some(channel) = cf.channel {
                let ic = self.channels[channel].intercomm.as_ref().unwrap();
                let done = Request::Done { version: cf.version }.encode();
                for r in 0..ic.remote_size() {
                    ic.send(r, TAG_REQ, &done);
                }
            }
        }
        Ok(())
    }

    /// Consumer finalize: tell producers on every non-exhausted memory
    /// channel that this rank will not request again. Idempotent.
    pub(super) fn finalize(&mut self) -> Result<()> {
        for ch in &mut self.channels {
            if ch.routes.any_memory() && !ch.eof_acked {
                if let Some(ic) = &ch.intercomm {
                    let ack = Request::EofAck.encode();
                    for r in 0..ic.remote_size() {
                        ic.send(r, TAG_REQ, &ack);
                    }
                }
                ch.eof_acked = true;
            }
        }
        Ok(())
    }
}

/// Apply one data reply to the caller's output buffer.
///
/// Inline replies (§Perf iteration 3) stream block bytes straight
/// from the wire buffer — which on socket transports *is* the pooled
/// receive buffer, so a remote `DataRep` body reaches this hyperslab
/// fill with exactly one copy off the wire; shared replies resolve
/// the token against the process-local registry and copy regions
/// directly out of the producer's snapshot — the zero-copy fast
/// path's receiving half.
fn apply_data_reply(
    cx: &mut EngineCx<'_>,
    dset: &str,
    bytes: &crate::comm::buf::Payload,
    want: &Hyperslab,
    out: &mut [u8],
    esize: usize,
) -> Result<()> {
    let mut r = crate::comm::wire::Reader::new(bytes);
    match r.get_u8()? {
        1 => {
            let nblocks = r.get_u64()? as usize;
            for _ in 0..nblocks {
                let region = Hyperslab::decode(&mut r)?;
                let data = r.get_bytes()?; // borrowed, no copy
                cx.stats.bytes_read += data.len() as u64;
                copy_region(&region, data, want, out, &region, esize);
                crate::comm::buf::note_copied(data.len());
            }
            Ok(())
        }
        REP_SHARED_DISCRIMINANT => {
            let token = r.get_u64()?;
            let snap: Arc<H5File> = route::take_snapshot(token).ok_or_else(|| {
                WilkinsError::LowFive("shared serve token did not resolve".into())
            })?;
            let filled = snap.dataset(dset)?.read_into(want, out);
            cx.stats.bytes_read += filled * esize as u64;
            Ok(())
        }
        c => Err(WilkinsError::LowFive(format!("bad data reply code {c}"))),
    }
}
