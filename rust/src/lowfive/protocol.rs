//! Wire protocol for the consumer-pull redistribution (memory mode).
//!
//! LowFive serves data from producer to consumer when the producer
//! closes a file and the consumer opens it (paper Sec. 4.2.2). We
//! reproduce that as a request/serve protocol over the channel
//! intercommunicator:
//!
//! consumer rank j                      producer rank i
//! ---------------                      ---------------
//! MetaReq{pattern, min_version}  -->
//!                                <--   MetaRep{file metadata} | Eof
//! DataReq{file, dset, slab}      -->
//!                                <--   DataRep{intersecting blocks}
//! Done{version}                  -->
//! EofAck                         -->   (finalize drain only)
//!
//! Versions are the producer's file-close serve counter; they keep
//! serve rounds from mixing when a fast consumer re-opens while a slow
//! consumer rank is still reading (the paper's flow-control scenarios).

use crate::comm::buf::Payload;
use crate::comm::wire::{Reader, Writer};
use crate::error::{Result, WilkinsError};

use super::hyperslab::Hyperslab;
use super::model::{AttrValue, DatasetMeta};

/// Tag used by consumer→producer requests on a channel intercomm.
pub const TAG_REQ: u64 = 1;
/// Wire discriminant of [`Request::DataReq`] (the first payload
/// byte). The flow pump's selective receive peeks it to answer data
/// reads without absorbing plan-owned protocol events, so it is
/// named here — next to the encoding that owns it — and used by
/// both `Request::encode` and the drain.
pub(crate) const REQ_DATA_DISCRIMINANT: u8 = 1;
/// Tag used by producer→consumer replies.
pub const TAG_REP: u64 = 2;
/// Wire discriminant of a zero-copy shared-snapshot reply (the first
/// payload byte, distinct from every [`Reply`] variant): the body is
/// just the shared-registry token. Shared replies exist only between
/// ranks of one OS process and are consumed on the data-read path,
/// never by [`Reply::decode`].
pub(crate) const REP_SHARED_DISCRIMINANT: u8 = 3;

/// Encode a shared-snapshot reply: discriminant + registry token.
pub(crate) fn encode_shared_reply(token: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u8(REP_SHARED_DISCRIMINANT);
    w.put_u64(token);
    w.into_vec()
}
/// Tag used by the consumer-side driver query "more data?" (Sec. 3.5.1).
pub const TAG_QUERY: u64 = 3;

/// Consumer→producer requests on a channel intercommunicator.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Open request: the consumer wants a file matching `pattern` with
    /// version >= `min_version`.
    MetaReq { pattern: String, min_version: u64 },
    /// Read request for the blocks of `dset` intersecting `slab`.
    DataReq { file: String, dset: String, slab: Hyperslab },
    /// The consumer rank is finished with this serve round.
    Done { version: u64 },
    /// The consumer rank acknowledges end-of-stream and will not
    /// contact this producer again.
    EofAck,
}

impl Request {
    /// Wire form of this request.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Request::MetaReq { pattern, min_version } => {
                w.put_u8(0);
                w.put_str(pattern);
                w.put_u64(*min_version);
            }
            Request::DataReq { file, dset, slab } => {
                w.put_u8(REQ_DATA_DISCRIMINANT);
                w.put_str(file);
                w.put_str(dset);
                slab.encode(&mut w);
            }
            Request::Done { version } => {
                w.put_u8(2);
                w.put_u64(*version);
            }
            Request::EofAck => w.put_u8(3),
        }
        w.into_vec()
    }

    /// Decode a request from its wire form.
    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut r = Reader::new(buf);
        Ok(match r.get_u8()? {
            0 => Request::MetaReq {
                pattern: r.get_str()?,
                min_version: r.get_u64()?,
            },
            REQ_DATA_DISCRIMINANT => Request::DataReq {
                file: r.get_str()?,
                dset: r.get_str()?,
                slab: Hyperslab::decode(&mut r)?,
            },
            2 => Request::Done { version: r.get_u64()? },
            3 => Request::EofAck,
            c => return Err(WilkinsError::LowFive(format!("bad request code {c}"))),
        })
    }
}

/// One producer rank's view of a file: which slabs of which datasets it
/// owns. The consumer merges M of these into a global table.
#[derive(Debug, Clone, PartialEq)]
pub struct FileMeta {
    /// The actual filename (glob requests resolve to this).
    pub filename: String,
    /// Serve-round version on the channel.
    pub version: u64,
    /// File attributes (consumers keep rank 0's copy).
    pub attrs: Vec<(String, AttrValue)>,
    /// (dataset meta, slabs owned by the replying rank)
    pub datasets: Vec<(DatasetMeta, Vec<Hyperslab>)>,
}

/// Producer→consumer replies.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Answer to a MetaReq: this rank's view of the served file.
    Meta(FileMeta),
    /// Blocks intersecting a DataReq: (region, bytes) pairs where the
    /// region is in global coordinates and bytes are row-major in it.
    /// The bytes are refcounted views — [`Reply::decode_from`] slices
    /// them out of the received payload without copying.
    Data(Vec<(Hyperslab, Payload)>),
    /// No more files will be produced.
    Eof,
}

impl Reply {
    /// Wire form of this reply.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Reply::Meta(m) => {
                w.put_u8(0);
                w.put_str(&m.filename);
                w.put_u64(m.version);
                w.put_u64(m.attrs.len() as u64);
                for (k, v) in &m.attrs {
                    w.put_str(k);
                    v.encode(&mut w);
                }
                w.put_u64(m.datasets.len() as u64);
                for (meta, slabs) in &m.datasets {
                    meta.encode(&mut w);
                    w.put_u64(slabs.len() as u64);
                    for s in slabs {
                        s.encode(&mut w);
                    }
                }
            }
            Reply::Data(blocks) => {
                // Pre-size for the payload (§Perf: avoids realloc
                // churn while appending multi-MiB blocks).
                let payload: usize = blocks.iter().map(|(_, b)| b.len() + 64).sum();
                w = Writer::with_capacity(payload + 16);
                w.put_u8(1);
                w.put_u64(blocks.len() as u64);
                for (slab, bytes) in blocks {
                    slab.encode(&mut w);
                    w.put_bytes(bytes);
                }
            }
            Reply::Eof => w.put_u8(2),
        }
        w.into_vec()
    }

    /// Decode a reply from raw bytes. Data-block bytes are copied out
    /// (there is no shared buffer to slice) — hot paths that hold the
    /// received [`Payload`] should use [`Reply::decode_from`], which
    /// borrows instead.
    pub fn decode(buf: &[u8]) -> Result<Reply> {
        Reply::decode_from(&Payload::copy_from_slice(buf))
    }

    /// Decode a reply from the received payload. Data blocks are O(1)
    /// slices of `buf` — the frame layer already copied these bytes
    /// off the wire once, and decode must not copy them again; the
    /// blocks keep the receive buffer alive until the hyperslab fill
    /// consumes them.
    pub fn decode_from(buf: &Payload) -> Result<Reply> {
        let mut r = Reader::new(buf);
        Ok(match r.get_u8()? {
            0 => {
                let filename = r.get_str()?;
                let version = r.get_u64()?;
                let nattr = r.get_u64()? as usize;
                let mut attrs = Vec::with_capacity(nattr);
                for _ in 0..nattr {
                    let k = r.get_str()?;
                    attrs.push((k, AttrValue::decode(&mut r)?));
                }
                let nds = r.get_u64()? as usize;
                let mut datasets = Vec::with_capacity(nds);
                for _ in 0..nds {
                    let meta = DatasetMeta::decode(&mut r)?;
                    let nslab = r.get_u64()? as usize;
                    let mut slabs = Vec::with_capacity(nslab);
                    for _ in 0..nslab {
                        slabs.push(Hyperslab::decode(&mut r)?);
                    }
                    datasets.push((meta, slabs));
                }
                Reply::Meta(FileMeta { filename, version, attrs, datasets })
            }
            1 => {
                let n = r.get_u64()? as usize;
                let mut blocks = Vec::with_capacity(n);
                for _ in 0..n {
                    let slab = Hyperslab::decode(&mut r)?;
                    blocks.push((slab, r.get_bytes_sliced(buf)?));
                }
                Reply::Data(blocks)
            }
            2 => Reply::Eof,
            c => return Err(WilkinsError::LowFive(format!("bad reply code {c}"))),
        })
    }
}

/// "More data?" query replies (consumer driver → producer rank 0).
#[derive(Debug, Clone, PartialEq)]
pub enum QueryReply {
    /// Producer will generate more files (consumer should re-open).
    More,
    /// All done.
    Finished,
}

impl QueryReply {
    /// Wire form of this query reply.
    pub fn encode(&self) -> Vec<u8> {
        vec![match self {
            QueryReply::More => 1,
            QueryReply::Finished => 0,
        }]
    }

    /// Decode a query reply.
    pub fn decode(buf: &[u8]) -> Result<QueryReply> {
        match buf.first() {
            Some(1) => Ok(QueryReply::More),
            Some(0) => Ok(QueryReply::Finished),
            _ => Err(WilkinsError::LowFive("bad query reply".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowfive::model::DType;

    #[test]
    fn request_roundtrip() {
        for req in [
            Request::MetaReq { pattern: "*.h5".into(), min_version: 7 },
            Request::DataReq {
                file: "outfile.h5".into(),
                dset: "/group1/grid".into(),
                slab: Hyperslab::new(&[0, 2], &[3, 4]),
            },
            Request::Done { version: 9 },
            Request::EofAck,
        ] {
            assert_eq!(Request::decode(&req.encode()).unwrap(), req);
        }
    }

    #[test]
    fn reply_roundtrip() {
        let meta = FileMeta {
            filename: "outfile.h5".into(),
            version: 3,
            attrs: vec![
                ("timestep".into(), AttrValue::Int(12)),
                ("origin".into(), AttrValue::Str("lammps".into())),
            ],
            datasets: vec![(
                DatasetMeta {
                    name: "/group1/grid".into(),
                    dtype: DType::U64,
                    dims: vec![100, 3],
                },
                vec![Hyperslab::new(&[0, 0], &[50, 3])],
            )],
        };
        for rep in [
            Reply::Meta(meta),
            Reply::Data(vec![(
                Hyperslab::range1d(4, 2),
                crate::comm::buf::Payload::from(vec![1, 2, 3, 4]),
            )]),
            Reply::Eof,
        ] {
            assert_eq!(Reply::decode(&rep.encode()).unwrap(), rep);
        }
    }

    #[test]
    fn shared_reply_discriminant_is_distinct() {
        // The shared-snapshot reply must never collide with a Reply
        // variant's first byte (0 = Meta, 1 = Data, 2 = Eof).
        for rep in [
            Reply::Eof,
            Reply::Data(vec![]),
            Reply::Meta(FileMeta {
                filename: "f".into(),
                version: 1,
                attrs: vec![],
                datasets: vec![],
            }),
        ] {
            assert_ne!(rep.encode()[0], REP_SHARED_DISCRIMINANT);
        }
        let shared = encode_shared_reply(42);
        assert_eq!(shared[0], REP_SHARED_DISCRIMINANT);
        let mut r = Reader::new(&shared[1..]);
        assert_eq!(r.get_u64().unwrap(), 42);
    }

    #[test]
    fn data_req_discriminant_is_pinned() {
        // Sanity: the named discriminant really is the first payload
        // byte the selective receive will peek.
        let req = Request::DataReq {
            file: "f".into(),
            dset: "/d".into(),
            slab: Hyperslab::range1d(0, 1),
        };
        assert_eq!(req.encode()[0], REQ_DATA_DISCRIMINANT);
    }

    #[test]
    fn query_roundtrip() {
        for q in [QueryReply::More, QueryReply::Finished] {
            assert_eq!(QueryReply::decode(&q.encode()).unwrap(), q);
        }
    }
}
