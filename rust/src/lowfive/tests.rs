//! Integration tests for the LowFive transport: producer/consumer
//! groups on real threads with real intercommunicators, exercising the
//! redistribution, versioning, EOF, file-mode and callback machinery.


use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use crate::comm::{InterComm, World};
use crate::error::WilkinsError;

use super::*;

/// Build a 2-task world: M producer ranks + N consumer ranks with a
/// channel between them, then run the closures on every rank thread.
/// `route` is the channel's uniform transport route.
fn couple<P, C>(m: usize, n: usize, route: Route, producer: P, consumer: C)
where
    P: Fn(usize, &mut Vol) + Send + Sync + 'static,
    C: Fn(usize, &mut Vol) + Send + Sync + 'static,
{
    couple_routed(m, n, m, RouteTable::uniform(route), producer, consumer)
}

/// Same but with only the first `nwriters` producer ranks doing I/O.
fn couple_writers<P, C>(
    m: usize,
    n: usize,
    nwriters: usize,
    route: Route,
    producer: P,
    consumer: C,
) where
    P: Fn(usize, &mut Vol) + Send + Sync + 'static,
    C: Fn(usize, &mut Vol) + Send + Sync + 'static,
{
    couple_routed(m, n, nwriters, RouteTable::uniform(route), producer, consumer)
}

/// The general harness: any per-dataset route table on the channel.
fn couple_routed<P, C>(
    m: usize,
    n: usize,
    nwriters: usize,
    routes: RouteTable,
    producer: P,
    consumer: C,
) where
    P: Fn(usize, &mut Vol) + Send + Sync + 'static,
    C: Fn(usize, &mut Vol) + Send + Sync + 'static,
{
    let world = World::new(m + n);
    let producer = Arc::new(producer);
    let consumer = Arc::new(consumer);
    let workdir = std::env::temp_dir().join(format!(
        "wilkins-test-{}-{}",
        std::process::id(),
        WORKDIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let prod_ranks: Vec<usize> = (0..m).collect();
    let cons_ranks: Vec<usize> = (m..m + n).collect();
    let io_ranks: Vec<usize> = (0..nwriters).collect();
    let pid = world.alloc_comm_id();
    let cid = world.alloc_comm_id();
    let ioid = world.alloc_comm_id();
    let chid = world.alloc_comm_id();
    let mut handles = Vec::new();
    for g in 0..m + n {
        let world = world.clone();
        let producer = Arc::clone(&producer);
        let consumer = Arc::clone(&consumer);
        let prod_ranks = prod_ranks.clone();
        let cons_ranks = cons_ranks.clone();
        let io_ranks = io_ranks.clone();
        let workdir = workdir.clone();
        let routes = routes.clone();
        handles.push(thread::spawn(move || {
            if g < m {
                let local = world.comm_from_ranks(pid, &prod_ranks, g);
                let mut vol = Vol::new(local.clone(), workdir);
                if g < nwriters {
                    let io = world.comm_from_ranks(ioid, &io_ranks, g);
                    vol.set_io_comm(Some(io));
                    let ic = routes
                        .any_memory()
                        .then(|| InterComm::new(local, chid, cons_ranks.clone()));
                    vol.add_out_channel(OutChannel::new(ic, "outfile.h5", routes));
                } else {
                    vol.add_out_channel(OutChannel::new(None, "outfile.h5", routes));
                }
                producer(g, &mut vol);
                vol.finalize_producer().unwrap();
            } else {
                let local = world.comm_from_ranks(cid, &cons_ranks, g - m);
                let mut vol = Vol::new(local.clone(), workdir);
                let ic = routes
                    .any_memory()
                    .then(|| InterComm::new(local, chid, io_ranks.clone()));
                vol.add_in_channel(InChannel::new(ic, "outfile.h5", routes));
                consumer(g - m, &mut vol);
                vol.finalize_consumer().unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

static WORKDIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Producer helper: write a 1-D u64 grid of `total` elements split by
/// rows over `m` ranks, values = global index * 10.
fn write_grid(vol: &mut Vol, rank: usize, m: usize, total: u64) {
    vol.file_create("outfile.h5").unwrap();
    vol.attr_write("outfile.h5", "timestep", AttrValue::Int(1)).unwrap();
    vol.dataset_create("outfile.h5", "/group1/grid", DType::U64, &[total])
        .unwrap();
    let slabs = split_rows(&[total], m);
    let slab = slabs[rank].clone();
    let vals: Vec<u8> = (slab.offset[0]..slab.offset[0] + slab.count[0])
        .flat_map(|i| (i * 10).to_le_bytes())
        .collect();
    vol.dataset_write("outfile.h5", "/group1/grid", slab, vals).unwrap();
    vol.file_close("outfile.h5").unwrap();
}

/// Consumer helper: open, read own row-split share, verify, close.
fn read_grid(vol: &mut Vol, rank: usize, n: usize, total: u64) {
    let name = vol.file_open("outfile.h5").unwrap();
    assert_eq!(name, "outfile.h5");
    let meta = vol.dataset_meta(&name, "/group1/grid").unwrap();
    assert_eq!(meta.dims, vec![total]);
    assert_eq!(meta.dtype, DType::U64);
    let want = split_rows(&[total], n)[rank].clone();
    let bytes = vol.dataset_read(&name, "/group1/grid", &want).unwrap();
    for (k, chunk) in bytes.chunks_exact(8).enumerate() {
        let v = u64::from_le_bytes(chunk.try_into().unwrap());
        assert_eq!(v, (want.offset[0] + k as u64) * 10);
    }
    vol.file_close(&name).unwrap();
}

#[test]
fn one_to_one_memory() {
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| write_grid(vol, r, 1, 100),
        |r, vol| read_grid(vol, r, 1, 100),
    );
}

#[test]
fn m_to_n_redistribution() {
    // 3 producers, 2 consumers: consumer slabs straddle producer
    // boundaries, exercising multi-source assembly.
    couple(
        3,
        2,
        Route::Memory,
        |r, vol| write_grid(vol, r, 3, 90),
        |r, vol| read_grid(vol, r, 2, 90),
    );
}

#[test]
fn n_to_one_fan_in_ranks() {
    couple(
        4,
        1,
        Route::Memory,
        |r, vol| write_grid(vol, r, 4, 64),
        |r, vol| read_grid(vol, r, 1, 64),
    );
}

#[test]
fn multiple_timesteps_versioned() {
    const STEPS: u64 = 5;
    couple(
        2,
        2,
        Route::Memory,
        |r, vol| {
            for t in 0..STEPS {
                vol.file_create("outfile.h5").unwrap();
                vol.attr_write("outfile.h5", "timestep", AttrValue::Int(t as i64))
                    .unwrap();
                vol.dataset_create("outfile.h5", "/d", DType::U64, &[10]).unwrap();
                let slab = split_rows(&[10], 2)[r].clone();
                let vals: Vec<u8> = (slab.offset[0]..slab.offset[0] + slab.count[0])
                    .flat_map(|i| (i + t * 100).to_le_bytes())
                    .collect();
                vol.dataset_write("outfile.h5", "/d", slab, vals).unwrap();
                vol.file_close("outfile.h5").unwrap();
            }
        },
        |r, vol| {
            for t in 0..STEPS {
                let name = vol.file_open("outfile.h5").unwrap();
                let ts = vol
                    .consumer_file(&name)
                    .unwrap()
                    .attr("timestep")
                    .unwrap()
                    .as_i64()
                    .unwrap();
                assert_eq!(ts, t as i64, "consumer rank {r} saw wrong timestep");
                let want = split_rows(&[10], 2)[r].clone();
                let bytes = vol.dataset_read(&name, "/d", &want).unwrap();
                let first = u64::from_le_bytes(bytes[..8].try_into().unwrap());
                assert_eq!(first, want.offset[0] + t * 100);
                vol.file_close(&name).unwrap();
            }
        },
    );
}

#[test]
fn eof_after_last_step() {
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| write_grid(vol, r, 1, 8),
        |r, vol| {
            read_grid(vol, r, 1, 8);
            match vol.file_open("outfile.h5") {
                Err(WilkinsError::EndOfStream) => {}
                other => panic!("expected EndOfStream, got {other:?}"),
            }
            assert!(!vol.has_live_inputs());
        },
    );
}

#[test]
fn consumer_quits_early() {
    // Producer writes 4 steps; consumer reads only 1 then finalizes.
    // finalize_consumer's EofAck must unblock the producer's serves.
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            for _ in 0..4 {
                write_grid(vol, r, 1, 8);
            }
        },
        |r, vol| {
            read_grid(vol, r, 1, 8);
            vol.finalize_consumer().unwrap();
        },
    );
}

#[test]
fn subset_writers_single_io_rank() {
    // 4 producer ranks, only rank 0 writes (LAMMPS pattern).
    couple_writers(
        4,
        2,
        1,
        Route::Memory,
        |r, vol| {
            if vol.is_io_rank() {
                assert_eq!(r, 0);
                write_grid(vol, 0, 1, 40);
            }
            // Non-I/O ranks do no I/O at all.
        },
        |r, vol| read_grid(vol, r, 2, 40),
    );
}

#[test]
fn file_mode_roundtrip() {
    couple(
        2,
        2,
        Route::File,
        |r, vol| write_grid(vol, r, 2, 50),
        |r, vol| read_grid(vol, r, 2, 50),
    );
}

#[test]
fn file_mode_eof() {
    couple(
        1,
        1,
        Route::File,
        |r, vol| write_grid(vol, r, 1, 10),
        |r, vol| {
            read_grid(vol, r, 1, 10);
            match vol.file_open("outfile.h5") {
                Err(WilkinsError::EndOfStream) => {}
                other => panic!("expected EndOfStream, got {other:?}"),
            }
        },
    );
}

#[test]
fn two_datasets_two_types() {
    couple(
        1,
        1,
        Route::Memory,
        |_, vol| {
            vol.file_create("outfile.h5").unwrap();
            vol.dataset_create("outfile.h5", "/group1/grid", DType::U64, &[16])
                .unwrap();
            vol.dataset_create("outfile.h5", "/group1/particles", DType::F32, &[8, 3])
                .unwrap();
            vol.dataset_write(
                "outfile.h5",
                "/group1/grid",
                Hyperslab::whole(&[16]),
                (0u64..16).flat_map(|i| i.to_le_bytes()).collect(),
            )
            .unwrap();
            vol.dataset_write(
                "outfile.h5",
                "/group1/particles",
                Hyperslab::whole(&[8, 3]),
                (0..24).flat_map(|i| (i as f32).to_le_bytes()).collect(),
            )
            .unwrap();
            vol.file_close("outfile.h5").unwrap();
        },
        |_, vol| {
            let name = vol.file_open("outfile.h5").unwrap();
            let names = vol.consumer_file(&name).unwrap().dataset_names();
            assert_eq!(names, vec!["/group1/grid", "/group1/particles"]);
            let p = vol
                .dataset_read(&name, "/group1/particles", &Hyperslab::new(&[2, 0], &[1, 3]))
                .unwrap();
            let vals: Vec<f32> = p
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            assert_eq!(vals, vec![6.0, 7.0, 8.0]);
            vol.file_close(&name).unwrap();
        },
    );
}

#[test]
fn callback_after_dataset_write_counts() {
    let count = Arc::new(AtomicU64::new(0));
    let c2 = Arc::clone(&count);
    couple(
        1,
        1,
        Route::Memory,
        move |r, vol| {
            let c = Arc::clone(&c2);
            vol.set_after_dataset_write(Box::new(move |_vol, _dset| {
                c.fetch_add(1, Ordering::Relaxed);
            }));
            write_grid(vol, r, 1, 8);
        },
        |r, vol| read_grid(vol, r, 1, 8),
    );
    assert_eq!(count.load(Ordering::Relaxed), 1);
}

#[test]
fn skip_serve_some_strategy() {
    // Producer closes 4 times but serves only every 2nd close
    // (the *some* flow-control strategy, N=2).
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            vol.set_before_file_close(Box::new(|vol, name| {
                if (vol.closes_of(name) + 1) % 2 != 0 {
                    vol.skip_serve();
                }
            }));
            for _ in 0..4 {
                write_grid(vol, r, 1, 8);
            }
            assert_eq!(vol.stats.files_served, 2);
            assert_eq!(vol.stats.serves_suppressed, 2);
        },
        |r, vol| {
            for _ in 0..2 {
                read_grid(vol, r, 1, 8);
            }
            match vol.file_open("outfile.h5") {
                Err(WilkinsError::EndOfStream) => {}
                other => panic!("expected EndOfStream, got {other:?}"),
            }
        },
    );
}

#[test]
fn latest_strategy_skips_when_no_request() {
    // Slow consumer: producer runs 6 steps under the *latest* strategy;
    // consumer opens twice. The producer must skip serves with no
    // pending request and never deadlock.
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            vol.set_before_file_close(Box::new(|vol, name| {
                if !vol.any_pending_requests(name) {
                    vol.skip_serve();
                }
            }));
            for _ in 0..6 {
                write_grid(vol, r, 1, 8);
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            assert!(vol.stats.serves_suppressed > 0, "expected skipped serves");
        },
        |r, vol| {
            read_grid(vol, r, 1, 8);
            std::thread::sleep(std::time::Duration::from_millis(12));
            read_grid(vol, r, 1, 8);
            vol.finalize_consumer().unwrap();
        },
    );
}

#[test]
fn broadcast_files_shares_rank0_state() {
    // Producer group of 3: rank 0 creates the file + attr, broadcasts;
    // all ranks then write their slab and close (Nyx-like).
    couple(
        3,
        1,
        Route::Memory,
        |r, vol| {
            if r == 0 {
                vol.file_create("outfile.h5").unwrap();
                vol.attr_write("outfile.h5", "origin", AttrValue::Str("nyx".into()))
                    .unwrap();
                vol.dataset_create("outfile.h5", "/d", DType::U64, &[30]).unwrap();
            }
            vol.broadcast_files().unwrap();
            assert!(vol.producer_file_exists("outfile.h5"));
            let slab = split_rows(&[30], 3)[r].clone();
            let vals: Vec<u8> = (slab.offset[0]..slab.offset[0] + slab.count[0])
                .flat_map(|i| (i * 10).to_le_bytes())
                .collect();
            vol.dataset_write("outfile.h5", "/d", slab, vals).unwrap();
            vol.file_close("outfile.h5").unwrap();
        },
        |_, vol| {
            let name = vol.file_open("outfile.h5").unwrap();
            assert_eq!(
                vol.consumer_file(&name).unwrap().attr("origin"),
                Some(&AttrValue::Str("nyx".into()))
            );
            let bytes = vol
                .dataset_read(&name, "/d", &Hyperslab::whole(&[30]))
                .unwrap();
            for (k, chunk) in bytes.chunks_exact(8).enumerate() {
                assert_eq!(u64::from_le_bytes(chunk.try_into().unwrap()), k as u64 * 10);
            }
            vol.file_close(&name).unwrap();
        },
    );
}

#[test]
fn pattern_matching_globs() {
    assert!(pattern_matches("plt*.h5", "plt0001.h5"));
    assert!(pattern_matches("*.h5", "outfile.h5"));
    assert!(pattern_matches("/particles/*", "/particles/position"));
    assert!(!pattern_matches("plt*.h5", "dump.bp"));
    assert!(pattern_matches("outfile.h5", "outfile.h5"));
}

#[test]
fn stats_track_bytes() {
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            write_grid(vol, r, 1, 100);
            assert_eq!(vol.stats.files_served, 1);
            assert_eq!(vol.stats.bytes_served, 800);
        },
        |r, vol| {
            read_grid(vol, r, 1, 100);
            assert_eq!(vol.stats.bytes_read, 800);
            assert_eq!(vol.stats.files_opened, 1);
        },
    );
}

/// Fan-in across *channels*: one consumer task with two in-channels
/// round-robins opens between the two producers.
#[test]
fn fan_in_round_robin_channels() {
    let world = World::new(3); // producer A, producer B, consumer
    let ida = world.alloc_comm_id();
    let idb = world.alloc_comm_id();
    let idc = world.alloc_comm_id();
    let cha = world.alloc_comm_id();
    let chb = world.alloc_comm_id();
    let workdir = std::env::temp_dir().join("wilkins-test-rr");
    let mk_producer = |world: &World, comm_id, g: usize, chan_id, tag: i64| {
        let world = world.clone();
        let workdir = workdir.clone();
        thread::spawn(move || {
            let local = world.comm_from_ranks(comm_id, &[g], 0);
            let mut vol = Vol::new(local.clone(), workdir);
            vol.set_io_comm(Some(local.clone()));
            let ic = InterComm::new(local, chan_id, vec![2]);
            vol.add_out_channel(OutChannel::new(Some(ic), "outfile.h5", RouteTable::memory()));
            vol.file_create("outfile.h5").unwrap();
            vol.attr_write("outfile.h5", "who", AttrValue::Int(tag)).unwrap();
            vol.dataset_create("outfile.h5", "/d", DType::U64, &[4]).unwrap();
            vol.dataset_write(
                "outfile.h5",
                "/d",
                Hyperslab::whole(&[4]),
                (0u64..4).flat_map(|i| i.to_le_bytes()).collect(),
            )
            .unwrap();
            vol.file_close("outfile.h5").unwrap();
            vol.finalize_producer().unwrap();
        })
    };
    let ha = mk_producer(&world, ida, 0, cha, 100);
    let hb = mk_producer(&world, idb, 1, chb, 200);
    let hc = {
        let world = world.clone();
        let workdir = workdir.clone();
        thread::spawn(move || {
            let local = world.comm_from_ranks(idc, &[2], 0);
            let mut vol = Vol::new(local.clone(), workdir);
            let ica = InterComm::new(local.clone(), cha, vec![0]);
            let icb = InterComm::new(local, chb, vec![1]);
            vol.add_in_channel(InChannel::new(Some(ica), "outfile.h5", RouteTable::memory()));
            vol.add_in_channel(InChannel::new(Some(icb), "outfile.h5", RouteTable::memory()));
            let mut whos = Vec::new();
            loop {
                match vol.file_open("outfile.h5") {
                    Ok(name) => {
                        whos.push(
                            vol.consumer_file(&name)
                                .unwrap()
                                .attr("who")
                                .unwrap()
                                .as_i64()
                                .unwrap(),
                        );
                        vol.file_close(&name).unwrap();
                    }
                    Err(WilkinsError::EndOfStream) => break,
                    Err(e) => panic!("{e}"),
                }
            }
            // Round-robin: both producers consumed exactly once.
            whos.sort();
            assert_eq!(whos, vec![100, 200]);
            vol.finalize_consumer().unwrap();
        })
    };
    ha.join().unwrap();
    hb.join().unwrap();
    hc.join().unwrap();
}

// ---- Hyperslab edge cases: the M×N redistribution math the socket
// ---- substrate re-exercises across process boundaries.

#[test]
fn hyperslab_edge_touching_boxes_do_not_intersect() {
    // Boxes that share a face (producer block boundary) must produce
    // an EMPTY intersection — a serve must never duplicate the
    // boundary row.
    let a = Hyperslab::new(&[0, 0], &[2, 4]);
    let b = Hyperslab::new(&[2, 0], &[2, 4]);
    assert!(a.intersect(&b).is_none());
    assert!(!a.overlaps(&b));
    // Touching along the second axis too.
    let c = Hyperslab::new(&[0, 4], &[2, 4]);
    assert!(a.intersect(&c).is_none());
}

#[test]
fn hyperslab_zero_count_slab_is_empty_everywhere() {
    // split_rows hands empty slabs to surplus ranks; they must behave
    // as proper empties: no intersection with anything, element count
    // zero, and copy_region over them is a no-op.
    let empty = Hyperslab::new(&[3, 0], &[0, 4]);
    assert!(empty.is_empty());
    assert_eq!(empty.element_count(), 0);
    let whole = Hyperslab::new(&[0, 0], &[8, 4]);
    assert!(empty.intersect(&whole).is_none());
    assert!(whole.intersect(&empty).is_none());

    let src = vec![1u8; 32];
    let mut dst = vec![7u8; 32];
    hyperslab::copy_region(&whole, &src, &whole, &mut dst, &empty, 1);
    assert_eq!(dst, vec![7u8; 32], "empty region copies nothing");
}

#[test]
fn hyperslab_full_overlap_is_identity() {
    // Identical slabs: intersection is the slab itself and the copy
    // is byte-for-byte.
    let s = Hyperslab::new(&[2, 1], &[3, 5]);
    assert_eq!(s.intersect(&s).unwrap(), s);
    let src: Vec<u8> = (0..15).collect();
    let mut dst = vec![0u8; 15];
    let region = s.intersect(&s).unwrap();
    hyperslab::copy_region(&s, &src, &s, &mut dst, &region, 1);
    assert_eq!(dst, src);
}

#[test]
fn hyperslab_consumer_spanning_producer_stride_boundaries() {
    // 3 producers own row blocks of an 8x4 dataset (split_rows gives
    // rows 0..3, 3..6, 6..8); one consumer wants rows 2..6 — a slab
    // crossing BOTH producer boundaries. Assembling the consumer
    // buffer from per-producer intersections must cover every element
    // exactly once with the right values.
    let dims = [8u64, 4];
    let producers = split_rows(&dims, 3);
    assert_eq!(producers[0].count[0], 3);
    assert_eq!(producers[1].offset[0], 3);
    assert_eq!(producers[2].offset[0], 6);

    let consumer = Hyperslab::new(&[2, 0], &[4, 4]);
    // Producer buffers hold the global linear index of each element.
    let fill = |slab: &Hyperslab| -> Vec<u8> {
        let mut buf = Vec::new();
        for r in slab.offset[0]..slab.offset[0] + slab.count[0] {
            for c in slab.offset[1]..slab.offset[1] + slab.count[1] {
                buf.push((r * dims[1] + c) as u8);
            }
        }
        buf
    };
    let mut dst = vec![255u8; consumer.element_count() as usize];
    let mut covered = 0u64;
    for p in &producers {
        if let Some(region) = p.intersect(&consumer) {
            covered += region.element_count();
            let src = fill(p);
            hyperslab::copy_region(p, &src, &consumer, &mut dst, &region, 1);
        }
    }
    assert_eq!(covered, consumer.element_count(), "boundary rows covered once");
    for (i, &v) in dst.iter().enumerate() {
        let row = 2 + (i as u64) / 4;
        let col = (i as u64) % 4;
        assert_eq!(v as u64, row * dims[1] + col, "element ({row},{col})");
    }
}

#[test]
fn hyperslab_single_element_overlap_at_corner() {
    // Diagonal neighbours overlapping in exactly one element: the
    // minimal non-empty intersection.
    let a = Hyperslab::new(&[0, 0], &[3, 3]);
    let b = Hyperslab::new(&[2, 2], &[3, 3]);
    let i = a.intersect(&b).unwrap();
    assert_eq!(i, Hyperslab::new(&[2, 2], &[1, 1]));
    assert_eq!(i.element_count(), 1);
    let src: Vec<u8> = (0..9).collect(); // a's buffer
    let mut dst = vec![0u8; 9]; // b's buffer
    hyperslab::copy_region(&a, &src, &b, &mut dst, &i, 1);
    assert_eq!(dst[0], 8, "global (2,2) is a's last element, b's first");
    assert!(dst[1..].iter().all(|&v| v == 0));
}

// ---- Routed data plane: mixed per-dataset transports, write-through
// ---- and the zero-copy same-process fast path.

#[test]
fn mixed_routes_deliver_every_dataset() {
    // One channel, three datasets on three routes: /mem over memory,
    // /disk file-only, /wt write-through. The consumer must see all
    // three with correct bytes, and never the internal disk-version
    // attribute.
    let routes = RouteTable::new(vec![
        ("/mem".into(), Route::Memory),
        ("/disk".into(), Route::File),
        ("/wt".into(), Route::Both),
    ]);
    couple_routed(
        2,
        2,
        2,
        routes,
        |r, vol| {
            for t in 0..2u64 {
                vol.file_create("outfile.h5").unwrap();
                vol.attr_write("outfile.h5", "timestep", AttrValue::Int(t as i64))
                    .unwrap();
                for (d, base) in [("/mem", 0u64), ("/disk", 1000), ("/wt", 2000)] {
                    vol.dataset_create("outfile.h5", d, DType::U64, &[16]).unwrap();
                    let slab = split_rows(&[16], 2)[r].clone();
                    let vals: Vec<u8> = (slab.offset[0]..slab.offset[0] + slab.count[0])
                        .flat_map(|i| (base + i + t * 100).to_le_bytes())
                        .collect();
                    vol.dataset_write("outfile.h5", d, slab, vals).unwrap();
                }
                vol.file_close("outfile.h5").unwrap();
            }
        },
        |r, vol| {
            for t in 0..2u64 {
                let name = vol.file_open("outfile.h5").unwrap();
                let cf = vol.consumer_file(&name).unwrap();
                assert_eq!(cf.dataset_names(), vec!["/disk", "/mem", "/wt"]);
                assert_eq!(cf.attr("timestep").unwrap().as_i64(), Some(t as i64));
                assert!(
                    cf.attr(super::route::DISK_VERSION_ATTR).is_none(),
                    "internal routing attr must be stripped"
                );
                for (d, base) in [("/mem", 0u64), ("/disk", 1000), ("/wt", 2000)] {
                    let want = split_rows(&[16], 2)[r].clone();
                    let bytes = vol.dataset_read(&name, d, &want).unwrap();
                    for (k, chunk) in bytes.chunks_exact(8).enumerate() {
                        let v = u64::from_le_bytes(chunk.try_into().unwrap());
                        assert_eq!(
                            v,
                            base + want.offset[0] + k as u64 + t * 100,
                            "{d} at {k}, step {t}, rank {r}"
                        );
                    }
                }
                vol.file_close(&name).unwrap();
            }
        },
    );
}

#[test]
fn write_through_serves_memory_and_archives_disk() {
    // Route::Both on every dataset: the consumer reads in situ while
    // a versioned .l5 artifact also lands in the workdir.
    couple(
        1,
        1,
        Route::Both,
        |r, vol| {
            write_grid(vol, r, 1, 20);
            assert!(vol.stats.bytes_shared > 0, "in-process serve shares");
            let archived = std::fs::read_dir(vol.workdir())
                .unwrap()
                .filter_map(|e| e.ok())
                .any(|e| e.file_name().to_string_lossy().ends_with(".l5"));
            assert!(archived, "write-through must land a .l5 artifact");
        },
        |r, vol| read_grid(vol, r, 1, 20),
    );
}

#[test]
fn zero_copy_fast_path_counts_shared_bytes() {
    // In-memory worlds host every rank in one process, so every data
    // reply takes the shared-snapshot path.
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            write_grid(vol, r, 1, 100);
            assert_eq!(vol.stats.bytes_served, 800);
            assert_eq!(vol.stats.bytes_shared, 800);
            assert_eq!(vol.stats.bytes_copied, 0);
        },
        |r, vol| {
            read_grid(vol, r, 1, 100);
            assert_eq!(vol.stats.bytes_read, 800);
        },
    );
}

#[test]
fn zero_copy_disabled_takes_encoded_path() {
    // The ablation switch forces the encode/decode round-trip; the
    // consumer must read identical bytes either way (read_grid
    // verifies every element).
    couple(
        1,
        1,
        Route::Memory,
        |r, vol| {
            vol.set_zero_copy(false);
            write_grid(vol, r, 1, 100);
            assert_eq!(vol.stats.bytes_served, 800);
            assert_eq!(vol.stats.bytes_shared, 0);
            assert_eq!(vol.stats.bytes_copied, 800);
        },
        |r, vol| read_grid(vol, r, 1, 100),
    );
}

#[test]
fn encoded_serve_rounds_account_pool_hits_and_misses() {
    // Force the encode path (zero-copy ablated) for several rounds:
    // every data reply must be accounted either as a pool hit
    // (bytes_pooled) or as an allocation (alloc_rounds). The exact
    // split depends on global pool contention from concurrently
    // running tests, so assert the invariant, not the split — the
    // tight steady-state bound (alloc_rounds == 0) is asserted by
    // benches/wire.rs and the mixed-transport CI smoke, which own
    // their process.
    let rounds = 3u64;
    couple(
        1,
        1,
        Route::Memory,
        move |_, vol| {
            vol.set_zero_copy(false);
            for _ in 0..rounds {
                vol.file_create("outfile.h5").unwrap();
                vol.dataset_create("outfile.h5", "/g", DType::U64, &[64]).unwrap();
                vol.dataset_write(
                    "outfile.h5",
                    "/g",
                    Hyperslab::whole(&[64]),
                    vec![9u8; 512],
                )
                .unwrap();
                vol.file_close("outfile.h5").unwrap();
            }
            assert_eq!(vol.stats.bytes_copied, 512 * rounds);
            assert!(
                vol.stats.alloc_rounds <= rounds,
                "cannot allocate more often than it encodes"
            );
            assert!(
                vol.stats.bytes_pooled > 0 || vol.stats.alloc_rounds == rounds,
                "every reply is a pool hit or a counted allocation \
                 (pooled={} alloc_rounds={})",
                vol.stats.bytes_pooled,
                vol.stats.alloc_rounds
            );
        },
        move |_, vol| {
            for _ in 0..rounds {
                let name = vol.file_open("outfile.h5").unwrap();
                let bytes = vol.dataset_read(&name, "/g", &Hyperslab::whole(&[64])).unwrap();
                assert_eq!(bytes, vec![9u8; 512]);
                vol.file_close(&name).unwrap();
            }
        },
    );
}

#[test]
fn file_mode_archives_undeclared_sibling_datasets() {
    // A pure file-mode channel that names only /declared must still
    // archive the whole file (the historical behavior): the consumer
    // reads the sibling dataset from the polled disk file.
    let routes = RouteTable::new(vec![("/declared".into(), Route::File)]);
    couple_routed(
        1,
        1,
        1,
        routes,
        |_, vol| {
            vol.file_create("outfile.h5").unwrap();
            for d in ["/declared", "/sibling"] {
                vol.dataset_create("outfile.h5", d, DType::U64, &[8]).unwrap();
                vol.dataset_write(
                    "outfile.h5",
                    d,
                    Hyperslab::whole(&[8]),
                    (0u64..8).flat_map(|i| (i * 3).to_le_bytes()).collect(),
                )
                .unwrap();
            }
            vol.file_close("outfile.h5").unwrap();
        },
        |_, vol| {
            let name = vol.file_open("outfile.h5").unwrap();
            assert_eq!(
                vol.consumer_file(&name).unwrap().dataset_names(),
                vec!["/declared", "/sibling"],
                "siblings must survive the disk archive"
            );
            let bytes = vol
                .dataset_read(&name, "/sibling", &Hyperslab::whole(&[8]))
                .unwrap();
            for (k, chunk) in bytes.chunks_exact(8).enumerate() {
                assert_eq!(u64::from_le_bytes(chunk.try_into().unwrap()), k as u64 * 3);
            }
            vol.file_close(&name).unwrap();
        },
    );
}
