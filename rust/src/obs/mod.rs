//! Unified observability plane: structured tracing, a counter
//! registry, live worker telemetry, a wire-frame tap, and exporters.
//!
//! Everything in this module is dependency-free and layered *under*
//! the rest of the system:
//!
//! * [`clock`] — the run-relative monotonic [`Clock`] every span and
//!   telemetry sample is stamped against, plus [`ClockSync`], the
//!   min-latency offset estimator that aligns worker clocks to the
//!   coordinator clock when traces from many processes are merged.
//! * [`recorder`] — [`TraceRecorder`], the lock-light (sharded)
//!   structured span/instant store. The Gantt machinery in
//!   [`crate::metrics`] is a *view* over this recorder, not a parallel
//!   mechanism: [`Span`] and [`SpanKind`] live here and are
//!   re-exported there.
//! * [`counters`] — the declarative counter registry ([`CounterDef`]
//!   with [`Merge`] semantics). The `VolStats`/`FaultStats` families
//!   register their counters once; wire encoding, report merging and
//!   JSON export all iterate the registry instead of hand-plumbing
//!   each field. Also home of the process-global live counters
//!   ([`Ctr`]) that telemetry frames snapshot.
//! * [`telemetry`] — the periodic worker → coordinator counter
//!   samples (wire `K_TELEMETRY`, VERSION 6): cumulative snapshots so
//!   the coordinator-side [`TelemetryStore`] keeps a worker's counts
//!   even after the worker dies, plus the clock-offset samples
//!   [`ClockSync`] feeds on.
//! * [`wiretap`] — the `WILKINS_TRACE_WIRE=1` frame tap: every frame's
//!   kind/len/link/direction/timestamp to a per-process binary log
//!   (the record half of record/replay; `WILKINS_TRACE_WIRE=full`
//!   additionally captures payloads). Disabled cost is one atomic
//!   load + branch per frame (asserted in `benches/wire.rs`).
//! * [`replay`] — the replay half: load a recorded run's per-process
//!   logs ([`RecordedRun`]), re-drive the coordinator bookkeeping
//!   deterministically in one process, and diff the reassembled
//!   report against the recorded one (`wilkins replay <dir>`).
//! * [`chrome`] — the merged Chrome-trace JSON exporter (`--trace`):
//!   one track per worker/rank, flow arrows pairing cross-worker
//!   serves with their opens, loadable in `chrome://tracing`/Perfetto.
//! * [`json`] — the tiny JSON writer behind `RunReport::to_json` and
//!   the Chrome exporter (no serde in this repo, by policy).
//!
//! See `docs/observability.md` for the trace model, the wire-tap
//! format, the Chrome-trace workflow and the JSON report schemas.

pub mod chrome;
pub mod clock;
pub mod counters;
pub mod json;
pub mod recorder;
pub mod replay;
pub mod telemetry;
pub mod wiretap;

pub use chrome::{add_serve_open_flows, ChromeTrace};
pub use clock::{Clock, ClockSync};
pub use counters::{global_snapshot, merge_values, CounterDef, Ctr, Merge, GLOBAL_DEFS};
pub use recorder::{InstantEvent, Span, SpanKind, TraceRecorder};
pub use replay::{RecordedRun, ReplayedReport};
pub use telemetry::{TelemetrySample, TelemetryStore, TelemetrySummary};
