//! Wire-level frame tap: `WILKINS_TRACE_WIRE=1` logs every frame
//! crossing the socket substrate — kind, length, link id, direction,
//! timestamp — to a per-process binary log, and
//! `WILKINS_TRACE_WIRE=full` additionally captures the full frame
//! payload bytes. This is the *record* half of ROADMAP item 4a
//! (record/replay); [`crate::obs::replay`] re-feeds a captured
//! schedule deterministically.
//!
//! ## Log format (`wilkins-wire-<pid>.wtap`)
//!
//! Header: magic `WTAP` (4 bytes) + `u32` LE version (1 or 2).
//! Then little-endian records with an 18-byte fixed head:
//!
//! | offset | size | field                                          |
//! |--------|------|------------------------------------------------|
//! | 0      | 8    | `t_us` — µs since process tap start (u64)      |
//! | 8      | 4    | `link` — link id (u32; `0xffff_ffff` = unset)  |
//! | 12     | 4    | `len` — frame payload length (u32)             |
//! | 16     | 1    | `dir` — 0 = Tx, 1 = Rx (u8)                    |
//! | 17     | 1    | `kind` — wire frame kind (u8, see `net::proto`)|
//!
//! Version 1 records end there (header-only capture, the cheap
//! default). Version 2 records append:
//!
//! | offset | size  | field                                         |
//! |--------|-------|-----------------------------------------------|
//! | 18     | 4     | `cap` — captured payload byte count (u32)     |
//! | 22     | `cap` | payload bytes (usually `cap == len`)          |
//!
//! [`read_log`] parses both versions and tolerates a *torn tail*: a
//! process hard-killed mid-write (the CI chaos smoke does exactly
//! this) leaves a partial final record, which is reported as the
//! complete-record prefix plus [`WtapLog::truncated`] — never an
//! error.
//!
//! ## Cost when disabled
//!
//! The hot-path calls [`frame`] / [`frame_parts`] are one `OnceLock`
//! load and a `None` branch — no syscalls, no locks. `benches/wire.rs`
//! measures and asserts this stays in the nanoseconds.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use super::clock::Clock;

/// Frame direction relative to this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Written to a socket.
    Tx,
    /// Read from a socket.
    Rx,
}

/// Link id recorded when the sending thread never called
/// [`set_link`].
pub const LINK_UNSET: u32 = u32::MAX;

/// One decoded tap record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireRecord {
    /// Microseconds since the process tap started.
    pub t_us: u64,
    /// Link id the frame crossed ([`LINK_UNSET`] if unknown).
    pub link: u32,
    /// Frame payload length in bytes.
    pub len: u32,
    /// Direction.
    pub dir: Dir,
    /// Wire frame kind (`net::proto::K_*`).
    pub kind: u8,
    /// Captured payload bytes — empty for version-1 (header-only)
    /// logs and for records written without capture.
    pub payload: Vec<u8>,
}

/// A parsed tap log: format version, torn-tail marker, records.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WtapLog {
    /// Format version the log header declared (1 or 2).
    pub version: u32,
    /// True when the file ended inside a record (the writing process
    /// died mid-write); `records` holds the complete prefix.
    pub truncated: bool,
    /// Every complete record, in write order.
    pub records: Vec<WireRecord>,
}

const MAGIC: &[u8; 4] = b"WTAP";
const VERSION_HEADERS: u32 = 1;
const VERSION_FULL: u32 = 2;
const HEAD_LEN: usize = 18;

/// An open tap log (also usable standalone in tests; the process-wide
/// tap behind [`frame`] wraps one of these).
pub struct WireLog {
    file: File,
    clock: Clock,
    version: u32,
}

impl WireLog {
    /// Create a header-only (version 1) log at `path`.
    pub fn create(path: &Path) -> std::io::Result<WireLog> {
        WireLog::create_version(path, VERSION_HEADERS)
    }

    /// Create a full-capture (version 2) log at `path`: every record
    /// written with [`WireLog::record_parts`] stores the payload
    /// bytes alongside the fixed head.
    pub fn create_full(path: &Path) -> std::io::Result<WireLog> {
        WireLog::create_version(path, VERSION_FULL)
    }

    fn create_version(path: &Path, version: u32) -> std::io::Result<WireLog> {
        let mut file = File::create(path)?;
        file.write_all(MAGIC)?;
        file.write_all(&version.to_le_bytes())?;
        Ok(WireLog { file, clock: Clock::new(), version })
    }

    /// Append one header-only record stamped "now" and flush it (the
    /// process-wide tap is never dropped, so buffering would lose the
    /// tail). Under a version-2 log this writes a zero-length capture.
    pub fn record(&mut self, link: u32, dir: Dir, kind: u8, len: u32) -> std::io::Result<()> {
        self.write_record(link, dir, kind, len, &[])
    }

    /// Append one record capturing the payload scattered across
    /// `parts` (the vectored-write shape the codec already has in
    /// hand). Under a version-1 log the payload bytes are dropped and
    /// only the head is written.
    pub fn record_parts(
        &mut self,
        link: u32,
        dir: Dir,
        kind: u8,
        parts: &[&[u8]],
    ) -> std::io::Result<()> {
        let len: usize = parts.iter().map(|p| p.len()).sum();
        self.write_record(link, dir, kind, len as u32, parts)
    }

    /// Append one record whose *frame* is `body` (what actually
    /// crossed the socket — a shm descriptor, say) but whose capture
    /// additionally carries `image` bytes the wire never saw (the shm
    /// segment contents). The head's `len` reflects only the frame;
    /// the capture is `body ‖ image`, which is exactly the layout
    /// [`crate::net::proto::ShmDesc::decode_with_image`] re-splits at
    /// replay time. Under a version-1 log only the head is written.
    pub fn record_with_image(
        &mut self,
        link: u32,
        dir: Dir,
        kind: u8,
        body: &[&[u8]],
        image: &[u8],
    ) -> std::io::Result<()> {
        let len: usize = body.iter().map(|p| p.len()).sum();
        let mut parts: Vec<&[u8]> = body.to_vec();
        parts.push(image);
        self.write_record(link, dir, kind, len as u32, &parts)
    }

    fn write_record(
        &mut self,
        link: u32,
        dir: Dir,
        kind: u8,
        len: u32,
        parts: &[&[u8]],
    ) -> std::io::Result<()> {
        let t_us = (self.clock.now_s() * 1e6) as u64;
        let mut rec = [0u8; HEAD_LEN];
        rec[0..8].copy_from_slice(&t_us.to_le_bytes());
        rec[8..12].copy_from_slice(&link.to_le_bytes());
        rec[12..16].copy_from_slice(&len.to_le_bytes());
        rec[16] = match dir {
            Dir::Tx => 0,
            Dir::Rx => 1,
        };
        rec[17] = kind;
        self.file.write_all(&rec)?;
        if self.version >= VERSION_FULL {
            let cap: usize = parts.iter().map(|p| p.len()).sum();
            self.file.write_all(&(cap as u32).to_le_bytes())?;
            for part in parts {
                self.file.write_all(part)?;
            }
        }
        self.file.flush()
    }
}

/// Read a tap log back (the replay half's entry point; also used by
/// tests and tooling). Version 1 and 2 logs both parse; a torn final
/// record — the writer was killed mid-write — yields the complete
/// prefix with [`WtapLog::truncated`] set instead of an error. Errors
/// only on a bad magic, a short file header, or an unknown version.
pub fn read_log(path: &Path) -> std::io::Result<WtapLog> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 8 || &buf[0..4] != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: not a wiretap log (bad magic)", path.display()),
        ));
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VERSION_HEADERS && version != VERSION_FULL {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!(
                "{}: wiretap log version {version}, expected {VERSION_HEADERS} or {VERSION_FULL}",
                path.display()
            ),
        ));
    }
    let mut records = Vec::new();
    let mut at = 8usize;
    let mut truncated = false;
    while at < buf.len() {
        if at + HEAD_LEN > buf.len() {
            truncated = true;
            break;
        }
        let r = &buf[at..at + HEAD_LEN];
        let mut rec = WireRecord {
            t_us: u64::from_le_bytes(r[0..8].try_into().unwrap()),
            link: u32::from_le_bytes(r[8..12].try_into().unwrap()),
            len: u32::from_le_bytes(r[12..16].try_into().unwrap()),
            dir: if r[16] == 0 { Dir::Tx } else { Dir::Rx },
            kind: r[17],
            payload: Vec::new(),
        };
        let mut next = at + HEAD_LEN;
        if version >= VERSION_FULL {
            if next + 4 > buf.len() {
                truncated = true;
                break;
            }
            let cap = u32::from_le_bytes(buf[next..next + 4].try_into().unwrap()) as usize;
            next += 4;
            if next + cap > buf.len() {
                truncated = true;
                break;
            }
            rec.payload = buf[next..next + cap].to_vec();
            next += cap;
        }
        records.push(rec);
        at = next;
    }
    Ok(WtapLog { version, truncated, records })
}

struct Tap {
    log: Mutex<WireLog>,
    path: PathBuf,
    full: bool,
}

static TAP: OnceLock<Option<Tap>> = OnceLock::new();

fn tap() -> Option<&'static Tap> {
    TAP.get_or_init(|| {
        let full = match std::env::var("WILKINS_TRACE_WIRE").ok().as_deref() {
            Some("1") => false,
            Some("full") => true,
            _ => return None,
        };
        let dir = std::env::var("WILKINS_TRACE_DIR").unwrap_or_else(|_| ".".to_string());
        let path = Path::new(&dir).join(format!("wilkins-wire-{}.wtap", std::process::id()));
        let made = if full { WireLog::create_full(&path) } else { WireLog::create(&path) };
        match made {
            Ok(log) => Some(Tap { log: Mutex::new(log), path, full }),
            Err(e) => {
                eprintln!("wilkins: cannot open wiretap log {}: {e}", path.display());
                None
            }
        }
    })
    .as_ref()
}

/// True when the process-wide tap is armed (env checked once).
pub fn enabled() -> bool {
    tap().is_some()
}

/// The path of the process-wide tap log, if armed.
pub fn log_path() -> Option<&'static Path> {
    tap().map(|t| t.path.as_path())
}

thread_local! {
    static LINK: std::cell::Cell<u32> = const { std::cell::Cell::new(LINK_UNSET) };
}

/// Tag this thread's subsequent [`frame`] calls with a link id. Pump
/// and beat threads each own one link, so a thread-local keeps the
/// codec signatures unchanged.
pub fn set_link(link: u32) {
    LINK.with(|l| l.set(link));
}

/// Record one frame crossing the wire, header only. When the tap is
/// disabled (the default) this is one atomic load and a branch.
#[inline]
pub fn frame(dir: Dir, kind: u8, len: u32) {
    if let Some(t) = tap() {
        let link = LINK.with(|l| l.get());
        let _ = t.log.lock().unwrap().record(link, dir, kind, len);
    }
}

/// Record one frame whose body is scattered across `parts`, capturing
/// the payload bytes when the tap is armed in full mode
/// (`WILKINS_TRACE_WIRE=full`). Header-only mode records just the
/// head; disabled, this is one atomic load and a branch like
/// [`frame`].
#[inline]
pub fn frame_parts(dir: Dir, kind: u8, parts: &[&[u8]]) {
    if let Some(t) = tap() {
        let link = LINK.with(|l| l.get());
        let mut log = t.log.lock().unwrap();
        let _ = if t.full {
            log.record_parts(link, dir, kind, parts)
        } else {
            let len: usize = parts.iter().map(|p| p.len()).sum();
            log.record(link, dir, kind, len as u32)
        };
    }
}

/// Record one shm delivery: the descriptor frame `body` plus the
/// segment `image` the wire never carried. In full mode the capture
/// stores `body ‖ image` so replay can reconstruct the payload; in
/// header-only mode just the head (with the descriptor's length) is
/// written; disabled, one atomic load and a branch.
#[inline]
pub fn frame_with_image(dir: Dir, kind: u8, body: &[&[u8]], image: &[u8]) {
    if let Some(t) = tap() {
        let link = LINK.with(|l| l.get());
        let mut log = t.log.lock().unwrap();
        let _ = if t.full {
            log.record_with_image(link, dir, kind, body, image)
        } else {
            let len: usize = body.iter().map(|p| p.len()).sum();
            log.record(link, dir, kind, len as u32)
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::{run_prop, Rng};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wilkins-wtap-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_records() {
        let path = tmp("roundtrip");
        let mut log = WireLog::create(&path).unwrap();
        log.record(0, Dir::Tx, 7, 4096).unwrap();
        log.record(LINK_UNSET, Dir::Rx, 11, 64).unwrap();
        let parsed = read_log(&path).unwrap();
        assert_eq!(parsed.version, 1);
        assert!(!parsed.truncated);
        let recs = &parsed.records;
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].link, recs[0].dir, recs[0].kind, recs[0].len), (0, Dir::Tx, 7, 4096));
        assert_eq!(
            (recs[1].link, recs[1].dir, recs[1].kind, recs[1].len),
            (LINK_UNSET, Dir::Rx, 11, 64)
        );
        assert!(recs[1].t_us >= recs[0].t_us, "tap timestamps must be monotone");
        assert!(recs.iter().all(|r| r.payload.is_empty()), "v1 captures no payload");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_full_capture() {
        let path = tmp("roundtrip-full");
        let mut log = WireLog::create_full(&path).unwrap();
        log.record_parts(3, Dir::Tx, 8, &[b"hello ", b"world"]).unwrap();
        log.record(7, Dir::Rx, 10, 9).unwrap(); // head-only record in a v2 log
        let parsed = read_log(&path).unwrap();
        assert_eq!(parsed.version, 2);
        assert!(!parsed.truncated);
        assert_eq!(parsed.records.len(), 2);
        assert_eq!(parsed.records[0].payload, b"hello world");
        assert_eq!(parsed.records[0].len, 11);
        assert_eq!(parsed.records[1].payload, b"");
        assert_eq!(parsed.records[1].len, 9);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(read_log(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_unknown_version() {
        let path = tmp("badver");
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_log(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn disabled_frame_is_noop() {
        // The env var is not set in unit tests, so this exercises the
        // cold branch; it must not panic or create files.
        frame(Dir::Tx, 1, 10);
        frame_parts(Dir::Rx, 2, &[b"abc"]);
    }

    /// Property: random v2 frame schedules round-trip bit-identically
    /// through write + [`read_log`], and truncating the file at any
    /// byte offset inside the final record yields the complete prefix
    /// with the truncation flag — never an error.
    #[test]
    fn prop_v2_roundtrip_and_torn_tail() {
        run_prop("wtap-v2-roundtrip", 40, |rng: &mut Rng| {
            let path = tmp(&format!("prop-{}", rng.next_u64()));
            let n = rng.range(1, 12) as usize;
            let mut want = Vec::new();
            {
                let mut log = WireLog::create_full(&path).unwrap();
                for _ in 0..n {
                    let link = if rng.bool() { rng.range(0, 8) as u32 } else { LINK_UNSET };
                    let dir = if rng.bool() { Dir::Tx } else { Dir::Rx };
                    let kind = rng.range(1, 12) as u8;
                    let payload: Vec<u8> =
                        (0..rng.range(0, 64)).map(|_| rng.range(0, 256) as u8).collect();
                    // Split the payload at a random point to exercise
                    // the scattered-parts write path.
                    let cut = rng.range(0, payload.len() as u64 + 1) as usize;
                    log.record_parts(link, dir, kind, &[&payload[..cut], &payload[cut..]])
                        .unwrap();
                    want.push((link, dir, kind, payload));
                }
            }
            let parsed = read_log(&path).unwrap();
            assert_eq!(parsed.version, 2);
            assert!(!parsed.truncated);
            assert_eq!(parsed.records.len(), n);
            for (rec, (link, dir, kind, payload)) in parsed.records.iter().zip(&want) {
                assert_eq!(rec.link, *link);
                assert_eq!(rec.dir, *dir);
                assert_eq!(rec.kind, *kind);
                assert_eq!(rec.len as usize, payload.len());
                assert_eq!(&rec.payload, payload);
            }

            // Torn tail: chop the file anywhere inside the last record.
            let bytes = std::fs::read(&path).unwrap();
            let last_len = HEAD_LEN + 4 + want.last().unwrap().3.len();
            let cut_at = bytes.len() - 1 - rng.range(0, last_len as u64 - 1) as usize;
            std::fs::write(&path, &bytes[..cut_at]).unwrap();
            let torn = read_log(&path).unwrap();
            assert!(torn.truncated, "cut at {cut_at}/{} must set truncated", bytes.len());
            assert_eq!(torn.records.len(), n - 1);
            let _ = std::fs::remove_file(&path);
        });
    }

    /// Property: v1 header-only logs still parse (backward compat),
    /// including torn tails.
    #[test]
    fn prop_v1_back_compat() {
        run_prop("wtap-v1-back-compat", 40, |rng: &mut Rng| {
            let path = tmp(&format!("prop-v1-{}", rng.next_u64()));
            let n = rng.range(1, 12) as usize;
            {
                let mut log = WireLog::create(&path).unwrap();
                for _ in 0..n {
                    log.record(
                        rng.range(0, 8) as u32,
                        if rng.bool() { Dir::Tx } else { Dir::Rx },
                        rng.range(1, 12) as u8,
                        rng.range(0, 1 << 20) as u32,
                    )
                    .unwrap();
                }
            }
            let parsed = read_log(&path).unwrap();
            assert_eq!(parsed.version, 1);
            assert!(!parsed.truncated);
            assert_eq!(parsed.records.len(), n);

            let bytes = std::fs::read(&path).unwrap();
            let cut_at = bytes.len() - 1 - rng.range(0, HEAD_LEN as u64 - 1) as usize;
            std::fs::write(&path, &bytes[..cut_at]).unwrap();
            let torn = read_log(&path).unwrap();
            assert!(torn.truncated);
            assert_eq!(torn.records.len(), n - 1);
            let _ = std::fs::remove_file(&path);
        });
    }
}
