//! Wire-level frame tap: `WILKINS_TRACE_WIRE=1` logs every frame
//! crossing the socket substrate — kind, length, link id, direction,
//! timestamp — to a per-process binary log. This is the *record* half
//! of ROADMAP item 4a (record/replay): a replay harness can re-feed
//! the exact frame schedule a run produced.
//!
//! ## Log format (`wilkins-wire-<pid>.wtap`)
//!
//! Header: magic `WTAP` (4 bytes) + `u32` LE version (currently 1).
//! Then fixed 18-byte little-endian records:
//!
//! | offset | size | field                                          |
//! |--------|------|------------------------------------------------|
//! | 0      | 8    | `t_us` — µs since process tap start (u64)      |
//! | 8      | 4    | `link` — link id (u32; `0xffff_ffff` = unset)  |
//! | 12     | 4    | `len` — frame payload length (u32)             |
//! | 16     | 1    | `dir` — 0 = Tx, 1 = Rx (u8)                    |
//! | 17     | 1    | `kind` — wire frame kind (u8, see `net::proto`)|
//!
//! ## Cost when disabled
//!
//! The hot-path call [`frame`] is one `OnceLock` load and a `None`
//! branch — no syscalls, no locks. `benches/wire.rs` measures and
//! asserts this stays in the nanoseconds.

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use super::clock::Clock;

/// Frame direction relative to this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Written to a socket.
    Tx,
    /// Read from a socket.
    Rx,
}

/// Link id recorded when the sending thread never called
/// [`set_link`].
pub const LINK_UNSET: u32 = u32::MAX;

/// One decoded tap record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireRecord {
    /// Microseconds since the process tap started.
    pub t_us: u64,
    /// Link id the frame crossed ([`LINK_UNSET`] if unknown).
    pub link: u32,
    /// Frame payload length in bytes.
    pub len: u32,
    /// Direction.
    pub dir: Dir,
    /// Wire frame kind (`net::proto::K_*`).
    pub kind: u8,
}

const MAGIC: &[u8; 4] = b"WTAP";
const VERSION: u32 = 1;
const RECORD_LEN: usize = 18;

/// An open tap log (also usable standalone in tests; the process-wide
/// tap behind [`frame`] wraps one of these).
pub struct WireLog {
    file: File,
    clock: Clock,
}

impl WireLog {
    /// Create a log at `path`, writing the header.
    pub fn create(path: &Path) -> std::io::Result<WireLog> {
        let mut file = File::create(path)?;
        file.write_all(MAGIC)?;
        file.write_all(&VERSION.to_le_bytes())?;
        Ok(WireLog { file, clock: Clock::new() })
    }

    /// Append one record stamped "now" and flush it (the process-wide
    /// tap is never dropped, so buffering would lose the tail).
    pub fn record(&mut self, link: u32, dir: Dir, kind: u8, len: u32) -> std::io::Result<()> {
        let t_us = (self.clock.now_s() * 1e6) as u64;
        let mut rec = [0u8; RECORD_LEN];
        rec[0..8].copy_from_slice(&t_us.to_le_bytes());
        rec[8..12].copy_from_slice(&link.to_le_bytes());
        rec[12..16].copy_from_slice(&len.to_le_bytes());
        rec[16] = match dir {
            Dir::Tx => 0,
            Dir::Rx => 1,
        };
        rec[17] = kind;
        self.file.write_all(&rec)?;
        self.file.flush()
    }
}

/// Read a tap log back into records (the replay half's entry point;
/// also used by tests and future tooling).
pub fn read_log(path: &Path) -> std::io::Result<Vec<WireRecord>> {
    let mut buf = Vec::new();
    File::open(path)?.read_to_end(&mut buf)?;
    if buf.len() < 8 || &buf[0..4] != MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: not a wiretap log (bad magic)", path.display()),
        ));
    }
    let ver = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if ver != VERSION {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{}: wiretap log version {ver}, expected {VERSION}", path.display()),
        ));
    }
    let mut out = Vec::new();
    let mut at = 8;
    while at + RECORD_LEN <= buf.len() {
        let r = &buf[at..at + RECORD_LEN];
        out.push(WireRecord {
            t_us: u64::from_le_bytes(r[0..8].try_into().unwrap()),
            link: u32::from_le_bytes(r[8..12].try_into().unwrap()),
            len: u32::from_le_bytes(r[12..16].try_into().unwrap()),
            dir: if r[16] == 0 { Dir::Tx } else { Dir::Rx },
            kind: r[17],
        });
        at += RECORD_LEN;
    }
    Ok(out)
}

struct Tap {
    log: Mutex<WireLog>,
    path: PathBuf,
}

static TAP: OnceLock<Option<Tap>> = OnceLock::new();

fn tap() -> Option<&'static Tap> {
    TAP.get_or_init(|| {
        if std::env::var("WILKINS_TRACE_WIRE").ok().as_deref() != Some("1") {
            return None;
        }
        let dir = std::env::var("WILKINS_TRACE_DIR").unwrap_or_else(|_| ".".to_string());
        let path = Path::new(&dir).join(format!("wilkins-wire-{}.wtap", std::process::id()));
        match WireLog::create(&path) {
            Ok(log) => Some(Tap { log: Mutex::new(log), path }),
            Err(e) => {
                eprintln!("wilkins: cannot open wiretap log {}: {e}", path.display());
                None
            }
        }
    })
    .as_ref()
}

/// True when the process-wide tap is armed (env checked once).
pub fn enabled() -> bool {
    tap().is_some()
}

/// The path of the process-wide tap log, if armed.
pub fn log_path() -> Option<&'static Path> {
    tap().map(|t| t.path.as_path())
}

thread_local! {
    static LINK: std::cell::Cell<u32> = const { std::cell::Cell::new(LINK_UNSET) };
}

/// Tag this thread's subsequent [`frame`] calls with a link id. Pump
/// and beat threads each own one link, so a thread-local keeps the
/// codec signatures unchanged.
pub fn set_link(link: u32) {
    LINK.with(|l| l.set(link));
}

/// Record one frame crossing the wire. When the tap is disabled
/// (the default) this is one atomic load and a branch.
#[inline]
pub fn frame(dir: Dir, kind: u8, len: u32) {
    if let Some(t) = tap() {
        let link = LINK.with(|l| l.get());
        let _ = t.log.lock().unwrap().record(link, dir, kind, len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("wilkins-wtap-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_records() {
        let path = tmp("roundtrip");
        let mut log = WireLog::create(&path).unwrap();
        log.record(0, Dir::Tx, 7, 4096).unwrap();
        log.record(LINK_UNSET, Dir::Rx, 11, 64).unwrap();
        let recs = read_log(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!((recs[0].link, recs[0].dir, recs[0].kind, recs[0].len), (0, Dir::Tx, 7, 4096));
        assert_eq!(
            (recs[1].link, recs[1].dir, recs[1].kind, recs[1].len),
            (LINK_UNSET, Dir::Rx, 11, 64)
        );
        assert!(recs[1].t_us >= recs[0].t_us, "tap timestamps must be monotone");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOPE0000").unwrap();
        assert!(read_log(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn disabled_frame_is_noop() {
        // The env var is not set in unit tests, so this exercises the
        // cold branch; it must not panic or create files.
        frame(Dir::Tx, 1, 10);
    }
}
