//! Deterministic replay of recorded multi-worker runs — the *replay*
//! half of ROADMAP item 4a, closing the loop [`wiretap`] opened.
//!
//! A run recorded with `WILKINS_TRACE_WIRE=full` leaves one
//! full-capture `.wtap` log per process (coordinator + every worker).
//! [`RecordedRun::load`] classifies the logs by the frames they
//! carry, and [`replay`] re-drives the *coordinator's* bookkeeping —
//! dispatch accounting, fault counters, telemetry ingestion, report
//! assembly — from the recorded frame schedule alone, in one process,
//! with no sockets, no timers and no races. Same input, same log,
//! same report: bit-for-bit, every time.
//!
//! Two replay levels:
//!
//! * **Coordinator replay** ([`replay`]) — walk the coordinator log
//!   in record order and mirror exactly what the live coordinator did
//!   with each frame: `RunInstance` dispatches (a re-dispatch of an
//!   instance whose prior dispatch never answered is a worker loss +
//!   requeue), `InstanceDone` completions matched by idempotency key,
//!   `LaunchWorld`/`WorldDone` merges for distributed worlds, and
//!   `Telemetry` ingestion. Per-instance [`RunReport`]s come verbatim
//!   from the recorded completion payloads, so their counters
//!   reproduce exactly.
//! * **Execution replay** ([`replay_worker_ranks`]) — re-*run* one
//!   recorded worker's ranks against a
//!   [`ReplayWorld`](crate::comm::ReplayWorld): every inbound data
//!   and flow-control message from the worker's log is pre-injected
//!   into the hosted mailboxes, outbound cross-process sends are
//!   suppressed, and the actual task code (lowfive engines, flow
//!   control, collectives) executes under the recorded message
//!   schedule.
//!
//! Reports are compared with [`normalize_report_json`], which strips
//! only wall-clock-derived members (elapsed/start/finish times,
//! heartbeat misses, scheduler poll rounds, event attributes carrying
//! error prose); every counter, instance row, event name and
//! telemetry total must match exactly. See `docs/replay.md`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::report::{self, RankOutcome};
use crate::coordinator::{FaultStats, RunReport};
use crate::ensemble::{EnsembleReport, EnsembleSpec, InstanceReport, Placement};
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::metrics::MergedTrace;
use crate::net::proto::{
    ChunkAssembler, Hello, InstanceDone, LaunchWorld, RunInstance, WorldDone, K_DATA,
    K_DATA_CHUNK, K_DATA_SHM, K_HELLO, K_INSTANCE_DONE, K_LAUNCH_WORLD, K_RUN_INSTANCE,
    K_TELEMETRY, K_WORLD_DONE,
};
use crate::obs::recorder::InstantEvent;
use crate::obs::telemetry::{TelemetrySample, TelemetryStore};
use crate::obs::wiretap::{self, Dir, WireRecord};
use crate::tasks::builtin_registry;
use crate::Wilkins;

/// What kind of run a recorded log set captures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunKind {
    /// An ensemble campaign (`process-per-instance`): the coordinator
    /// dispatched `RunInstance` frames.
    Ensemble,
    /// One distributed workflow world (`process-per-node`): the
    /// coordinator broadcast a `LaunchWorld`.
    World,
}

/// A loaded set of per-process wire logs from one recorded run.
pub struct RecordedRun {
    /// What the coordinator log says this run was.
    pub kind: RunKind,
    /// The coordinator's records, in write order.
    pub coordinator: Vec<WireRecord>,
    /// Per-worker records, sorted by worker id (decoded from each
    /// worker's `Hello`).
    pub workers: Vec<(u64, Vec<WireRecord>)>,
    /// True when any log ended in a torn record (a process was killed
    /// mid-write; the complete prefix is still replayed).
    pub truncated: bool,
}

impl RecordedRun {
    /// Load every `*.wtap` log in `dir` and classify coordinator vs
    /// workers. Requires full-capture (version 2) logs; header-only
    /// v1 logs parse but cannot be replayed, so they are rejected
    /// with a pointer at `WILKINS_TRACE_WIRE=full`.
    pub fn load(dir: &Path) -> Result<RecordedRun> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| {
                WilkinsError::Config(format!("cannot read replay dir {}: {e}", dir.display()))
            })?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "wtap"))
            .collect();
        paths.sort();
        if paths.is_empty() {
            return Err(WilkinsError::Config(format!(
                "no .wtap logs in {} (record a run with WILKINS_TRACE_WIRE=full \
                 and WILKINS_TRACE_DIR pointing here)",
                dir.display()
            )));
        }

        let mut coordinator: Option<Vec<WireRecord>> = None;
        let mut workers: Vec<(u64, Vec<WireRecord>)> = Vec::new();
        let mut truncated = false;
        for path in &paths {
            let log = wiretap::read_log(path).map_err(WilkinsError::Io)?;
            if log.version < 2 {
                return Err(WilkinsError::Config(format!(
                    "{}: header-only wiretap log (WILKINS_TRACE_WIRE=1); replay needs \
                     payload capture — record with WILKINS_TRACE_WIRE=full",
                    path.display()
                )));
            }
            truncated |= log.truncated;
            // A worker's first outbound frame is its rendezvous Hello;
            // the coordinator never sends one.
            let hello = log
                .records
                .iter()
                .find(|r| r.dir == Dir::Tx && r.kind == K_HELLO);
            if let Some(h) = hello {
                let id = Hello::decode(&h.payload)?.worker_id;
                workers.push((id, log.records));
            } else if log
                .records
                .iter()
                .any(|r| r.dir == Dir::Tx && matches!(r.kind, K_RUN_INSTANCE | K_LAUNCH_WORLD))
            {
                if coordinator.is_some() {
                    return Err(WilkinsError::Config(format!(
                        "{}: two coordinator logs in one replay dir (mixed runs?)",
                        dir.display()
                    )));
                }
                coordinator = Some(log.records);
            }
            // Logs with neither (a process that died before doing
            // anything) are ignored.
        }
        let coordinator = coordinator.ok_or_else(|| {
            WilkinsError::Config(format!(
                "{}: no coordinator log (no recorded RunInstance/LaunchWorld dispatch)",
                dir.display()
            ))
        })?;
        let kind = if coordinator
            .iter()
            .any(|r| r.dir == Dir::Tx && r.kind == K_RUN_INSTANCE)
        {
            RunKind::Ensemble
        } else {
            RunKind::World
        };
        workers.sort_by_key(|(id, _)| *id);
        Ok(RecordedRun { kind, coordinator, workers, truncated })
    }
}

/// The report a replay reproduces: the same type the recorded run
/// printed and exported.
pub enum ReplayedReport {
    /// An ensemble campaign's merged report.
    Ensemble(EnsembleReport),
    /// A distributed workflow world's merged report.
    World(RunReport),
}

impl ReplayedReport {
    /// The machine-readable JSON, same schema as the recorded run's
    /// `--json` artifact.
    pub fn to_json(&self) -> String {
        match self {
            ReplayedReport::Ensemble(r) => r.to_json(),
            ReplayedReport::World(r) => r.to_json(),
        }
    }

    /// The CLI table, same renderer as the recorded run.
    pub fn render(&self) -> String {
        match self {
            ReplayedReport::Ensemble(r) => r.render(),
            ReplayedReport::World(r) => r.render(),
        }
    }
}

/// Re-drive the coordinator's bookkeeping from the recorded frame
/// schedule and reassemble the run's report. Deterministic: the only
/// input is the log.
pub fn replay(run: &RecordedRun) -> Result<ReplayedReport> {
    match run.kind {
        RunKind::Ensemble => replay_ensemble(run).map(ReplayedReport::Ensemble),
        RunKind::World => replay_world(run).map(ReplayedReport::World),
    }
}

/// Seconds on the coordinator clock of record `r`, relative to the
/// log's first record.
fn rel_s(t0: u64, t_us: u64) -> f64 {
    (t_us.saturating_sub(t0)) as f64 / 1e6
}

fn replay_ensemble(run: &RecordedRun) -> Result<EnsembleReport> {
    // The spec ships inside every dispatch; the first one pins down
    // names, ranks, budget and policy exactly as workers re-parsed it.
    let first = run
        .coordinator
        .iter()
        .find(|r| r.dir == Dir::Tx && r.kind == K_RUN_INSTANCE)
        .expect("RunKind::Ensemble implies a RunInstance dispatch");
    let ri0 = RunInstance::decode(&first.payload)?;
    let spec = EnsembleSpec::from_yaml_str(&ri0.spec_src, Path::new(&ri0.base_dir))?;
    let n = spec.instances.len();

    let t0 = run.coordinator.first().map(|r| r.t_us).unwrap_or(0);
    let mut started = vec![0.0_f64; n];
    let mut finished = vec![0.0_f64; n];
    let mut reports: Vec<Option<RunReport>> = (0..n).map(|_| None).collect();
    let mut spans: Vec<Vec<crate::obs::Span>> = vec![Vec::new(); n];
    let mut done_once = vec![false; n];
    let mut faults = FaultStats::default();
    let mut events: Vec<InstantEvent> = Vec::new();
    let mut telemetry = TelemetryStore::new();
    // idem_key -> instance idx, for dispatches still awaiting their
    // completion. Keys are unique per dispatch, so this mirrors the
    // pool's per-worker outstanding maps merged into one.
    let mut outstanding: HashMap<u64, usize> = HashMap::new();
    let mut peak = 0usize;
    let mut last_t = 0.0_f64;

    for rec in &run.coordinator {
        let t_s = rel_s(t0, rec.t_us);
        last_t = last_t.max(t_s);
        match (rec.dir, rec.kind) {
            (Dir::Tx, K_RUN_INSTANCE) => {
                let ri = RunInstance::decode(&rec.payload)?;
                let idx = ri.instance_idx as usize;
                if idx >= n {
                    return Err(WilkinsError::Config(format!(
                        "recorded dispatch of instance {idx}, spec has {n}"
                    )));
                }
                // A second dispatch while the first never answered is
                // the coordinator surviving a worker loss: the live
                // run recorded WorkerLost, then Requeue, then this
                // re-dispatch under a fresh idempotency key.
                if let Some(prev) = outstanding
                    .iter()
                    .find_map(|(k, i)| if *i == idx { Some(*k) } else { None })
                {
                    outstanding.remove(&prev);
                    faults.lost_workers += 1;
                    events.push(InstantEvent {
                        rank: 0,
                        name: "WorkerLost".into(),
                        t: t_s,
                        attrs: vec![("instance".into(), spec.instances[idx].name.clone())],
                    });
                    faults.retries += 1;
                    events.push(InstantEvent {
                        rank: 0,
                        name: "Requeue".into(),
                        t: t_s,
                        attrs: vec![("instance".into(), spec.instances[idx].name.clone())],
                    });
                }
                outstanding.insert(ri.idem_key, idx);
                started[idx] = t_s;
                let in_use: usize = {
                    let mut idxs: Vec<usize> = outstanding.values().copied().collect();
                    idxs.sort_unstable();
                    idxs.dedup();
                    idxs.iter().map(|&i| spec.instances[i].ranks()).sum()
                };
                peak = peak.max(in_use);
            }
            (Dir::Rx, K_INSTANCE_DONE) => {
                let done = InstanceDone::decode(&rec.payload)?;
                let Some(idx) = outstanding.remove(&done.idem_key) else {
                    // Stale reply from a presumed-dead worker; the
                    // live pool's idempotency check dropped it too.
                    faults.dup_done += 1;
                    continue;
                };
                if done_once[idx] {
                    faults.dup_done += 1;
                    continue;
                }
                if !done.error.is_empty() {
                    return Err(WilkinsError::Task(format!(
                        "recorded campaign failed: {}: {}",
                        spec.instances[idx].name, done.error
                    )));
                }
                done_once[idx] = true;
                finished[idx] = t_s;
                spans[idx] = done.spans;
                reports[idx] = done.report;
            }
            (Dir::Rx, K_TELEMETRY) => {
                let s = TelemetrySample::decode(&rec.payload)?;
                telemetry.ingest(&s, t_s);
            }
            _ => {}
        }
    }

    if let Some((_, &idx)) = outstanding.iter().next() {
        return Err(WilkinsError::Task(format!(
            "recorded campaign never completed instance {} (incomplete log?)",
            spec.instances[idx].name
        )));
    }

    let mut trace = MergedTrace::new();
    let mut instances = Vec::with_capacity(n);
    for (idx, inst) in spec.instances.iter().enumerate() {
        trace.add_instance(&inst.name, started[idx], &spans[idx]);
        instances.push(InstanceReport {
            name: inst.name.clone(),
            ranks: inst.ranks(),
            started_s: started[idx],
            finished_s: finished[idx],
            report: reports[idx].take().ok_or_else(|| {
                WilkinsError::Task(format!(
                    "recorded campaign has no completion for instance {}",
                    inst.name
                ))
            })?,
        });
    }
    Ok(EnsembleReport {
        elapsed: Duration::from_secs_f64(last_t),
        budget: spec.max_ranks,
        policy: spec.policy,
        placement: Placement::ProcessPerInstance,
        workers: Some(run.workers.len()),
        peak_ranks: peak,
        // The live round count includes idle scheduler polls — pure
        // wall-clock noise, not reconstructable from frames (the
        // normalizer strips it from comparisons).
        rounds: 0,
        instances,
        trace,
        faults,
        events,
        telemetry: telemetry.summary(),
    })
}

fn replay_world(run: &RecordedRun) -> Result<RunReport> {
    let launch = run
        .coordinator
        .iter()
        .find(|r| r.dir == Dir::Tx && r.kind == K_LAUNCH_WORLD)
        .expect("RunKind::World implies a LaunchWorld dispatch");
    let lw = LaunchWorld::decode(&launch.payload)?;
    let cfg = crate::config::WorkflowConfig::from_yaml_str(&lw.config_src)?;
    let graph = WorkflowGraph::build(&cfg)?;

    let t0 = run.coordinator.first().map(|r| r.t_us).unwrap_or(0);
    let mut outcomes: Vec<RankOutcome> = Vec::with_capacity(graph.total_ranks);
    let mut bytes_sent = 0u64;
    let mut msgs_sent = 0u64;
    let mut telemetry = TelemetryStore::new();
    let mut last_t = 0.0_f64;
    // launch_world reads replies link by link in worker-id order, so
    // Rx order is worker order; the link tag (when the recording
    // binary stamped one) double-checks it.
    let mut reply_no = 0usize;
    for rec in &run.coordinator {
        let t_s = rel_s(t0, rec.t_us);
        last_t = last_t.max(t_s);
        match (rec.dir, rec.kind) {
            (Dir::Rx, K_WORLD_DONE) => {
                let reply = WorldDone::decode(&rec.payload)?;
                let wid = if rec.link != wiretap::LINK_UNSET {
                    rec.link as usize
                } else {
                    reply_no
                };
                reply_no += 1;
                if !reply.error.is_empty() {
                    return Err(WilkinsError::Task(format!(
                        "worker {wid} failed: {}",
                        reply.error
                    )));
                }
                bytes_sent += reply.bytes_sent;
                msgs_sent += reply.msgs_sent;
                for o in &reply.outcomes {
                    outcomes.push(RankOutcome {
                        node: o.node as usize,
                        stats: o.stats.clone(),
                        error: if o.error.is_empty() { None } else { Some(o.error.clone()) },
                    });
                }
            }
            (Dir::Rx, K_TELEMETRY) => {
                let s = TelemetrySample::decode(&rec.payload)?;
                telemetry.ingest(&s, t_s);
            }
            _ => {}
        }
    }
    if outcomes.len() != graph.total_ranks {
        return Err(WilkinsError::Task(format!(
            "recorded workers reported {} rank outcomes, world has {} (incomplete log?)",
            outcomes.len(),
            graph.total_ranks
        )));
    }
    let mut report = report::build(
        &graph,
        outcomes,
        Duration::from_secs_f64(last_t),
        bytes_sent,
        msgs_sent,
    )?;
    // Heartbeat misses are wall-clock noise (normalized away); the
    // replay has no timers to miss.
    report.faults.heartbeat_misses = 0;
    report.telemetry = telemetry.summary();
    Ok(report)
}

/// Execution replay: actually *re-run* the ranks worker `worker_id`
/// hosted in a recorded `process-per-node` world, feeding them the
/// exact inbound message schedule from the worker's log. Outbound
/// cross-process sends are suppressed (their effects are already in
/// the log); hosted-to-hosted traffic runs live, exactly as it did in
/// the recorded process. Returns the partial [`RunReport`] built from
/// the re-executed ranks' outcomes (non-hosted nodes report zeros).
///
/// `workdir` redirects file-mode transports away from the recorded
/// run's directory; pass a fresh temp dir.
pub fn replay_worker_ranks(
    run: &RecordedRun,
    worker_id: u64,
    workdir: &Path,
) -> Result<RunReport> {
    if run.kind != RunKind::World {
        return Err(WilkinsError::Config(
            "execution replay re-runs `process-per-node` worlds; this recording is an \
             ensemble campaign (use `wilkins replay` on the coordinator schedule instead)"
                .into(),
        ));
    }
    let records = run
        .workers
        .iter()
        .find(|(id, _)| *id == worker_id)
        .map(|(_, recs)| recs)
        .ok_or_else(|| {
            WilkinsError::Config(format!("no recorded log for worker {worker_id}"))
        })?;
    let launch = records
        .iter()
        .find(|r| r.dir == Dir::Rx && r.kind == K_LAUNCH_WORLD)
        .ok_or_else(|| {
            WilkinsError::Config(format!(
                "worker {worker_id} never received a LaunchWorld (log incomplete?)"
            ))
        })?;
    let lw = LaunchWorld::decode(&launch.payload)?;
    let cfg = crate::config::WorkflowConfig::from_yaml_str(&lw.config_src)?;
    let graph = WorkflowGraph::build(&cfg)?;

    let hosted: Vec<usize> = lw
        .owner_of
        .iter()
        .enumerate()
        .filter(|(_, &o)| o == worker_id)
        .map(|(r, _)| r)
        .collect();
    if hosted.is_empty() {
        return Err(WilkinsError::Config(format!(
            "worker {worker_id} hosted no ranks in the recorded world"
        )));
    }
    let mut is_hosted = vec![false; graph.total_ranks];
    for &r in &hosted {
        is_hosted[r] = true;
    }

    let rw = crate::comm::ReplayWorld::new(graph.total_ranks, is_hosted.clone());
    // Pre-inject every recorded inbound data-plane message in log
    // order. Mailbox matching is (comm, tag, src) FIFO, so receivers
    // observe exactly the recorded per-key arrival order; messages
    // they never got to consume in the recorded run just sit unread.
    let mut assembler = ChunkAssembler::new();
    for rec in records {
        if rec.dir != Dir::Rx {
            continue;
        }
        match rec.kind {
            K_DATA => {
                let m = crate::net::proto::decode_data(&rec.payload)?;
                if is_hosted.get(m.dst_global as usize).copied().unwrap_or(false) {
                    rw.inject(m.dst_global as usize, m.src_global as usize, m.comm_id, m.tag, m.payload);
                }
            }
            K_DATA_CHUNK => {
                let c = crate::net::proto::decode_data_chunk(&rec.payload)?;
                if let Some(m) = assembler.feed(c)? {
                    if is_hosted.get(m.dst_global as usize).copied().unwrap_or(false) {
                        rw.inject(m.dst_global as usize, m.src_global as usize, m.comm_id, m.tag, m.payload);
                    }
                }
            }
            // Shm delivery: the tap stored the descriptor frame plus
            // the segment image the wire never carried; re-split and
            // inject a copy of the image (no segment files exist at
            // replay time).
            K_DATA_SHM => {
                let (d, image) =
                    crate::net::proto::ShmDesc::decode_with_image(&rec.payload)?;
                if is_hosted.get(d.dst_global as usize).copied().unwrap_or(false) {
                    rw.inject(
                        d.dst_global as usize,
                        d.src_global as usize,
                        d.comm_id,
                        d.tag,
                        crate::comm::buf::Payload::copy_from_slice(image),
                    );
                }
            }
            // K_SHM_ACK and the rest of the control plane carry no
            // payload to re-deliver.
            _ => {}
        }
    }

    let mut w = Wilkins::from_yaml_str(&lw.config_src, builtin_registry())?
        .with_time_scale(lw.time_scale)
        .with_workdir(workdir.to_path_buf());
    // Science payloads need the AOT engine, exactly as the recorded
    // worker attached it.
    let _engine;
    let art = Path::new(&lw.artifacts);
    if !lw.artifacts.is_empty() && art.join("manifest.tsv").exists() {
        let engine = crate::runtime::Engine::start(art)?;
        w = w.with_engine(engine.handle());
        _engine = Some(engine);
    } else {
        _engine = None;
    }

    let t0 = std::time::Instant::now();
    let outcomes = w.run_hosted(rw.world(), &hosted)?;
    report::build(
        &graph,
        outcomes,
        t0.elapsed(),
        rw.world().bytes_sent(),
        rw.world().msgs_sent(),
    )
}

/// JSON object keys whose values are wall-clock-derived and therefore
/// legitimately differ between a live run and its replay. Everything
/// else — every counter, name, event and telemetry total — must
/// match bit-for-bit.
pub const VOLATILE_KEYS: &[&str] = &[
    "elapsed_s",
    "started_s",
    "finished_s",
    "t_s",
    "heartbeat_misses",
    "rounds",
    "attrs",
];

/// Re-emit a report JSON document with [`VOLATILE_KEYS`] members
/// removed (recursively) and all insignificant whitespace dropped, so
/// a recorded report and its replay compare byte-for-byte on exactly
/// the deterministic surface.
pub fn normalize_report_json(src: &str) -> Result<String> {
    let b = src.as_bytes();
    let mut out = String::with_capacity(src.len());
    let mut i = 0usize;
    emit_value(b, &mut i, &mut out)?;
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(WilkinsError::Config(format!(
            "trailing bytes at offset {i} in report JSON"
        )));
    }
    Ok(out)
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn json_err(what: &str, i: usize) -> WilkinsError {
    WilkinsError::Config(format!("bad report JSON: {what} at offset {i}"))
}

/// Parse one JSON string (cursor at the opening quote), returning the
/// raw source span including quotes.
fn raw_string<'a>(b: &'a [u8], i: &mut usize) -> Result<&'a str> {
    let start = *i;
    if b.get(*i) != Some(&b'"') {
        return Err(json_err("expected string", *i));
    }
    *i += 1;
    while *i < b.len() {
        match b[*i] {
            b'\\' => *i += 2,
            b'"' => {
                *i += 1;
                return std::str::from_utf8(&b[start..*i])
                    .map_err(|_| json_err("non-utf8 string", start));
            }
            _ => *i += 1,
        }
    }
    Err(json_err("unterminated string", start))
}

/// Emit one JSON value at the cursor, normalized, into `out`.
fn emit_value(b: &[u8], i: &mut usize, out: &mut String) -> Result<()> {
    skip_ws(b, i);
    match b.get(*i) {
        Some(b'{') => {
            *i += 1;
            out.push('{');
            let mut first = true;
            loop {
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b'}') => {
                        *i += 1;
                        break;
                    }
                    Some(b',') => {
                        *i += 1;
                        continue;
                    }
                    Some(b'"') => {
                        let rawkey = raw_string(b, i)?;
                        let key = &rawkey[1..rawkey.len() - 1];
                        skip_ws(b, i);
                        if b.get(*i) != Some(&b':') {
                            return Err(json_err("expected ':'", *i));
                        }
                        *i += 1;
                        if VOLATILE_KEYS.contains(&key) {
                            let mut sink = String::new();
                            emit_value(b, i, &mut sink)?;
                        } else {
                            if !first {
                                out.push(',');
                            }
                            first = false;
                            out.push_str(rawkey);
                            out.push(':');
                            emit_value(b, i, out)?;
                        }
                    }
                    _ => return Err(json_err("expected member or '}'", *i)),
                }
            }
            out.push('}');
            Ok(())
        }
        Some(b'[') => {
            *i += 1;
            out.push('[');
            let mut first = true;
            loop {
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b']') => {
                        *i += 1;
                        break;
                    }
                    Some(b',') => {
                        *i += 1;
                        continue;
                    }
                    Some(_) => {
                        if !first {
                            out.push(',');
                        }
                        first = false;
                        emit_value(b, i, out)?;
                    }
                    None => return Err(json_err("unterminated array", *i)),
                }
            }
            out.push(']');
            Ok(())
        }
        Some(b'"') => {
            out.push_str(raw_string(b, i)?);
            Ok(())
        }
        Some(_) => {
            // Number / true / false / null: copy the raw token.
            let start = *i;
            while *i < b.len()
                && !matches!(b[*i], b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r')
            {
                *i += 1;
            }
            if start == *i {
                return Err(json_err("expected value", *i));
            }
            out.push_str(
                std::str::from_utf8(&b[start..*i]).map_err(|_| json_err("non-utf8", start))?,
            );
            Ok(())
        }
        None => Err(json_err("unexpected end", *i)),
    }
}

/// Compare two already-normalized report documents; `None` when they
/// are byte-identical, otherwise a human-readable first-divergence
/// excerpt.
pub fn diff_reports(recorded: &str, replayed: &str) -> Option<String> {
    if recorded == replayed {
        return None;
    }
    let at = recorded
        .bytes()
        .zip(replayed.bytes())
        .position(|(a, b)| a != b)
        .unwrap_or_else(|| recorded.len().min(replayed.len()));
    let ctx = |s: &str| {
        let lo = at.saturating_sub(40);
        let hi = (at + 40).min(s.len());
        s.get(lo..hi).unwrap_or("<out of range>").to_string()
    };
    Some(format!(
        "reports diverge at byte {at}:\n  recorded: …{}…\n  replayed: …{}…",
        ctx(recorded),
        ctx(replayed)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizer_strips_volatile_keys_recursively() {
        let src = r#"{"schema":"x","elapsed_s":1.23,"nested":{"rounds":7,"keep":1},"list":[{"t_s":0.5,"name":"a"}]}"#;
        let n = normalize_report_json(src).unwrap();
        assert_eq!(n, r#"{"schema":"x","nested":{"keep":1},"list":[{"name":"a"}]}"#);
    }

    #[test]
    fn normalizer_is_whitespace_insensitive() {
        let a = normalize_report_json(r#"{"a": 1, "b": [1, 2]}"#).unwrap();
        let b = normalize_report_json(r#"{"a":1,"b":[1,2]}"#).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn normalizer_preserves_escaped_strings() {
        let src = r#"{"msg":"a \"quoted\" piece","attrs":{"error":"gone"}}"#;
        let n = normalize_report_json(src).unwrap();
        assert_eq!(n, r#"{"msg":"a \"quoted\" piece"}"#);
    }

    #[test]
    fn diff_names_first_divergence() {
        assert!(diff_reports("abc", "abc").is_none());
        let d = diff_reports("aXc", "aYc").unwrap();
        assert!(d.contains("byte 1"), "{d}");
    }
}
