//! Live worker telemetry: periodic counter snapshots + clock samples.
//!
//! Workers piggyback a `K_TELEMETRY` frame on their heartbeat cadence
//! carrying a *cumulative* snapshot of the process-global counters
//! ([`super::counters::global_snapshot`]) plus the worker's
//! run-relative send time. The coordinator-side [`TelemetryStore`]
//! differences successive snapshots into per-worker totals — so a
//! worker dying mid-run loses at most one beat interval of counts,
//! never its history — and feeds every (send time, receive time) pair
//! into a [`ClockSync`] so worker traces can be shifted onto the
//! coordinator clock when merging.

use std::collections::HashMap;

use crate::comm::wire::{Reader, Writer};
use crate::error::Result;

use super::clock::ClockSync;
use super::counters::{merge_values, GLOBAL_DEFS};

/// One telemetry frame: worker-local cumulative counters + a clock
/// sample.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySample {
    /// Sending worker's id.
    pub worker_id: u64,
    /// Monotone per-worker sequence number (stale frames are dropped).
    pub seq: u64,
    /// Seconds on the worker's run-relative clock at send time.
    pub t_mono_s: f64,
    /// Cumulative counter snapshot, aligned with
    /// [`super::counters::GLOBAL_DEFS`].
    pub counters: Vec<u64>,
}

impl TelemetrySample {
    /// Encode for the wire (`K_TELEMETRY` payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.worker_id);
        w.put_u64(self.seq);
        w.put_f64(self.t_mono_s);
        w.put_u64_slice(&self.counters);
        w.into_vec()
    }

    /// Decode a `K_TELEMETRY` payload.
    pub fn decode(buf: &[u8]) -> Result<TelemetrySample> {
        let mut r = Reader::new(buf);
        Ok(TelemetrySample {
            worker_id: r.get_u64()?,
            seq: r.get_u64()?,
            t_mono_s: r.get_f64()?,
            counters: r.get_u64_vec()?,
        })
    }
}

#[derive(Default)]
struct WorkerState {
    last: Vec<u64>,
    totals: Vec<u64>,
    sync: ClockSync,
    last_seq: Option<u64>,
}

/// Coordinator-side accumulator for worker telemetry.
#[derive(Default)]
pub struct TelemetryStore {
    frames: u64,
    workers: HashMap<u64, WorkerState>,
}

impl TelemetryStore {
    /// An empty store.
    pub fn new() -> TelemetryStore {
        TelemetryStore::default()
    }

    /// Fold in one received sample; `local_s` is the coordinator
    /// clock's receive time (the clock-sample pair). Stale or repeated
    /// sequence numbers are ignored. Counter totals accumulate
    /// *saturating deltas* of the cumulative snapshots, so a worker
    /// process restart (counters reset to near zero) contributes a
    /// zero delta instead of a huge negative one.
    pub fn ingest(&mut self, s: &TelemetrySample, local_s: f64) {
        let w = self.workers.entry(s.worker_id).or_default();
        if let Some(prev) = w.last_seq {
            if s.seq <= prev {
                return;
            }
        }
        w.last_seq = Some(s.seq);
        self.frames += 1;
        w.sync.add_sample(local_s, s.t_mono_s);
        if w.last.len() != s.counters.len() {
            w.last = vec![0; s.counters.len()];
            w.totals = vec![0; s.counters.len()];
        }
        for i in 0..s.counters.len() {
            w.totals[i] = w.totals[i].saturating_add(s.counters[i].saturating_sub(w.last[i]));
            w.last[i] = s.counters[i];
        }
    }

    /// Fold in a clock sample that did not arrive as a telemetry frame
    /// (e.g. the `t_mono_s` stamped on a `WorldDone`); improves the
    /// offset estimate without counting toward [`Self::frames`].
    pub fn clock_sample(&mut self, worker_id: u64, remote_s: f64, local_s: f64) {
        self.workers
            .entry(worker_id)
            .or_default()
            .sync
            .add_sample(local_s, remote_s);
    }

    /// Telemetry frames ingested (stale frames excluded).
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Workers heard from (frames or clock samples).
    pub fn workers(&self) -> u64 {
        self.workers.len() as u64
    }

    /// Estimated clock offset for a worker: remote time `t` maps to
    /// the local clock as `t + offset`. `None` before any sample.
    pub fn offset_s(&self, worker_id: u64) -> Option<f64> {
        self.workers.get(&worker_id).and_then(|w| w.sync.offset_s())
    }

    /// Counter totals summed across all workers, aligned with
    /// [`GLOBAL_DEFS`]. Zeros if no telemetry arrived.
    pub fn totals(&self) -> Vec<u64> {
        let mut out = vec![0u64; GLOBAL_DEFS.len()];
        for w in self.workers.values() {
            if w.totals.len() == out.len() {
                merge_values(&mut out, &w.totals, GLOBAL_DEFS);
            }
        }
        out
    }

    /// Condense into the summary that rides reports.
    pub fn summary(&self) -> TelemetrySummary {
        TelemetrySummary {
            frames: self.frames,
            workers: self.workers(),
            counters: self.totals(),
        }
    }
}

/// The report-facing condensation of a [`TelemetryStore`]: how many
/// frames arrived from how many workers, and the summed counter
/// totals (aligned with [`GLOBAL_DEFS`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetrySummary {
    /// Telemetry frames ingested.
    pub frames: u64,
    /// Distinct workers heard from.
    pub workers: u64,
    /// Summed counter totals, aligned with [`GLOBAL_DEFS`]; empty or
    /// zeros when no telemetry arrived.
    pub counters: Vec<u64>,
}

impl TelemetrySummary {
    /// True when no telemetry was collected at all.
    pub fn is_empty(&self) -> bool {
        self.frames == 0 && self.workers == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::counters::Ctr;

    fn sample(worker: u64, seq: u64, t: f64, counters: Vec<u64>) -> TelemetrySample {
        TelemetrySample { worker_id: worker, seq, t_mono_s: t, counters }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = sample(3, 17, 1.25, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(TelemetrySample::decode(&s.encode()).unwrap(), s);
    }

    #[test]
    fn deltas_merge_under_clock_skew() {
        // Two workers whose clocks started at different times relative
        // to the coordinator: worker 1 is 0.5 s behind (offset +0.5),
        // worker 2 is 2 s ahead (offset -2.0). Each sends cumulative
        // snapshots; totals must sum the deltas and offsets must land
        // within the smallest simulated latency.
        let n = GLOBAL_DEFS.len();
        let mut store = TelemetryStore::new();
        let offsets = [(1u64, 0.5), (2u64, -2.0)];
        for (w, off) in offsets {
            let beats = [(1.0, 0.010, 10u64), (2.0, 0.002, 25), (3.0, 0.040, 40)];
            for (seq, (t_remote, lat, count)) in beats.into_iter().enumerate() {
                let s = sample(w, seq as u64 + 1, t_remote, vec![count; n]);
                store.ingest(&s, t_remote + off + lat);
            }
        }
        // Each worker's cumulative snapshots end at 40 ⇒ totals 80.
        assert_eq!(store.totals(), vec![80; n]);
        assert_eq!(store.frames(), 6);
        for (w, off) in offsets {
            let est = store.offset_s(w).unwrap();
            assert!(
                (est - off).abs() <= 0.002 + 1e-9,
                "worker {w}: estimated {est}, true {off}"
            );
        }
    }

    #[test]
    fn stale_and_duplicate_frames_dropped() {
        let n = GLOBAL_DEFS.len();
        let mut store = TelemetryStore::new();
        store.ingest(&sample(1, 2, 1.0, vec![10; n]), 1.0);
        store.ingest(&sample(1, 2, 1.0, vec![10; n]), 1.1); // dup
        store.ingest(&sample(1, 1, 0.5, vec![4; n]), 1.2); // stale
        assert_eq!(store.frames(), 1);
        assert_eq!(store.totals(), vec![10; n]);
    }

    #[test]
    fn restart_resets_contribute_zero_delta() {
        let n = GLOBAL_DEFS.len();
        let mut store = TelemetryStore::new();
        store.ingest(&sample(1, 1, 1.0, vec![100; n]), 1.0);
        // Worker restarted: counters fell back to 3. Saturating delta
        // is 0, then growth resumes from the restart.
        store.ingest(&sample(1, 2, 2.0, vec![3; n]), 2.0);
        store.ingest(&sample(1, 3, 3.0, vec![8; n]), 3.0);
        assert_eq!(store.totals(), vec![105; n]);
    }

    #[test]
    fn summary_and_clock_fallback() {
        let mut store = TelemetryStore::new();
        assert!(store.summary().is_empty());
        store.clock_sample(7, 1.0, 1.5);
        assert_eq!(store.offset_s(7), Some(0.5));
        let sum = store.summary();
        assert_eq!(sum.frames, 0);
        assert_eq!(sum.workers, 1);
        assert!(!sum.is_empty());
        // Sanity: the Ctr indices line up with GLOBAL_DEFS length.
        assert!((Ctr::TelemetrySent as usize) < GLOBAL_DEFS.len());
    }
}
