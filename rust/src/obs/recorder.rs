//! The structured trace recorder: spans + instant events on one
//! run-relative clock, stored in sharded (lock-light) buffers.

use std::sync::Mutex;
use std::time::Instant;

use super::clock::Clock;

/// What a rank was doing during a span (the paper's Fig. 5 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Task computation (blue bars).
    Compute,
    /// Blocked waiting on a coupled task (red bars).
    Idle,
    /// Data transfer (orange bars).
    Transfer,
    /// Producer stalled waiting for flow-control credits (Sec. 3.6);
    /// a distinguished sub-kind of idle so backpressure is visible in
    /// the Gantt without reading counters.
    Stall,
}

impl SpanKind {
    /// The one-character Gantt cell for this kind.
    pub fn glyph(&self) -> char {
        match self {
            SpanKind::Compute => '#',
            SpanKind::Idle => '.',
            SpanKind::Transfer => '=',
            SpanKind::Stall => 'x',
        }
    }

    /// Lowercase kind name (CSV/JSON category).
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Idle => "idle",
            SpanKind::Transfer => "transfer",
            SpanKind::Stall => "stall",
        }
    }
}

/// One recorded span: what `rank` did from `start` to `end` (seconds
/// on the recorder's run-relative clock), with optional key=value
/// attributes (dataset names, byte counts, …) that ride into the
/// Chrome-trace `args`.
#[derive(Debug, Clone)]
pub struct Span {
    /// Track the span belongs to (global rank within a run).
    pub rank: usize,
    /// Span category.
    pub kind: SpanKind,
    /// Human-readable label (`serve outfile.h5`, `flow stall`, …).
    pub label: String,
    /// Seconds since recorder origin.
    pub start: f64,
    /// Seconds since recorder origin; always `>= start`.
    pub end: f64,
    /// Key=value attributes (empty for most spans).
    pub attrs: Vec<(String, String)>,
}

/// A point-in-time event (`WorkerLost`, `Requeue`, …) on one track.
#[derive(Debug, Clone)]
pub struct InstantEvent {
    /// Track the event belongs to.
    pub rank: usize,
    /// Event name.
    pub name: String,
    /// Seconds since recorder origin.
    pub t: f64,
    /// Key=value attributes.
    pub attrs: Vec<(String, String)>,
}

/// How many independently locked buffers a [`TraceRecorder`] shards
/// its events across. Threads hash to shards by thread id, so
/// concurrent ranks almost never contend on one mutex, and each
/// critical section is a single `Vec::push`.
const NSHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    spans: Vec<Span>,
    instants: Vec<InstantEvent>,
}

/// Thread-safe structured recorder: spans and instant events on one
/// run-relative [`Clock`], sharded per thread so recording from many
/// ranks is lock-light. [`crate::metrics::Recorder`] (Gantt/CSV) is a
/// view over this type.
pub struct TraceRecorder {
    clock: Clock,
    shards: Vec<Mutex<Shard>>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    /// A recorder whose clock origin is now.
    pub fn new() -> TraceRecorder {
        TraceRecorder {
            clock: Clock::new(),
            shards: (0..NSHARDS).map(|_| Mutex::new(Shard::default())).collect(),
        }
    }

    /// The recorder's run-relative clock.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    fn shard(&self) -> &Mutex<Shard> {
        // Hash the thread id into a shard. ThreadId has no stable
        // numeric accessor, so hash its Debug identity — stable for
        // the life of the thread, which is all sharding needs.
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        &self.shards[(h.finish() as usize) % NSHARDS]
    }

    /// Record a span from two instants on this recorder's clock.
    /// `t1 < t0` is clamped (never a negative duration), and instants
    /// before the clock origin saturate to 0.
    pub fn span(&self, rank: usize, kind: SpanKind, label: &str, t0: Instant, t1: Instant) {
        self.span_with(rank, kind, label, t0, t1, Vec::new());
    }

    /// [`TraceRecorder::span`] with key=value attributes.
    pub fn span_with(
        &self,
        rank: usize,
        kind: SpanKind,
        label: &str,
        t0: Instant,
        t1: Instant,
        attrs: Vec<(String, String)>,
    ) {
        let start = self.clock.since_origin(t0);
        let end = self.clock.since_origin(t1).max(start);
        self.shard().lock().unwrap().spans.push(Span {
            rank,
            kind,
            label: label.to_string(),
            start,
            end,
            attrs,
        });
    }

    /// Record a point-in-time event at "now".
    pub fn instant(&self, rank: usize, name: &str, attrs: Vec<(String, String)>) {
        let t = self.clock.now_s();
        self.shard().lock().unwrap().instants.push(InstantEvent {
            rank,
            name: name.to_string(),
            t,
            attrs,
        });
    }

    /// Snapshot every span recorded so far. Within one recording
    /// thread, order is preserved; across threads, order follows shard
    /// order (callers sort by time when they need a global order).
    pub fn spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().unwrap().spans.iter().cloned());
        }
        out
    }

    /// Snapshot every instant event recorded so far.
    pub fn instants(&self) -> Vec<InstantEvent> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.lock().unwrap().instants.iter().cloned());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn spans_are_clamped_monotonic() {
        let rec = TraceRecorder::new();
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(5);
        rec.span(0, SpanKind::Compute, "fwd", t0, t1);
        // Reversed instants clamp to a zero-length span, never a
        // negative one.
        rec.span(0, SpanKind::Idle, "rev", t1, t0);
        let spans = rec.spans();
        assert_eq!(spans.len(), 2);
        for s in &spans {
            assert!(s.end >= s.start, "span {} runs backwards", s.label);
            assert!(s.start >= 0.0);
        }
    }

    #[test]
    fn nested_spans_preserve_containment() {
        let rec = TraceRecorder::new();
        let outer0 = Instant::now();
        let inner0 = outer0 + Duration::from_millis(2);
        let inner1 = outer0 + Duration::from_millis(6);
        let outer1 = outer0 + Duration::from_millis(10);
        rec.span(3, SpanKind::Transfer, "inner", inner0, inner1);
        rec.span(3, SpanKind::Compute, "outer", outer0, outer1);
        let spans = rec.spans();
        let outer = spans.iter().find(|s| s.label == "outer").unwrap();
        let inner = spans.iter().find(|s| s.label == "inner").unwrap();
        // Proper nesting: inner fully inside outer on the shared clock.
        assert!(outer.start <= inner.start && inner.end <= outer.end);
    }

    #[test]
    fn attrs_and_instants_survive() {
        let rec = TraceRecorder::new();
        let t0 = Instant::now();
        rec.span_with(
            1,
            SpanKind::Transfer,
            "serve x.h5",
            t0,
            t0,
            vec![("bytes".into(), "4096".into())],
        );
        rec.instant(0, "WorkerLost", vec![("worker".into(), "2".into())]);
        let spans = rec.spans();
        assert_eq!(spans[0].attrs[0], ("bytes".into(), "4096".into()));
        let evs = rec.instants();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "WorkerLost");
        assert!(evs[0].t >= 0.0);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let rec = std::sync::Arc::new(TraceRecorder::new());
        let mut joins = Vec::new();
        for r in 0..8usize {
            let rec = std::sync::Arc::clone(&rec);
            joins.push(std::thread::spawn(move || {
                for i in 0..100 {
                    let t = Instant::now();
                    rec.span(r, SpanKind::Compute, &format!("s{i}"), t, t);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let spans = rec.spans();
        assert_eq!(spans.len(), 800);
        // Per-thread order is preserved (one thread = one shard): each
        // rank's spans appear in the order that thread recorded them.
        for r in 0..8usize {
            let labels: Vec<&str> = spans
                .iter()
                .filter(|s| s.rank == r)
                .map(|s| s.label.as_str())
                .collect();
            let expect: Vec<String> = (0..100).map(|i| format!("s{i}")).collect();
            assert_eq!(labels, expect.iter().map(String::as_str).collect::<Vec<_>>());
        }
    }
}
