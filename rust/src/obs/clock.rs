//! Run-relative monotonic time and cross-process clock alignment.

use std::time::Instant;

/// A run-relative monotonic clock: every span, instant event and
/// telemetry sample in one process is stamped in seconds since this
/// clock's origin. Monotonic by construction (backed by [`Instant`]),
/// so spans can never run backwards no matter what the wall clock
/// does.
#[derive(Debug, Clone, Copy)]
pub struct Clock {
    origin: Instant,
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::new()
    }
}

impl Clock {
    /// A clock whose origin is now.
    pub fn new() -> Clock {
        Clock { origin: Instant::now() }
    }

    /// Seconds since the clock origin.
    pub fn now_s(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// The origin instant (so other timestamp sources — e.g. a
    /// [`super::TraceRecorder`] created later in the same process —
    /// can be rebased onto this clock exactly).
    pub fn origin(&self) -> Instant {
        self.origin
    }

    /// Map an [`Instant`] onto this clock (saturating at 0 for
    /// instants before the origin).
    pub fn since_origin(&self, t: Instant) -> f64 {
        t.saturating_duration_since(self.origin).as_secs_f64()
    }
}

/// Estimates the offset between a remote process's run-relative clock
/// and the local one from (local receive time, remote send time)
/// sample pairs.
///
/// Every sample satisfies `local = remote + offset + latency` with
/// `latency >= 0`, so the *minimum* of `local - remote` over all
/// samples is the tightest upper bound on the true offset — the
/// classic min-latency estimator (the sample that crossed the wire
/// fastest is the most honest one). A remote timestamp `t` maps to
/// local time as `t + offset_s()`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClockSync {
    best: Option<f64>,
    samples: u64,
}

impl ClockSync {
    /// An estimator with no samples yet.
    pub fn new() -> ClockSync {
        ClockSync::default()
    }

    /// Fold in one (local receive, remote send) pair, both in seconds
    /// on their respective run-relative clocks.
    pub fn add_sample(&mut self, local_s: f64, remote_s: f64) {
        let d = local_s - remote_s;
        self.best = Some(match self.best {
            Some(b) => b.min(d),
            None => d,
        });
        self.samples += 1;
    }

    /// The current offset estimate (`None` before the first sample).
    pub fn offset_s(&self) -> Option<f64> {
        self.best
    }

    /// Sample pairs folded in so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let c = Clock::new();
        let a = c.now_s();
        let b = c.now_s();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn since_origin_saturates() {
        let before = Instant::now();
        let c = Clock::new();
        assert_eq!(c.since_origin(before), 0.0);
        assert!(c.since_origin(Instant::now()) >= 0.0);
    }

    #[test]
    fn min_latency_offset_estimation() {
        // Remote clock started 2.5 s before ours (offset = -2.5) and
        // samples arrive with varying latency; the estimator must pick
        // the lowest-latency sample.
        let true_offset = -2.5;
        let mut sync = ClockSync::new();
        for (remote_s, latency) in [(1.0, 0.050), (2.0, 0.003), (3.0, 0.120)] {
            let local_s = remote_s + true_offset + latency;
            sync.add_sample(local_s, remote_s);
        }
        let est = sync.offset_s().unwrap();
        assert!((est - (true_offset + 0.003)).abs() < 1e-12);
        assert_eq!(sync.samples(), 3);
    }

    #[test]
    fn no_samples_no_offset() {
        assert_eq!(ClockSync::new().offset_s(), None);
    }
}
