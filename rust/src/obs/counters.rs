//! Declarative counter registry + process-global live counters.
//!
//! A counter family (`VolStats`, `FaultStats`, the wire-level globals)
//! declares its counters **once** as a `&'static [CounterDef]` table.
//! Everything downstream — cross-rank merging, wire encoding, JSON
//! export, telemetry snapshots — iterates the table instead of
//! hand-plumbing each field, so adding a counter is a one-line table
//! edit plus the field itself.

use std::sync::atomic::{AtomicU64, Ordering};

/// How a counter combines across ranks/processes of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Merge {
    /// Values add (byte totals, event counts).
    Sum,
    /// The largest value wins (per-rank rounds, wait times, depths —
    /// families where SPMD ranks each see the whole story and summing
    /// would double-count).
    Max,
}

/// One registered counter: its wire/JSON name and merge semantics.
/// Table *order* is the wire order — append only.
#[derive(Debug, Clone, Copy)]
pub struct CounterDef {
    /// Stable snake_case name used on the wire, in JSON and in docs.
    pub name: &'static str,
    /// How values from different ranks combine.
    pub merge: Merge,
}

impl CounterDef {
    /// A summed counter.
    pub const fn sum(name: &'static str) -> CounterDef {
        CounterDef { name, merge: Merge::Sum }
    }

    /// A max-merged counter.
    pub const fn max(name: &'static str) -> CounterDef {
        CounterDef { name, merge: Merge::Max }
    }
}

/// Merge `from` into `into` element-wise per the family's defs.
/// Lengths must equal the table length (callers encode/decode through
/// the same table, so a mismatch is a bug).
pub fn merge_values(into: &mut [u64], from: &[u64], defs: &[CounterDef]) {
    assert_eq!(into.len(), defs.len(), "counter value/def length mismatch");
    assert_eq!(from.len(), defs.len(), "counter value/def length mismatch");
    for (i, d) in defs.iter().enumerate() {
        into[i] = match d.merge {
            Merge::Sum => into[i].saturating_add(from[i]),
            Merge::Max => into[i].max(from[i]),
        };
    }
}

/// Process-global live counters: cheap relaxed atomics bumped on the
/// hot wire path and snapshotted into every telemetry frame. These are
/// *cumulative* — the coordinator's `TelemetryStore` differences
/// successive snapshots, so a worker dying between beats loses at most
/// one interval, never its history.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Ctr {
    /// Frames written to any socket link.
    FramesSent,
    /// Frames read from any socket link.
    FramesRecv,
    /// Payload + header bytes written to sockets.
    BytesSentWire,
    /// Payload + header bytes read from sockets.
    BytesRecvWire,
    /// Heartbeat frames sent by this process's beat threads.
    HeartbeatsSent,
    /// Telemetry frames sent by this process's beat threads.
    TelemetrySent,
    /// Times the transport I/O thread's poller returned (readiness,
    /// timer deadline, or wake pipe). The per-frame wakeup tax the
    /// event-driven core is meant to shrink — watch it against
    /// `frames_recv`.
    PollerWakeups,
    /// Small frames appended to an already-nonempty staging buffer:
    /// each one is a `write` syscall the coalescing send path avoided.
    FramesCoalesced,
    /// Payload bytes delivered through the shared-memory plane instead
    /// of the socket mesh (the bytes the kernel never had to copy).
    BytesShm,
    /// Shared-memory segments created by this process's `ShmPool` —
    /// steady-state runs recycle a handful; a climbing count means acks
    /// are not coming back.
    ShmSegments,
    /// Large payloads that wanted the shm plane but fell back to the
    /// inline socket path (pool exhausted, segment creation failed).
    ShmFallbacks,
}

/// Registry for the [`Ctr`] family, in `Ctr` discriminant order.
pub const GLOBAL_DEFS: &[CounterDef] = &[
    CounterDef::sum("frames_sent"),
    CounterDef::sum("frames_recv"),
    CounterDef::sum("bytes_sent_wire"),
    CounterDef::sum("bytes_recv_wire"),
    CounterDef::sum("heartbeats_sent"),
    CounterDef::sum("telemetry_sent"),
    CounterDef::sum("poller_wakeups"),
    CounterDef::sum("frames_coalesced"),
    CounterDef::sum("bytes_shm"),
    CounterDef::sum("shm_segments"),
    CounterDef::sum("shm_fallbacks"),
];

const NGLOBAL: usize = GLOBAL_DEFS.len();

static GLOBALS: [AtomicU64; NGLOBAL] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

impl Ctr {
    /// Add `n` to this counter (relaxed; ordering never matters for
    /// monotonic telemetry counts).
    #[inline]
    pub fn bump(self, n: u64) {
        GLOBALS[self as usize].fetch_add(n, Ordering::Relaxed);
    }

    /// Current value of this counter.
    pub fn get(self) -> u64 {
        GLOBALS[self as usize].load(Ordering::Relaxed)
    }
}

/// Snapshot every global counter, aligned with [`GLOBAL_DEFS`].
pub fn global_snapshot() -> Vec<u64> {
    GLOBALS.iter().map(|c| c.load(Ordering::Relaxed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_respects_semantics() {
        let defs = &[CounterDef::sum("a"), CounterDef::max("b")];
        let mut into = vec![3, 7];
        merge_values(&mut into, &[5, 4], defs);
        assert_eq!(into, vec![8, 7]);
        merge_values(&mut into, &[0, 9], defs);
        assert_eq!(into, vec![8, 9]);
    }

    #[test]
    fn sum_saturates() {
        let defs = &[CounterDef::sum("a")];
        let mut into = vec![u64::MAX - 1];
        merge_values(&mut into, &[5], defs);
        assert_eq!(into, vec![u64::MAX]);
    }

    #[test]
    fn globals_bump_and_snapshot() {
        let before = Ctr::HeartbeatsSent.get();
        Ctr::HeartbeatsSent.bump(3);
        assert_eq!(Ctr::HeartbeatsSent.get(), before + 3);
        let snap = global_snapshot();
        assert_eq!(snap.len(), GLOBAL_DEFS.len());
        assert_eq!(snap[Ctr::HeartbeatsSent as usize], before + 3);
    }
}
