//! A tiny JSON writer — just enough for the Chrome-trace exporter and
//! the machine-readable run reports (this repo is dependency-free by
//! policy, so no serde).
//!
//! The builders are push-based: [`Obj`] and [`Arr`] accumulate into a
//! `String` and `finish()` returns it. Nesting is by composing the
//! finished strings with [`Obj::field_raw`] / [`Arr::push_raw`].

/// Escape a string for use inside JSON quotes (the output does *not*
/// include the surrounding quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format an `f64` as a JSON number. JSON has no Infinity/NaN, so
/// non-finite values become `0` (they only arise from bugs; a parseable
/// report beats a crash in the exporter).
pub fn num_f64(v: f64) -> String {
    if v.is_finite() {
        // Rust's Display for f64 is shortest-round-trip and always a
        // valid JSON number for finite values.
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// A JSON object builder.
#[derive(Default)]
pub struct Obj {
    buf: String,
}

impl Obj {
    /// An empty object.
    pub fn new() -> Obj {
        Obj::default()
    }

    fn key(&mut self, name: &str) {
        if !self.buf.is_empty() {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(&escape(name));
        self.buf.push_str("\":");
    }

    /// Add a string field.
    pub fn field_str(&mut self, name: &str, v: &str) -> &mut Obj {
        self.key(name);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Add an unsigned integer field.
    pub fn field_u64(&mut self, name: &str, v: u64) -> &mut Obj {
        self.key(name);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a signed integer field.
    pub fn field_i64(&mut self, name: &str, v: i64) -> &mut Obj {
        self.key(name);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a floating-point field (non-finite values become `0`).
    pub fn field_f64(&mut self, name: &str, v: f64) -> &mut Obj {
        self.key(name);
        self.buf.push_str(&num_f64(v));
        self
    }

    /// Add a pre-serialized JSON value (nested object/array).
    pub fn field_raw(&mut self, name: &str, json: &str) -> &mut Obj {
        self.key(name);
        self.buf.push_str(json);
        self
    }

    /// Serialize: `{...}`.
    pub fn finish(&self) -> String {
        format!("{{{}}}", self.buf)
    }
}

/// A JSON array builder.
#[derive(Default)]
pub struct Arr {
    buf: String,
}

impl Arr {
    /// An empty array.
    pub fn new() -> Arr {
        Arr::default()
    }

    fn sep(&mut self) {
        if !self.buf.is_empty() {
            self.buf.push(',');
        }
    }

    /// Append a pre-serialized JSON value.
    pub fn push_raw(&mut self, json: &str) -> &mut Arr {
        self.sep();
        self.buf.push_str(json);
        self
    }

    /// Append a string value.
    pub fn push_str(&mut self, v: &str) -> &mut Arr {
        self.sep();
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Append an unsigned integer value.
    pub fn push_u64(&mut self, v: u64) -> &mut Arr {
        self.sep();
        self.buf.push_str(&v.to_string());
        self
    }

    /// Serialize: `[...]`.
    pub fn finish(&self) -> String {
        format!("[{}]", self.buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn builds_nested() {
        let mut inner = Arr::new();
        inner.push_u64(1).push_str("x");
        let mut o = Obj::new();
        o.field_str("name", "run")
            .field_f64("t", 1.5)
            .field_raw("items", &inner.finish());
        assert_eq!(o.finish(), r#"{"name":"run","t":1.5,"items":[1,"x"]}"#);
    }

    #[test]
    fn nonfinite_becomes_zero() {
        assert_eq!(num_f64(f64::NAN), "0");
        assert_eq!(num_f64(f64::INFINITY), "0");
        assert_eq!(num_f64(2.25), "2.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Obj::new().finish(), "{}");
        assert_eq!(Arr::new().finish(), "[]");
    }
}
