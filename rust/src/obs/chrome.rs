//! Chrome-trace (Trace Event Format) exporter: the `--trace out.json`
//! artifact, loadable in `chrome://tracing` or Perfetto.
//!
//! The mapping is: one *process* (`pid`) per worker (or per ensemble
//! instance), one *thread* (`tid`) per global rank, `ph:"X"` complete
//! events for spans, `ph:"i"` instants for scheduler events such as
//! `WorkerLost`, and `ph:"s"`/`ph:"f"` flow arrows pairing a
//! cross-worker `serve <dataset>` with the `open <dataset>` it fed.
//! Timestamps are microseconds on the coordinator's run-relative
//! clock; worker spans are shifted by the telemetry clock offset
//! before they get here.

use super::json::{Arr, Obj};
use super::recorder::Span;

/// One exported trace event (structural form, so tests can assert on
/// events without parsing JSON).
#[derive(Debug, Clone)]
pub struct ChromeEvent {
    /// Event phase: `X` span, `i` instant, `s`/`f` flow, `M` metadata.
    pub ph: char,
    /// Event name.
    pub name: String,
    /// Category (span kind, `flow`, …).
    pub cat: String,
    /// Process track (worker / instance).
    pub pid: u64,
    /// Thread track (global rank).
    pub tid: u64,
    /// Microseconds since the run origin.
    pub ts_us: i64,
    /// Duration in microseconds (`X` events only; never negative).
    pub dur_us: Option<u64>,
    /// Flow id (`s`/`f` events only).
    pub flow_id: Option<u64>,
    /// Key=value args.
    pub args: Vec<(String, String)>,
}

fn us(t_s: f64) -> i64 {
    (t_s * 1e6).round() as i64
}

/// Builder for one merged Chrome-trace JSON document.
#[derive(Default)]
pub struct ChromeTrace {
    events: Vec<ChromeEvent>,
    next_flow: u64,
}

impl ChromeTrace {
    /// An empty trace.
    pub fn new() -> ChromeTrace {
        ChromeTrace::default()
    }

    /// Name a process track (`ph:"M"` `process_name` metadata).
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.events.push(ChromeEvent {
            ph: 'M',
            name: "process_name".into(),
            cat: String::new(),
            pid,
            tid: 0,
            ts_us: 0,
            dur_us: None,
            flow_id: None,
            args: vec![("name".into(), name.into())],
        });
    }

    /// Name a thread track (`ph:"M"` `thread_name` metadata).
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        self.events.push(ChromeEvent {
            ph: 'M',
            name: "thread_name".into(),
            cat: String::new(),
            pid,
            tid,
            ts_us: 0,
            dur_us: None,
            flow_id: None,
            args: vec![("name".into(), name.into())],
        });
    }

    /// Add a complete (`ph:"X"`) event on the `(pid, tid)` track.
    /// `t1_s < t0_s` clamps to a zero-duration event — the exporter
    /// never emits negative `dur`.
    pub fn span(
        &mut self,
        track: (u64, u64),
        name: &str,
        cat: &str,
        t0_s: f64,
        t1_s: f64,
        args: &[(String, String)],
    ) {
        let t0 = us(t0_s);
        let t1 = us(t1_s).max(t0);
        self.events.push(ChromeEvent {
            ph: 'X',
            name: name.into(),
            cat: cat.into(),
            pid: track.0,
            tid: track.1,
            ts_us: t0,
            dur_us: Some((t1 - t0) as u64),
            flow_id: None,
            args: args.to_vec(),
        });
    }

    /// Add a [`Span`] on the given process track, shifted by
    /// `offset_s` (the span's clock → coordinator clock).
    pub fn add_span(&mut self, pid: u64, span: &Span, offset_s: f64) {
        self.span(
            (pid, span.rank as u64),
            &span.label,
            span.kind.name(),
            span.start + offset_s,
            span.end + offset_s,
            &span.attrs,
        );
    }

    /// Add an instant (`ph:"i"`, global scope) event.
    pub fn instant(&mut self, pid: u64, tid: u64, name: &str, t_s: f64, args: &[(String, String)]) {
        self.events.push(ChromeEvent {
            ph: 'i',
            name: name.into(),
            cat: "event".into(),
            pid,
            tid,
            ts_us: us(t_s),
            dur_us: None,
            flow_id: None,
            args: args.to_vec(),
        });
    }

    /// Add a flow arrow from `(src_pid, src_tid, src_ts_s)` to
    /// `(dst_pid, dst_tid, dst_ts_s)` named `name`.
    pub fn flow(
        &mut self,
        name: &str,
        src: (u64, u64, f64),
        dst: (u64, u64, f64),
    ) {
        let id = self.next_flow;
        self.next_flow += 1;
        for (ph, (pid, tid, ts)) in [('s', src), ('f', dst)] {
            self.events.push(ChromeEvent {
                ph,
                name: name.into(),
                cat: "flow".into(),
                pid,
                tid,
                ts_us: us(ts),
                dur_us: None,
                flow_id: Some(id),
                args: Vec::new(),
            });
        }
    }

    /// The events added so far (tests assert on these instead of
    /// re-parsing the JSON).
    pub fn events(&self) -> &[ChromeEvent] {
        &self.events
    }

    /// Serialize to Trace Event Format JSON (object form, so Perfetto
    /// metadata like `displayTimeUnit` can ride along).
    pub fn to_json(&self) -> String {
        let mut arr = Arr::new();
        for e in &self.events {
            let mut o = Obj::new();
            o.field_str("ph", &e.ph.to_string()).field_str("name", &e.name);
            if !e.cat.is_empty() {
                o.field_str("cat", &e.cat);
            }
            o.field_u64("pid", e.pid).field_u64("tid", e.tid);
            if e.ph != 'M' {
                o.field_i64("ts", e.ts_us);
            }
            if let Some(d) = e.dur_us {
                o.field_u64("dur", d);
            }
            if let Some(id) = e.flow_id {
                o.field_u64("id", id);
                if e.ph == 'f' {
                    // Bind the arrow head to the enclosing slice.
                    o.field_str("bp", "e");
                }
            }
            if e.ph == 'i' {
                o.field_str("s", "g");
            }
            if !e.args.is_empty() {
                let mut args = Obj::new();
                for (k, v) in &e.args {
                    args.field_str(k, v);
                }
                o.field_raw("args", &args.finish());
            }
            arr.push_raw(&o.finish());
        }
        let mut doc = Obj::new();
        doc.field_raw("traceEvents", &arr.finish())
            .field_str("displayTimeUnit", "ms");
        doc.finish()
    }
}

/// Pair `serve <dataset>` transfer spans with the `open <dataset>`
/// spans they fed and draw a flow arrow for each cross-process pair.
///
/// Spans arrive as `(pid, span, offset_s)` across all tracks. For each
/// dataset name, the k-th serve (by adjusted start time) pairs with
/// the k-th open — serve rounds and opens are both ordered by timestep
/// per dataset, so ordinal pairing reconstructs the coupling without
/// any extra wire state. Same-pid pairs are skipped (arrows are for
/// *cross-worker* serves; local ones share a track already).
pub fn add_serve_open_flows(trace: &mut ChromeTrace, spans: &[(u64, &Span, f64)]) {
    use std::collections::BTreeMap;
    // dataset -> (serves, opens), each (pid, tid, adjusted t, end t)
    type Ends = (Vec<(u64, u64, f64, f64)>, Vec<(u64, u64, f64, f64)>);
    let mut by_ds: BTreeMap<&str, Ends> = BTreeMap::new();
    for (pid, s, off) in spans {
        if let Some(name) = s.label.strip_prefix("serve ") {
            by_ds.entry(name).or_default().0.push((
                *pid,
                s.rank as u64,
                s.start + off,
                s.end + off,
            ));
        } else if let Some(name) = s.label.strip_prefix("open ") {
            by_ds.entry(name).or_default().1.push((
                *pid,
                s.rank as u64,
                s.start + off,
                s.end + off,
            ));
        }
    }
    for (name, (mut serves, mut opens)) in by_ds {
        serves.sort_by(|a, b| a.2.total_cmp(&b.2));
        opens.sort_by(|a, b| a.2.total_cmp(&b.2));
        for (srv, opn) in serves.iter().zip(opens.iter()) {
            if srv.0 == opn.0 {
                continue;
            }
            // Arrow tail inside the serve span, head at the open's end
            // (when the data actually landed).
            trace.flow(name, (srv.0, srv.1, srv.2), (opn.0, opn.1, opn.3));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;

    fn span(rank: usize, label: &str, start: f64, end: f64) -> Span {
        Span {
            rank,
            kind: SpanKind::Transfer,
            label: label.into(),
            start,
            end,
            attrs: Vec::new(),
        }
    }

    #[test]
    fn spans_never_negative_duration() {
        let mut t = ChromeTrace::new();
        t.span((0, 0), "x", "compute", 2.0, 1.0, &[]);
        let e = &t.events()[0];
        assert_eq!(e.dur_us, Some(0));
        assert!(!t.to_json().contains("\"dur\":-"));
    }

    #[test]
    fn json_has_tracks_and_metadata() {
        let mut t = ChromeTrace::new();
        t.process_name(1, "worker 1");
        t.thread_name(1, 0, "rank 0");
        t.span((1, 0), "fwd", "compute", 0.0, 0.5, &[("k".into(), "v".into())]);
        t.instant(1, 0, "WorkerLost", 0.25, &[]);
        let j = t.to_json();
        assert!(j.starts_with("{\"traceEvents\":["));
        assert!(j.contains("\"process_name\""));
        assert!(j.contains("\"worker 1\""));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"dur\":500000"));
        assert!(j.contains("\"WorkerLost\""));
        assert!(j.contains("\"args\":{\"k\":\"v\"}"));
    }

    #[test]
    fn flow_pairs_cross_pid_serve_open() {
        let s0 = span(0, "serve grid.h5", 1.0, 1.2);
        let s1 = span(0, "serve grid.h5", 2.0, 2.2);
        let o0 = span(3, "open grid.h5", 1.1, 1.3);
        let o1 = span(3, "open grid.h5", 2.1, 2.3);
        let local = span(1, "serve loc.h5", 0.5, 0.6);
        let lopen = span(1, "open loc.h5", 0.55, 0.65);
        let mut t = ChromeTrace::new();
        let spans: Vec<(u64, &Span, f64)> = vec![
            (0, &s0, 0.0),
            (0, &s1, 0.0),
            (1, &o0, 0.0),
            (1, &o1, 0.0),
            (2, &local, 0.0),
            (2, &lopen, 0.0),
        ];
        add_serve_open_flows(&mut t, &spans);
        let flows: Vec<_> = t.events().iter().filter(|e| e.ph == 's').collect();
        // Two cross-pid pairs for grid.h5; loc.h5 pair shares pid 2.
        assert_eq!(flows.len(), 2);
        let heads: Vec<_> = t.events().iter().filter(|e| e.ph == 'f').collect();
        assert_eq!(heads.len(), 2);
        assert_eq!(flows[0].flow_id, heads[0].flow_id);
        assert!(t.to_json().contains("\"bp\":\"e\""));
    }

    #[test]
    fn add_span_applies_offset() {
        let s = span(2, "serve a", 1.0, 2.0);
        let mut t = ChromeTrace::new();
        t.add_span(7, &s, 0.5);
        let e = &t.events()[0];
        assert_eq!((e.pid, e.tid, e.ts_us, e.dur_us), (7, 2, 1_500_000, Some(1_000_000)));
        assert_eq!(e.cat, "transfer");
    }
}
