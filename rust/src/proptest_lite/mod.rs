//! Property-testing mini-framework (S16): the offline toolchain has no
//! proptest, so invariants are swept with a deterministic xorshift RNG
//! over many seeded cases. On failure the panic message names the
//! failing case index so it can be replayed exactly.

/// Deterministic xorshift64* generator.
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }

    /// Random dims: `nd` dimensions each in [1, max_extent].
    pub fn dims(&mut self, nd: usize, max_extent: u64) -> Vec<u64> {
        (0..nd).map(|_| self.range(1, max_extent + 1)).collect()
    }

    /// Random hyperslab inside `dims`.
    pub fn slab_within(&mut self, dims: &[u64]) -> crate::lowfive::Hyperslab {
        let mut offset = Vec::with_capacity(dims.len());
        let mut count = Vec::with_capacity(dims.len());
        for &d in dims {
            let o = self.range(0, d);
            let c = self.range(1, d - o + 1);
            offset.push(o);
            count.push(c);
        }
        crate::lowfive::Hyperslab::new(&offset, &count)
    }
}

/// Run `f` over `cases` deterministic seeds; name the failing case.
pub fn run_prop(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(case);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} FAILED at case {case} (replay with Rng::new({case}))");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_stays_in_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.range(5, 10);
            assert!((5..10).contains(&v));
        }
    }

    #[test]
    fn slab_fits() {
        let mut r = Rng::new(3);
        for _ in 0..200 {
            let dims = r.dims(3, 20);
            let s = r.slab_within(&dims);
            assert!(s.fits_within(&dims));
            assert!(!s.is_empty());
        }
    }

    #[test]
    #[should_panic]
    fn failing_prop_reports() {
        run_prop("always-fails", 3, |_| panic!("boom"));
    }
}
