//! Data-centric workflow graph construction (S3, paper Sec. 3.2).
//!
//! Users never list dependencies: Wilkins matches producer outports to
//! consumer inports by filename/dataset (glob-aware), expands
//! `taskCount` ensembles, links instance pairs round-robin (Fig. 3),
//! and classifies the resulting topology. Any directed graph is
//! accepted, including cycles.

mod topology;

pub use topology::Topology;

use crate::config::{PortConfig, TaskConfig, WorkflowConfig};
use crate::error::{Result, WilkinsError};
use crate::flow::ChannelPolicy;
use crate::lowfive::{pattern_matches, ChannelMode};

/// One runnable task instance (ensemble member).
#[derive(Debug, Clone)]
pub struct TaskInstance {
    /// Index into `WorkflowConfig::tasks`.
    pub task_idx: usize,
    /// Ensemble instance number (0-based).
    pub instance: usize,
    /// Display name: `func` or `func[i]` for ensembles.
    pub name: String,
    /// First global rank of this instance's contiguous rank range.
    pub first_rank: usize,
    pub nprocs: usize,
    pub nwriters: usize,
}

impl TaskInstance {
    pub fn ranks(&self) -> std::ops::Range<usize> {
        self.first_rank..self.first_rank + self.nprocs
    }

    /// Global ranks of the I/O (writer) subset.
    pub fn io_ranks(&self) -> std::ops::Range<usize> {
        self.first_rank..self.first_rank + self.nwriters
    }
}

/// A matched producer→consumer communication channel.
#[derive(Debug, Clone)]
pub struct ChannelSpec {
    /// Node indices into `WorkflowGraph::nodes`.
    pub producer: usize,
    pub consumer: usize,
    /// Producer-side filename pattern (what file closes serve on).
    pub out_pattern: String,
    /// Consumer-side filename pattern (what opens request).
    pub in_pattern: String,
    /// Matched dataset name patterns.
    pub dsets: Vec<String>,
    pub mode: ChannelMode,
    /// Flow-control policy of this link (consumer-side `flow:` key or
    /// its `io_freq` sugar, lowered).
    pub flow: ChannelPolicy,
}

/// The expanded workflow graph.
#[derive(Debug, Clone)]
pub struct WorkflowGraph {
    pub nodes: Vec<TaskInstance>,
    pub channels: Vec<ChannelSpec>,
    pub total_ranks: usize,
}

impl WorkflowGraph {
    /// Build the graph from a validated config.
    pub fn build(cfg: &WorkflowConfig) -> Result<WorkflowGraph> {
        // 1. Expand ensembles into instances with contiguous ranks.
        let mut nodes = Vec::new();
        let mut next_rank = 0;
        for (task_idx, t) in cfg.tasks.iter().enumerate() {
            for instance in 0..t.task_count {
                let name = if t.task_count == 1 {
                    t.func.clone()
                } else {
                    format!("{}[{}]", t.func, instance)
                };
                nodes.push(TaskInstance {
                    task_idx,
                    instance,
                    name,
                    first_rank: next_rank,
                    nprocs: t.nprocs,
                    nwriters: t.writers(),
                });
                next_rank += t.nprocs;
            }
        }

        // 2. Task-level port matching.
        let mut channels = Vec::new();
        for (pi, pt) in cfg.tasks.iter().enumerate() {
            for (ci, ct) in cfg.tasks.iter().enumerate() {
                for op in &pt.outports {
                    for ip in &ct.inports {
                        if let Some(link) = match_ports(pt, pi, op, ct, ci, ip)? {
                            // 3. Round-robin ensemble linking (Fig. 3).
                            let pn = pt.task_count;
                            let cn = ct.task_count;
                            for k in 0..pn.max(cn) {
                                let pnode = node_index(cfg, pi, k % pn);
                                let cnode = node_index(cfg, ci, k % cn);
                                channels.push(ChannelSpec {
                                    producer: pnode,
                                    consumer: cnode,
                                    out_pattern: link.out_pattern.clone(),
                                    in_pattern: link.in_pattern.clone(),
                                    dsets: link.dsets.clone(),
                                    mode: link.mode,
                                    flow: link.flow,
                                });
                            }
                        }
                    }
                }
            }
        }

        // 4. Every inport must have at least one producer.
        for (ci, ct) in cfg.tasks.iter().enumerate() {
            for ip in &ct.inports {
                let fed = channels.iter().any(|ch| {
                    nodes[ch.consumer].task_idx == ci && ch.in_pattern == ip.filename
                });
                if !fed {
                    return Err(WilkinsError::Graph(format!(
                        "inport {} of task {} matches no producer outport",
                        ip.filename, ct.func
                    )));
                }
            }
        }

        Ok(WorkflowGraph { nodes, channels, total_ranks: next_rank })
    }

    /// Which node owns a global rank?
    pub fn node_of_rank(&self, rank: usize) -> Option<usize> {
        self.nodes.iter().position(|n| n.ranks().contains(&rank))
    }

    /// Channels where `node` is the producer.
    pub fn out_channels_of(&self, node: usize) -> Vec<usize> {
        (0..self.channels.len())
            .filter(|&i| self.channels[i].producer == node)
            .collect()
    }

    /// Channels where `node` is the consumer.
    pub fn in_channels_of(&self, node: usize) -> Vec<usize> {
        (0..self.channels.len())
            .filter(|&i| self.channels[i].consumer == node)
            .collect()
    }

    /// Classify the instance-level topology (reporting / tests).
    pub fn topology(&self) -> Topology {
        topology::classify(self)
    }

    /// Human-readable summary (CLI `graph` command).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "workflow: {} task instances, {} channels, {} ranks, topology {:?}\n",
            self.nodes.len(),
            self.channels.len(),
            self.total_ranks,
            self.topology()
        ));
        for n in &self.nodes {
            s.push_str(&format!(
                "  node {:<24} ranks {}..{} (writers {})\n",
                n.name,
                n.first_rank,
                n.first_rank + n.nprocs,
                n.nwriters
            ));
        }
        for c in &self.channels {
            s.push_str(&format!(
                "  channel {} -> {}  file {}  dsets {:?}  {:?}  flow {}\n",
                self.nodes[c.producer].name,
                self.nodes[c.consumer].name,
                c.in_pattern,
                c.dsets,
                c.mode,
                c.flow
            ));
        }
        s
    }
}

struct Link {
    out_pattern: String,
    in_pattern: String,
    dsets: Vec<String>,
    mode: ChannelMode,
    flow: ChannelPolicy,
}

/// Do an outport and an inport match? Filenames must be compatible and
/// at least one dataset must match. All matched datasets must agree on
/// the transport mode.
fn match_ports(
    pt: &TaskConfig,
    _pi: usize,
    op: &PortConfig,
    ct: &TaskConfig,
    _ci: usize,
    ip: &PortConfig,
) -> Result<Option<Link>> {
    if !patterns_compatible(&op.filename, &ip.filename) {
        return Ok(None);
    }
    let mut dsets = Vec::new();
    let mut mode: Option<ChannelMode> = None;
    for od in &op.dsets {
        for id in &ip.dsets {
            if !patterns_compatible(&od.name, &id.name) {
                continue;
            }
            // Consumer side selects the transport; both sides must not
            // contradict (paper sets the flags identically on both).
            let m = if id.memory {
                ChannelMode::Memory
            } else {
                ChannelMode::File
            };
            let pm = if od.memory { ChannelMode::Memory } else { ChannelMode::File };
            if pm != m {
                return Err(WilkinsError::Graph(format!(
                    "transport mismatch for dset {} between {} and {}",
                    id.name, pt.func, ct.func
                )));
            }
            if let Some(prev) = mode {
                if prev != m {
                    return Err(WilkinsError::Graph(format!(
                        "mixed transports within one channel ({} -> {})",
                        pt.func, ct.func
                    )));
                }
            }
            mode = Some(m);
            dsets.push(id.name.clone());
        }
    }
    match mode {
        None => Ok(None),
        Some(mode) => Ok(Some(Link {
            out_pattern: op.filename.clone(),
            in_pattern: ip.filename.clone(),
            dsets,
            mode,
            flow: ip.flow,
        })),
    }
}

/// Two filename/dataset patterns are compatible if either matches the
/// other (both may be globs; identical globs are compatible).
pub fn patterns_compatible(a: &str, b: &str) -> bool {
    pattern_matches(a, b) || pattern_matches(b, a)
}

fn node_index(cfg: &WorkflowConfig, task_idx: usize, instance: usize) -> usize {
    cfg.tasks[..task_idx]
        .iter()
        .map(|t| t.task_count)
        .sum::<usize>()
        + instance
}

#[cfg(test)]
mod tests;
