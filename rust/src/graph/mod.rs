//! Data-centric workflow graph construction (S3, paper Sec. 3.2).
//!
//! Users never list dependencies: Wilkins matches producer outports to
//! consumer inports by filename/dataset (glob-aware), expands
//! `taskCount` ensembles, links instance pairs round-robin (Fig. 3),
//! and classifies the resulting topology. Any directed graph is
//! accepted, including cycles.

mod topology;

pub use topology::Topology;

use crate::config::{DsetSpec, PortConfig, TaskConfig, WorkflowConfig};
use crate::error::{Result, WilkinsError};
use crate::flow::ChannelPolicy;
use crate::lowfive::{pattern_matches, Route, RouteTable};

/// One runnable task instance (ensemble member).
#[derive(Debug, Clone)]
pub struct TaskInstance {
    /// Index into `WorkflowConfig::tasks`.
    pub task_idx: usize,
    /// Ensemble instance number (0-based).
    pub instance: usize,
    /// Display name: `func` or `func[i]` for ensembles.
    pub name: String,
    /// First global rank of this instance's contiguous rank range.
    pub first_rank: usize,
    pub nprocs: usize,
    pub nwriters: usize,
}

impl TaskInstance {
    pub fn ranks(&self) -> std::ops::Range<usize> {
        self.first_rank..self.first_rank + self.nprocs
    }

    /// Global ranks of the I/O (writer) subset.
    pub fn io_ranks(&self) -> std::ops::Range<usize> {
        self.first_rank..self.first_rank + self.nwriters
    }
}

/// A matched producer→consumer communication channel.
#[derive(Debug, Clone)]
pub struct ChannelSpec {
    /// Node indices into `WorkflowGraph::nodes`.
    pub producer: usize,
    pub consumer: usize,
    /// Producer-side filename pattern (what file closes serve on).
    pub out_pattern: String,
    /// Consumer-side filename pattern (what opens request).
    pub in_pattern: String,
    /// Per-dataset transport routing: one (pattern, route) entry per
    /// matched dataset pair. Different datasets of one channel may
    /// ride different transports (paper Sec. 4.2), including
    /// write-through to both.
    pub routes: RouteTable,
    /// Flow-control policy of this link (consumer-side `flow:` key or
    /// its `io_freq` sugar, lowered).
    pub flow: ChannelPolicy,
}

impl ChannelSpec {
    /// The matched dataset name patterns, in match order.
    pub fn dset_patterns(&self) -> Vec<&str> {
        self.routes.entries().iter().map(|(p, _)| p.as_str()).collect()
    }
}

/// The expanded workflow graph.
#[derive(Debug, Clone)]
pub struct WorkflowGraph {
    pub nodes: Vec<TaskInstance>,
    pub channels: Vec<ChannelSpec>,
    pub total_ranks: usize,
}

impl WorkflowGraph {
    /// Build the graph from a validated config.
    pub fn build(cfg: &WorkflowConfig) -> Result<WorkflowGraph> {
        // 1. Expand ensembles into instances with contiguous ranks.
        let mut nodes = Vec::new();
        let mut next_rank = 0;
        for (task_idx, t) in cfg.tasks.iter().enumerate() {
            for instance in 0..t.task_count {
                let name = if t.task_count == 1 {
                    t.func.clone()
                } else {
                    format!("{}[{}]", t.func, instance)
                };
                nodes.push(TaskInstance {
                    task_idx,
                    instance,
                    name,
                    first_rank: next_rank,
                    nprocs: t.nprocs,
                    nwriters: t.writers(),
                });
                next_rank += t.nprocs;
            }
        }

        // 2. Task-level port matching.
        let mut channels = Vec::new();
        for (pi, pt) in cfg.tasks.iter().enumerate() {
            for (ci, ct) in cfg.tasks.iter().enumerate() {
                for op in &pt.outports {
                    for ip in &ct.inports {
                        if let Some(link) = match_ports(pt, pi, op, ct, ci, ip)? {
                            // 3. Round-robin ensemble linking (Fig. 3).
                            let pn = pt.task_count;
                            let cn = ct.task_count;
                            for k in 0..pn.max(cn) {
                                let pnode = node_index(cfg, pi, k % pn);
                                let cnode = node_index(cfg, ci, k % cn);
                                channels.push(ChannelSpec {
                                    producer: pnode,
                                    consumer: cnode,
                                    out_pattern: link.out_pattern.clone(),
                                    in_pattern: link.in_pattern.clone(),
                                    routes: link.routes.clone(),
                                    flow: link.flow,
                                });
                            }
                        }
                    }
                }
            }
        }

        // 4. Every inport must have at least one producer.
        for (ci, ct) in cfg.tasks.iter().enumerate() {
            for ip in &ct.inports {
                let fed = channels.iter().any(|ch| {
                    nodes[ch.consumer].task_idx == ci && ch.in_pattern == ip.filename
                });
                if !fed {
                    return Err(WilkinsError::Graph(format!(
                        "inport {} of task {} matches no producer outport",
                        ip.filename, ct.func
                    )));
                }
            }
        }

        Ok(WorkflowGraph { nodes, channels, total_ranks: next_rank })
    }

    /// Which node owns a global rank?
    pub fn node_of_rank(&self, rank: usize) -> Option<usize> {
        self.nodes.iter().position(|n| n.ranks().contains(&rank))
    }

    /// Channels where `node` is the producer.
    pub fn out_channels_of(&self, node: usize) -> Vec<usize> {
        (0..self.channels.len())
            .filter(|&i| self.channels[i].producer == node)
            .collect()
    }

    /// Channels where `node` is the consumer.
    pub fn in_channels_of(&self, node: usize) -> Vec<usize> {
        (0..self.channels.len())
            .filter(|&i| self.channels[i].consumer == node)
            .collect()
    }

    /// Classify the instance-level topology (reporting / tests).
    pub fn topology(&self) -> Topology {
        topology::classify(self)
    }

    /// Human-readable summary (CLI `graph` command).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "workflow: {} task instances, {} channels, {} ranks, topology {:?}\n",
            self.nodes.len(),
            self.channels.len(),
            self.total_ranks,
            self.topology()
        ));
        for n in &self.nodes {
            s.push_str(&format!(
                "  node {:<24} ranks {}..{} (writers {})\n",
                n.name,
                n.first_rank,
                n.first_rank + n.nprocs,
                n.nwriters
            ));
        }
        for c in &self.channels {
            s.push_str(&format!(
                "  channel {} -> {}  file {}  routes {}  flow {}\n",
                self.nodes[c.producer].name,
                self.nodes[c.consumer].name,
                c.in_pattern,
                c.routes,
                c.flow
            ));
        }
        s
    }
}

struct Link {
    out_pattern: String,
    in_pattern: String,
    routes: RouteTable,
    flow: ChannelPolicy,
}

/// Do an outport and an inport match? Filenames must be compatible and
/// at least one dataset must match. Each matched dataset pair resolves
/// to its own transport route (memory | file | both) — mixed routing
/// within one channel is the paper's Sec. 4.2 scenario, not an error;
/// only genuinely contradictory flags (no common transport) are
/// rejected.
fn match_ports(
    pt: &TaskConfig,
    _pi: usize,
    op: &PortConfig,
    ct: &TaskConfig,
    _ci: usize,
    ip: &PortConfig,
) -> Result<Option<Link>> {
    if !patterns_compatible(&op.filename, &ip.filename) {
        return Ok(None);
    }
    let mut entries: Vec<(String, Route)> = Vec::new();
    for od in &op.dsets {
        for id in &ip.dsets {
            if !patterns_compatible(&od.name, &id.name) {
                continue;
            }
            let route = resolve_route(od, id).ok_or_else(|| {
                WilkinsError::Graph(format!(
                    "contradictory routes for dataset {}: producer {} offers {} \
                     but consumer {} expects {} — the two sides share no transport",
                    id.name,
                    pt.func,
                    flags_desc(od),
                    ct.func,
                    flags_desc(id)
                ))
            })?;
            // Key the table by the more concrete side: a consumer glob
            // (`/particles/*`) matching several producer datasets must
            // yield one discriminating entry per dataset, not several
            // entries under one pattern where first-match-wins would
            // silently misroute all but the first.
            let key = if pattern_matches(&id.name, &od.name) {
                od.name.clone()
            } else {
                id.name.clone()
            };
            match entries.iter().find(|(k, _)| *k == key) {
                Some((_, prev)) if *prev != route => {
                    return Err(WilkinsError::Graph(format!(
                        "ambiguous routes for dataset {key} between {} and {}: \
                         matched as both {prev} and {route}",
                        pt.func, ct.func
                    )));
                }
                Some(_) => {} // identical duplicate match
                None => entries.push((key, route)),
            }
        }
    }
    if entries.is_empty() {
        return Ok(None);
    }
    Ok(Some(Link {
        out_pattern: op.filename.clone(),
        in_pattern: ip.filename.clone(),
        routes: RouteTable::new(entries),
        flow: ip.flow,
    }))
}

/// Resolve one matched dataset pair's route from its two flag sets.
/// `None` means the sides share no transport (producer file-only vs
/// consumer memory-only, or vice versa).
///
/// Memory delivery wins whenever both sides allow it; a producer-side
/// `file: 1` then upgrades the route to write-through (`Both`) — the
/// consumer reads in situ while a traditional file also lands on
/// disk. A pair agreeing only on `file` routes via disk.
fn resolve_route(od: &DsetSpec, id: &DsetSpec) -> Option<Route> {
    let mem = od.memory && id.memory;
    let file = od.file && id.file;
    match (mem, file) {
        (true, true) => Some(Route::Both),
        (true, false) => Some(if od.file { Route::Both } else { Route::Memory }),
        (false, true) => Some(Route::File),
        (false, false) => None,
    }
}

/// Human form of a dataset's transport flags, for route errors.
fn flags_desc(d: &DsetSpec) -> &'static str {
    match (d.memory, d.file) {
        (true, true) => "memory+file",
        (true, false) => "memory-only",
        (false, true) => "file-only",
        (false, false) => "no transport",
    }
}

/// Two filename/dataset patterns are compatible if either matches the
/// other (both may be globs; identical globs are compatible).
pub fn patterns_compatible(a: &str, b: &str) -> bool {
    pattern_matches(a, b) || pattern_matches(b, a)
}

fn node_index(cfg: &WorkflowConfig, task_idx: usize, instance: usize) -> usize {
    cfg.tasks[..task_idx]
        .iter()
        .map(|t| t.task_count)
        .sum::<usize>()
        + instance
}

#[cfg(test)]
mod tests;
