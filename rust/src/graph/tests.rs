//! Graph construction tests over the paper's listings and the three
//! ensemble topologies of the synthetic experiments (Figs. 6-9).

use crate::config::tests::{LISTING1, LISTING2, LISTING4, LISTING6};
use crate::config::WorkflowConfig;
use crate::flow::FlowControl;
use crate::lowfive::Route;

use super::{patterns_compatible, Topology, WorkflowGraph};

fn build(src: &str) -> WorkflowGraph {
    WorkflowGraph::build(&WorkflowConfig::from_yaml_str(src).unwrap()).unwrap()
}

#[test]
fn listing1_two_channels() {
    let g = build(LISTING1);
    assert_eq!(g.nodes.len(), 3);
    assert_eq!(g.channels.len(), 2);
    // producer -> consumer1 carries the grid, -> consumer2 particles.
    let c1 = &g.channels[0];
    assert_eq!(g.nodes[c1.producer].name, "producer");
    assert_eq!(g.nodes[c1.consumer].name, "consumer1");
    assert_eq!(c1.dset_patterns(), vec!["/group1/grid"]);
    let c2 = &g.channels[1];
    assert_eq!(g.nodes[c2.consumer].name, "consumer2");
    assert_eq!(c2.dset_patterns(), vec!["/group1/particles"]);
    assert_eq!(c1.routes.route_of("/group1/grid"), Route::Memory);
    assert!(c1.routes.any_memory() && !c1.routes.any_file());
    assert_eq!(g.topology(), Topology::FanOut);
    assert_eq!(g.total_ranks, 12);
}

#[test]
fn rank_assignment_contiguous() {
    let g = build(LISTING1);
    assert_eq!(g.nodes[0].ranks(), 0..4);
    assert_eq!(g.nodes[1].ranks(), 4..9);
    assert_eq!(g.nodes[2].ranks(), 9..12);
    assert_eq!(g.node_of_rank(0), Some(0));
    assert_eq!(g.node_of_rank(8), Some(1));
    assert_eq!(g.node_of_rank(11), Some(2));
    assert_eq!(g.node_of_rank(12), None);
}

#[test]
fn listing2_round_robin_fan_in() {
    let g = build(LISTING2);
    assert_eq!(g.nodes.len(), 6); // 4 producers + 2 consumers
    assert_eq!(g.channels.len(), 4);
    // Figure 3 pairing: p0->c0, p1->c1, p2->c0, p3->c1.
    let pairs: Vec<(usize, usize)> = g
        .channels
        .iter()
        .map(|c| (g.nodes[c.producer].instance, g.nodes[c.consumer].instance))
        .collect();
    assert_eq!(pairs, vec![(0, 0), (1, 1), (2, 0), (3, 1)]);
    assert_eq!(g.topology(), Topology::General);
}

#[test]
fn listing4_nxn_ensembles() {
    let g = build(LISTING4);
    assert_eq!(g.nodes.len(), 128);
    assert_eq!(g.channels.len(), 64);
    // NxN: instance i -> instance i.
    for c in &g.channels {
        assert_eq!(g.nodes[c.producer].instance, g.nodes[c.consumer].instance);
    }
    assert_eq!(g.topology(), Topology::NxN);
    // Subset writers recorded on the node.
    assert_eq!(g.nodes[0].nwriters, 1);
    assert_eq!(g.nodes[0].io_ranks(), 0..1);
}

#[test]
fn listing6_globs_and_flow() {
    let g = build(LISTING6);
    assert_eq!(g.channels.len(), 1);
    let c = &g.channels[0];
    assert_eq!(c.in_pattern, "plt*.h5");
    assert_eq!(c.flow, FlowControl::Some(2).lower());
    assert_eq!(c.dset_patterns(), vec!["/level_0/density"]);
    assert_eq!(g.topology(), Topology::Pipeline);
}

#[test]
fn fan_out_topology() {
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 2\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n  - func: c\n    taskCount: 4\n    nprocs: 2\n    inports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 4);
    assert_eq!(g.topology(), Topology::FanOut);
    // All channels share the same producer node.
    assert!(g.channels.iter().all(|c| c.producer == 0));
}

#[test]
fn fan_in_topology() {
    let g = build(
        "tasks:\n  - func: p\n    taskCount: 4\n    nprocs: 2\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 2\n    inports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 4);
    assert_eq!(g.topology(), Topology::FanIn);
    assert!(g.channels.iter().all(|c| c.consumer == 4));
    assert_eq!(g.in_channels_of(4).len(), 4);
}

#[test]
fn pipeline_with_intermediate() {
    let g = build(
        "tasks:\n  - func: sim\n    nprocs: 2\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n  - func: filter\n    nprocs: 2\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: b.h5\n        dsets:\n          - name: /d\n  - func: viz\n    nprocs: 1\n    inports:\n      - filename: b.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 2);
    assert_eq!(g.topology(), Topology::Pipeline);
}

#[test]
fn cycle_detected() {
    let g = build(
        "tasks:\n  - func: sim\n    nprocs: 1\n    inports:\n      - filename: steer.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: out.h5\n        dsets:\n          - name: /d\n  - func: steer\n    nprocs: 1\n    inports:\n      - filename: out.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: steer.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.topology(), Topology::Cyclic);
}

#[test]
fn dangling_inport_rejected() {
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: MISSING.h5\n        dsets:\n          - name: /d\n",
        )
        .unwrap(),
    );
    assert!(res.is_err());
}

#[test]
fn contradictory_routes_name_dataset_and_tasks() {
    // Producer memory-only vs consumer file-only: no shared
    // transport. The error must name the dataset pattern and both
    // tasks (the satellite diagnosability requirement).
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            memory: 1\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            file: 1\n            memory: 0\n",
        )
        .unwrap(),
    );
    let err = res.unwrap_err().to_string();
    for needle in ["/d", "p", "c", "memory-only", "file-only"] {
        assert!(err.contains(needle), "missing {needle:?} in error: {err}");
    }

    // The mirror image (producer file-only, consumer memory-only) is
    // just as contradictory.
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            file: 1\n            memory: 0\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            memory: 1\n",
        )
        .unwrap(),
    );
    let err = res.unwrap_err().to_string();
    assert!(err.contains("/d") && err.contains("file-only"), "{err}");
}

#[test]
fn mixed_routes_within_one_channel_accepted() {
    // The paper's Sec. 4.2 scenario: one channel carrying a memory
    // dataset, a file dataset and a write-through dataset — formerly
    // rejected as "mixed transports within one channel".
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /mem\n          - name: /disk\n            file: 1\n            memory: 0\n          - name: /wt\n            file: 1\n            memory: 1\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /mem\n          - name: /disk\n            file: 1\n            memory: 0\n          - name: /wt\n            file: 1\n            memory: 1\n",
    );
    assert_eq!(g.channels.len(), 1);
    let routes = &g.channels[0].routes;
    assert_eq!(routes.route_of("/mem"), Route::Memory);
    assert_eq!(routes.route_of("/disk"), Route::File);
    assert_eq!(routes.route_of("/wt"), Route::Both);
    assert!(routes.any_memory() && routes.any_file() && routes.any_file_only());
}

#[test]
fn producer_write_through_upgrades_memory_consumer() {
    // Producer flags memory+file, consumer asks memory-only: the
    // consumer reads in situ while the producer still archives the
    // dataset (route Both).
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            file: 1\n            memory: 1\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            memory: 1\n",
    );
    assert_eq!(g.channels[0].routes.route_of("/d"), Route::Both);
}

#[test]
fn no_match_on_different_datasets() {
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /x\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /y\n",
        )
        .unwrap(),
    );
    // Filenames match but no dataset does -> dangling inport.
    assert!(res.is_err());
}

#[test]
fn glob_dataset_matching() {
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/position\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/*\n",
    );
    assert_eq!(g.channels.len(), 1);
    // The table is keyed by the concrete producer name, not the
    // consumer glob: globs matching several datasets must stay
    // discriminable per dataset.
    assert_eq!(g.channels[0].dset_patterns(), vec!["/particles/position"]);
}

#[test]
fn glob_consumer_keeps_per_dataset_routes() {
    // One consumer glob matching two producer datasets with different
    // transport flags: each dataset keeps its own route.
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/position\n          - name: /particles/velocity\n            memory: 1\n            file: 1\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/*\n            memory: 1\n            file: 1\n",
    );
    let routes = &g.channels[0].routes;
    assert_eq!(routes.route_of("/particles/position"), Route::Memory);
    assert_eq!(routes.route_of("/particles/velocity"), Route::Both);
}

#[test]
fn duplicate_dataset_with_conflicting_flags_rejected() {
    // The same concrete dataset matched twice with different resolved
    // routes is ambiguous — the error names the dataset and tasks.
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n          - name: /*\n            file: 1\n            memory: 0\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            memory: 1\n            file: 1\n",
    )
        .unwrap(),
    );
    let err = res.unwrap_err().to_string();
    assert!(err.contains("ambiguous") && err.contains("/d"), "{err}");
}

#[test]
fn pattern_compat_is_symmetric() {
    assert!(patterns_compatible("plt*.h5", "plt*.h5"));
    assert!(patterns_compatible("outfile.h5", "*.h5"));
    assert!(patterns_compatible("*.h5", "outfile.h5"));
    assert!(!patterns_compatible("a.h5", "b.h5"));
}

#[test]
fn describe_mentions_nodes_and_channels() {
    let g = build(LISTING1);
    let d = g.describe();
    assert!(d.contains("producer"));
    assert!(d.contains("consumer2"));
    assert!(d.contains("channel"));
}
