//! Graph construction tests over the paper's listings and the three
//! ensemble topologies of the synthetic experiments (Figs. 6-9).

use crate::config::tests::{LISTING1, LISTING2, LISTING4, LISTING6};
use crate::config::WorkflowConfig;
use crate::flow::FlowControl;
use crate::lowfive::ChannelMode;

use super::{patterns_compatible, Topology, WorkflowGraph};

fn build(src: &str) -> WorkflowGraph {
    WorkflowGraph::build(&WorkflowConfig::from_yaml_str(src).unwrap()).unwrap()
}

#[test]
fn listing1_two_channels() {
    let g = build(LISTING1);
    assert_eq!(g.nodes.len(), 3);
    assert_eq!(g.channels.len(), 2);
    // producer -> consumer1 carries the grid, -> consumer2 particles.
    let c1 = &g.channels[0];
    assert_eq!(g.nodes[c1.producer].name, "producer");
    assert_eq!(g.nodes[c1.consumer].name, "consumer1");
    assert_eq!(c1.dsets, vec!["/group1/grid"]);
    let c2 = &g.channels[1];
    assert_eq!(g.nodes[c2.consumer].name, "consumer2");
    assert_eq!(c2.dsets, vec!["/group1/particles"]);
    assert_eq!(c1.mode, ChannelMode::Memory);
    assert_eq!(g.topology(), Topology::FanOut);
    assert_eq!(g.total_ranks, 12);
}

#[test]
fn rank_assignment_contiguous() {
    let g = build(LISTING1);
    assert_eq!(g.nodes[0].ranks(), 0..4);
    assert_eq!(g.nodes[1].ranks(), 4..9);
    assert_eq!(g.nodes[2].ranks(), 9..12);
    assert_eq!(g.node_of_rank(0), Some(0));
    assert_eq!(g.node_of_rank(8), Some(1));
    assert_eq!(g.node_of_rank(11), Some(2));
    assert_eq!(g.node_of_rank(12), None);
}

#[test]
fn listing2_round_robin_fan_in() {
    let g = build(LISTING2);
    assert_eq!(g.nodes.len(), 6); // 4 producers + 2 consumers
    assert_eq!(g.channels.len(), 4);
    // Figure 3 pairing: p0->c0, p1->c1, p2->c0, p3->c1.
    let pairs: Vec<(usize, usize)> = g
        .channels
        .iter()
        .map(|c| (g.nodes[c.producer].instance, g.nodes[c.consumer].instance))
        .collect();
    assert_eq!(pairs, vec![(0, 0), (1, 1), (2, 0), (3, 1)]);
    assert_eq!(g.topology(), Topology::General);
}

#[test]
fn listing4_nxn_ensembles() {
    let g = build(LISTING4);
    assert_eq!(g.nodes.len(), 128);
    assert_eq!(g.channels.len(), 64);
    // NxN: instance i -> instance i.
    for c in &g.channels {
        assert_eq!(g.nodes[c.producer].instance, g.nodes[c.consumer].instance);
    }
    assert_eq!(g.topology(), Topology::NxN);
    // Subset writers recorded on the node.
    assert_eq!(g.nodes[0].nwriters, 1);
    assert_eq!(g.nodes[0].io_ranks(), 0..1);
}

#[test]
fn listing6_globs_and_flow() {
    let g = build(LISTING6);
    assert_eq!(g.channels.len(), 1);
    let c = &g.channels[0];
    assert_eq!(c.in_pattern, "plt*.h5");
    assert_eq!(c.flow, FlowControl::Some(2).lower());
    assert_eq!(c.dsets, vec!["/level_0/density"]);
    assert_eq!(g.topology(), Topology::Pipeline);
}

#[test]
fn fan_out_topology() {
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 2\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n  - func: c\n    taskCount: 4\n    nprocs: 2\n    inports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 4);
    assert_eq!(g.topology(), Topology::FanOut);
    // All channels share the same producer node.
    assert!(g.channels.iter().all(|c| c.producer == 0));
}

#[test]
fn fan_in_topology() {
    let g = build(
        "tasks:\n  - func: p\n    taskCount: 4\n    nprocs: 2\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 2\n    inports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 4);
    assert_eq!(g.topology(), Topology::FanIn);
    assert!(g.channels.iter().all(|c| c.consumer == 4));
    assert_eq!(g.in_channels_of(4).len(), 4);
}

#[test]
fn pipeline_with_intermediate() {
    let g = build(
        "tasks:\n  - func: sim\n    nprocs: 2\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n  - func: filter\n    nprocs: 2\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: b.h5\n        dsets:\n          - name: /d\n  - func: viz\n    nprocs: 1\n    inports:\n      - filename: b.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.channels.len(), 2);
    assert_eq!(g.topology(), Topology::Pipeline);
}

#[test]
fn cycle_detected() {
    let g = build(
        "tasks:\n  - func: sim\n    nprocs: 1\n    inports:\n      - filename: steer.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: out.h5\n        dsets:\n          - name: /d\n  - func: steer\n    nprocs: 1\n    inports:\n      - filename: out.h5\n        dsets:\n          - name: /d\n    outports:\n      - filename: steer.h5\n        dsets:\n          - name: /d\n",
    );
    assert_eq!(g.topology(), Topology::Cyclic);
}

#[test]
fn dangling_inport_rejected() {
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: MISSING.h5\n        dsets:\n          - name: /d\n",
        )
        .unwrap(),
    );
    assert!(res.is_err());
}

#[test]
fn transport_mismatch_rejected() {
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            memory: 1\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /d\n            file: 1\n            memory: 0\n",
        )
        .unwrap(),
    );
    assert!(res.is_err());
}

#[test]
fn no_match_on_different_datasets() {
    let res = WorkflowGraph::build(
        &WorkflowConfig::from_yaml_str(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: a.h5\n        dsets:\n          - name: /x\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: a.h5\n        dsets:\n          - name: /y\n",
        )
        .unwrap(),
    );
    // Filenames match but no dataset does -> dangling inport.
    assert!(res.is_err());
}

#[test]
fn glob_dataset_matching() {
    let g = build(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/position\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: dump.h5\n        dsets:\n          - name: /particles/*\n",
    );
    assert_eq!(g.channels.len(), 1);
    assert_eq!(g.channels[0].dsets, vec!["/particles/*"]);
}

#[test]
fn pattern_compat_is_symmetric() {
    assert!(patterns_compatible("plt*.h5", "plt*.h5"));
    assert!(patterns_compatible("outfile.h5", "*.h5"));
    assert!(patterns_compatible("*.h5", "outfile.h5"));
    assert!(!patterns_compatible("a.h5", "b.h5"));
}

#[test]
fn describe_mentions_nodes_and_channels() {
    let g = build(LISTING1);
    let d = g.describe();
    assert!(d.contains("producer"));
    assert!(d.contains("consumer2"));
    assert!(d.contains("channel"));
}
