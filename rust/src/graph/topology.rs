//! Instance-level topology classification: the shapes named by the
//! paper (pipeline, fan-in, fan-out, NxN/ensembles, cycles).

use std::collections::HashSet;

use super::WorkflowGraph;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Single node, no channels.
    Single,
    /// A linear chain of nodes.
    Pipeline,
    /// One producer feeding many consumers.
    FanOut,
    /// Many producers feeding one consumer.
    FanIn,
    /// Matched producer/consumer instance pairs (1:1 links).
    NxN,
    /// Contains a directed cycle (steering workflows).
    Cyclic,
    /// Anything else (mixed/general DAG).
    General,
}

pub fn classify(g: &WorkflowGraph) -> Topology {
    let n = g.nodes.len();
    // Unique node-level edges.
    let edges: HashSet<(usize, usize)> = g
        .channels
        .iter()
        .map(|c| (c.producer, c.consumer))
        .collect();
    if edges.is_empty() {
        return if n <= 1 { Topology::Single } else { Topology::General };
    }
    if has_cycle(n, &edges) {
        return Topology::Cyclic;
    }
    let mut outdeg = vec![0usize; n];
    let mut indeg = vec![0usize; n];
    for &(p, c) in &edges {
        outdeg[p] += 1;
        indeg[c] += 1;
    }
    let producers: Vec<usize> = (0..n).filter(|&i| outdeg[i] > 0 && indeg[i] == 0).collect();
    let consumers: Vec<usize> = (0..n).filter(|&i| indeg[i] > 0 && outdeg[i] == 0).collect();

    // NxN: every node has degree exactly 1 and edges form a matching.
    if edges.len() * 2 == n
        && (0..n).all(|i| outdeg[i] + indeg[i] == 1)
    {
        return if edges.len() == 1 { Topology::Pipeline } else { Topology::NxN };
    }
    // Pipeline: a single chain.
    if edges.len() == n - 1
        && producers.len() == 1
        && consumers.len() == 1
        && (0..n).all(|i| outdeg[i] <= 1 && indeg[i] <= 1)
    {
        return Topology::Pipeline;
    }
    // Fan-out: one source, many sinks, edges only source->sink.
    if producers.len() == 1 && edges.iter().all(|&(p, _)| p == producers[0]) {
        return Topology::FanOut;
    }
    // Fan-in: many sources, one sink.
    if consumers.len() == 1 && edges.iter().all(|&(_, c)| c == consumers[0]) {
        return Topology::FanIn;
    }
    Topology::General
}

fn has_cycle(n: usize, edges: &HashSet<(usize, usize)>) -> bool {
    // Kahn's algorithm: cycle iff not all nodes can be peeled.
    let mut indeg = vec![0usize; n];
    for &(_, c) in edges {
        indeg[c] += 1;
    }
    let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut seen = 0;
    while let Some(u) = queue.pop() {
        seen += 1;
        for &(p, c) in edges {
            if p == u {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
    }
    seen != n
}
