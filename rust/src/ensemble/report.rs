//! Ensemble run reports: per-instance [`RunReport`]s plus scheduling
//! facts (admission times, packing peak) and the merged Gantt trace.

use std::time::Duration;

use crate::coordinator::{FaultStats, RunReport};
use crate::metrics::MergedTrace;

use super::scheduler::{Placement, Policy};

/// One instance's outcome inside an ensemble run.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub name: String,
    /// Ranks the instance held while running.
    pub ranks: usize,
    /// Seconds after ensemble start when the co-scheduler admitted it.
    pub started_s: f64,
    /// Seconds after ensemble start when it completed.
    pub finished_s: f64,
    /// The instance's own workflow report.
    pub report: RunReport,
}

impl InstanceReport {
    /// Wall seconds the instance spent running.
    pub fn elapsed_s(&self) -> f64 {
        self.finished_s - self.started_s
    }
}

/// The result of an ensemble run.
#[derive(Debug, Clone)]
pub struct EnsembleReport {
    pub elapsed: Duration,
    /// The rank budget instances were packed onto.
    pub budget: usize,
    pub policy: Policy,
    /// Where the instances executed: in-process rank threads or a
    /// worker-process pool.
    pub placement: Placement,
    /// Pool width for process placement (`None` under threads).
    pub workers: Option<usize>,
    /// Peak ranks simultaneously in use (packing efficiency: compare
    /// against `budget`).
    pub peak_ranks: usize,
    /// Scheduling rounds the co-scheduler took.
    pub rounds: u64,
    /// Per-instance reports, in spec order.
    pub instances: Vec<InstanceReport>,
    /// Merged Gantt trace across all instances, on the ensemble clock.
    pub trace: MergedTrace,
    /// Fault-tolerance engagement counters: worker losses survived,
    /// re-dispatches, heartbeat misses, duplicate completions dropped.
    /// All-zero on a healthy campaign.
    pub faults: FaultStats,
}

impl EnsembleReport {
    pub fn instance(&self, name: &str) -> Option<&InstanceReport> {
        self.instances.iter().find(|i| i.name == name)
    }

    /// Pretty per-instance table for the CLI.
    pub fn render(&self) -> String {
        let where_run = match self.workers {
            Some(w) => format!("{} on {w} workers", self.placement),
            None => self.placement.to_string(),
        };
        let mut s = format!(
            "ensemble completed in {:.3}s  ({} instances, budget {} ranks, peak {} in use, {} policy, {} rounds, {} placement)\n",
            self.elapsed.as_secs_f64(),
            self.instances.len(),
            self.budget,
            self.peak_ranks,
            self.policy,
            self.rounds,
            where_run
        );
        s.push_str(&format!(
            "{:<20} {:>6} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>12} {:>12}\n",
            "instance", "ranks", "start", "finish", "elapsed", "served", "dropped", "opened",
            "bytes_moved", "shared"
        ));
        for i in &self.instances {
            let served: u64 = i.report.nodes.iter().map(|n| n.files_served).sum();
            let dropped: u64 = i.report.nodes.iter().map(|n| n.serves_dropped).sum();
            let opened: u64 = i.report.nodes.iter().map(|n| n.files_opened).sum();
            // Zero-copy serve bytes (the routed data plane's fast
            // path); under process placement instances run whole in
            // one worker, so same-process serves stay shared there.
            let shared: u64 = i.report.nodes.iter().map(|n| n.bytes_shared).sum();
            s.push_str(&format!(
                "{:<20} {:>6} {:>8.3}s {:>8.3}s {:>8.3}s {:>8} {:>8} {:>8} {:>12} {:>12}\n",
                i.name,
                i.ranks,
                i.started_s,
                i.finished_s,
                i.elapsed_s(),
                served,
                dropped,
                opened,
                i.report.bytes_sent,
                shared
            ));
        }
        if self.faults.any() {
            s.push_str(&self.faults.render_line());
        }
        s
    }
}
