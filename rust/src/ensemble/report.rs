//! Ensemble run reports: per-instance [`RunReport`]s plus scheduling
//! facts (admission times, packing peak), the merged Gantt trace,
//! coordinator-side instant events (worker losses, re-dispatches) and
//! the campaign's live telemetry summary.

use std::time::Duration;

use crate::coordinator::report::telemetry_json;
use crate::coordinator::{FaultStats, RunReport};
use crate::metrics::MergedTrace;
use crate::obs::json::{Arr, Obj};
use crate::obs::{InstantEvent, TelemetrySummary};

use super::scheduler::{Placement, Policy};

/// One instance's outcome inside an ensemble run.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub name: String,
    /// Ranks the instance held while running.
    pub ranks: usize,
    /// Seconds after ensemble start when the co-scheduler admitted it.
    pub started_s: f64,
    /// Seconds after ensemble start when it completed.
    pub finished_s: f64,
    /// The instance's own workflow report.
    pub report: RunReport,
}

impl InstanceReport {
    /// Wall seconds the instance spent running.
    pub fn elapsed_s(&self) -> f64 {
        self.finished_s - self.started_s
    }
}

/// The result of an ensemble run.
#[derive(Debug, Clone)]
pub struct EnsembleReport {
    pub elapsed: Duration,
    /// The rank budget instances were packed onto.
    pub budget: usize,
    pub policy: Policy,
    /// Where the instances executed: in-process rank threads or a
    /// worker-process pool.
    pub placement: Placement,
    /// Pool width for process placement (`None` under threads).
    pub workers: Option<usize>,
    /// Peak ranks simultaneously in use (packing efficiency: compare
    /// against `budget`).
    pub peak_ranks: usize,
    /// Scheduling rounds the co-scheduler took.
    pub rounds: u64,
    /// Per-instance reports, in spec order.
    pub instances: Vec<InstanceReport>,
    /// Merged Gantt trace across all instances, on the ensemble clock.
    pub trace: MergedTrace,
    /// Fault-tolerance engagement counters: worker losses survived,
    /// re-dispatches, heartbeat misses, duplicate completions dropped.
    /// All-zero on a healthy campaign.
    pub faults: FaultStats,
    /// Coordinator-side instant events on the ensemble clock —
    /// `WorkerLost` and `Requeue` markers that the `--trace` exporter
    /// paints onto the merged timeline.
    pub events: Vec<InstantEvent>,
    /// Live worker telemetry collected across the campaign (empty
    /// under thread placement — there are no worker processes to
    /// sample).
    pub telemetry: TelemetrySummary,
}

impl EnsembleReport {
    pub fn instance(&self, name: &str) -> Option<&InstanceReport> {
        self.instances.iter().find(|i| i.name == name)
    }

    /// Pretty per-instance table for the CLI. The `faults:` line is
    /// emitted unconditionally (zeros included), matching
    /// [`RunReport::render`].
    pub fn render(&self) -> String {
        let where_run = match self.workers {
            Some(w) => format!("{} on {w} workers", self.placement),
            None => self.placement.to_string(),
        };
        let mut s = format!(
            "ensemble completed in {:.3}s  ({} instances, budget {} ranks, peak {} in use, {} policy, {} rounds, {} placement)\n",
            self.elapsed.as_secs_f64(),
            self.instances.len(),
            self.budget,
            self.peak_ranks,
            self.policy,
            self.rounds,
            where_run
        );
        s.push_str(&format!(
            "{:<20} {:>6} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>12} {:>12}\n",
            "instance", "ranks", "start", "finish", "elapsed", "served", "dropped", "opened",
            "bytes_moved", "shared"
        ));
        for i in &self.instances {
            // Registry-driven sums (the old hand-written per-field
            // folds now live once, in `RunReport::sum_counter`). The
            // `shared` column is the zero-copy serve bytes of the
            // routed data plane's fast path; under process placement
            // instances run whole in one worker, so same-process
            // serves stay shared there.
            s.push_str(&format!(
                "{:<20} {:>6} {:>8.3}s {:>8.3}s {:>8.3}s {:>8} {:>8} {:>8} {:>12} {:>12}\n",
                i.name,
                i.ranks,
                i.started_s,
                i.finished_s,
                i.elapsed_s(),
                i.report.sum_counter("files_served"),
                i.report.sum_counter("serves_dropped"),
                i.report.sum_counter("files_opened"),
                i.report.bytes_sent,
                i.report.sum_counter("bytes_shared")
            ));
        }
        s.push_str(&self.faults.render_line());
        if !self.telemetry.is_empty() {
            s.push_str(&format!(
                "telemetry: frames={} workers={}\n",
                self.telemetry.frames, self.telemetry.workers
            ));
        }
        s
    }

    /// Machine-readable report (schema `wilkins.ensemble_report/1`;
    /// see docs/observability.md). Per-instance workflow reports embed
    /// their own [`RunReport::to_json`] objects.
    pub fn to_json(&self) -> String {
        let mut instances = Arr::new();
        for i in &self.instances {
            let mut o = Obj::new();
            o.field_str("name", &i.name)
                .field_u64("ranks", i.ranks as u64)
                .field_f64("started_s", i.started_s)
                .field_f64("finished_s", i.finished_s)
                .field_raw("report", &i.report.to_json());
            instances.push_raw(&o.finish());
        }
        let mut events = Arr::new();
        for e in &self.events {
            let mut attrs = Obj::new();
            for (k, v) in &e.attrs {
                attrs.field_str(k, v);
            }
            let mut o = Obj::new();
            o.field_str("name", &e.name)
                .field_u64("rank", e.rank as u64)
                .field_f64("t_s", e.t)
                .field_raw("attrs", &attrs.finish());
            events.push_raw(&o.finish());
        }
        let mut faults = Obj::new();
        for (d, v) in FaultStats::DEFS.iter().zip(self.faults.counter_values()) {
            faults.field_u64(d.name, v);
        }
        let mut o = Obj::new();
        o.field_str("schema", "wilkins.ensemble_report/1")
            .field_f64("elapsed_s", self.elapsed.as_secs_f64())
            .field_u64("budget", self.budget as u64)
            .field_u64("peak_ranks", self.peak_ranks as u64)
            .field_u64("rounds", self.rounds)
            .field_str("placement", &self.placement.to_string())
            .field_raw("instances", &instances.finish())
            .field_raw("events", &events.finish())
            .field_raw("faults", &faults.finish())
            .field_raw("telemetry", &telemetry_json(&self.telemetry));
        o.finish()
    }
}
