//! Ensemble co-scheduling (paper Sec. 4.1 scale-up, Figures 7–10):
//! run N workflow instances concurrently against one shared scheduler.
//!
//! A single [`Wilkins`](crate::coordinator::Wilkins) run executes one
//! workflow; campaigns run *ensembles* — many instances of the same
//! (or similar) workflows, racing for the same machine. This module
//! adds the missing layer:
//!
//! * [`EnsembleSpec`] — a YAML list of instances with per-instance
//!   overrides (`params`, `io_freq`, `time_scale`), reusing the
//!   workflow YAML unchanged ([`spec`]).
//! * [`CoScheduler`] — packs instances onto a bounded global rank
//!   budget, FIFO or round-robin, with instance-level admission
//!   backpressure reusing [`FlowControl`](crate::flow::FlowControl)
//!   semantics ([`scheduler`]).
//! * [`Ensemble`] — the driver: admits instances as the budget allows,
//!   runs each as a full Wilkins workflow in its own workdir, shares
//!   one AOT engine across instances
//!   ([`runtime::shared_engine`](crate::runtime::shared_engine)), and
//!   aggregates per-instance [`RunReport`]s plus a merged Gantt trace
//!   ([`report`], [`MergedTrace`](crate::metrics::MergedTrace)).
//!
//! Admitted instances execute under a [`Placement`]: in-process rank
//! threads ([`Ensemble::run`], the default) or one worker *process*
//! per instance drawn from a [`net::WorkerPool`](crate::net::WorkerPool)
//! ([`Ensemble::run_on_pool`], the `wilkins up` path), which turns the
//! one-core serialization of independent instances into real
//! multi-core parallelism. [`packing_plan`] renders the scheduler's
//! plan without launching anything (`wilkins ensemble --dry-run`).
//!
//! ```no_run
//! use wilkins::ensemble::Ensemble;
//! use wilkins::tasks::builtin_registry;
//!
//! let ens = Ensemble::from_yaml_file(
//!     std::path::Path::new("configs/ensemble_pipeline.yaml"),
//!     builtin_registry(),
//! )?;
//! let report = ens.run()?;
//! print!("{}", report.render());
//! # Ok::<(), wilkins::WilkinsError>(())
//! ```

pub mod report;
pub mod scheduler;
pub mod spec;

pub use report::{EnsembleReport, InstanceReport};
pub use scheduler::{CoScheduler, Placement, Policy};
pub use spec::{EnsembleSpec, InstanceSpec};

use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Instant;

use crate::coordinator::{RunReport, Wilkins};
use crate::error::{Result, WilkinsError};
use crate::graph::WorkflowGraph;
use crate::henson::Registry;
use crate::metrics::{MergedTrace, Span};
use crate::net::proto::RunInstance;
use crate::net::WorkerPool;
use crate::obs::InstantEvent;
use crate::runtime::EngineHandle;

/// What an instance thread sends back when its workflow completes.
struct Completion {
    idx: usize,
    finished_s: f64,
    result: Result<RunReport>,
    spans: Vec<Span>,
}

/// The ensemble driver. Build one per ensemble run; the entry point
/// parallel to [`Wilkins::run`].
pub struct Ensemble {
    spec: EnsembleSpec,
    registry: Registry,
    engine: Option<EngineHandle>,
    time_scale: f64,
    workdir: PathBuf,
    /// True when the workdir was chosen by the spec or the caller (as
    /// opposed to the temp-dir default). An explicitly chosen ensemble
    /// workdir overrides per-workflow `workdir:` fields; the default
    /// yields to them.
    workdir_explicit: bool,
}

impl Ensemble {
    /// Fast-fails like the coordinator does: every instance's graph
    /// must build and every task code must resolve before anything
    /// launches.
    pub fn new(spec: EnsembleSpec, registry: Registry) -> Result<Ensemble> {
        for inst in &spec.instances {
            WorkflowGraph::build(&inst.cfg).map_err(|e| {
                WilkinsError::Config(format!("instance {}: {e}", inst.name))
            })?;
            for t in &inst.cfg.tasks {
                registry.get(&t.func).map_err(|e| {
                    WilkinsError::Config(format!("instance {}: {e}", inst.name))
                })?;
            }
        }
        let workdir_explicit = spec.workdir.is_some();
        let workdir = spec
            .workdir
            .clone()
            .map(PathBuf::from)
            .unwrap_or_else(|| {
                std::env::temp_dir().join(format!("wilkins-ens-{}", std::process::id()))
            });
        Ok(Ensemble {
            spec,
            registry,
            engine: None,
            time_scale: 1.0,
            workdir,
            workdir_explicit,
        })
    }

    pub fn from_yaml_str(src: &str, registry: Registry) -> Result<Ensemble> {
        Ensemble::new(EnsembleSpec::from_yaml_str(src, Path::new("."))?, registry)
    }

    pub fn from_yaml_file(path: &Path, registry: Registry) -> Result<Ensemble> {
        Ensemble::new(EnsembleSpec::from_yaml_file(path)?, registry)
    }

    /// Attach an AOT engine handle shared by every instance. Use
    /// [`crate::runtime::shared_engine`] so identical artifacts
    /// compile/load once across instances (and across ensembles in the
    /// same process).
    pub fn with_engine(mut self, engine: EngineHandle) -> Ensemble {
        self.engine = Some(engine);
        self
    }

    /// Convenience: attach the process-shared engine for an artifacts
    /// directory (see [`crate::runtime::shared_engine`]).
    pub fn with_shared_artifacts(self, artifacts_dir: &Path) -> Result<Ensemble> {
        let handle = crate::runtime::shared_engine(artifacts_dir)?;
        Ok(self.with_engine(handle))
    }

    /// Default time scale for instances that do not override it.
    pub fn with_time_scale(mut self, s: f64) -> Ensemble {
        self.time_scale = s;
        self
    }

    pub fn with_workdir(mut self, dir: PathBuf) -> Ensemble {
        self.workdir = dir;
        self.workdir_explicit = true;
        self
    }

    /// Override the spec's rank budget.
    pub fn with_budget(mut self, max_ranks: usize) -> Ensemble {
        self.spec.max_ranks = max_ranks;
        self
    }

    /// Override the spec's scheduling policy.
    pub fn with_policy(mut self, policy: Policy) -> Ensemble {
        self.spec.policy = policy;
        self
    }

    pub fn spec(&self) -> &EnsembleSpec {
        &self.spec
    }

    /// Launch the ensemble and block until every instance finishes.
    ///
    /// Instances are admitted by the [`CoScheduler`]; each admitted
    /// instance runs as a complete Wilkins workflow on its own threads
    /// in `<workdir>/<instance-name>` (instances must not share
    /// file-mode transport directories). A failing instance does not
    /// abort the others — the error is reported after the ensemble
    /// drains.
    pub fn run(&self) -> Result<EnsembleReport> {
        let n = self.spec.instances.len();
        let sched_insts: Vec<(usize, crate::flow::FlowControl)> = self
            .spec
            .instances
            .iter()
            .map(|i| (i.ranks(), i.admission))
            .collect();
        let mut sched =
            CoScheduler::new(self.spec.max_ranks, self.spec.policy, &sched_insts)?;
        std::fs::create_dir_all(&self.workdir)?;

        let origin = Instant::now();
        let (tx, rx) = mpsc::channel::<Completion>();
        let mut joins: Vec<Option<thread::JoinHandle<()>>> = (0..n).map(|_| None).collect();
        let mut started = vec![0.0_f64; n];
        let mut finished = vec![0.0_f64; n];
        let mut reports: Vec<Option<RunReport>> = (0..n).map(|_| None).collect();
        let mut spans: Vec<Vec<Span>> = vec![Vec::new(); n];
        let mut errors: Vec<String> = Vec::new();
        let mut peak = 0usize;
        let mut completed = 0usize;
        let mut idle_rounds = 0u32;

        while completed < n {
            let admitted = sched.next_round();
            if admitted.is_empty() && sched.running() == 0 {
                // Nothing running and nothing admitted: only admission
                // throttles can be holding instances back; they clear
                // within their own period. Back off instead of
                // hot-spinning (idle rounds would otherwise advance at
                // CPU speed, which both burns a core and makes
                // `Some(n)` throttles trivially satisfiable), and
                // guard against scheduler bugs: ~100 s of continuous
                // idling with pending instances is a stall.
                idle_rounds += 1;
                if idle_rounds > 100_000 {
                    return Err(WilkinsError::Task(
                        "ensemble co-scheduler stalled with pending instances".into(),
                    ));
                }
                thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            idle_rounds = 0;
            for idx in admitted {
                peak = peak.max(sched.in_use());
                let inst = &self.spec.instances[idx];
                started[idx] = origin.elapsed().as_secs_f64();
                match self.launch(idx, origin, tx.clone()) {
                    Ok(handle) => joins[idx] = Some(handle),
                    Err(e) => {
                        // Could not even start: record and release.
                        errors.push(format!("{}: {e}", inst.name));
                        finished[idx] = origin.elapsed().as_secs_f64();
                        sched.finish(idx);
                        completed += 1;
                    }
                }
            }
            if sched.running() > 0 {
                let done = rx.recv().map_err(|_| {
                    WilkinsError::Task("ensemble instance channel closed".into())
                })?;
                let idx = done.idx;
                finished[idx] = done.finished_s;
                spans[idx] = done.spans;
                match done.result {
                    Ok(r) => reports[idx] = Some(r),
                    Err(e) => errors.push(format!("{}: {e}", self.spec.instances[idx].name)),
                }
                if let Some(h) = joins[idx].take() {
                    let _ = h.join();
                }
                sched.finish(idx);
                completed += 1;
            }
        }

        if !errors.is_empty() {
            return Err(WilkinsError::Task(format!(
                "{} ensemble instance(s) failed: {}",
                errors.len(),
                errors.join("; ")
            )));
        }

        let mut trace = MergedTrace::new();
        let mut instances = Vec::with_capacity(n);
        for (idx, inst) in self.spec.instances.iter().enumerate() {
            trace.add_instance(&inst.name, started[idx], &spans[idx]);
            instances.push(InstanceReport {
                name: inst.name.clone(),
                ranks: inst.ranks(),
                started_s: started[idx],
                finished_s: finished[idx],
                report: reports[idx]
                    .take()
                    .expect("no failures, so every instance has a report"),
            });
        }
        Ok(EnsembleReport {
            elapsed: origin.elapsed(),
            budget: self.spec.max_ranks,
            policy: self.spec.policy,
            placement: Placement::Threads,
            workers: None,
            peak_ranks: peak,
            rounds: sched.rounds(),
            instances,
            trace,
            faults: crate::coordinator::FaultStats::default(),
            events: Vec::new(),
            telemetry: Default::default(),
        })
    }

    /// Launch the ensemble across a worker-process pool — the
    /// `process-per-instance` placement. Each admitted instance is
    /// dispatched to an exclusive worker process, so independent
    /// instances run on separate cores instead of serializing inside
    /// one process (the DESIGN.md "one core" caveat, made measurable).
    ///
    /// `spec_src`/`base_dir` must be the YAML this ensemble was parsed
    /// from: workers re-parse it (parsing is deterministic) and run
    /// instances by index, while workdirs and time scales are resolved
    /// *here*, exactly as the in-process path resolves them, and
    /// shipped pre-resolved.
    ///
    /// Fault tolerance: a dispatch that fails with
    /// [`WilkinsError::WorkerLost`] does not fail the campaign. The
    /// dead worker leaves the pool (the scheduler's slot cap shrinks
    /// with it) and the instance is requeued onto a survivor under a
    /// fresh idempotency key, up to the spec's `retries` budget per
    /// instance. Only zero live workers — or an instance exhausting
    /// its retries — is fatal. The merged report carries the
    /// engagement counters ([`crate::coordinator::FaultStats`]).
    pub fn run_on_pool(
        &self,
        pool: Arc<WorkerPool>,
        spec_src: &str,
        base_dir: &Path,
        artifacts: Option<&Path>,
    ) -> Result<EnsembleReport> {
        let n = self.spec.instances.len();
        let sched_insts: Vec<(usize, crate::flow::FlowControl)> = self
            .spec
            .instances
            .iter()
            .map(|i| (i.ranks(), i.admission))
            .collect();
        let mut sched = CoScheduler::new(self.spec.max_ranks, self.spec.policy, &sched_insts)?
            .with_worker_slots(pool.size())?;
        std::fs::create_dir_all(&self.workdir)?;

        let origin = Instant::now();
        let (tx, rx) = mpsc::channel::<Completion>();
        let mut joins: Vec<Option<thread::JoinHandle<()>>> = (0..n).map(|_| None).collect();
        let mut assigned: Vec<Option<usize>> = vec![None; n];
        let mut started = vec![0.0_f64; n];
        let mut finished = vec![0.0_f64; n];
        let mut reports: Vec<Option<RunReport>> = (0..n).map(|_| None).collect();
        let mut spans: Vec<Vec<Span>> = vec![Vec::new(); n];
        let mut errors: Vec<String> = Vec::new();
        let mut peak = 0usize;
        let mut completed = 0usize;
        let mut idle_rounds = 0u32;
        // Fault accounting + the per-instance re-dispatch budget.
        let mut faults = crate::coordinator::FaultStats::default();
        // Instant events on the ensemble clock — the `--trace`
        // exporter paints these onto the merged timeline.
        let mut events: Vec<InstantEvent> = Vec::new();
        let mut retries_left = vec![self.spec.retries; n];
        // Defense in depth behind the pool's idempotency-key dedup: an
        // instance that already completed is never recorded twice.
        let mut done_once = vec![false; n];
        // Idempotency keys are unique per *dispatch*, so a stale reply
        // from a presumed-dead worker can never satisfy a later
        // dispatch of the same instance.
        let mut dispatch_seq = 0u64;

        while completed < n {
            let admitted = sched.next_round();
            if admitted.is_empty() && sched.running() == 0 {
                // Same admission-throttle backoff + stall guard as the
                // in-process runner.
                idle_rounds += 1;
                if idle_rounds > 100_000 {
                    return Err(WilkinsError::Task(
                        "ensemble co-scheduler stalled with pending instances".into(),
                    ));
                }
                thread::sleep(std::time::Duration::from_millis(1));
                continue;
            }
            idle_rounds = 0;
            for idx in admitted {
                peak = peak.max(sched.in_use());
                let wid = pool.acquire().ok_or_else(|| {
                    WilkinsError::Task(
                        "scheduler admitted an instance with no free worker".into(),
                    )
                })?;
                assigned[idx] = Some(wid);
                started[idx] = origin.elapsed().as_secs_f64();
                dispatch_seq += 1;
                let inst = &self.spec.instances[idx];
                match self.launch_remote(
                    Arc::clone(&pool),
                    idx,
                    wid,
                    spec_src,
                    base_dir,
                    artifacts,
                    origin,
                    dispatch_seq,
                    tx.clone(),
                ) {
                    Ok(handle) => joins[idx] = Some(handle),
                    Err(e) => {
                        errors.push(format!("{}: {e}", inst.name));
                        finished[idx] = origin.elapsed().as_secs_f64();
                        pool.release(wid);
                        assigned[idx] = None;
                        sched.finish(idx);
                        completed += 1;
                    }
                }
            }
            if sched.running() > 0 {
                let done = rx.recv().map_err(|_| {
                    WilkinsError::Task("ensemble instance channel closed".into())
                })?;
                let idx = done.idx;
                if let Some(h) = joins[idx].take() {
                    let _ = h.join();
                }
                if matches!(done.result, Err(WilkinsError::WorkerLost(_))) {
                    // The worker died under this instance. It never
                    // returns to the free list; the scheduler's slot
                    // cap shrinks to the surviving pool.
                    assigned[idx] = None;
                    faults.lost_workers += 1;
                    sched.lose_worker_slot();
                    let why = match &done.result {
                        Err(e) => e.to_string(),
                        Ok(_) => unreachable!("matched Err above"),
                    };
                    events.push(InstantEvent {
                        rank: 0,
                        name: "WorkerLost".into(),
                        t: done.finished_s,
                        attrs: vec![
                            ("instance".into(), self.spec.instances[idx].name.clone()),
                            ("error".into(), why.clone()),
                        ],
                    });
                    if pool.alive() == 0 {
                        return Err(WilkinsError::Task(format!(
                            "ensemble campaign lost every worker (last: {why})"
                        )));
                    }
                    if retries_left[idx] > 0 {
                        retries_left[idx] -= 1;
                        faults.retries += 1;
                        events.push(InstantEvent {
                            rank: 0,
                            name: "Requeue".into(),
                            t: origin.elapsed().as_secs_f64(),
                            attrs: vec![(
                                "instance".into(),
                                self.spec.instances[idx].name.clone(),
                            )],
                        });
                        sched.requeue(idx);
                        continue;
                    }
                    errors.push(format!(
                        "{}: {why} (retry budget exhausted)",
                        self.spec.instances[idx].name
                    ));
                    finished[idx] = done.finished_s;
                    sched.finish(idx);
                    completed += 1;
                    continue;
                }
                if done_once[idx] {
                    // A stale completion slipped past the pool-level
                    // dedup (should be impossible); count, don't
                    // double-record.
                    faults.dup_done += 1;
                    continue;
                }
                done_once[idx] = true;
                finished[idx] = done.finished_s;
                spans[idx] = done.spans;
                match done.result {
                    Ok(r) => reports[idx] = Some(r),
                    Err(e) => errors.push(format!("{}: {e}", self.spec.instances[idx].name)),
                }
                if let Some(wid) = assigned[idx].take() {
                    pool.release(wid);
                }
                sched.finish(idx);
                completed += 1;
            }
        }
        faults.heartbeat_misses = pool.heartbeat_misses();
        faults.dup_done += pool.dup_done();

        if !errors.is_empty() {
            return Err(WilkinsError::Task(format!(
                "{} ensemble instance(s) failed: {}",
                errors.len(),
                errors.join("; ")
            )));
        }

        let mut trace = MergedTrace::new();
        let mut instances = Vec::with_capacity(n);
        for (idx, inst) in self.spec.instances.iter().enumerate() {
            trace.add_instance(&inst.name, started[idx], &spans[idx]);
            instances.push(InstanceReport {
                name: inst.name.clone(),
                ranks: inst.ranks(),
                started_s: started[idx],
                finished_s: finished[idx],
                report: reports[idx]
                    .take()
                    .expect("no failures, so every instance has a report"),
            });
        }
        Ok(EnsembleReport {
            elapsed: origin.elapsed(),
            budget: self.spec.max_ranks,
            policy: self.spec.policy,
            placement: Placement::ProcessPerInstance,
            workers: Some(pool.size()),
            peak_ranks: peak,
            rounds: sched.rounds(),
            instances,
            trace,
            faults,
            events,
            telemetry: pool.telemetry_summary(),
        })
    }

    /// Dispatch one instance to worker `wid` on its own thread (the
    /// blocking socket round-trip must not stall the scheduler loop).
    #[allow(clippy::too_many_arguments)]
    fn launch_remote(
        &self,
        pool: Arc<WorkerPool>,
        idx: usize,
        wid: usize,
        spec_src: &str,
        base_dir: &Path,
        artifacts: Option<&Path>,
        origin: Instant,
        idem_key: u64,
        tx: mpsc::Sender<Completion>,
    ) -> Result<thread::JoinHandle<()>> {
        let inst = &self.spec.instances[idx];
        // Same workdir precedence as the in-process `launch`.
        let parent = match (&inst.cfg.workdir, self.workdir_explicit) {
            (Some(dir), false) => PathBuf::from(dir),
            _ => self.workdir.clone(),
        };
        let req = RunInstance {
            spec_src: spec_src.to_string(),
            base_dir: base_dir.display().to_string(),
            instance_idx: idx as u64,
            workdir: parent.join(&inst.name).display().to_string(),
            artifacts: artifacts.map(|p| p.display().to_string()).unwrap_or_default(),
            time_scale: inst.time_scale.unwrap_or(self.time_scale),
            idem_key,
        };
        thread::Builder::new()
            .name(format!("wk-ens-remote-{}", inst.name))
            .spawn(move || {
                let outcome = pool.run_instance(wid, &req);
                let finished_s = origin.elapsed().as_secs_f64();
                let (result, spans) = match outcome {
                    Ok(done) => {
                        let spans = done.spans;
                        if !done.error.is_empty() {
                            (Err(WilkinsError::Task(done.error)), spans)
                        } else if let Some(report) = done.report {
                            (Ok(report), spans)
                        } else {
                            (
                                Err(WilkinsError::Task(
                                    "worker returned no report".into(),
                                )),
                                spans,
                            )
                        }
                    }
                    Err(e) => (Err(e), Vec::new()),
                };
                let _ = tx.send(Completion { idx, finished_s, result, spans });
            })
            .map_err(|e| WilkinsError::Task(format!("spawn remote dispatcher: {e}")))
    }

    /// The packing plan the co-scheduler would follow for this
    /// ensemble, without launching anything. See [`packing_plan`].
    pub fn plan(&self, workers: Option<usize>) -> Result<String> {
        packing_plan(&self.spec, workers)
    }

    /// Build and launch one instance on its own driver thread.
    fn launch(
        &self,
        idx: usize,
        origin: Instant,
        tx: mpsc::Sender<Completion>,
    ) -> Result<thread::JoinHandle<()>> {
        let inst = &self.spec.instances[idx];
        // Instances always get a per-name subdirectory (they share
        // filenames, so file-mode transports must not collide), but a
        // workflow-level `workdir:` is honored as the parent unless
        // the spec/caller chose an ensemble workdir explicitly.
        let parent = match (&inst.cfg.workdir, self.workdir_explicit) {
            (Some(dir), false) => PathBuf::from(dir),
            _ => self.workdir.clone(),
        };
        let mut w = Wilkins::new(inst.cfg.clone(), self.registry.clone())?
            .with_workdir(parent.join(&inst.name))
            .with_time_scale(inst.time_scale.unwrap_or(self.time_scale));
        if let Some(engine) = &self.engine {
            w = w.with_engine(engine.clone());
        }
        let recorder = w.recorder();
        thread::Builder::new()
            .name(format!("wk-ens-{}", inst.name))
            .spawn(move || {
                // A Completion must reach the driver even if the
                // instance panics — a lost send would deadlock the
                // recv loop with the instance still counted Running.
                let result = match std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| w.run()),
                ) {
                    Ok(res) => res,
                    Err(_) => Err(WilkinsError::Task("instance driver panicked".into())),
                };
                let finished_s = origin.elapsed().as_secs_f64();
                // spans() locks the recorder mutex, which a panicking
                // rank may have poisoned; never lose the Completion.
                let spans = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    recorder.spans()
                }))
                .unwrap_or_default();
                let _ = tx.send(Completion { idx, finished_s, result, spans });
            })
            .map_err(|e| WilkinsError::Task(format!("spawn instance driver: {e}")))
    }
}

/// Render the co-scheduler's packing plan for `spec` without
/// launching anything — the `wilkins ensemble --dry-run` surface.
///
/// `workers` adds the worker-slot constraint of process placement.
/// The simulation assumes instances complete in admission order (the
/// scheduler is a pure state machine, so the *shape* — waves, who
/// blocks whom, budget utilization — is exact; only completion order
/// is an assumption).
pub fn packing_plan(spec: &EnsembleSpec, workers: Option<usize>) -> Result<String> {
    use std::fmt::Write as _;

    let insts: Vec<(usize, crate::flow::FlowControl)> = spec
        .instances
        .iter()
        .map(|i| (i.ranks(), i.admission))
        .collect();
    let mut sched = CoScheduler::new(spec.max_ranks, spec.policy, &insts)?;
    if let Some(w) = workers {
        sched = sched.with_worker_slots(w)?;
    }
    let placement = match workers {
        Some(w) => format!("{} on {w} workers", Placement::ProcessPerInstance),
        None => spec.placement.to_string(),
    };
    let mut out = String::new();
    let _ = writeln!(
        out,
        "packing plan: {} instances, budget {} ranks, {} policy, {} placement",
        spec.instances.len(),
        spec.max_ranks,
        spec.policy,
        placement
    );
    let mut running: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut wave = 0usize;
    let mut idle = 0u64;
    while !sched.is_done() {
        let admitted = sched.next_round();
        if admitted.is_empty() {
            if let Some(idx) = running.pop_front() {
                sched.finish(idx);
                let _ = writeln!(
                    out,
                    "  round {:>3}: finish {} (frees {} ranks; {}/{} in use)",
                    sched.rounds(),
                    spec.instances[idx].name,
                    spec.instances[idx].ranks(),
                    sched.in_use(),
                    spec.max_ranks
                );
            } else {
                // Only admission throttles can hold everything back;
                // they clear within their period (capped by the spec).
                idle += 1;
                if idle > 1_000_000 {
                    return Err(WilkinsError::Task(
                        "packing plan did not converge".into(),
                    ));
                }
            }
            continue;
        }
        idle = 0;
        wave += 1;
        let names: Vec<String> = admitted
            .iter()
            .map(|&i| format!("{}({} ranks)", spec.instances[i].name, spec.instances[i].ranks()))
            .collect();
        running.extend(admitted.iter().copied());
        let _ = writeln!(
            out,
            "  wave {wave} (round {:>3}): admit {}   [{}/{} ranks in use]",
            sched.rounds(),
            names.join(", "),
            sched.in_use(),
            spec.max_ranks
        );
    }
    let _ = writeln!(
        out,
        "  {} scheduling rounds, {} waves, all {} instances placed",
        sched.rounds(),
        wave,
        spec.instances.len()
    );
    Ok(out)
}
