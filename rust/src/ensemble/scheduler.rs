//! The co-scheduler: packs workflow instances onto a bounded global
//! rank budget.
//!
//! This is deliberately a pure state machine — no threads, no clocks —
//! so packing and ordering are unit-testable. The [`Ensemble`]
//! runner drives it: one [`CoScheduler::next_round`] call per
//! scheduling opportunity (startup, and every instance completion),
//! spawning whatever the round admits.
//!
//! Two policies, mirroring the co-scheduling literature on ensembles
//! of in situ workflows:
//!
//! * **FIFO** — strict submission order. Instances are admitted in
//!   spec order while they fit in the remaining budget; the first
//!   instance that does not fit (or is not yet eligible) blocks
//!   everything behind it. Predictable, and preserves priority
//!   encoded as ordering.
//! * **Round-robin** — a rotating first-fit. The scan starts after the
//!   last admitted instance and skips entries that do not fit, so
//!   small instances backfill around large ones and no single wide
//!   instance starves the tail. Better packing, weaker ordering.
//!
//! Instance-level backpressure reuses [`FlowControl`] semantics
//! (the YAML `io_freq` convention, decoded with
//! [`FlowControl::from_io_freq`]):
//!
//! * [`FlowControl::All`] — always eligible (the default).
//! * [`FlowControl::Some`]\(n\) — eligible every nth scheduling round
//!   only: a submission throttle for low-priority instances.
//! * [`FlowControl::Latest`] — eligible only when the budget is
//!   completely idle: the instance only *starts* on a quiet machine
//!   (it does not keep the budget to itself once running).
//!
//! [`Ensemble`]: crate::ensemble::Ensemble

use crate::error::{Result, WilkinsError};
use crate::flow::FlowControl;

/// Instance admission policy of the co-scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Strict submission order with head-of-line blocking.
    #[default]
    Fifo,
    /// Rotating first-fit: skip what does not fit, resume the scan
    /// after the last admission.
    RoundRobin,
}

impl Policy {
    /// Parse the YAML `policy:` field.
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" | "FIFO" => Ok(Policy::Fifo),
            "round-robin" | "round_robin" | "rr" => Ok(Policy::RoundRobin),
            other => Err(WilkinsError::Config(format!(
                "unknown scheduling policy {other:?}; use fifo or round-robin"
            ))),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Fifo => write!(f, "fifo"),
            Policy::RoundRobin => write!(f, "round-robin"),
        }
    }
}

/// Where admitted instances execute — the second scheduling dimension
/// next to the rank budget. Threads is the in-process substrate
/// (instances share one process, ranks are threads); process
/// placement fans instances out across a `net::WorkerPool`, each
/// instance exclusively owning one worker process while it runs, so
/// independent instances land on separate cores instead of
/// serializing in one process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Rank threads inside the driver process (the default, today's
    /// single-process behavior).
    #[default]
    Threads,
    /// One worker process per running instance, drawn from the pool.
    ProcessPerInstance,
}

impl Placement {
    /// Parse the YAML `placement:` field.
    pub fn parse(s: &str) -> Result<Placement> {
        match s {
            "threads" | "thread" => Ok(Placement::Threads),
            "process" | "process-per-instance" | "process_per_instance" => {
                Ok(Placement::ProcessPerInstance)
            }
            other => Err(WilkinsError::Config(format!(
                "unknown placement {other:?}; use threads or process-per-instance"
            ))),
        }
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Placement::Threads => write!(f, "threads"),
            Placement::ProcessPerInstance => write!(f, "process-per-instance"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InstState {
    Pending,
    Running,
    Finished,
}

/// Packs instances (each a rank count + admission throttle) onto a
/// bounded rank budget. See the module docs for the policies.
#[derive(Debug)]
pub struct CoScheduler {
    budget: usize,
    policy: Policy,
    ranks: Vec<usize>,
    admission: Vec<FlowControl>,
    state: Vec<InstState>,
    /// Round-robin scan start.
    cursor: usize,
    /// Scheduling round counter (drives `Some(n)` throttles).
    round: u64,
    in_use: usize,
    /// Process placement: size of the worker pool (`None` = thread
    /// placement, instances are not slot-limited).
    worker_slots: Option<usize>,
    workers_in_use: usize,
}

impl CoScheduler {
    /// `insts` is one `(ranks, admission)` pair per instance, in spec
    /// order. Errors if any single instance is wider than the budget
    /// (it could never run).
    pub fn new(
        budget: usize,
        policy: Policy,
        insts: &[(usize, FlowControl)],
    ) -> Result<CoScheduler> {
        if budget == 0 {
            return Err(WilkinsError::Config(
                "ensemble rank budget must be >= 1".into(),
            ));
        }
        for (i, (ranks, _)) in insts.iter().enumerate() {
            if *ranks == 0 {
                return Err(WilkinsError::Config(format!(
                    "ensemble instance #{i} has zero ranks"
                )));
            }
            if *ranks > budget {
                return Err(WilkinsError::Config(format!(
                    "ensemble instance #{i} needs {ranks} ranks but the budget is {budget}"
                )));
            }
        }
        Ok(CoScheduler {
            budget,
            policy,
            ranks: insts.iter().map(|(r, _)| *r).collect(),
            admission: insts.iter().map(|(_, a)| *a).collect(),
            state: vec![InstState::Pending; insts.len()],
            cursor: 0,
            round: 0,
            in_use: 0,
            worker_slots: None,
            workers_in_use: 0,
        })
    }

    /// Constrain admissions to a pool of `n` worker processes
    /// (process-per-instance placement): a pending instance also needs
    /// a free worker slot, and finishing releases it. Errors on an
    /// empty pool.
    pub fn with_worker_slots(mut self, n: usize) -> Result<CoScheduler> {
        if n == 0 {
            return Err(WilkinsError::Config(
                "process placement needs a pool of >= 1 worker".into(),
            ));
        }
        self.worker_slots = Some(n);
        Ok(self)
    }

    /// Worker processes currently held by running instances.
    pub fn workers_in_use(&self) -> usize {
        self.workers_in_use
    }

    fn slot_free(&self) -> bool {
        match self.worker_slots {
            None => true,
            Some(n) => self.workers_in_use < n,
        }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Ranks currently held by running instances.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Number of running instances.
    pub fn running(&self) -> usize {
        self.state
            .iter()
            .filter(|s| **s == InstState::Running)
            .count()
    }

    /// Scheduling rounds taken so far.
    pub fn rounds(&self) -> u64 {
        self.round
    }

    /// All instances finished?
    pub fn is_done(&self) -> bool {
        self.state.iter().all(|s| *s == InstState::Finished)
    }

    fn eligible(&self, i: usize) -> bool {
        match self.admission[i] {
            FlowControl::All => true,
            FlowControl::Some(n) => self.round % n == 0,
            FlowControl::Latest => self.in_use == 0,
        }
    }

    fn admit(&mut self, i: usize, admitted: &mut Vec<usize>) {
        self.state[i] = InstState::Running;
        self.in_use += self.ranks[i];
        if self.worker_slots.is_some() {
            self.workers_in_use += 1;
        }
        admitted.push(i);
    }

    /// One scheduling round: admit pending instances under the policy
    /// and return their indices (possibly empty — e.g. nothing fits
    /// until a running instance releases ranks).
    pub fn next_round(&mut self) -> Vec<usize> {
        self.round += 1;
        let n = self.ranks.len();
        let mut admitted = Vec::new();
        match self.policy {
            Policy::Fifo => {
                for i in 0..n {
                    match self.state[i] {
                        InstState::Pending => {
                            if !self.eligible(i)
                                || !self.slot_free()
                                || self.in_use + self.ranks[i] > self.budget
                            {
                                break; // head-of-line blocks the rest
                            }
                            self.admit(i, &mut admitted);
                        }
                        InstState::Running | InstState::Finished => continue,
                    }
                }
            }
            Policy::RoundRobin => {
                let mut i = self.cursor % n.max(1);
                for _ in 0..n {
                    if self.state[i] == InstState::Pending
                        && self.eligible(i)
                        && self.slot_free()
                        && self.in_use + self.ranks[i] <= self.budget
                    {
                        self.admit(i, &mut admitted);
                        self.cursor = (i + 1) % n;
                    }
                    i = (i + 1) % n;
                }
            }
        }
        admitted
    }

    /// A running instance completed; its ranks return to the budget.
    pub fn finish(&mut self, i: usize) {
        debug_assert_eq!(self.state[i], InstState::Running, "finish of non-running instance");
        if self.state[i] == InstState::Running {
            self.state[i] = InstState::Finished;
            self.in_use -= self.ranks[i];
            if self.worker_slots.is_some() {
                self.workers_in_use -= 1;
            }
        }
    }

    /// A running instance's worker died under it: put the instance
    /// back in line (its ranks and worker slot return immediately)
    /// so a later round re-admits it onto a survivor. Pair with
    /// [`CoScheduler::lose_worker_slot`] when the pool shrank.
    pub fn requeue(&mut self, i: usize) {
        debug_assert_eq!(self.state[i], InstState::Running, "requeue of non-running instance");
        if self.state[i] == InstState::Running {
            self.state[i] = InstState::Pending;
            self.in_use -= self.ranks[i];
            if self.worker_slots.is_some() {
                self.workers_in_use -= 1;
            }
        }
    }

    /// A worker process died: the pool is one slot smaller from now
    /// on (the paper's "worker churn shrinks the budget" stance —
    /// the campaign degrades instead of failing). Never shrinks below
    /// one; with zero live workers the *driver* fails the campaign,
    /// because the scheduler alone cannot know whether survivors
    /// remain.
    pub fn lose_worker_slot(&mut self) {
        if let Some(n) = self.worker_slots {
            self.worker_slots = Some(n.saturating_sub(1).max(1));
        }
    }

    /// Current worker-slot cap (`None` = thread placement).
    pub fn worker_slots(&self) -> Option<usize> {
        self.worker_slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all(n: usize, ranks: usize) -> Vec<(usize, FlowControl)> {
        vec![(ranks, FlowControl::All); n]
    }

    /// Drive the scheduler to completion, finishing running instances
    /// in admission order; returns the admission order as waves.
    fn run_to_completion(sched: &mut CoScheduler) -> Vec<Vec<usize>> {
        let mut waves = Vec::new();
        let mut running: Vec<usize> = Vec::new();
        let mut guard = 0;
        while !sched.is_done() {
            guard += 1;
            assert!(guard < 10_000, "scheduler stalled");
            let admitted = sched.next_round();
            if !admitted.is_empty() {
                running.extend(&admitted);
                waves.push(admitted);
            } else if let Some(idx) = running.first().copied() {
                running.remove(0);
                sched.finish(idx);
            }
        }
        waves
    }

    #[test]
    fn fifo_packs_in_order_within_budget() {
        let mut s = CoScheduler::new(6, Policy::Fifo, &all(5, 2)).unwrap();
        let w1 = s.next_round();
        assert_eq!(w1, vec![0, 1, 2], "three 2-rank instances fill a 6-rank budget");
        assert_eq!(s.in_use(), 6);
        assert!(s.next_round().is_empty(), "budget exhausted");
        s.finish(1);
        assert_eq!(s.next_round(), vec![3]);
        s.finish(0);
        s.finish(2);
        assert_eq!(s.next_round(), vec![4]);
        s.finish(3);
        s.finish(4);
        assert!(s.is_done());
    }

    #[test]
    fn fifo_head_of_line_blocks() {
        // 4-rank head does not fit after the first 2-rank admission
        // with budget 5; FIFO must NOT let the later 1-rank instance
        // jump the queue.
        let insts = vec![
            (2, FlowControl::All),
            (4, FlowControl::All),
            (1, FlowControl::All),
        ];
        let mut s = CoScheduler::new(5, Policy::Fifo, &insts).unwrap();
        assert_eq!(s.next_round(), vec![0]);
        assert!(s.next_round().is_empty(), "instance 2 must wait behind 1");
        s.finish(0);
        assert_eq!(s.next_round(), vec![1, 2]);
    }

    #[test]
    fn round_robin_backfills_around_wide_instances() {
        // Same shape as the FIFO head-of-line test: round-robin skips
        // the 4-rank instance and backfills the 1-rank one.
        let insts = vec![
            (2, FlowControl::All),
            (4, FlowControl::All),
            (1, FlowControl::All),
        ];
        let mut s = CoScheduler::new(5, Policy::RoundRobin, &insts).unwrap();
        let w1 = s.next_round();
        assert_eq!(w1, vec![0, 2], "1-rank instance backfills past the 4-rank one");
        s.finish(0);
        s.finish(2);
        assert_eq!(s.next_round(), vec![1]);
    }

    #[test]
    fn round_robin_cursor_rotates() {
        // Budget fits exactly one instance at a time; admissions must
        // rotate 0, 1, 2, 3 even though 0 frees up first every time.
        let mut s = CoScheduler::new(2, Policy::RoundRobin, &all(4, 2)).unwrap();
        let order: Vec<usize> = run_to_completion(&mut s)
            .into_iter()
            .flatten()
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn some_n_throttles_admission_rounds() {
        // Instance 1 is only eligible every 3rd round; FIFO blocks
        // instance 2 behind it until then.
        let insts = vec![
            (1, FlowControl::All),
            (1, FlowControl::Some(3)),
            (1, FlowControl::All),
        ];
        let mut s = CoScheduler::new(4, Policy::Fifo, &insts).unwrap();
        assert_eq!(s.next_round(), vec![0], "round 1: throttled head blocks");
        assert_eq!(s.next_round(), Vec::<usize>::new(), "round 2: still throttled");
        assert_eq!(s.next_round(), vec![1, 2], "round 3: 3 % 3 == 0, all admitted");
    }

    #[test]
    fn latest_only_starts_on_idle_budget() {
        let insts = vec![
            (2, FlowControl::All),
            (1, FlowControl::Latest),
            (1, FlowControl::All),
        ];
        let mut s = CoScheduler::new(4, Policy::RoundRobin, &insts).unwrap();
        let w1 = s.next_round();
        // Instance 1 must not start while 0 (admitted earlier in the
        // same round) holds ranks; 2 backfills normally.
        assert_eq!(w1, vec![0, 2]);
        s.finish(0);
        assert!(s.next_round().is_empty(), "still busy: instance 2 running");
        s.finish(2);
        assert_eq!(s.next_round(), vec![1], "idle budget at last");
        s.finish(1);
        assert!(s.is_done());
    }

    #[test]
    fn rejects_unrunnable_shapes() {
        assert!(CoScheduler::new(0, Policy::Fifo, &all(1, 1)).is_err());
        assert!(CoScheduler::new(4, Policy::Fifo, &all(1, 5)).is_err());
        assert!(CoScheduler::new(4, Policy::Fifo, &[(0, FlowControl::All)]).is_err());
    }

    #[test]
    fn all_instances_complete_under_both_policies() {
        for policy in [Policy::Fifo, Policy::RoundRobin] {
            let insts: Vec<(usize, FlowControl)> = vec![
                (3, FlowControl::All),
                (2, FlowControl::Some(2)),
                (4, FlowControl::All),
                (1, FlowControl::Latest),
                (2, FlowControl::All),
            ];
            let mut s = CoScheduler::new(4, policy, &insts).unwrap();
            let waves = run_to_completion(&mut s);
            let mut seen: Vec<usize> = waves.into_iter().flatten().collect();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 3, 4], "{policy}: every instance ran");
            assert_eq!(s.in_use(), 0);
        }
    }

    #[test]
    fn worker_slots_cap_concurrency() {
        // The rank budget fits all four at once, but the pool only has
        // two worker processes: admissions must respect both.
        let mut s = CoScheduler::new(8, Policy::RoundRobin, &all(4, 2))
            .unwrap()
            .with_worker_slots(2)
            .unwrap();
        assert_eq!(s.next_round(), vec![0, 1]);
        assert_eq!(s.workers_in_use(), 2);
        assert!(s.next_round().is_empty(), "no free worker slot");
        s.finish(0);
        assert_eq!(s.workers_in_use(), 1);
        assert_eq!(s.next_round(), vec![2]);
        s.finish(1);
        s.finish(2);
        assert_eq!(s.next_round(), vec![3]);
        s.finish(3);
        assert!(s.is_done());
        assert_eq!(s.workers_in_use(), 0);
    }

    #[test]
    fn worker_slots_block_fifo_head() {
        // FIFO with one slot: strictly one instance at a time, in
        // order, even though the budget never binds.
        let mut s = CoScheduler::new(100, Policy::Fifo, &all(3, 1))
            .unwrap()
            .with_worker_slots(1)
            .unwrap();
        let order: Vec<usize> = run_to_completion(&mut s).into_iter().flatten().collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn rejects_empty_worker_pool() {
        assert!(CoScheduler::new(4, Policy::Fifo, &all(2, 1))
            .unwrap()
            .with_worker_slots(0)
            .is_err());
    }

    #[test]
    fn requeue_returns_instance_to_pending() {
        // Two slots, three instances. Instance 1's worker dies: its
        // ranks and slot free immediately, the pool shrinks to one
        // slot, and 1 is re-admitted later — exactly once.
        let mut s = CoScheduler::new(8, Policy::Fifo, &all(3, 2))
            .unwrap()
            .with_worker_slots(2)
            .unwrap();
        assert_eq!(s.next_round(), vec![0, 1]);
        s.requeue(1);
        s.lose_worker_slot();
        assert_eq!(s.worker_slots(), Some(1));
        assert_eq!(s.in_use(), 2, "requeue released instance 1's ranks");
        assert!(s.next_round().is_empty(), "survivor still busy with 0");
        s.finish(0);
        assert_eq!(s.next_round(), vec![1], "lost instance re-admitted first (FIFO)");
        s.finish(1);
        assert_eq!(s.next_round(), vec![2]);
        s.finish(2);
        assert!(s.is_done());
    }

    #[test]
    fn worker_slots_never_shrink_below_one() {
        let mut s = CoScheduler::new(4, Policy::Fifo, &all(2, 1))
            .unwrap()
            .with_worker_slots(2)
            .unwrap();
        s.lose_worker_slot();
        s.lose_worker_slot();
        s.lose_worker_slot();
        assert_eq!(s.worker_slots(), Some(1), "floor of one slot");
        // And the remaining slot still schedules work.
        assert_eq!(s.next_round(), vec![0]);
    }

    #[test]
    fn requeue_then_rerun_completes_under_round_robin() {
        let mut s = CoScheduler::new(4, Policy::RoundRobin, &all(4, 1))
            .unwrap()
            .with_worker_slots(3)
            .unwrap();
        let w1 = s.next_round();
        assert_eq!(w1, vec![0, 1, 2]);
        // Worker under instance 2 dies.
        s.requeue(2);
        s.lose_worker_slot();
        s.finish(0);
        s.finish(1);
        // Both remaining instances eventually run on the shrunk pool.
        let rest: Vec<usize> = run_to_completion(&mut s).into_iter().flatten().collect();
        let mut sorted = rest.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![2, 3], "requeued + tail instance both ran");
    }

    #[test]
    fn placement_parse_and_display() {
        assert_eq!(Placement::parse("threads").unwrap(), Placement::Threads);
        assert_eq!(
            Placement::parse("process-per-instance").unwrap(),
            Placement::ProcessPerInstance
        );
        assert_eq!(Placement::parse("process").unwrap(), Placement::ProcessPerInstance);
        assert!(Placement::parse("gpu").is_err());
        assert_eq!(Placement::ProcessPerInstance.to_string(), "process-per-instance");
        assert_eq!(Placement::default(), Placement::Threads);
    }

    #[test]
    fn policy_parse_and_display() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("round-robin").unwrap(), Policy::RoundRobin);
        assert_eq!(Policy::parse("rr").unwrap(), Policy::RoundRobin);
        assert!(Policy::parse("lifo").is_err());
        assert_eq!(Policy::RoundRobin.to_string(), "round-robin");
    }
}
