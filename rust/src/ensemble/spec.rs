//! The ensemble specification: a YAML list of workflow instances with
//! per-instance overrides, sharing one rank budget.
//!
//! The spec keeps the Wilkins ease-of-use contract: it is pure data,
//! reusing the workflow YAML unchanged. A minimal spec:
//!
//! ```yaml
//! ensemble:
//!   max_ranks: 8
//!   policy: round-robin
//!   workflow: pipeline.yaml     # shared base workflow (or inline tasks:)
//!   instances:
//!     - name: lo
//!       params: { producer: { steps: 2 } }
//!     - name: hi
//!       count: 3                # expands to hi[0], hi[1], hi[2]
//!       io_freq: -1             # override every inport of this instance
//!       admission: 2            # co-scheduler throttle (io_freq convention)
//! ```
//!
//! Each instance names a base workflow — the shared `workflow:` /
//! `tasks:` of the spec, or its own — and optionally overrides task
//! `params:` (per `func`), every inport's `io_freq`, and the emulated
//! `time_scale`. `admission:` throttles *scheduling* with the same
//! `io_freq` conventions (see [`crate::ensemble::scheduler`]).

use std::path::Path;

use crate::config::{get_usize, WorkflowConfig};
use crate::configyaml::{self, Yaml};
use crate::error::{Result, WilkinsError};
use crate::flow::FlowControl;
use crate::net::HeartbeatConfig;

use super::scheduler::{Placement, Policy};

/// Default per-instance re-dispatch budget after a worker loss.
pub const DEFAULT_RETRIES: usize = 2;

/// Upper bound on `admission: N` throttle periods. Scheduling rounds
/// happen at startup, on every instance completion, and at ~1 kHz
/// while the budget idles, so this keeps every throttle well inside
/// the runner's stall guard (which trips after ~100k idle rounds).
pub const MAX_ADMISSION_PERIOD: i64 = 10_000;

/// One co-scheduled workflow instance.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    /// Unique instance name; also the instance's workdir subdirectory
    /// and its lane group in the merged Gantt trace.
    pub name: String,
    /// The instance's fully-resolved workflow configuration (base plus
    /// overrides).
    pub cfg: WorkflowConfig,
    /// Per-instance `time_scale` override (else the ensemble's).
    pub time_scale: Option<f64>,
    /// Admission throttle for the co-scheduler.
    pub admission: FlowControl,
}

impl InstanceSpec {
    /// Ranks this instance occupies while running.
    pub fn ranks(&self) -> usize {
        self.cfg.total_ranks()
    }
}

/// A parsed ensemble specification.
#[derive(Debug, Clone)]
pub struct EnsembleSpec {
    /// Global rank budget instances are packed onto.
    pub max_ranks: usize,
    pub policy: Policy,
    /// Where admitted instances execute: in-process rank threads
    /// (default) or one worker process per instance.
    pub placement: Placement,
    /// Worker-pool width for process placement (`None`: the driver
    /// picks — CLI `--workers`, else the host's parallelism).
    pub workers: Option<usize>,
    /// Ensemble workdir; every instance runs in `<workdir>/<name>`.
    pub workdir: Option<String>,
    /// How many times one instance may be re-dispatched after its
    /// worker dies (`retries:`, process placement only).
    pub retries: usize,
    /// Worker liveness cadence for process placement (`heartbeat:`
    /// mapping; defaults apply when absent).
    pub heartbeat: HeartbeatConfig,
    pub instances: Vec<InstanceSpec>,
}

impl EnsembleSpec {
    /// Parse a spec from YAML text. `base_dir` resolves relative
    /// `workflow:` paths (use the spec file's directory, or `.`).
    pub fn from_yaml_str(src: &str, base_dir: &Path) -> Result<EnsembleSpec> {
        let doc = configyaml::parse(src)?;
        from_doc(&doc, base_dir)
    }

    pub fn from_yaml_file(path: &Path) -> Result<EnsembleSpec> {
        let src = std::fs::read_to_string(path)?;
        let base_dir = path.parent().unwrap_or_else(|| Path::new("."));
        EnsembleSpec::from_yaml_str(&src, base_dir)
    }

    /// Sum of all instance rank counts (the footprint of running
    /// everything at once).
    pub fn total_ranks(&self) -> usize {
        self.instances.iter().map(InstanceSpec::ranks).sum()
    }
}

fn from_doc(doc: &Yaml, base_dir: &Path) -> Result<EnsembleSpec> {
    let ens = doc
        .get("ensemble")
        .ok_or_else(|| WilkinsError::Config("missing `ensemble:` mapping".into()))?;
    if ens.as_map().is_none() {
        return Err(WilkinsError::Config(format!(
            "`ensemble:` must be a mapping, got {}",
            ens.type_name()
        )));
    }

    let base = base_workflow(ens, base_dir, "ensemble")?;
    let policy = match ens.get("policy").and_then(Yaml::as_str) {
        Some(s) => Policy::parse(s)?,
        None => Policy::Fifo,
    };
    let placement = match ens.get("placement") {
        None => Placement::Threads,
        Some(v) => {
            let s = v.as_str().ok_or_else(|| {
                WilkinsError::Config("`placement` must be a string".into())
            })?;
            Placement::parse(s)?
        }
    };
    let workers = match get_usize(ens, "workers")? {
        None => None,
        Some(0) => {
            return Err(WilkinsError::Config("`workers` must be >= 1".into()));
        }
        Some(n) => Some(n),
    };
    let workdir = ens
        .get("workdir")
        .and_then(Yaml::as_str)
        .map(str::to_string);
    let retries = get_usize(ens, "retries")?.unwrap_or(DEFAULT_RETRIES);
    let heartbeat = match ens.get("heartbeat") {
        None => HeartbeatConfig::default(),
        Some(hb) => {
            if hb.as_map().is_none() {
                return Err(WilkinsError::Config(
                    "`heartbeat` must be a mapping with `interval_ms` (and optionally `deadline_ms`)"
                        .into(),
                ));
            }
            let interval = get_usize(hb, "interval_ms")?.ok_or_else(|| {
                WilkinsError::Config("`heartbeat` mapping needs `interval_ms`".into())
            })? as u64;
            let deadline = match get_usize(hb, "deadline_ms")? {
                Some(d) => d as u64,
                // Default deadline: the pool's stock multiple of the
                // chosen interval (20x, matching HeartbeatConfig's
                // 250ms/5s defaults).
                None => interval.saturating_mul(20),
            };
            HeartbeatConfig::from_millis(interval, deadline)?
        }
    };

    let insts_y = ens
        .get("instances")
        .and_then(Yaml::as_seq)
        .ok_or_else(|| WilkinsError::Config("ensemble missing `instances:` list".into()))?;
    if insts_y.is_empty() {
        return Err(WilkinsError::Config("ensemble has no instances".into()));
    }

    let mut instances = Vec::new();
    for (i, inst_y) in insts_y.iter().enumerate() {
        let parsed = parse_instance(inst_y, i, base.as_ref(), base_dir)
            .map_err(|e| WilkinsError::Config(format!("instance #{i}: {e}")))?;
        instances.extend(parsed);
    }

    // Names must be unique: they key workdirs and trace lanes.
    let mut names: Vec<&str> = instances.iter().map(|x| x.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    if names.len() != instances.len() {
        return Err(WilkinsError::Config(
            "duplicate ensemble instance names; use `count:` or distinct `name:` fields".into(),
        ));
    }

    let total: usize = instances.iter().map(InstanceSpec::ranks).sum();
    let max_ranks = match get_usize(ens, "max_ranks")? {
        Some(0) | None => total, // absent or 0: fully concurrent
        Some(n) => n,
    };
    for inst in &instances {
        if inst.ranks() > max_ranks {
            return Err(WilkinsError::Config(format!(
                "instance {} needs {} ranks but max_ranks is {max_ranks}",
                inst.name,
                inst.ranks()
            )));
        }
    }

    Ok(EnsembleSpec { max_ranks, policy, placement, workers, workdir, retries, heartbeat, instances })
}

/// The base workflow named by a spec level (`tasks:` inline wins over
/// a `workflow:` path); `None` when the level names neither.
fn base_workflow(y: &Yaml, base_dir: &Path, who: &str) -> Result<Option<WorkflowConfig>> {
    if y.get("tasks").is_some() {
        return Ok(Some(WorkflowConfig::from_yaml_doc(y)?));
    }
    match y.get("workflow") {
        None => Ok(None),
        Some(w) => {
            let rel = w.as_str().ok_or_else(|| {
                WilkinsError::Config(format!("{who}: `workflow` must be a path string"))
            })?;
            let path = if Path::new(rel).is_absolute() {
                Path::new(rel).to_path_buf()
            } else {
                base_dir.join(rel)
            };
            Ok(Some(WorkflowConfig::from_yaml_file(&path)?))
        }
    }
}

fn parse_instance(
    y: &Yaml,
    idx: usize,
    shared: Option<&WorkflowConfig>,
    base_dir: &Path,
) -> Result<Vec<InstanceSpec>> {
    if y.as_map().is_none() {
        return Err(WilkinsError::Config(format!(
            "instance entries must be mappings, got {}",
            y.type_name()
        )));
    }
    let mut cfg = match base_workflow(y, base_dir, "instance")? {
        Some(own) => own,
        None => shared.cloned().ok_or_else(|| {
            WilkinsError::Config(
                "no workflow: set `tasks:`/`workflow:` on the instance or the ensemble".into(),
            )
        })?,
    };

    // Per-instance inport io_freq override.
    if let Some(freq) = y.get("io_freq") {
        let freq = freq.as_i64().ok_or_else(|| {
            WilkinsError::Config("`io_freq` must be an integer".into())
        })?;
        let flow = FlowControl::from_io_freq(freq)?.lower();
        for t in &mut cfg.tasks {
            for p in &mut t.inports {
                p.flow = flow;
            }
        }
    }

    // Per-task params overrides: `params: { func: { key: value } }`.
    if let Some(over) = y.get("params") {
        let over = over.as_map().ok_or_else(|| {
            WilkinsError::Config("instance `params` must map task func -> overrides".into())
        })?;
        for (func, kv) in over {
            let kv = kv.as_map().ok_or_else(|| {
                WilkinsError::Config(format!("params override for {func:?} must be a mapping"))
            })?;
            let task = cfg
                .tasks
                .iter_mut()
                .find(|t| &t.func == func)
                .ok_or_else(|| {
                    WilkinsError::Config(format!(
                        "params override names unknown task {func:?}"
                    ))
                })?;
            for (k, v) in kv {
                task.params.insert(k.clone(), v.clone());
            }
        }
    }

    let time_scale = match y.get("time_scale") {
        None => None,
        Some(v) => Some(v.as_f64().ok_or_else(|| {
            WilkinsError::Config("`time_scale` must be a number".into())
        })?),
    };
    let admission = match y.get("admission") {
        None => FlowControl::All,
        Some(v) => {
            let n = v.as_i64().ok_or_else(|| {
                WilkinsError::Config("`admission` must be an integer (io_freq convention)".into())
            })?;
            // Bound the throttle period: the runner's stall guard
            // (Ensemble::run) tolerates ~100k consecutive idle rounds,
            // so an unbounded `Some(n)` could look like a stall.
            if n > MAX_ADMISSION_PERIOD {
                return Err(WilkinsError::Config(format!(
                    "`admission` period must be <= {MAX_ADMISSION_PERIOD} scheduling rounds, got {n}"
                )));
            }
            FlowControl::from_io_freq(n)?
        }
    };

    let name = y
        .get("name")
        .and_then(Yaml::as_str)
        .map(str::to_string)
        .unwrap_or_else(|| format!("instance{idx}"));
    let count = get_usize(y, "count")?.unwrap_or(1);
    if count == 0 {
        return Err(WilkinsError::Config("`count` must be >= 1".into()));
    }

    let mut out = Vec::with_capacity(count);
    for j in 0..count {
        let name = if count == 1 { name.clone() } else { format!("{name}[{j}]") };
        out.push(InstanceSpec {
            name,
            cfg: cfg.clone(),
            time_scale,
            admission,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const PIPELINE: &str = "\
tasks:
  - func: producer
    nprocs: 2
    params: { steps: 2, grid_per_proc: 100, particles_per_proc: 100 }
    outports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
  - func: consumer
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets: [ { name: /group1/grid }, { name: /group1/particles } ]
";

    fn inline_spec() -> String {
        let indented: String = PIPELINE
            .lines()
            .map(|l| format!("  {l}\n"))
            .collect();
        format!(
            "\
ensemble:
  max_ranks: 8
  policy: round-robin
{indented}  instances:
    - name: a
      params:
        producer: {{ steps: 5 }}
    - name: b
      count: 2
      io_freq: -1
      admission: 2
      time_scale: 0.5
"
        )
    }

    #[test]
    fn parses_inline_spec_with_overrides() {
        let spec = EnsembleSpec::from_yaml_str(&inline_spec(), Path::new(".")).unwrap();
        assert_eq!(spec.max_ranks, 8);
        assert_eq!(spec.policy, Policy::RoundRobin);
        assert_eq!(spec.instances.len(), 3, "count: 2 expands");
        let names: Vec<&str> = spec.instances.iter().map(|i| i.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b[0]", "b[1]"]);
        assert_eq!(spec.total_ranks(), 12);

        // a: params override reaches the producer task only.
        let a = &spec.instances[0];
        assert_eq!(a.cfg.tasks[0].params.get("steps").unwrap().as_i64(), Some(5));
        assert_eq!(a.admission, FlowControl::All);
        assert_eq!(a.time_scale, None);

        // b: io_freq -1 lands on every inport; admission/time_scale set.
        let b = &spec.instances[1];
        assert_eq!(b.cfg.tasks[0].params.get("steps").unwrap().as_i64(), Some(2));
        assert_eq!(b.cfg.tasks[1].inports[0].flow, FlowControl::Latest);
        assert_eq!(b.admission, FlowControl::Some(2));
        assert_eq!(b.time_scale, Some(0.5));
    }

    #[test]
    fn shared_workflow_file_resolves_relative_to_base_dir() {
        let dir = std::env::temp_dir().join("wilkins-spec-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("pipe.yaml"), PIPELINE).unwrap();
        let spec = EnsembleSpec::from_yaml_str(
            "\
ensemble:
  workflow: pipe.yaml
  instances:
    - name: only
",
            &dir,
        )
        .unwrap();
        assert_eq!(spec.instances.len(), 1);
        assert_eq!(spec.instances[0].ranks(), 4);
        // max_ranks defaults to the fully-concurrent footprint.
        assert_eq!(spec.max_ranks, 4);
        assert_eq!(spec.policy, Policy::Fifo);
    }

    #[test]
    fn parses_placement_and_workers() {
        let spec = EnsembleSpec::from_yaml_str(&inline_spec(), Path::new(".")).unwrap();
        assert_eq!(spec.placement, Placement::Threads, "threads is the default");
        assert_eq!(spec.workers, None);

        let with_placement = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  placement: process-per-instance\n  workers: 2\n",
        );
        let spec = EnsembleSpec::from_yaml_str(&with_placement, Path::new(".")).unwrap();
        assert_eq!(spec.placement, Placement::ProcessPerInstance);
        assert_eq!(spec.workers, Some(2));

        let bad_placement = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  placement: gpu\n",
        );
        assert!(EnsembleSpec::from_yaml_str(&bad_placement, Path::new(".")).is_err());
        let zero_workers = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  workers: 0\n",
        );
        assert!(EnsembleSpec::from_yaml_str(&zero_workers, Path::new(".")).is_err());
    }

    #[test]
    fn parses_retries_and_heartbeat() {
        let spec = EnsembleSpec::from_yaml_str(&inline_spec(), Path::new(".")).unwrap();
        assert_eq!(spec.retries, DEFAULT_RETRIES);
        assert_eq!(spec.heartbeat, HeartbeatConfig::default());

        let tuned = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  retries: 0\n  heartbeat: { interval_ms: 50, deadline_ms: 400 }\n",
        );
        let spec = EnsembleSpec::from_yaml_str(&tuned, Path::new(".")).unwrap();
        assert_eq!(spec.retries, 0);
        assert_eq!(spec.heartbeat, HeartbeatConfig::from_millis(50, 400).unwrap());

        // Deadline defaults to 20x the interval; interval 0 disables.
        let defaulted = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  heartbeat: { interval_ms: 100 }\n",
        );
        let spec = EnsembleSpec::from_yaml_str(&defaulted, Path::new(".")).unwrap();
        assert_eq!(spec.heartbeat, HeartbeatConfig::from_millis(100, 2000).unwrap());
        let off = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  heartbeat: { interval_ms: 0 }\n",
        );
        let spec = EnsembleSpec::from_yaml_str(&off, Path::new(".")).unwrap();
        assert!(spec.heartbeat.interval.is_zero(), "interval 0 disables liveness");

        // A deadline shorter than two intervals is a config error, as
        // is a bare scalar instead of the mapping.
        let tight = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  heartbeat: { interval_ms: 100, deadline_ms: 150 }\n",
        );
        assert!(EnsembleSpec::from_yaml_str(&tight, Path::new(".")).is_err());
        let scalar = inline_spec().replace(
            "  policy: round-robin\n",
            "  policy: round-robin\n  heartbeat: 100\n",
        );
        assert!(EnsembleSpec::from_yaml_str(&scalar, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_bad_specs() {
        let base = Path::new(".");
        // No ensemble key.
        assert!(EnsembleSpec::from_yaml_str("tasks: []\n", base).is_err());
        // No instances.
        assert!(EnsembleSpec::from_yaml_str("ensemble:\n  instances: []\n", base).is_err());
        // Instance without any workflow.
        assert!(EnsembleSpec::from_yaml_str(
            "ensemble:\n  instances:\n    - name: x\n",
            base
        )
        .is_err());
        // Unknown task in params override.
        let mut bad = inline_spec();
        bad = bad.replace("        producer: { steps: 5 }", "        nope: { steps: 5 }");
        assert!(EnsembleSpec::from_yaml_str(&bad, base).is_err());
        // Duplicate names (drop the count so both entries collide on `a`).
        let dup = inline_spec()
            .replace("      count: 2\n", "")
            .replace("- name: b", "- name: a");
        assert!(EnsembleSpec::from_yaml_str(&dup, base).is_err());
        // Budget narrower than one instance.
        let narrow = inline_spec().replace("max_ranks: 8", "max_ranks: 2");
        assert!(EnsembleSpec::from_yaml_str(&narrow, base).is_err());
        // Admission period beyond the stall-guard bound.
        let huge = inline_spec().replace("admission: 2", "admission: 150000");
        assert!(EnsembleSpec::from_yaml_str(&huge, base).is_err());
    }
}
