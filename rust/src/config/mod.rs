//! Workflow configuration schema (S2): the typed form of the Wilkins
//! YAML interface (paper Sec. 3.2, Listings 1/2/4/6).
//!
//! Users describe *data requirements*, not dependencies: each task
//! lists inports/outports as filename + dataset names; Wilkins matches
//! them into channels (see [`crate::graph`]). The only other fields are
//! resources (`nprocs`), ensembles (`taskCount`), subset writers
//! (`nwriters` / `io_proc`), flow control (`flow:` / its `io_freq`
//! sugar) and custom actions (`actions`).

mod validate;

use std::collections::BTreeMap;

use crate::configyaml::{self, Yaml};
use crate::error::{Result, WilkinsError};
use crate::flow::{ChannelPolicy, FlowControl, PolicyMode};

/// Transport selection per dataset (`memory: 1` / `file: 1`).
#[derive(Debug, Clone, PartialEq)]
pub struct DsetSpec {
    /// Dataset path or glob, e.g. `/group1/grid`, `/particles/*`.
    pub name: String,
    pub file: bool,
    pub memory: bool,
}

/// One inport/outport: a filename (or glob) plus its datasets.
#[derive(Debug, Clone, PartialEq)]
pub struct PortConfig {
    /// Filename or glob, e.g. `outfile.h5`, `plt*.h5`.
    pub filename: String,
    /// Flow control for this port (consumer side): the lowered form of
    /// the `flow:` key or its `io_freq` sugar.
    pub flow: ChannelPolicy,
    pub dsets: Vec<DsetSpec>,
}

/// Whether a consumer task keeps state across timesteps (Sec. 3.5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConsumerKind {
    /// Launched once; loops over timesteps itself.
    #[default]
    Stateful,
    /// Relaunched by the driver for every incoming file.
    Stateless,
}

/// One task entry of the YAML `tasks:` list.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskConfig {
    /// Task code name (the "shared object" to load).
    pub func: String,
    /// Ensemble instance count (`taskCount`, default 1).
    pub task_count: usize,
    /// Ranks per instance (`nprocs`).
    pub nprocs: usize,
    /// Subset writers (`nwriters`/`io_proc`): how many of the first
    /// ranks perform I/O. Defaults to all.
    pub nwriters: Option<usize>,
    /// Custom action: (script/registry name, function name).
    pub actions: Option<(String, String)>,
    pub consumer_kind: ConsumerKind,
    pub inports: Vec<PortConfig>,
    pub outports: Vec<PortConfig>,
    /// Free-form task parameters forwarded to the task code
    /// (`params:` mapping; this is how benches set sizes/steps).
    pub params: BTreeMap<String, Yaml>,
}

impl TaskConfig {
    pub fn writers(&self) -> usize {
        self.nwriters.unwrap_or(self.nprocs).min(self.nprocs)
    }
}

/// A parsed workflow configuration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkflowConfig {
    pub tasks: Vec<TaskConfig>,
    /// Directory for file-mode transports (default: a temp dir).
    pub workdir: Option<String>,
}

impl WorkflowConfig {
    pub fn from_yaml_str(src: &str) -> Result<WorkflowConfig> {
        let doc = configyaml::parse(src)?;
        let cfg = from_doc(&doc)?;
        validate::validate(&cfg)?;
        Ok(cfg)
    }

    pub fn from_yaml_file(path: &std::path::Path) -> Result<WorkflowConfig> {
        let src = std::fs::read_to_string(path)?;
        WorkflowConfig::from_yaml_str(&src)
    }

    /// Build from an already-parsed YAML value whose mapping carries
    /// the `tasks:` (and optionally `workdir:`) keys. This is how the
    /// ensemble spec (see [`crate::ensemble`]) embeds whole workflows
    /// inline under an instance entry; unrelated sibling keys are
    /// ignored, exactly as unknown top-level keys are in a workflow
    /// file.
    pub fn from_yaml_doc(doc: &Yaml) -> Result<WorkflowConfig> {
        let cfg = from_doc(doc)?;
        validate::validate(&cfg)?;
        Ok(cfg)
    }

    /// Total ranks across all tasks and instances.
    pub fn total_ranks(&self) -> usize {
        self.tasks.iter().map(|t| t.nprocs * t.task_count).sum()
    }
}

fn from_doc(doc: &Yaml) -> Result<WorkflowConfig> {
    let tasks_y = doc
        .get("tasks")
        .and_then(Yaml::as_seq)
        .ok_or_else(|| WilkinsError::Config("missing `tasks:` list".into()))?;
    let mut tasks = Vec::with_capacity(tasks_y.len());
    for (i, t) in tasks_y.iter().enumerate() {
        tasks.push(parse_task(t).map_err(|e| {
            WilkinsError::Config(format!("task #{i}: {e}"))
        })?);
    }
    let workdir = doc
        .get("workdir")
        .and_then(Yaml::as_str)
        .map(str::to_string);
    Ok(WorkflowConfig { tasks, workdir })
}

fn parse_task(y: &Yaml) -> Result<TaskConfig> {
    let func = y
        .get("func")
        .and_then(Yaml::as_str)
        .ok_or_else(|| WilkinsError::Config("missing `func`".into()))?
        .to_string();
    let task_count = get_usize(y, "taskCount")?.unwrap_or(1);
    let nprocs = get_usize(y, "nprocs")?.unwrap_or(1);
    let nwriters = match get_usize(y, "nwriters")? {
        Some(n) => Some(n),
        None => get_usize(y, "io_proc")?,
    };
    let actions = match y.get("actions") {
        None => None,
        Some(a) => {
            let seq = a.as_seq().ok_or_else(|| {
                WilkinsError::Config("`actions` must be a [script, func] list".into())
            })?;
            if seq.len() != 2 {
                return Err(WilkinsError::Config(
                    "`actions` must have exactly two entries".into(),
                ));
            }
            let s = seq[0].as_str().ok_or_else(|| {
                WilkinsError::Config("`actions[0]` must be a string".into())
            })?;
            let f = seq[1].as_str().ok_or_else(|| {
                WilkinsError::Config("`actions[1]` must be a string".into())
            })?;
            Some((s.to_string(), f.to_string()))
        }
    };
    let consumer_kind = match y.get("stateless").and_then(Yaml::as_bool) {
        Some(true) => ConsumerKind::Stateless,
        _ => ConsumerKind::Stateful,
    };
    let inports = parse_ports(y.get("inports"))?;
    let outports = parse_ports(y.get("outports"))?;
    let mut params = BTreeMap::new();
    if let Some(p) = y.get("params").and_then(Yaml::as_map) {
        for (k, v) in p {
            params.insert(k.clone(), v.clone());
        }
    }
    Ok(TaskConfig {
        func,
        task_count,
        nprocs,
        nwriters,
        actions,
        consumer_kind,
        inports,
        outports,
        params,
    })
}

fn parse_ports(y: Option<&Yaml>) -> Result<Vec<PortConfig>> {
    let Some(y) = y else { return Ok(Vec::new()) };
    let seq = y
        .as_seq()
        .ok_or_else(|| WilkinsError::Config("ports must be a list".into()))?;
    let mut out = Vec::with_capacity(seq.len());
    for p in seq {
        let filename = p
            .get("filename")
            .and_then(Yaml::as_str)
            .ok_or_else(|| WilkinsError::Config("port missing `filename`".into()))?
            .to_string();
        let flow = parse_flow(p)?;
        let dsets_y = p
            .get("dsets")
            .and_then(Yaml::as_seq)
            .ok_or_else(|| WilkinsError::Config("port missing `dsets` list".into()))?;
        let mut dsets = Vec::with_capacity(dsets_y.len());
        for d in dsets_y {
            let name = d
                .get("name")
                .and_then(Yaml::as_str)
                .ok_or_else(|| WilkinsError::Config("dset missing `name`".into()))?
                .to_string();
            let file = d.get("file").and_then(Yaml::as_bool).unwrap_or(false);
            // Memory is the default transport when neither is given.
            let memory = d
                .get("memory")
                .and_then(Yaml::as_bool)
                .unwrap_or(!file);
            dsets.push(DsetSpec { name, file, memory });
        }
        out.push(PortConfig { filename, flow, dsets });
    }
    Ok(out)
}

/// Flow control of one port: the `flow:` key (mapping or shorthand
/// string) or the legacy `io_freq` sugar, never both.
///
/// ```yaml
/// io_freq: 5                      # sugar: block, every 5th close
/// flow: latest                    # shorthand: policy only
/// flow: { policy: block, depth: 3 }
/// flow: { policy: drop-oldest, depth: 2, every: 2 }
/// ```
fn parse_flow(p: &Yaml) -> Result<ChannelPolicy> {
    let io_freq = p.get("io_freq");
    let flow = p.get("flow");
    if io_freq.is_some() && flow.is_some() {
        return Err(WilkinsError::Config(
            "port sets both `io_freq` and `flow`; `io_freq` is sugar for `flow`, use one".into(),
        ));
    }
    if let Some(freq) = io_freq {
        let freq = freq.as_i64().ok_or_else(|| {
            WilkinsError::Config("`io_freq` must be an integer".into())
        })?;
        return Ok(FlowControl::from_io_freq(freq)?.lower());
    }
    let Some(flow) = flow else {
        return Ok(ChannelPolicy::block());
    };
    if let Some(s) = flow.as_str() {
        // Shorthand: `flow: latest`.
        return Ok(ChannelPolicy::block().with_mode(PolicyMode::parse(s)?));
    }
    if flow.as_map().is_none() {
        return Err(WilkinsError::Config(
            "`flow` must be a policy name or a mapping with policy/depth/every".into(),
        ));
    }
    let mut policy = ChannelPolicy::block();
    if let Some(m) = flow.get("policy") {
        let s = m.as_str().ok_or_else(|| {
            WilkinsError::Config("`flow.policy` must be a string".into())
        })?;
        policy = policy.with_mode(PolicyMode::parse(s)?);
    }
    if let Some(d) = get_usize(flow, "depth")? {
        policy = policy.with_depth(d);
    }
    if let Some(e) = get_usize(flow, "every")? {
        policy = policy.with_every(e as u64);
    }
    policy.validate()?;
    Ok(policy)
}

/// Optional non-negative integer field (shared with the ensemble
/// spec parser).
pub(crate) fn get_usize(y: &Yaml, key: &str) -> Result<Option<usize>> {
    match y.get(key) {
        None => Ok(None),
        Some(v) => {
            let n = v.as_i64().ok_or_else(|| {
                WilkinsError::Config(format!("`{key}` must be an integer, got {}", v.type_name()))
            })?;
            if n < 0 {
                return Err(WilkinsError::Config(format!("`{key}` must be >= 0, got {n}")));
            }
            Ok(Some(n as usize))
        }
    }
}

#[cfg(test)]
pub mod tests;
