//! Schema validation: reject configurations that would deadlock or
//! misbehave at launch with a readable message instead.

use crate::error::{Result, WilkinsError};

use super::{TaskConfig, WorkflowConfig};

pub fn validate(cfg: &WorkflowConfig) -> Result<()> {
    if cfg.tasks.is_empty() {
        return Err(WilkinsError::Config("workflow has no tasks".into()));
    }
    for t in &cfg.tasks {
        validate_task(t)?;
    }
    // Task names must be unique: instances are addressed as func[i].
    let mut names: Vec<&str> = cfg.tasks.iter().map(|t| t.func.as_str()).collect();
    names.sort();
    names.dedup();
    if names.len() != cfg.tasks.len() {
        return Err(WilkinsError::Config(
            "duplicate `func` names; use taskCount for ensembles".into(),
        ));
    }
    Ok(())
}

fn validate_task(t: &TaskConfig) -> Result<()> {
    let who = &t.func;
    if t.func.is_empty() {
        return Err(WilkinsError::Config("empty `func` name".into()));
    }
    if t.nprocs == 0 {
        return Err(WilkinsError::Config(format!("{who}: `nprocs` must be >= 1")));
    }
    if t.task_count == 0 {
        return Err(WilkinsError::Config(format!("{who}: `taskCount` must be >= 1")));
    }
    if let Some(w) = t.nwriters {
        if w == 0 || w > t.nprocs {
            return Err(WilkinsError::Config(format!(
                "{who}: `nwriters` must be in 1..=nprocs ({})",
                t.nprocs
            )));
        }
    }
    if t.inports.is_empty() && t.outports.is_empty() {
        return Err(WilkinsError::Config(format!(
            "{who}: task has neither inports nor outports"
        )));
    }
    for p in t.inports.iter().chain(&t.outports) {
        if p.filename.is_empty() {
            return Err(WilkinsError::Config(format!("{who}: empty port filename")));
        }
        // Flow windows are parsed leniently (builders accept anything);
        // reject degenerate credit windows / cadences here so every
        // construction path — YAML, ensemble overrides, programmatic
        // configs — hits the same gate. Documented in
        // docs/yaml-schema.md (`flow:` key).
        p.flow.validate().map_err(|e| {
            WilkinsError::Config(format!("{who}: port {}: {e}", p.filename))
        })?;
        if p.dsets.is_empty() {
            return Err(WilkinsError::Config(format!(
                "{who}: port {} has no dsets",
                p.filename
            )));
        }
        for d in &p.dsets {
            if d.name.is_empty() {
                return Err(WilkinsError::Config(format!(
                    "{who}: dset with empty name in port {}",
                    p.filename
                )));
            }
            if !d.file && !d.memory {
                return Err(WilkinsError::Config(format!(
                    "{who}: dset {} disables both file and memory transport",
                    d.name
                )));
            }
        }
    }
    Ok(())
}
