//! Config schema tests, including the paper's listings end-to-end.

use crate::flow::{ChannelPolicy, FlowControl, PolicyMode};

use super::*;

/// Paper Listing 1 (3-task workflow: producer + 2 consumers).
pub const LISTING1: &str = "\
tasks:
  - func: producer
    nprocs: 4
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
          - name: /group1/particles
            file: 0
            memory: 1
  - func: consumer1
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
  - func: consumer2
    nprocs: 3
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/particles
            file: 0
            memory: 1
";

/// Paper Listing 2 (fan-in ensemble: 4 producers, 2 consumers).
pub const LISTING2: &str = "\
tasks:
  - func: producer
    taskCount: 4 #Only change needed to define ensembles
    nprocs: 2
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
  - func: consumer
    taskCount: 2 #Only change needed to define ensembles
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
";

/// Paper Listing 4 (materials science: LAMMPS + diamond detector).
pub const LISTING4: &str = "\
tasks:
  - func: freeze
    taskCount: 64 #Only change needed to define ensembles
    nprocs: 32
    nwriters: 1 #Only rank 0 performs I/O
    outports:
      - filename: dump-h5md.h5
        dsets:
          - name: /particles/*
            file: 0
            memory: 1
  - func: detector
    taskCount: 64
    nprocs: 8
    inports:
      - filename: dump-h5md.h5
        dsets:
          - name: /particles/*
            file: 0
            memory: 1
";

/// Paper Listing 6 (cosmology: Nyx + Reeber with actions + io_freq).
pub const LISTING6: &str = "\
tasks:
  - func: nyx
    nprocs: 1024
    actions: [\"actions\", \"nyx\"]
    outports:
      - filename: plt*.h5
        dsets:
          - name: /level_0/density
            file: 0
            memory: 1
  - func: reeber
    nprocs: 64
    inports:
      - filename: plt*.h5
        io_freq: 2 #Setting the some flow control strategy
        dsets:
          - name: /level_0/density
            file: 0
            memory: 1
";

#[test]
fn listing1_parses() {
    let cfg = WorkflowConfig::from_yaml_str(LISTING1).unwrap();
    assert_eq!(cfg.tasks.len(), 3);
    let p = &cfg.tasks[0];
    assert_eq!(p.func, "producer");
    assert_eq!(p.nprocs, 4);
    assert_eq!(p.outports.len(), 1);
    assert_eq!(p.outports[0].dsets.len(), 2);
    assert!(p.outports[0].dsets[0].memory);
    assert!(!p.outports[0].dsets[0].file);
    assert_eq!(cfg.tasks[1].inports[0].dsets[0].name, "/group1/grid");
    assert_eq!(cfg.total_ranks(), 12);
}

#[test]
fn listing2_ensembles() {
    let cfg = WorkflowConfig::from_yaml_str(LISTING2).unwrap();
    assert_eq!(cfg.tasks[0].task_count, 4);
    assert_eq!(cfg.tasks[1].task_count, 2);
    assert_eq!(cfg.total_ranks(), 4 * 2 + 2 * 5);
}

#[test]
fn listing4_subset_writers() {
    let cfg = WorkflowConfig::from_yaml_str(LISTING4).unwrap();
    let f = &cfg.tasks[0];
    assert_eq!(f.task_count, 64);
    assert_eq!(f.nprocs, 32);
    assert_eq!(f.nwriters, Some(1));
    assert_eq!(f.writers(), 1);
    assert_eq!(f.outports[0].dsets[0].name, "/particles/*");
}

#[test]
fn listing6_actions_and_flow() {
    let cfg = WorkflowConfig::from_yaml_str(LISTING6).unwrap();
    assert_eq!(
        cfg.tasks[0].actions,
        Some(("actions".to_string(), "nyx".to_string()))
    );
    assert_eq!(cfg.tasks[0].outports[0].filename, "plt*.h5");
    assert_eq!(cfg.tasks[1].inports[0].flow, FlowControl::Some(2).lower());
}

#[test]
fn io_proc_alias_for_nwriters() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 4\n    io_proc: 2\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(cfg.tasks[0].nwriters, Some(2));
}

#[test]
fn memory_is_default_transport() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    let d = &cfg.tasks[0].outports[0].dsets[0];
    assert!(d.memory && !d.file);
}

#[test]
fn write_through_flags_parse_together() {
    // `memory: 1, file: 1` on one dataset is write-through (paper
    // Sec. 4.2) — both flags land on the DsetSpec; the graph layer
    // lowers the pair onto Route::Both.
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /wt\n            memory: 1\n            file: 1\n          - name: /disk\n            file: 1\n            memory: 0\n",
    )
    .unwrap();
    let dsets = &cfg.tasks[0].outports[0].dsets;
    assert!(dsets[0].memory && dsets[0].file, "write-through keeps both");
    assert!(!dsets[1].memory && dsets[1].file, "file-only");
}

#[test]
fn file_flag_alone_disables_memory_default() {
    // `file: 1` with `memory` unset means file-only (the historical
    // default `memory = !file`), not write-through.
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n            file: 1\n",
    )
    .unwrap();
    let d = &cfg.tasks[0].outports[0].dsets[0];
    assert!(d.file && !d.memory);
}

#[test]
fn stateless_flag() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: c\n    nprocs: 1\n    stateless: 1\n    inports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(cfg.tasks[0].consumer_kind, ConsumerKind::Stateless);
}

#[test]
fn params_passthrough() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    params:\n      steps: 10\n      size: 4096\n    outports:\n      - filename: f.h5\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(
        cfg.tasks[0].params.get("steps").and_then(|y| y.as_i64()),
        Some(10)
    );
}

// ---- validation failures ---------------------------------------------------

#[test]
fn rejects_empty_tasks() {
    assert!(WorkflowConfig::from_yaml_str("tasks:\n").is_err());
}

#[test]
fn rejects_zero_nprocs() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 0\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}

#[test]
fn rejects_nwriters_above_nprocs() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 2\n    nwriters: 3\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}

#[test]
fn rejects_portless_task() {
    assert!(WorkflowConfig::from_yaml_str("tasks:\n  - func: p\n    nprocs: 1\n").is_err());
}

#[test]
fn rejects_no_transport() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n            file: 0\n            memory: 0\n",
    );
    assert!(err.is_err());
}

#[test]
fn rejects_duplicate_funcs() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n  - func: p\n    nprocs: 1\n    inports:\n      - filename: f\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}

#[test]
fn flow_key_mapping_and_shorthand() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        flow: { policy: drop-oldest, depth: 2, every: 3 }\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(
        cfg.tasks[1].inports[0].flow,
        ChannelPolicy::block()
            .with_mode(PolicyMode::DropOldest)
            .with_depth(2)
            .with_every(3)
    );
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        flow: latest\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(cfg.tasks[1].inports[0].flow, ChannelPolicy::latest());
}

#[test]
fn flow_defaults_to_block() {
    let cfg = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        dsets:\n          - name: /d\n",
    )
    .unwrap();
    assert_eq!(cfg.tasks[1].inports[0].flow, ChannelPolicy::block());
}

#[test]
fn rejects_flow_and_io_freq_together() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        io_freq: 2\n        flow: latest\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}

#[test]
fn rejects_bad_flow_values() {
    for port in [
        "flow: { policy: yolo }",
        "flow: { policy: block, depth: 0 }",
        "flow: { policy: block, every: 0 }",
        "flow: 7",
    ] {
        let yaml = format!(
            "tasks:\n  - func: p\n    nprocs: 1\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        {port}\n        dsets:\n          - name: /d\n"
        );
        assert!(WorkflowConfig::from_yaml_str(&yaml).is_err(), "{port} must be rejected");
    }
}

#[test]
fn rejects_bad_io_freq() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: c\n    nprocs: 1\n    inports:\n      - filename: f\n        io_freq: -7\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}

#[test]
fn rejects_malformed_actions() {
    let err = WorkflowConfig::from_yaml_str(
        "tasks:\n  - func: p\n    nprocs: 1\n    actions: [\"only-one\"]\n    outports:\n      - filename: f\n        dsets:\n          - name: /d\n",
    );
    assert!(err.is_err());
}
