//! In-repo YAML-subset parser (substrate S1).
//!
//! The offline toolchain has no `serde_yaml`, so Wilkins ships its own
//! parser for the YAML subset its workflow configuration files use
//! (paper Listings 1, 2, 4, 6):
//!
//! * block mappings nested by indentation,
//! * block sequences (`- ` items, including mapping items),
//! * flow (inline) sequences `["actions", "nyx"]`,
//! * scalars: integers, floats, booleans, plain and quoted strings,
//! * `#` comments (full-line and trailing) and blank lines.
//!
//! Anchors, aliases, multi-document streams, block scalars and flow
//! mappings are intentionally out of scope — the Wilkins interface
//! never needs them (ease-of-use is the paper's point: configs stay
//! simple).

mod lexer;
mod value;

pub use value::Yaml;

use crate::error::{Result, WilkinsError};
use lexer::{Line, LineKind};

/// Parse a YAML document into a [`Yaml`] value tree.
pub fn parse(src: &str) -> Result<Yaml> {
    let lines = lexer::lex(src)?;
    if lines.is_empty() {
        return Ok(Yaml::Map(Vec::new()));
    }
    let mut pos = 0;
    let doc = parse_block(&lines, &mut pos, 0)?;
    if pos != lines.len() {
        let line = lines[pos].number;
        return Err(WilkinsError::Yaml {
            line,
            msg: format!("unexpected content at indent {}", lines[pos].indent),
        });
    }
    Ok(doc)
}

/// Parse a block (mapping or sequence) whose items sit at `indent`.
fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml> {
    match lines[*pos].kind {
        LineKind::SeqItem { .. } => parse_sequence(lines, pos, indent),
        _ => parse_mapping(lines, pos, indent),
    }
}

fn parse_mapping(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml> {
    let mut entries: Vec<(String, Yaml)> = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(WilkinsError::Yaml {
                line: line.number,
                msg: format!(
                    "bad indentation: expected {} spaces, found {}",
                    indent, line.indent
                ),
            });
        }
        match &line.kind {
            LineKind::KeyValue { key, value } => {
                *pos += 1;
                entries.push((key.clone(), value::parse_scalar(value)));
            }
            LineKind::KeyOnly { key } => {
                let key = key.clone();
                let key_line = line.number;
                *pos += 1;
                if *pos < lines.len() && lines[*pos].indent > indent {
                    let child_indent = lines[*pos].indent;
                    let child = parse_block(lines, pos, child_indent)?;
                    entries.push((key, child));
                } else if *pos < lines.len()
                    && lines[*pos].indent == indent
                    && matches!(lines[*pos].kind, LineKind::SeqItem { .. })
                {
                    // Sequences are commonly indented at the same level
                    // as their key ("tasks:\n- func: ...").
                    let child = parse_sequence(lines, pos, indent)?;
                    entries.push((key, child));
                } else {
                    // Key with no value: YAML null; we use an empty map,
                    // the only way Wilkins configs use this form.
                    let _ = key_line;
                    entries.push((key, Yaml::Null));
                }
            }
            LineKind::SeqItem { .. } => break,
        }
    }
    Ok(Yaml::Map(entries))
}

fn parse_sequence(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !matches!(line.kind, LineKind::SeqItem { .. }) {
            if line.indent >= indent && !matches!(line.kind, LineKind::SeqItem { .. }) {
                break;
            }
            if line.indent < indent {
                break;
            }
            return Err(WilkinsError::Yaml {
                line: line.number,
                msg: "inconsistent sequence indentation".into(),
            });
        }
        let LineKind::SeqItem { rest } = &line.kind else {
            unreachable!()
        };
        let rest = rest.clone();
        let item_line = line.number;
        *pos += 1;
        if rest.is_empty() {
            // "-" alone: nested block on following, deeper lines.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Yaml::Null);
            }
            continue;
        }
        // "- key: value" or "- key:" starts an inline mapping whose
        // continuation lines are indented past the dash.
        if let Some(first) = lexer::split_key(&rest, item_line)? {
            // Re-interpret as a mapping: the first entry comes from the
            // dash line; continuation entries are the following lines
            // indented deeper than the dash.
            let mut entries: Vec<(String, Yaml)> = Vec::new();
            match first {
                lexer::KeySplit::KeyValue { key, value } => {
                    entries.push((key, value::parse_scalar(&value)));
                }
                lexer::KeySplit::KeyOnly { key } => {
                    if *pos < lines.len() && lines[*pos].indent > indent + 2 {
                        let ci = lines[*pos].indent;
                        let child = parse_block(lines, pos, ci)?;
                        entries.push((key, child));
                    } else {
                        entries.push((key, Yaml::Null));
                    }
                }
            }
            // Continuation lines of this mapping item.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let cont_indent = lines[*pos].indent;
                if let Yaml::Map(more) = parse_mapping(lines, pos, cont_indent)? {
                    entries.extend(more);
                }
            }
            items.push(Yaml::Map(entries));
        } else {
            items.push(value::parse_scalar(&rest));
        }
    }
    Ok(Yaml::Seq(items))
}

#[cfg(test)]
mod tests;
