//! Unit tests for the YAML-subset parser, including round-trips of the
//! paper's Listings 1, 2, 4 and 6.

use super::{parse, Yaml};

fn s(v: &str) -> Yaml {
    Yaml::Str(v.to_string())
}

#[test]
fn scalars_typed() {
    let doc = parse("a: 1\nb: 2.5\nc: hello\nd: true\ne: \"7\"\nf: /a/b\n").unwrap();
    assert_eq!(doc.get("a"), Some(&Yaml::Int(1)));
    assert_eq!(doc.get("b"), Some(&Yaml::Float(2.5)));
    assert_eq!(doc.get("c"), Some(&s("hello")));
    assert_eq!(doc.get("d"), Some(&Yaml::Bool(true)));
    assert_eq!(doc.get("e"), Some(&s("7")));
    assert_eq!(doc.get("f"), Some(&s("/a/b")));
}

#[test]
fn comments_and_blanks_ignored() {
    let doc = parse("# header\n\na: 1  # trailing\n\n# tail\n").unwrap();
    assert_eq!(doc.get("a"), Some(&Yaml::Int(1)));
}

#[test]
fn hash_inside_quotes_kept() {
    let doc = parse("a: \"x # y\"\n").unwrap();
    assert_eq!(doc.get("a"), Some(&s("x # y")));
}

#[test]
fn nested_mapping() {
    let doc = parse("outer:\n  inner:\n    k: 3\n").unwrap();
    let v = doc.get("outer").unwrap().get("inner").unwrap().get("k");
    assert_eq!(v, Some(&Yaml::Int(3)));
}

#[test]
fn sequence_of_scalars() {
    let doc = parse("xs:\n  - 1\n  - 2\n  - three\n").unwrap();
    let xs = doc.get("xs").unwrap().as_seq().unwrap();
    assert_eq!(xs, &[Yaml::Int(1), Yaml::Int(2), s("three")]);
}

#[test]
fn sequence_at_key_indent() {
    // Common YAML style: list items at the same indent as the key.
    let doc = parse("tasks:\n- func: a\n- func: b\n").unwrap();
    let tasks = doc.get("tasks").unwrap().as_seq().unwrap();
    assert_eq!(tasks.len(), 2);
    assert_eq!(tasks[0].get("func"), Some(&s("a")));
    assert_eq!(tasks[1].get("func"), Some(&s("b")));
}

#[test]
fn sequence_item_multiline_mapping() {
    let doc = parse(
        "tasks:\n  - func: producer\n    nprocs: 4\n  - func: consumer\n    nprocs: 2\n",
    )
    .unwrap();
    let tasks = doc.get("tasks").unwrap().as_seq().unwrap();
    assert_eq!(tasks[0].get("nprocs"), Some(&Yaml::Int(4)));
    assert_eq!(tasks[1].get("func"), Some(&s("consumer")));
}

#[test]
fn flow_sequence() {
    let doc = parse("actions: [\"actions\", \"nyx\"]\n").unwrap();
    let v = doc.get("actions").unwrap().as_seq().unwrap();
    assert_eq!(v, &[s("actions"), s("nyx")]);
}

#[test]
fn flow_sequence_unquoted_and_numbers() {
    let doc = parse("xs: [1, 2.5, abc]\n").unwrap();
    let v = doc.get("xs").unwrap().as_seq().unwrap();
    assert_eq!(v, &[Yaml::Int(1), Yaml::Float(2.5), s("abc")]);
}

#[test]
fn glob_values_stay_strings() {
    let doc = parse("filename: plt*.h5\nname: /level_0/density\n").unwrap();
    assert_eq!(doc.get("filename"), Some(&s("plt*.h5")));
    assert_eq!(doc.get("name"), Some(&s("/level_0/density")));
}

#[test]
fn key_only_is_null() {
    let doc = parse("a:\nb: 1\n").unwrap();
    assert_eq!(doc.get("a"), Some(&Yaml::Null));
    assert_eq!(doc.get("b"), Some(&Yaml::Int(1)));
}

#[test]
fn deep_ports_structure() {
    let src = "\
tasks:
  - func: producer
    nprocs: 4
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
          - name: /group1/particles
            file: 0
            memory: 1
  - func: consumer1
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
";
    let doc = parse(src).unwrap();
    let tasks = doc.get("tasks").unwrap().as_seq().unwrap();
    assert_eq!(tasks.len(), 2);
    let out = tasks[0].get("outports").unwrap().as_seq().unwrap();
    let dsets = out[0].get("dsets").unwrap().as_seq().unwrap();
    assert_eq!(dsets.len(), 2);
    assert_eq!(dsets[1].get("name"), Some(&s("/group1/particles")));
    assert_eq!(dsets[1].get("memory"), Some(&Yaml::Int(1)));
}

#[test]
fn listing2_ensembles() {
    let src = "\
tasks:
  - func: producer
    taskCount: 4 #Only change needed to define ensembles
    nprocs: 2
    outports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
  - func: consumer
    taskCount: 2
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - name: /group1/grid
            file: 0
            memory: 1
";
    let doc = parse(src).unwrap();
    let tasks = doc.get("tasks").unwrap().as_seq().unwrap();
    assert_eq!(tasks[0].get("taskCount"), Some(&Yaml::Int(4)));
    assert_eq!(tasks[1].get("taskCount"), Some(&Yaml::Int(2)));
}

#[test]
fn errors_carry_line_numbers() {
    let err = parse("a: 1\n\tb: 2\n").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("line 2"), "{msg}");
}

#[test]
fn bad_indent_rejected() {
    assert!(parse("a:\n  b: 1\n c: 2\n").is_err());
}

#[test]
fn empty_doc_is_empty_map() {
    assert_eq!(parse("").unwrap(), Yaml::Map(vec![]));
    assert_eq!(parse("# only comments\n").unwrap(), Yaml::Map(vec![]));
}

#[test]
fn colon_in_plain_scalar_not_split() {
    let doc = parse("when: 12:30:00\n").unwrap();
    assert_eq!(doc.get("when"), Some(&s("12:30:00")));
}

#[test]
fn order_preserved() {
    let doc = parse("b: 1\na: 2\nc: 3\n").unwrap();
    let keys: Vec<_> = doc.as_map().unwrap().iter().map(|(k, _)| k.as_str()).collect();
    assert_eq!(keys, ["b", "a", "c"]);
}
