//! The parsed YAML value tree and scalar typing rules.

use super::lexer::unquote;

/// A parsed YAML value. Mappings preserve document order (Wilkins task
/// order matters for rank assignment).
#[derive(Debug, Clone, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Yaml>),
    Map(Vec<(String, Yaml)>),
}

impl Yaml {
    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(entries) => entries
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer view; also accepts exact floats like `4.0`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Yaml::Int(v) => Some(*v),
            Yaml::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Float(f) => Some(*f),
            Yaml::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            // Wilkins configs use 0/1 flags for file/memory.
            Yaml::Int(0) => Some(false),
            Yaml::Int(1) => Some(true),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::Seq(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Yaml)]> {
        match self {
            Yaml::Map(entries) => Some(entries),
            _ => None,
        }
    }

    /// Scalar rendered back to a string (for error messages).
    pub fn type_name(&self) -> &'static str {
        match self {
            Yaml::Null => "null",
            Yaml::Bool(_) => "bool",
            Yaml::Int(_) => "int",
            Yaml::Float(_) => "float",
            Yaml::Str(_) => "string",
            Yaml::Seq(_) => "sequence",
            Yaml::Map(_) => "mapping",
        }
    }
}

/// Type a scalar token: flow collection, bool, int, float, else string.
pub fn parse_scalar(token: &str) -> Yaml {
    let t = token.trim();
    if t.is_empty() || t == "~" || t == "null" {
        return Yaml::Null;
    }
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        let items = split_flow_items(inner)
            .into_iter()
            .map(|s| parse_scalar(&s))
            .collect();
        return Yaml::Seq(items);
    }
    if t.starts_with('{') && t.ends_with('}') {
        let inner = &t[1..t.len() - 1];
        let mut entries = Vec::new();
        for item in split_flow_items(inner) {
            match split_flow_pair(&item) {
                Some((k, v)) => entries.push((k, parse_scalar(&v))),
                None => entries.push((item, Yaml::Null)),
            }
        }
        return Yaml::Map(entries);
    }
    if t.starts_with('"') || t.starts_with('\'') {
        return Yaml::Str(unquote(t));
    }
    match t {
        "true" | "True" => return Yaml::Bool(true),
        "false" | "False" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(v) = t.parse::<i64>() {
        return Yaml::Int(v);
    }
    if let Ok(f) = t.parse::<f64>() {
        // Reject things like `1e` that parse oddly; f64::parse is strict
        // enough, but keep plain words such as `nan`/`inf` as strings to
        // avoid surprising config typos becoming numbers.
        let lower = t.to_ascii_lowercase();
        if !lower.contains("nan") && !lower.contains("inf") {
            return Yaml::Float(f);
        }
    }
    Yaml::Str(t.to_string())
}

/// Split `a, b, "c,d", {x: 1, y: 2}, [p, q]` into top-level items,
/// respecting quotes and nested brackets/braces.
fn split_flow_items(inner: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut quote: Option<char> = None;
    let mut depth = 0usize;
    for c in inner.chars() {
        match quote {
            Some(q) => {
                cur.push(c);
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                '"' | '\'' => {
                    quote = Some(c);
                    cur.push(c);
                }
                '[' | '{' => {
                    depth += 1;
                    cur.push(c);
                }
                ']' | '}' => {
                    depth = depth.saturating_sub(1);
                    cur.push(c);
                }
                ',' if depth == 0 => {
                    items.push(cur.trim().to_string());
                    cur.clear();
                }
                _ => cur.push(c),
            },
        }
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    items.retain(|s| !s.is_empty());
    items
}

/// Split one flow-mapping entry `key: value` at the top level.
fn split_flow_pair(item: &str) -> Option<(String, String)> {
    let bytes = item.as_bytes();
    let mut quote: Option<u8> = None;
    let mut depth = 0usize;
    for i in 0..bytes.len() {
        let c = bytes[i];
        match quote {
            Some(q) => {
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                b'"' | b'\'' => quote = Some(c),
                b'[' | b'{' => depth += 1,
                b']' | b'}' => depth = depth.saturating_sub(1),
                b':' if depth == 0 => {
                    let key = unquote(item[..i].trim());
                    let value = item[i + 1..].trim().to_string();
                    return Some((key, value));
                }
                _ => {}
            },
        }
    }
    None
}
