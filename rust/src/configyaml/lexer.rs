//! Line lexer for the YAML subset: strips comments/blank lines, records
//! indentation, and classifies each line as `key: value`, `key:`, or a
//! sequence item.

use crate::error::{Result, WilkinsError};

#[derive(Debug, Clone)]
pub struct Line {
    pub number: usize,
    pub indent: usize,
    pub kind: LineKind,
}

#[derive(Debug, Clone)]
pub enum LineKind {
    KeyValue { key: String, value: String },
    KeyOnly { key: String },
    /// `- ...`; `rest` is the text after the dash (may be empty).
    SeqItem { rest: String },
}

pub enum KeySplit {
    KeyValue { key: String, value: String },
    KeyOnly { key: String },
}

pub fn lex(src: &str) -> Result<Vec<Line>> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let number = idx + 1;
        if raw.trim_start().starts_with('#') {
            continue;
        }
        let stripped = strip_comment(raw);
        let trimmed_end = stripped.trim_end();
        if trimmed_end.trim().is_empty() {
            continue;
        }
        if trimmed_end.contains('\t') {
            return Err(WilkinsError::Yaml {
                line: number,
                msg: "tabs are not allowed for indentation".into(),
            });
        }
        let indent = trimmed_end.len() - trimmed_end.trim_start().len();
        let body = trimmed_end.trim_start();

        let kind = if body == "-" {
            LineKind::SeqItem { rest: String::new() }
        } else if let Some(rest) = body.strip_prefix("- ") {
            LineKind::SeqItem { rest: rest.trim().to_string() }
        } else {
            match split_key(body, number)? {
                Some(KeySplit::KeyValue { key, value }) => {
                    LineKind::KeyValue { key, value }
                }
                Some(KeySplit::KeyOnly { key }) => LineKind::KeyOnly { key },
                None => {
                    return Err(WilkinsError::Yaml {
                        line: number,
                        msg: format!("expected `key:` or `- item`, got {body:?}"),
                    })
                }
            }
        };
        out.push(Line { number, indent, kind });
    }
    Ok(out)
}

/// Split `key: value` / `key:` — returns None for plain scalars.
/// Respects quotes (a `:` inside quotes is not a separator) and
/// requires the colon to be followed by space/EOL, so that plain
/// scalars such as `/group1/grid:x` or `12:30:00` are not mis-split.
pub fn split_key(body: &str, line: usize) -> Result<Option<KeySplit>> {
    let bytes = body.as_bytes();
    let mut quote: Option<u8> = None;
    for i in 0..bytes.len() {
        let c = bytes[i];
        match quote {
            Some(q) => {
                if c == q {
                    quote = None;
                }
            }
            None => {
                if c == b'"' || c == b'\'' {
                    quote = Some(c);
                } else if c == b':' && (i + 1 == bytes.len() || bytes[i + 1] == b' ')
                {
                    let key = unquote(body[..i].trim());
                    if key.is_empty() {
                        return Err(WilkinsError::Yaml {
                            line,
                            msg: "empty mapping key".into(),
                        });
                    }
                    let value = body[i + 1..].trim().to_string();
                    return Ok(Some(if value.is_empty() {
                        KeySplit::KeyOnly { key }
                    } else {
                        KeySplit::KeyValue { key, value }
                    }));
                }
            }
        }
    }
    Ok(None)
}

/// Remove a trailing `#comment` that is not inside quotes.
fn strip_comment(raw: &str) -> &str {
    let bytes = raw.as_bytes();
    let mut quote: Option<u8> = None;
    for i in 0..bytes.len() {
        let c = bytes[i];
        match quote {
            Some(q) => {
                if c == q {
                    quote = None;
                }
            }
            None => {
                if c == b'"' || c == b'\'' {
                    quote = Some(c);
                } else if c == b'#' && (i == 0 || bytes[i - 1] == b' ') {
                    return &raw[..i];
                }
            }
        }
    }
    raw
}

pub fn unquote(s: &str) -> String {
    let b = s.as_bytes();
    if b.len() >= 2
        && ((b[0] == b'"' && b[b.len() - 1] == b'"')
            || (b[0] == b'\'' && b[b.len() - 1] == b'\''))
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}
