//! Flow control (paper Sec. 3.6): the credit-based streaming layer
//! between producers and consumers with disparate data rates.
//!
//! Coupled in situ tasks run concurrently; a slow consumer stalls its
//! producer. The seed reproduced the paper's three `io_freq` modes as
//! a per-serve-attempt predicate evaluated inside `Vol::serve_file`;
//! this module is the grown-up version of that decision point: a
//! per-link **policy** ([`ChannelPolicy`]), a bounded **round buffer**
//! with **credit accounting** ([`LinkState`], [`Credits`]), and a
//! deterministic **section plan** ([`Plan`]) that keeps every SPMD
//! writer rank of a producer making bit-identical buffering decisions.
//!
//! # Policies
//!
//! A channel policy is a mode plus a credit window (`depth`) plus a
//! cadence (`every`), configured per consumer inport with the YAML
//! `flow:` key (the legacy `io_freq` field is sugar that lowers onto
//! it, see [`FlowControl::lower`]):
//!
//! * [`PolicyMode::Block`] — every admitted round is delivered; the
//!   producer stalls when its credits run out. `depth: 1` is the
//!   paper's *all* strategy (serve synchronously at every close);
//!   `depth: N` lets the producer run up to `N` rounds ahead of the
//!   consumer before stalling (bounded-buffer pipelining).
//! * [`PolicyMode::DropOldest`] — at zero credits the oldest queued
//!   (undelivered) round is discarded to admit the new one.
//! * [`PolicyMode::DropNewest`] — at zero credits the *incoming*
//!   round is discarded; queued rounds keep their slots.
//! * [`PolicyMode::Latest`] — only the newest undelivered round is
//!   kept: admitting a round discards everything queued before it.
//!   This is the paper's *latest* strategy; the consumer always
//!   receives the freshest available timestep.
//!
//! `every: N` serves every Nth eligible close (the paper's *some(N)*,
//! legacy `io_freq: N`); skipped closes never reach the buffer.
//!
//! Everything this layer moves — requests, section-plan broadcasts,
//! and the data replies the pump answers between coordinated
//! sections — rides the pooled [`Payload`](crate::comm::Payload)
//! plane: round snapshots are `Arc`s (admission moves no bytes),
//! reply bodies encode into recycled pool buffers, and on socket
//! transports the frames travel vectored and are decoded as slices
//! of one pooled receive buffer (see the copy-discipline table in
//! DESIGN.md).
//!
//! # Credit accounting
//!
//! The consumer grants `depth` dataset credits per link (the grant is
//! declared in the shared workflow config, so both sides know it
//! without a startup handshake). Admitting a round to the buffer
//! consumes one credit; the round's completion — a `Done` from every
//! consumer rank — returns it. At zero credits a blocking policy
//! stalls the producer (time accounted as [`LinkStats::stalled`]) and
//! a dropping policy discards per its mode. Because credits ride on
//! the ordinary channel request/reply traffic, the accounting is
//! transport-agnostic: the in-memory backend and the socket substrate
//! (`wilkins up`) drive the exact same [`LinkState`] and behave
//! identically.
//!
//! # SPMD consistency
//!
//! Every writer rank of a producer holds its own slab of a round, so
//! all writer ranks must agree on which rounds are admitted, dropped
//! and delivered — a torn decision would hand a consumer a timestep
//! assembled from different versions. Blocking policies are
//! deterministic without coordination (no drops; deliveries are a
//! pure function of the buffer). Dropping policies are coordinated by
//! I/O rank 0: it processes its request stream, decides, and
//! broadcasts a [`Plan`] of [`PlanOp`]s over the I/O communicator;
//! the other writer ranks replay the plan against their own mailboxes
//! (the generalization of the seed's *latest* probe broadcast).

use std::collections::VecDeque;
use std::time::Duration;

use crate::comm::wire::{Reader, Writer};
use crate::error::{Result, WilkinsError};

/// The paper's legacy three-mode strategy, decoded from `io_freq`.
/// Kept as the sugar surface: it lowers onto [`ChannelPolicy`] via
/// [`FlowControl::lower`] and appears nowhere below the config layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlowControl {
    /// Serve every timestep (producer waits for the consumer).
    #[default]
    All,
    /// Serve every Nth timestep (N >= 2).
    Some(u64),
    /// Serve only the newest available timestep.
    Latest,
}

impl FlowControl {
    /// Decode the YAML `io_freq` convention: N>1 => Some(N), 1 or 0 =>
    /// All, -1 => Latest.
    pub fn from_io_freq(freq: i64) -> Result<FlowControl> {
        match freq {
            0 | 1 => Ok(FlowControl::All),
            -1 => Ok(FlowControl::Latest),
            n if n > 1 => Ok(FlowControl::Some(n as u64)),
            n => Err(WilkinsError::Config(format!(
                "io_freq must be -1, 0, 1 or N>1; got {n}"
            ))),
        }
    }

    /// Lower the legacy mode onto the policy it is sugar for:
    /// `All` => synchronous block, `Some(N)` => block every Nth,
    /// `Latest` => keep-newest.
    pub fn lower(self) -> ChannelPolicy {
        match self {
            FlowControl::All => ChannelPolicy::block(),
            FlowControl::Some(n) => ChannelPolicy::block().with_every(n),
            FlowControl::Latest => ChannelPolicy::latest(),
        }
    }

    /// Count-based part of the legacy decision (kept for callers that
    /// still reason in attempts, e.g. the ensemble admission throttle).
    pub fn serves_attempt(&self, attempt: u64) -> bool {
        match self {
            FlowControl::All => true,
            FlowControl::Some(n) => attempt % n == 0,
            FlowControl::Latest => true,
        }
    }
}

impl std::fmt::Display for FlowControl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FlowControl::All => write!(f, "all"),
            FlowControl::Some(n) => write!(f, "some({n})"),
            FlowControl::Latest => write!(f, "latest"),
        }
    }
}

/// What a link does when its credits hit zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyMode {
    /// Stall the producer until a credit returns (never drops).
    #[default]
    Block,
    /// Discard the oldest queued round to admit the new one.
    DropOldest,
    /// Discard the incoming round; queued rounds keep their slots.
    DropNewest,
    /// Keep only the newest queued round (the paper's *latest*).
    Latest,
}

impl PolicyMode {
    /// Parse the YAML `flow.policy` spelling.
    pub fn parse(s: &str) -> Result<PolicyMode> {
        match s {
            "block" => Ok(PolicyMode::Block),
            "drop-oldest" => Ok(PolicyMode::DropOldest),
            "drop-newest" => Ok(PolicyMode::DropNewest),
            "latest" => Ok(PolicyMode::Latest),
            other => Err(WilkinsError::Config(format!(
                "unknown flow policy {other:?} (expected block | drop-oldest | drop-newest | latest)"
            ))),
        }
    }

    /// Does this mode ever discard rounds instead of stalling?
    pub fn drops(&self) -> bool {
        !matches!(self, PolicyMode::Block)
    }
}

impl std::fmt::Display for PolicyMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyMode::Block => "block",
            PolicyMode::DropOldest => "drop-oldest",
            PolicyMode::DropNewest => "drop-newest",
            PolicyMode::Latest => "latest",
        })
    }
}

/// A channel's full flow-control configuration: overflow mode, credit
/// window and serve cadence. Built from the YAML `flow:` key or
/// lowered from `io_freq` ([`FlowControl::lower`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelPolicy {
    /// What to do at zero credits.
    pub mode: PolicyMode,
    /// Credit window: rounds the producer may hold in flight (>= 1).
    pub depth: usize,
    /// Serve every Nth eligible file close (>= 1; 1 = every close).
    pub every: u64,
}

impl Default for ChannelPolicy {
    fn default() -> ChannelPolicy {
        ChannelPolicy::block()
    }
}

impl ChannelPolicy {
    /// Synchronous blocking policy (the paper's *all*; the default).
    pub fn block() -> ChannelPolicy {
        ChannelPolicy { mode: PolicyMode::Block, depth: 1, every: 1 }
    }

    /// Keep-newest policy (the paper's *latest*).
    pub fn latest() -> ChannelPolicy {
        ChannelPolicy { mode: PolicyMode::Latest, depth: 1, every: 1 }
    }

    /// Builder: replace the overflow mode.
    pub fn with_mode(mut self, mode: PolicyMode) -> ChannelPolicy {
        self.mode = mode;
        self
    }

    /// Builder: replace the credit window.
    pub fn with_depth(mut self, depth: usize) -> ChannelPolicy {
        self.depth = depth;
        self
    }

    /// Builder: replace the serve cadence.
    pub fn with_every(mut self, every: u64) -> ChannelPolicy {
        self.every = every;
        self
    }

    /// Reject windows the buffer machinery cannot honor.
    pub fn validate(&self) -> Result<()> {
        if self.depth == 0 {
            return Err(WilkinsError::Config("flow depth must be >= 1".into()));
        }
        if self.every == 0 {
            return Err(WilkinsError::Config("flow every must be >= 1".into()));
        }
        Ok(())
    }
}

impl std::fmt::Display for ChannelPolicy {
    /// Renders `block`, `block depth=3`, `latest every=2`, ...
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.mode)?;
        if self.depth != 1 {
            write!(f, " depth={}", self.depth)?;
        }
        if self.every != 1 {
            write!(f, " every={}", self.every)?;
        }
        Ok(())
    }
}

/// Per-link credit ledger: `depth` credits granted by the consumer,
/// one held per in-flight round.
#[derive(Debug, Clone, Copy)]
pub struct Credits {
    granted: usize,
    in_use: usize,
}

impl Credits {
    fn new(granted: usize) -> Credits {
        Credits { granted, in_use: 0 }
    }

    /// Credits currently available for new rounds.
    pub fn available(&self) -> usize {
        self.granted.saturating_sub(self.in_use)
    }

    fn take(&mut self) {
        self.in_use += 1;
    }

    fn put_back(&mut self) {
        debug_assert!(self.in_use > 0, "credit underflow");
        self.in_use = self.in_use.saturating_sub(1);
    }
}

/// Per-link flow counters, aggregated into `VolStats` / `RunReport`.
#[derive(Debug, Clone, Default)]
pub struct LinkStats {
    /// Closes gated out by `every` (never reached the buffer).
    pub skipped: u64,
    /// Rounds admitted to the buffer.
    pub admitted: u64,
    /// Rounds discarded by a dropping policy.
    pub dropped: u64,
    /// Rounds fully consumed (Done from every consumer rank).
    pub completed: u64,
    /// Time the producer stalled waiting for credits.
    pub stalled: Duration,
    /// High-water mark of the round buffer.
    pub max_queue_depth: u64,
}

/// One buffered serve round: a version plus this rank's snapshot of
/// the file, with per-consumer-rank delivery/completion flags.
pub struct Round<S> {
    /// Channel-monotonic round version (gaps = dropped rounds).
    pub version: u64,
    /// This writer rank's slab of the round's file.
    pub snapshot: S,
    delivered: Vec<bool>,
    done: Vec<bool>,
}

impl<S> Round<S> {
    fn fully_done(&self) -> bool {
        self.done.iter().all(|&d| d)
    }
}

/// What [`LinkState::admit`] decided for a dropping policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Admission {
    /// Versions discarded from the buffer to make room.
    pub dropped: Vec<u64>,
    /// The incoming round's version if it was pushed, `None` if the
    /// incoming round itself was discarded (drop-newest at 0 credits).
    pub pushed: Option<u64>,
}

/// The per-channel flow engine: round buffer + credits + policy. `S`
/// is the rank-local snapshot type (the Vol uses its in-memory file);
/// keeping it generic keeps this layer below `lowfive`.
pub struct LinkState<S> {
    policy: ChannelPolicy,
    nconsumers: usize,
    rounds: VecDeque<Round<S>>,
    credits: Credits,
    acked: Vec<bool>,
    attempts: u64,
    next_version: u64,
    /// Link counters; the Vol folds them into its `VolStats`.
    pub stats: LinkStats,
}

impl<S> LinkState<S> {
    /// A fresh link: full credit grant, empty buffer.
    pub fn new(policy: ChannelPolicy, nconsumers: usize) -> LinkState<S> {
        LinkState {
            policy,
            nconsumers,
            rounds: VecDeque::new(),
            credits: Credits::new(policy.depth),
            acked: vec![false; nconsumers],
            attempts: 0,
            next_version: 0,
            stats: LinkStats::default(),
        }
    }

    /// The configured policy.
    pub fn policy(&self) -> ChannelPolicy {
        self.policy
    }

    /// Current credit ledger (copy).
    pub fn credits(&self) -> Credits {
        self.credits
    }

    /// Count a file close against the `every` cadence. Returns whether
    /// this close is eligible for the buffer; ineligible closes are
    /// counted as skipped.
    pub fn note_attempt(&mut self) -> bool {
        self.attempts += 1;
        let eligible = self.attempts % self.policy.every == 0;
        if !eligible {
            self.stats.skipped += 1;
        }
        eligible
    }

    /// Serve attempts so far (eligible or not).
    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    /// Rounds in the buffer that are not yet fully consumed.
    pub fn occupancy(&self) -> usize {
        self.rounds.len()
    }

    /// Unconditional push (blocking policies; callers drain after).
    /// Returns the new round's version.
    pub fn push(&mut self, snapshot: S) -> u64 {
        self.next_version += 1;
        let version = self.next_version;
        let mut round = Round {
            version,
            snapshot,
            delivered: vec![false; self.nconsumers],
            done: self.acked.clone(),
        };
        // Ranks that already acked EOF never ask again.
        for (j, &a) in self.acked.iter().enumerate() {
            if a {
                round.delivered[j] = true;
            }
        }
        self.credits.take();
        self.rounds.push_back(round);
        self.stats.admitted += 1;
        self.stats.max_queue_depth = self.stats.max_queue_depth.max(self.rounds.len() as u64);
        self.pop_completed();
        version
    }

    /// Dropping-policy admission: discard per mode when credits are
    /// exhausted, then push (unless drop-newest discarded the incoming
    /// round). Never blocks. Only I/O rank 0 calls this; other ranks
    /// replay the resulting [`Plan`].
    pub fn admit(&mut self, snapshot: S) -> Admission {
        let mut dropped = Vec::new();
        match self.policy.mode {
            PolicyMode::Block => {}
            PolicyMode::Latest => {
                // Keep only the newest: discard everything queued and
                // always admit the incoming round, even while a
                // delivered round still holds a credit — the consumer
                // must find the freshest timestep when it next asks.
                dropped.extend(self.drop_undelivered(usize::MAX));
                let version = self.push(snapshot);
                return Admission { dropped, pushed: Some(version) };
            }
            PolicyMode::DropOldest => {
                while self.credits.available() == 0 {
                    let mut v = self.drop_undelivered(1);
                    if v.is_empty() {
                        break; // everything in flight is being read
                    }
                    dropped.append(&mut v);
                }
            }
            PolicyMode::DropNewest => {}
        }
        if self.credits.available() == 0 && self.policy.mode != PolicyMode::Block {
            self.stats.dropped += 1;
            return Admission { dropped, pushed: None };
        }
        let version = self.push(snapshot);
        Admission { dropped, pushed: Some(version) }
    }

    /// Discard up to `max` oldest undelivered rounds; returns their
    /// versions (oldest first).
    fn drop_undelivered(&mut self, max: usize) -> Vec<u64> {
        let mut dropped = Vec::new();
        while dropped.len() < max {
            let pos = {
                let acked = &self.acked;
                self.rounds.iter().position(|r| {
                    r.delivered
                        .iter()
                        .zip(acked.iter())
                        .all(|(&d, &a)| a || !d)
                })
            };
            let Some(pos) = pos else {
                break;
            };
            let r = self.rounds.remove(pos).unwrap();
            dropped.push(r.version);
            self.credits.put_back();
            self.stats.dropped += 1;
        }
        dropped
    }

    /// Replay a drop decided by I/O rank 0 (exact version).
    pub fn drop_version(&mut self, version: u64) -> Result<()> {
        let pos = self
            .rounds
            .iter()
            .position(|r| r.version == version)
            .ok_or_else(|| {
                WilkinsError::LowFive(format!("flow plan drops unknown round v{version}"))
            })?;
        self.rounds.remove(pos);
        self.credits.put_back();
        self.stats.dropped += 1;
        Ok(())
    }

    /// Count an incoming round discarded by drop-newest (replay side).
    pub fn note_drop_incoming(&mut self) {
        self.stats.dropped += 1;
    }

    /// Record producer stall time (blocked waiting for credits).
    pub fn note_stall(&mut self, d: Duration) {
        self.stats.stalled += d;
    }

    /// The round consumer rank `j`'s next `MetaReq` should receive:
    /// the oldest round with `version >= min_version` not yet
    /// delivered to `j`. Deterministic across writer ranks because
    /// buffers are kept identical.
    pub fn choose_deliver(&self, j: usize, min_version: u64) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.version >= min_version && !r.delivered[j])
            .map(|r| r.version)
    }

    /// Mark round `version` as being read by consumer rank `j`.
    pub fn mark_delivered(&mut self, version: u64, j: usize) -> Result<()> {
        let r = self.round_mut(version)?;
        r.delivered[j] = true;
        Ok(())
    }

    /// Absorb a `Done{version}` from consumer rank `j`. Returns `true`
    /// when the round completed (every rank done) and was retired. A
    /// Done for an already-retired round (another rank's EofAck can
    /// complete it first) is stale and ignored.
    pub fn mark_done(&mut self, version: u64, j: usize) -> Result<bool> {
        let Some(r) = self.rounds.iter_mut().find(|r| r.version == version) else {
            return Ok(false); // stale: round already retired
        };
        r.done[j] = true;
        r.delivered[j] = true;
        Ok(self.pop_completed() > 0)
    }

    /// Absorb an `EofAck` from consumer rank `j`: it will never
    /// request again, so it counts as done for every queued round.
    pub fn mark_eof(&mut self, j: usize) {
        self.acked[j] = true;
        for r in &mut self.rounds {
            r.done[j] = true;
            r.delivered[j] = true;
        }
        self.pop_completed();
    }

    /// How many consumer ranks have acknowledged EOF.
    pub fn acked_count(&self) -> usize {
        self.acked.iter().filter(|&&a| a).count()
    }

    /// Size of the consumer side of this link.
    pub fn nconsumers(&self) -> usize {
        self.nconsumers
    }

    /// The round consumer rank `j` currently has open (delivered, not
    /// done) — where its `DataReq`s are answered from.
    pub fn open_round(&self, j: usize) -> Option<&Round<S>> {
        self.rounds.iter().find(|r| r.delivered[j] && !r.done[j])
    }

    /// The buffered round with this version, if still queued.
    pub fn round(&self, version: u64) -> Option<&Round<S>> {
        self.rounds.iter().find(|r| r.version == version)
    }

    fn round_mut(&mut self, version: u64) -> Result<&mut Round<S>> {
        self.rounds
            .iter_mut()
            .find(|r| r.version == version)
            .ok_or_else(|| WilkinsError::LowFive(format!("flow event for unknown round v{version}")))
    }

    /// Retire fully-done rounds from the front (completions form a
    /// prefix: consumer ranks finish rounds in version order). Returns
    /// how many rounds retired.
    fn pop_completed(&mut self) -> usize {
        let mut n = 0;
        while self.rounds.front().is_some_and(Round::fully_done) {
            self.rounds.pop_front();
            self.credits.put_back();
            self.stats.completed += 1;
            n += 1;
        }
        n
    }
}

/// One step of a dropping-policy section, decided by I/O rank 0 and
/// replayed verbatim by every other writer rank. The per-consumer ops
/// appear in rank 0's processing order, which matches each consumer
/// rank's send order (per-pair FIFO), so replay is a sequential read
/// of each consumer's request stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanOp {
    /// Answer consumer rank `j`'s next `MetaReq` with round `version`.
    Deliver { j: u64, version: u64 },
    /// Absorb `Done{version}` from consumer rank `j`.
    Done { j: u64, version: u64 },
    /// Absorb `EofAck` from consumer rank `j`.
    Eof { j: u64 },
    /// Discard buffered round `version`.
    Drop { version: u64 },
    /// Push the incoming round; its version must come out as given.
    Push { version: u64 },
    /// Discard the incoming round (drop-newest at zero credits).
    DropIncoming,
}

/// A full section plan: the ops of one producer file close on a
/// dropping-policy channel.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Plan {
    /// Section steps in rank 0's processing order.
    pub ops: Vec<PlanOp>,
}

impl Plan {
    /// Wire form for the I/O-communicator broadcast.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.put_u64(self.ops.len() as u64);
        for op in &self.ops {
            match op {
                PlanOp::Deliver { j, version } => {
                    w.put_u8(0);
                    w.put_u64(*j);
                    w.put_u64(*version);
                }
                PlanOp::Done { j, version } => {
                    w.put_u8(1);
                    w.put_u64(*j);
                    w.put_u64(*version);
                }
                PlanOp::Eof { j } => {
                    w.put_u8(2);
                    w.put_u64(*j);
                }
                PlanOp::Drop { version } => {
                    w.put_u8(3);
                    w.put_u64(*version);
                }
                PlanOp::Push { version } => {
                    w.put_u8(4);
                    w.put_u64(*version);
                }
                PlanOp::DropIncoming => w.put_u8(5),
            }
        }
        w.into_vec()
    }

    /// Decode a broadcast section plan.
    pub fn decode(buf: &[u8]) -> Result<Plan> {
        let mut r = Reader::new(buf);
        let n = r.get_u64()? as usize;
        let mut ops = Vec::with_capacity(n);
        for _ in 0..n {
            ops.push(match r.get_u8()? {
                0 => PlanOp::Deliver { j: r.get_u64()?, version: r.get_u64()? },
                1 => PlanOp::Done { j: r.get_u64()?, version: r.get_u64()? },
                2 => PlanOp::Eof { j: r.get_u64()? },
                3 => PlanOp::Drop { version: r.get_u64()? },
                4 => PlanOp::Push { version: r.get_u64()? },
                5 => PlanOp::DropIncoming,
                c => {
                    return Err(WilkinsError::LowFive(format!("bad flow plan op code {c}")))
                }
            });
        }
        Ok(Plan { ops })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_freq_decoding() {
        assert_eq!(FlowControl::from_io_freq(0).unwrap(), FlowControl::All);
        assert_eq!(FlowControl::from_io_freq(1).unwrap(), FlowControl::All);
        assert_eq!(FlowControl::from_io_freq(-1).unwrap(), FlowControl::Latest);
        assert_eq!(FlowControl::from_io_freq(5).unwrap(), FlowControl::Some(5));
        assert!(FlowControl::from_io_freq(-3).is_err());
    }

    /// The satellite equivalence: `io_freq` sugar lowers onto exactly
    /// the policies the docs promise.
    #[test]
    fn io_freq_lowering_equivalence() {
        assert_eq!(FlowControl::All.lower(), ChannelPolicy::block());
        assert_eq!(
            FlowControl::Some(5).lower(),
            ChannelPolicy { mode: PolicyMode::Block, depth: 1, every: 5 }
        );
        assert_eq!(FlowControl::Latest.lower(), ChannelPolicy::latest());
        // And the lowered cadence matches the legacy predicate.
        let legacy = FlowControl::Some(3);
        let lowered = legacy.lower();
        let mut link: LinkState<()> = LinkState::new(lowered, 1);
        let legacy_served: Vec<u64> =
            (1..=9).filter(|&a| legacy.serves_attempt(a)).collect();
        let mut lowered_served = Vec::new();
        for _ in 1..=9 {
            if link.note_attempt() {
                lowered_served.push(link.attempts());
            }
        }
        assert_eq!(legacy_served, lowered_served);
    }

    #[test]
    fn policy_parse_and_validate() {
        assert_eq!(PolicyMode::parse("block").unwrap(), PolicyMode::Block);
        assert_eq!(PolicyMode::parse("drop-oldest").unwrap(), PolicyMode::DropOldest);
        assert_eq!(PolicyMode::parse("drop-newest").unwrap(), PolicyMode::DropNewest);
        assert_eq!(PolicyMode::parse("latest").unwrap(), PolicyMode::Latest);
        assert!(PolicyMode::parse("yolo").is_err());
        assert!(ChannelPolicy::block().with_depth(0).validate().is_err());
        assert!(ChannelPolicy::block().with_every(0).validate().is_err());
        assert!(ChannelPolicy::block().with_depth(3).validate().is_ok());
    }

    #[test]
    fn policy_display() {
        assert_eq!(ChannelPolicy::block().to_string(), "block");
        assert_eq!(ChannelPolicy::block().with_depth(3).to_string(), "block depth=3");
        assert_eq!(
            ChannelPolicy::latest().with_every(2).to_string(),
            "latest every=2"
        );
    }

    #[test]
    fn block_credits_round_trip() {
        let mut link: LinkState<u64> = LinkState::new(ChannelPolicy::block().with_depth(2), 2);
        assert_eq!(link.credits().available(), 2);
        let v1 = link.push(10);
        assert_eq!(v1, 1);
        assert_eq!(link.credits().available(), 1);
        let v2 = link.push(20);
        assert_eq!(link.credits().available(), 0);
        assert_eq!(link.occupancy(), 2);
        // Deliver + complete v1 on both consumer ranks.
        assert_eq!(link.choose_deliver(0, 1), Some(1));
        link.mark_delivered(1, 0).unwrap();
        assert!(!link.mark_done(1, 0).unwrap());
        assert!(link.mark_done(1, 1).unwrap());
        assert_eq!(link.credits().available(), 1);
        assert_eq!(link.occupancy(), 1);
        assert_eq!(link.stats.completed, 1);
        // The next deliverable for rank 0 is v2.
        assert_eq!(link.choose_deliver(0, 2), Some(v2));
    }

    #[test]
    fn latest_keeps_only_newest_undelivered() {
        let mut link: LinkState<u64> = LinkState::new(ChannelPolicy::latest(), 1);
        let a1 = link.admit(10);
        assert_eq!(a1, Admission { dropped: vec![], pushed: Some(1) });
        let a2 = link.admit(20);
        assert_eq!(a2, Admission { dropped: vec![1], pushed: Some(2) });
        let a3 = link.admit(30);
        assert_eq!(a3, Admission { dropped: vec![2], pushed: Some(3) });
        assert_eq!(link.occupancy(), 1);
        assert_eq!(link.stats.dropped, 2);
        // A delivered (in-flight) round is never discarded.
        link.mark_delivered(3, 0).unwrap();
        let a4 = link.admit(40);
        assert_eq!(a4.dropped, Vec::<u64>::new());
        assert_eq!(a4.pushed, Some(4));
        assert_eq!(link.occupancy(), 2);
    }

    #[test]
    fn drop_newest_discards_incoming() {
        let mut link: LinkState<u64> = LinkState::new(
            ChannelPolicy::block().with_mode(PolicyMode::DropNewest).with_depth(1),
            1,
        );
        assert_eq!(link.admit(10).pushed, Some(1));
        let a = link.admit(20);
        assert_eq!(a, Admission { dropped: vec![], pushed: None });
        assert_eq!(link.stats.dropped, 1);
        assert_eq!(link.occupancy(), 1);
        assert_eq!(link.round(1).unwrap().snapshot, 10);
    }

    #[test]
    fn drop_oldest_frees_a_slot() {
        let mut link: LinkState<u64> = LinkState::new(
            ChannelPolicy::block().with_mode(PolicyMode::DropOldest).with_depth(2),
            1,
        );
        link.admit(10);
        link.admit(20);
        let a = link.admit(30);
        assert_eq!(a, Admission { dropped: vec![1], pushed: Some(3) });
        assert_eq!(link.occupancy(), 2);
        assert_eq!(link.stats.max_queue_depth, 2);
    }

    #[test]
    fn eof_ack_retires_rounds() {
        let mut link: LinkState<u64> = LinkState::new(ChannelPolicy::block().with_depth(3), 2);
        link.push(1);
        link.push(2);
        link.mark_eof(1);
        assert_eq!(link.occupancy(), 2); // rank 0 still owes Dones
        link.mark_delivered(1, 0).unwrap();
        assert!(link.mark_done(1, 0).unwrap());
        assert!(link.mark_done(2, 0).unwrap());
        assert_eq!(link.occupancy(), 0);
        // Rounds pushed after an ack never wait on the acked rank.
        let v = link.push(3);
        assert!(link.mark_done(v, 0).unwrap());
    }

    #[test]
    fn plan_roundtrip() {
        let plan = Plan {
            ops: vec![
                PlanOp::Done { j: 1, version: 4 },
                PlanOp::Deliver { j: 0, version: 5 },
                PlanOp::Drop { version: 6 },
                PlanOp::Push { version: 7 },
                PlanOp::Eof { j: 2 },
                PlanOp::DropIncoming,
            ],
        };
        assert_eq!(Plan::decode(&plan.encode()).unwrap(), plan);
        assert_eq!(Plan::decode(&Plan::default().encode()).unwrap(), Plan::default());
    }
}
