//! Flow control strategies (paper Sec. 3.6, substrate S7).
//!
//! Coupled in situ tasks run concurrently; a slow consumer stalls its
//! producer. Wilkins offers three strategies, selected per channel with
//! the YAML `io_freq` field on the consumer inport:
//!
//! * **All** (`io_freq: 0|1` or absent) — serve every timestep; the
//!   producer blocks until the consumer is done (the default).
//! * **Some(N)** (`io_freq: N>1`) — serve every Nth timestep.
//! * **Latest** (`io_freq: -1`) — serve only when a consumer request is
//!   already pending; otherwise drop this timestep and move on.
//!
//! The decision is evaluated *per serve attempt* (once per producer
//! timestep), inside `Vol::serve_file`, so it composes with custom I/O
//! actions such as the Nyx double-close pattern (Sec. 4.2.2). For
//! *Latest*, producer I/O rank 0 probes for pending requests and
//! broadcasts the verdict over the I/O communicator so all writer
//! ranks skip or serve in lockstep (divergent decisions would tear a
//! timestep apart).

use crate::error::{Result, WilkinsError};

/// A channel's flow-control strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FlowControl {
    /// Serve every timestep (producer waits for the consumer).
    #[default]
    All,
    /// Serve every Nth timestep (N >= 2).
    Some(u64),
    /// Serve only when a consumer is already waiting.
    Latest,
}

impl FlowControl {
    /// Decode the YAML `io_freq` convention: N>1 => Some(N), 1 or 0 =>
    /// All, -1 => Latest.
    pub fn from_io_freq(freq: i64) -> Result<FlowControl> {
        match freq {
            0 | 1 => Ok(FlowControl::All),
            -1 => Ok(FlowControl::Latest),
            n if n > 1 => Ok(FlowControl::Some(n as u64)),
            n => Err(WilkinsError::Config(format!(
                "io_freq must be -1, 0, 1 or N>1; got {n}"
            ))),
        }
    }

    /// Count-based part of the decision (All/Some). Latest needs the
    /// pending-request probe and is resolved by the Vol.
    pub fn serves_attempt(&self, attempt: u64) -> bool {
        match self {
            FlowControl::All => true,
            FlowControl::Some(n) => attempt % n == 0,
            FlowControl::Latest => true, // refined by the probe
        }
    }
}

impl std::fmt::Display for FlowControl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FlowControl::All => write!(f, "all"),
            FlowControl::Some(n) => write!(f, "some({n})"),
            FlowControl::Latest => write!(f, "latest"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn io_freq_decoding() {
        assert_eq!(FlowControl::from_io_freq(0).unwrap(), FlowControl::All);
        assert_eq!(FlowControl::from_io_freq(1).unwrap(), FlowControl::All);
        assert_eq!(FlowControl::from_io_freq(-1).unwrap(), FlowControl::Latest);
        assert_eq!(FlowControl::from_io_freq(5).unwrap(), FlowControl::Some(5));
        assert!(FlowControl::from_io_freq(-3).is_err());
    }

    #[test]
    fn some_serves_every_nth() {
        let f = FlowControl::Some(3);
        let served: Vec<u64> = (1..=9).filter(|&a| f.serves_attempt(a)).collect();
        assert_eq!(served, vec![3, 6, 9]);
    }

    #[test]
    fn all_serves_everything() {
        assert!((1..=10).all(|a| FlowControl::All.serves_attempt(a)));
    }
}
