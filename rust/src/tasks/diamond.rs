//! Diamond-structure feature detector (`detector`, paper Sec. 4.2.1):
//! the stateless analysis task of the nucleation ensemble.
//!
//! Each invocation handles exactly one dump: the ranks read their row
//! split of the particle positions in parallel (exercising the M-to-N
//! redistribution), gather to rank 0, and rank 0 runs the AOT
//! `diamond_detector` payload (L1 Pallas coordination-counting kernel)
//! to count atoms in diamond-lattice coordination — the nucleation
//! signal.

use crate::error::{Result, WilkinsError};
use crate::henson::TaskContext;
use crate::lowfive::split_rows;

use super::bytes_to_f32s;

pub const FILE: &str = "dump-h5md.h5";
pub const POSITIONS: &str = "/particles/position";

pub fn detector(ctx: &mut TaskContext) -> Result<()> {
    let name = match ctx.vol.file_open(FILE) {
        Ok(n) => n,
        // Stateful use (launched once): drain until EOF ourselves.
        Err(WilkinsError::EndOfStream) => return Ok(()),
        Err(e) => return Err(e),
    };
    let meta = ctx.vol.dataset_meta(&name, POSITIONS)?;
    let want = split_rows(&meta.dims, ctx.size())[ctx.rank()].clone();
    let bytes = ctx.vol.dataset_read(&name, POSITIONS, &want)?;
    let timestep = ctx
        .vol
        .consumer_file(&name)?
        .attr("timestep")
        .and_then(|a| a.as_i64())
        .unwrap_or(-1);
    ctx.vol.file_close(&name)?;

    // Gather the slabs to rank 0 (in rank order == row order).
    let gathered = ctx.comm.gather(0, &bytes)?;
    if let Some(parts) = gathered {
        let mut pos: Vec<f32> = Vec::with_capacity(meta.element_count() as usize);
        for p in parts {
            pos.extend(bytes_to_f32s(&p));
        }
        let engine = ctx.engine()?.clone();
        let out = ctx.compute("diamond_detector", || {
            engine.run("diamond_detector", vec![pos])
        })?;
        let stats = &out[0];
        log::info!(
            "{}: dump t={} n_crystal={} mean_coord={:.3}",
            ctx.name,
            timestep,
            stats[0],
            stats[1]
        );
    }
    Ok(())
}
