//! Built-in task codes (S13): the user programs the paper couples.
//!
//! Each is written the way the paper demands — standalone SPMD code
//! that only talks to its restricted-world communicator and the
//! HDF5-like Vol API, with zero workflow awareness. The coordinator
//! resolves them by their YAML `func` name from [`builtin_registry`].

pub mod diamond;
pub mod lammps_proxy;
pub mod nyx_proxy;
pub mod reeber_proxy;
pub mod synthetic;

use crate::comm::Comm;
use crate::error::Result;
use crate::henson::Registry;
use crate::lowfive::Hyperslab;

/// Redistribute per-rank slabs onto the writer subset (Sec. 3.2.2):
/// every rank contributes its `(slab, bytes)`; the first `nwriters`
/// ranks return the collected list to write, others return empty.
/// This is the "LAMMPS gathers all data to rank 0" pattern, built on
/// the task's restricted world only — no workflow API involved.
pub fn gather_to_writers(
    comm: &Comm,
    nwriters: usize,
    slab: Hyperslab,
    bytes: Vec<u8>,
) -> Result<Vec<(Hyperslab, Vec<u8>)>> {
    let mut w = crate::comm::wire::Writer::with_capacity(bytes.len() + 64);
    slab.encode(&mut w);
    w.put_bytes(&bytes);
    let gathered = comm.gather(0, &w.into_vec())?;
    match gathered {
        None => Ok(Vec::new()),
        Some(parts) => {
            // Rank 0 fans the contributions out round-robin over the
            // writer subset (itself included).
            let mut per_writer: Vec<Vec<(Hyperslab, Vec<u8>)>> =
                vec![Vec::new(); nwriters.max(1)];
            for (i, part) in parts.into_iter().enumerate() {
                let mut r = crate::comm::wire::Reader::new(&part);
                let s = Hyperslab::decode(&mut r)?;
                let b = r.get_bytes()?.to_vec();
                per_writer[i % nwriters.max(1)].push((s, b));
            }
            for (widx, blocks) in per_writer.iter().enumerate().skip(1) {
                let mut w = crate::comm::wire::Writer::new();
                w.put_u64(blocks.len() as u64);
                for (s, b) in blocks {
                    s.encode(&mut w);
                    w.put_bytes(b);
                }
                comm.send_owned(widx, WRITER_TAG, w.into_vec());
            }
            Ok(per_writer.swap_remove(0))
        }
    }
    .and_then(|mine| {
        if comm.rank() == 0 || comm.rank() >= nwriters {
            return Ok(mine);
        }
        // Non-zero writer ranks receive their share from rank 0.
        let (_, buf) = comm.recv(0, WRITER_TAG)?;
        let mut r = crate::comm::wire::Reader::new(&buf);
        let n = r.get_u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let s = Hyperslab::decode(&mut r)?;
            out.push((s, r.get_bytes()?.to_vec()));
        }
        Ok(out)
    })
}

/// Reserved user tag for the writer-subset redistribution.
const WRITER_TAG: u64 = 1_000_001;

/// Registry with every built-in task code under its paper name.
pub fn builtin_registry() -> Registry {
    let mut r = Registry::new();
    // Synthetic benchmark pair (Sec. 4.1). The listings use several
    // consumer names; they all run the same code.
    r.register_fn("producer", synthetic::producer);
    r.register_fn("consumer", synthetic::consumer);
    r.register_fn("consumer1", synthetic::consumer);
    r.register_fn("consumer2", synthetic::consumer);
    // Materials science (Sec. 4.2.1).
    r.register_fn("freeze", lammps_proxy::freeze);
    r.register_fn("detector", diamond::detector);
    // Cosmology (Sec. 4.2.2).
    r.register_fn("nyx", nyx_proxy::nyx);
    r.register_fn("reeber", reeber_proxy::reeber);
    r
}

// ---- byte conversion helpers (shared by the task codes) --------------------

pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len() * 4];
    for (dst, v) in out.chunks_exact_mut(4).zip(xs) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
    out
}

/// Fill a fresh buffer with little-endian u64s produced by `f(i)` for
/// i in [0, n) — the fast path for synthetic data generation (§Perf
/// iteration 4: chunked writes instead of per-byte iterators).
pub fn gen_u64_bytes(n: u64, f: impl Fn(u64) -> u64) -> Vec<u8> {
    let mut out = vec![0u8; n as usize * 8];
    for (i, dst) in out.chunks_exact_mut(8).enumerate() {
        dst.copy_from_slice(&f(i as u64).to_le_bytes());
    }
    out
}

/// Same for f32 values.
pub fn gen_f32_bytes(n: u64, f: impl Fn(u64) -> f32) -> Vec<u8> {
    let mut out = vec![0u8; n as usize * 4];
    for (i, dst) in out.chunks_exact_mut(4).enumerate() {
        dst.copy_from_slice(&f(i as u64).to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

pub fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = vec![0u8; xs.len() * 8];
    for (dst, v) in out.chunks_exact_mut(8).zip(xs) {
        dst.copy_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}
