//! Synthetic producer/consumer pair (paper Sec. 4.1).
//!
//! The producer generates the paper's two datasets per timestep — a
//! regular grid of 64-bit unsigned integers and a list of particles,
//! each a 3-vector of f32 (8 B and 12 B per element; 10^6 of each per
//! producer rank = 19 MiB/rank at paper scale) — writes them with a
//! row-split hyperslab decomposition and closes the file, which is
//! where LowFive serves the data. The consumer opens, reads its own
//! row split of both datasets and closes.
//!
//! `params:` knobs (all optional):
//!   steps            timesteps to produce/consume        (default 1)
//!   grid_per_proc    grid elements per producer rank     (default 10^4)
//!   particles_per_proc particles per producer rank       (default 10^4)
//!   sleep_s          emulated compute seconds per step   (default 0)
//!   hold_s           consumer-only: analysis seconds spent
//!                    BEFORE closing, holding the serve round
//!                    open (producer backpressure; flow-control
//!                    benches)                            (default 0)
//!   extra_dset       producer-only: also write a third dataset
//!                    (/group1/extra, grid-valued) — lets configs mix
//!                    three per-dataset routes in one channel
//!                    (configs/mixed_transport.yaml)      (default 0)
//!   verify           consumer checks data values         (default 1)

use crate::error::{Result, WilkinsError};
use crate::henson::TaskContext;
use crate::lowfive::{split_rows, DType, Hyperslab};

use super::{bytes_to_f32s, bytes_to_u64s};

pub const FILE: &str = "outfile.h5";
pub const GRID: &str = "/group1/grid";
pub const PARTICLES: &str = "/group1/particles";
/// Optional third dataset (`extra_dset: 1`), grid-valued; exists so
/// one channel can mix memory / file / write-through routes.
pub const EXTRA: &str = "/group1/extra";

fn grid_value(global_idx: u64, step: u64) -> u64 {
    global_idx * 10 + step
}

fn particle_value(flat_idx: u64, step: u64) -> f32 {
    (flat_idx % 1000) as f32 + step as f32 * 0.5
}

pub fn producer(ctx: &mut TaskContext) -> Result<()> {
    let steps = ctx.param_i64("steps", 1) as u64;
    let gpp = ctx.param_i64("grid_per_proc", 10_000) as u64;
    let ppp = ctx.param_i64("particles_per_proc", 10_000) as u64;
    let sleep_s = ctx.param_f64("sleep_s", 0.0);
    let extra = ctx.param_i64("extra_dset", 0) != 0;
    let nprocs = ctx.size() as u64;
    let rank = ctx.rank();
    let gdims = [gpp * nprocs];
    let pdims = [ppp * nprocs, 3];
    let gslab = split_rows(&gdims, nprocs as usize)[rank].clone();
    let pslab = split_rows(&pdims, nprocs as usize)[rank].clone();

    for step in 0..steps {
        if sleep_s > 0.0 {
            ctx.sleep_compute("produce", sleep_s);
        }
        let goff = gslab.offset[0];
        let grid = super::gen_u64_bytes(gslab.count[0], |i| grid_value(goff + i, step));
        let poff = pslab.offset[0] * 3;
        let parts =
            super::gen_f32_bytes(pslab.count[0] * 3, |k| particle_value(poff + k, step));
        // Subset writers: redistribute every rank's slab onto the
        // writer subset first (the LAMMPS gather pattern, Sec. 3.2.2).
        let nwriters = ctx.nwriters;
        let (gblocks, pblocks) = if nwriters < ctx.size() {
            (
                super::gather_to_writers(&ctx.comm, nwriters, gslab.clone(), grid)?,
                super::gather_to_writers(&ctx.comm, nwriters, pslab.clone(), parts)?,
            )
        } else {
            (vec![(gslab.clone(), grid)], vec![(pslab.clone(), parts)])
        };
        if ctx.vol.is_io_rank() {
            let vol = &mut ctx.vol;
            vol.file_create(FILE)?;
            vol.attr_write(FILE, "timestep", crate::lowfive::AttrValue::Int(step as i64))?;
            vol.dataset_create(FILE, GRID, DType::U64, &gdims)?;
            vol.dataset_create(FILE, PARTICLES, DType::F32, &pdims)?;
            if extra {
                vol.dataset_create(FILE, EXTRA, DType::U64, &gdims)?;
                for (s, b) in &gblocks {
                    vol.dataset_write(FILE, EXTRA, s.clone(), b.clone())?;
                }
            }
            for (s, b) in gblocks {
                vol.dataset_write(FILE, GRID, s, b)?;
            }
            for (s, b) in pblocks {
                vol.dataset_write(FILE, PARTICLES, s, b)?;
            }
            vol.file_close(FILE)?;
        }
    }
    Ok(())
}

pub fn consumer(ctx: &mut TaskContext) -> Result<()> {
    let sleep_s = ctx.param_f64("sleep_s", 0.0);
    let hold_s = ctx.param_f64("hold_s", 0.0);
    let verify = ctx.param_i64("verify", 1) != 0;
    let nprocs = ctx.size();
    let rank = ctx.rank();
    loop {
        let name = match ctx.vol.file_open(FILE) {
            Ok(n) => n,
            Err(WilkinsError::EndOfStream) => return Ok(()),
            Err(e) => return Err(e),
        };
        let step = ctx
            .vol
            .consumer_file(&name)?
            .attr("timestep")
            .and_then(|a| a.as_i64())
            .unwrap_or(0) as u64;

        for dset in ctx.vol.consumer_file(&name)?.dataset_names() {
            let meta = ctx.vol.dataset_meta(&name, &dset)?;
            let want = split_rows(&meta.dims, nprocs)[rank].clone();
            let bytes = ctx.vol.dataset_read(&name, &dset, &want)?;
            if verify {
                verify_dset(&dset, &want, &bytes, step)?;
            }
        }
        // `hold_s` analyzes while the round is still open — the
        // producer's credit is held for the full analysis, which is
        // what a bounded credit window exists to overlap.
        if hold_s > 0.0 {
            ctx.sleep_compute("analyze-held", hold_s);
        }
        // Close first (releases the producer's serve round), then
        // analyze: the paper's consumers compute after receiving data.
        ctx.vol.file_close(&name)?;
        if sleep_s > 0.0 {
            ctx.sleep_compute("analyze", sleep_s);
        }
    }
}

fn verify_dset(dset: &str, want: &Hyperslab, bytes: &[u8], step: u64) -> Result<()> {
    let bad = |msg: String| Err(WilkinsError::Task(format!("verify {dset}: {msg}")));
    match dset {
        // The extra dataset carries grid values (see `producer`).
        GRID | EXTRA => {
            let vals = bytes_to_u64s(bytes);
            for (k, &v) in vals.iter().enumerate() {
                let expect = grid_value(want.offset[0] + k as u64, step);
                if v != expect {
                    return bad(format!("at {k}: {v} != {expect}"));
                }
            }
        }
        PARTICLES => {
            let vals = bytes_to_f32s(bytes);
            for (k, &v) in vals.iter().enumerate() {
                let expect = particle_value(want.offset[0] * 3 + k as u64, step);
                if v != expect {
                    return bad(format!("at {k}: {v} != {expect}"));
                }
            }
        }
        _ => {}
    }
    Ok(())
}
