//! LAMMPS proxy (`freeze`, paper Sec. 4.2.1): molecular-dynamics
//! producer for the nucleation ensemble.
//!
//! Mirrors LAMMPS's I/O scheme: all ranks advance the simulation, the
//! data are gathered to rank 0, and rank 0 alone writes the dump
//! (`nwriters: 1` in the YAML — Wilkins' subset-writers feature). The
//! MD physics is the AOT-compiled `md_step` payload (L2 JAX leapfrog
//! over the L1 Pallas pairwise-LJ kernel, N=4096 atoms; the paper uses
//! a 4,360-atom water model).
//!
//! `params:`
//!   dumps           analysis dumps to produce            (default 3)
//!   execs_per_dump  md_step executions between dumps     (default 1;
//!                   each fuses MD_UNROLL=10 leapfrog steps)
//!   seed            per-instance initial-condition seed offset

use crate::error::Result;
use crate::henson::TaskContext;
use crate::lowfive::{AttrValue, DType, Hyperslab};

use super::f32s_to_bytes;

pub const FILE: &str = "dump-h5md.h5";
pub const POSITIONS: &str = "/particles/position";

pub const N_ATOMS: usize = 4096;
pub const BOX: f32 = 18.0;

/// Deterministic jittered-lattice initial condition; the per-instance
/// seed varies the jitter (the ensemble's "different initial
/// configurations" hunting for a rare nucleation event).
pub fn init_positions(seed: u64) -> Vec<f32> {
    let nside = 16; // 16^3 == N_ATOMS
    let spacing = BOX / nside as f32;
    let mut pos = Vec::with_capacity(N_ATOMS * 3);
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
    let mut next = || {
        // xorshift64*
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        let v = state.wrapping_mul(0x2545F4914F6CDD1D);
        ((v >> 40) as f32 / (1u64 << 24) as f32) - 0.5
    };
    for i in 0..nside {
        for j in 0..nside {
            for k in 0..nside {
                pos.push((i as f32 + 0.5) * spacing + 0.1 * spacing * next());
                pos.push((j as f32 + 0.5) * spacing + 0.1 * spacing * next());
                pos.push((k as f32 + 0.5) * spacing + 0.1 * spacing * next());
            }
        }
    }
    pos
}

pub fn freeze(ctx: &mut TaskContext) -> Result<()> {
    let dumps = ctx.param_i64("dumps", 3) as u64;
    let execs = ctx.param_i64("execs_per_dump", 1).max(1) as u64;
    let seed = ctx.param_i64("seed", 0) as u64 + ctx.instance as u64;

    // Simulation state lives on rank 0 (LAMMPS gathers there anyway);
    // the other ranks participate in the stepping barrier so the whole
    // task advances in lockstep like a real domain-decomposed run.
    let mut pos = init_positions(seed);
    let mut vel = vec![0.0f32; N_ATOMS * 3];

    for t in 0..dumps {
        for _ in 0..execs {
            if ctx.rank() == 0 {
                let engine = ctx.engine()?.clone();
                let out = ctx.compute("md_step", || {
                    engine.run("md_step", vec![pos.clone(), vel.clone()])
                })?;
                pos = out[0].clone();
                vel = out[1].clone();
            }
            ctx.comm.barrier()?;
        }
        // Dump: rank 0 writes serially (subset writers).
        if ctx.vol.is_io_rank() {
            let vol = &mut ctx.vol;
            vol.file_create(FILE)?;
            vol.attr_write(FILE, "timestep", AttrValue::Int(t as i64))?;
            vol.attr_write(FILE, "instance", AttrValue::Int(ctx.instance as i64))?;
            vol.dataset_create(FILE, POSITIONS, DType::F32, &[N_ATOMS as u64, 3])?;
            vol.dataset_write(
                FILE,
                POSITIONS,
                Hyperslab::whole(&[N_ATOMS as u64, 3]),
                f32s_to_bytes(&pos),
            )?;
            vol.file_close(FILE)?;
        }
        ctx.comm.barrier()?;
    }
    Ok(())
}
