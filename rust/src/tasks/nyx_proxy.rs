//! Nyx proxy (`nyx`, paper Sec. 4.2.2): cosmological-simulation
//! producer with Nyx's pathological HDF5 I/O pattern.
//!
//! The physics is the AOT `nyx_step` payload (mass-conserving
//! diffusion + logistic overdensity growth on a 64^3 grid; the paper
//! runs 256^3). The I/O reproduces exactly what breaks LowFive's
//! assumptions and motivates the custom-callback feature:
//!
//!   1. rank 0 alone creates the plotfile and writes small metadata,
//!      then closes it               (file closed the 1st time);
//!   2. every rank re-opens the file collectively and writes its
//!      z-slab of the density, then closes (2nd close for rank 0).
//!
//! Without the `("actions", "nyx")` script (Listing 5) the default
//! serve-on-close would fire at the metadata close and deadlock /
//! serve torn data; with it, serving happens only after the bulk
//! writes.
//!
//! `params:`
//!   snapshots           plotfiles to produce              (default 5)
//!   steps_per_snapshot  nyx_step executions between them  (default 1)

use crate::error::Result;
use crate::henson::TaskContext;
use crate::lowfive::{split_rows, AttrValue, DType};

use super::{bytes_to_f32s, f32s_to_bytes};

pub const DENSITY: &str = "/level_0/density";
pub const GRID: u64 = 64;

/// Deterministic white-noise-around-1 initial density.
pub fn init_density() -> Vec<f32> {
    let n = (GRID * GRID * GRID) as usize;
    (0..n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
            1.0 + 0.3 * (((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5)
        })
        .collect()
}

pub fn nyx(ctx: &mut TaskContext) -> Result<()> {
    let snapshots = ctx.param_i64("snapshots", 5) as u64;
    let steps = ctx.param_i64("steps_per_snapshot", 1).max(1) as u64;
    let dims = [GRID, GRID, GRID];
    let nprocs = ctx.size();
    let rank = ctx.rank();
    let my_slab = split_rows(&dims, nprocs)[rank].clone();

    // Rank 0 holds the evolving field (the AMReX hierarchy proxy) and
    // scatters z-slabs after each evolution phase, emulating the
    // domain decomposition's owned data.
    let mut density = if rank == 0 { init_density() } else { Vec::new() };

    for t in 0..snapshots {
        // --- compute phase -------------------------------------------------
        if rank == 0 {
            let engine = ctx.engine()?.clone();
            for _ in 0..steps {
                let out = ctx.compute("nyx_step", || {
                    engine.run("nyx_step", vec![density.clone()])
                })?;
                density = out[0].clone();
            }
        }
        // Distribute the field so each rank owns its slab.
        let full = ctx.comm.bcast(
            0,
            if rank == 0 { Some(f32s_to_bytes(&density)) } else { None }
                .as_deref(),
        )?;
        let full = bytes_to_f32s(&full);
        let row = (GRID * GRID) as usize;
        let z0 = my_slab.offset[0] as usize;
        let zn = my_slab.count[0] as usize;
        let mine = &full[z0 * row..(z0 + zn) * row];

        // --- Nyx's custom I/O pattern ---------------------------------------
        let name = format!("plt{t:05}.h5");
        if rank == 0 {
            // 1st open/close: metadata only, single rank.
            ctx.vol.file_create(&name)?;
            ctx.vol.attr_write(&name, "timestep", AttrValue::Int(t as i64))?;
            ctx.vol
                .attr_write(&name, "code", AttrValue::Str("nyx-proxy".into()))?;
            ctx.vol.file_close(&name)?;
        }
        // 2nd open: collective; the nyx action moves rank 0's file
        // state to everyone in before_file_open.
        ctx.vol.producer_file_open(&name)?;
        ctx.vol.dataset_create(&name, DENSITY, DType::F32, &dims)?;
        ctx.vol
            .dataset_write(&name, DENSITY, my_slab.clone(), f32s_to_bytes(mine))?;
        ctx.vol.file_close(&name)?;
    }
    Ok(())
}
