//! Reeber proxy (`reeber`, paper Sec. 4.2.2): the halo-finding
//! consumer of the cosmology workflow.
//!
//! Each snapshot: ranks read their z-slab of the density in parallel,
//! gather to rank 0, and rank 0 runs the AOT `halo_finder` payload
//! (L1 Pallas thresholded local-max stencil). The paper intentionally
//! slowed Reeber ~100x by recomputing the halos many times to make
//! flow control visible — `analysis_rounds` reproduces that.
//!
//! `params:`
//!   analysis_rounds   halo_finder executions per snapshot (default 1;
//!                     the paper's slowed run uses 100)
//!   threshold         density threshold (default 2.0)
//!   sleep_s           extra emulated analysis seconds     (default 0)

use crate::error::{Result, WilkinsError};
use crate::henson::TaskContext;
use crate::lowfive::split_rows;

use super::bytes_to_f32s;

pub const DENSITY: &str = "/level_0/density";
pub const FILE_PATTERN: &str = "plt*.h5";

pub fn reeber(ctx: &mut TaskContext) -> Result<()> {
    let rounds = ctx.param_i64("analysis_rounds", 1).max(1);
    let threshold = ctx.param_f64("threshold", 2.0) as f32;
    let sleep_s = ctx.param_f64("sleep_s", 0.0);
    loop {
        let name = match ctx.vol.file_open(FILE_PATTERN) {
            Ok(n) => n,
            Err(WilkinsError::EndOfStream) => return Ok(()),
            Err(e) => return Err(e),
        };
        let meta = ctx.vol.dataset_meta(&name, DENSITY)?;
        let want = split_rows(&meta.dims, ctx.size())[ctx.rank()].clone();
        let bytes = ctx.vol.dataset_read(&name, DENSITY, &want)?;
        let timestep = ctx
            .vol
            .consumer_file(&name)?
            .attr("timestep")
            .and_then(|a| a.as_i64())
            .unwrap_or(-1);
        ctx.vol.file_close(&name)?;

        let gathered = ctx.comm.gather(0, &bytes)?;
        if let Some(parts) = gathered {
            let mut density: Vec<f32> = Vec::with_capacity(meta.element_count() as usize);
            for p in parts {
                density.extend(bytes_to_f32s(&p));
            }
            let engine = ctx.engine()?.clone();
            let mut stats = vec![0.0f32; 4];
            for _ in 0..rounds {
                let out = ctx.compute("halo_finder", || {
                    engine.run("halo_finder", vec![density.clone(), vec![threshold]])
                })?;
                stats = out[1].clone();
            }
            log::info!(
                "{}: snapshot t={} halos={} mass={:.1} peak={:.3}",
                ctx.name,
                timestep,
                stats[0],
                stats[1],
                stats[2]
            );
        }
        if sleep_s > 0.0 {
            ctx.sleep_compute("reeber_extra", sleep_s);
        }
        // Keep non-zero ranks in lockstep with rank 0's analysis.
        ctx.comm.barrier()?;
    }
}
