//! Henson-like execution model (S6, paper Sec. 3.5).
//!
//! In real Wilkins, task codes are compiled as shared objects and
//! dlopen'd by Henson, which runs them as cooperative coroutines under
//! a PMPI shim that swaps MPI_COMM_WORLD for a restricted world. Our
//! equivalent: task codes are [`TaskCode`] trait objects resolved by
//! name from a [`Registry`] (the dlopen analogue), each rank runs on
//! its own thread with a restricted-world [`Comm`], and the only
//! handles a task sees are its communicator and the HDF5-like Vol —
//! nothing workflow-specific, preserving "standalone code runs
//! unmodified" in spirit.

mod execution;

pub use execution::{drive_rank, Role};

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

use crate::comm::Comm;
use crate::configyaml::Yaml;
use crate::error::{Result, WilkinsError};
use crate::lowfive::Vol;
use crate::metrics::Recorder;
use crate::runtime::EngineHandle;

/// Everything a task code rank gets to see.
pub struct TaskContext {
    /// Restricted-world communicator (the task's MPI_COMM_WORLD).
    pub comm: Comm,
    /// The LowFive plugin handle (HDF5 stand-in).
    pub vol: Vol,
    /// Ensemble instance index of this task.
    pub instance: usize,
    /// Number of writer ranks (subset writers, Sec. 3.2.2); equals
    /// `size()` unless the YAML set `nwriters`/`io_proc`.
    pub nwriters: usize,
    /// Node name, e.g. `freeze[3]`.
    pub name: String,
    /// Free-form `params:` from the YAML.
    pub params: BTreeMap<String, Yaml>,
    /// AOT compute engine (None when the workflow has no artifacts).
    pub engine: Option<EngineHandle>,
    /// Gantt recorder.
    pub recorder: Option<Arc<Recorder>>,
    /// Global rank (for metrics labels).
    pub global_rank: usize,
    /// Wall-seconds per emulated paper-second (sleep scaling).
    pub time_scale: f64,
}

impl TaskContext {
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    pub fn size(&self) -> usize {
        self.comm.size()
    }

    pub fn param_i64(&self, key: &str, default: i64) -> i64 {
        self.params.get(key).and_then(Yaml::as_i64).unwrap_or(default)
    }

    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.params.get(key).and_then(Yaml::as_f64).unwrap_or(default)
    }

    pub fn param_str(&self, key: &str, default: &str) -> String {
        self.params
            .get(key)
            .and_then(Yaml::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// The AOT engine, erroring if the workflow was built without one.
    pub fn engine(&self) -> Result<&EngineHandle> {
        self.engine
            .as_ref()
            .ok_or_else(|| WilkinsError::Task("no AOT engine configured".into()))
    }

    /// Record a closure as a compute span.
    pub fn compute<T>(&self, label: &str, f: impl FnOnce() -> T) -> T {
        match &self.recorder {
            Some(rec) => rec.compute(self.global_rank, label, f),
            None => f(),
        }
    }

    /// Emulate `paper_secs` of computation by sleeping the scaled
    /// duration (the synthetic flow-control experiments).
    pub fn sleep_compute(&self, label: &str, paper_secs: f64) {
        let dur = Duration::from_secs_f64(paper_secs * self.time_scale);
        self.compute(label, || std::thread::sleep(dur));
    }
}

/// A task code: the analogue of one shared-object user program. `run`
/// is invoked SPMD on every rank of the task with that rank's context.
pub trait TaskCode: Send + Sync {
    fn run(&self, ctx: &mut TaskContext) -> Result<()>;
}

impl<F> TaskCode for F
where
    F: Fn(&mut TaskContext) -> Result<()> + Send + Sync,
{
    fn run(&self, ctx: &mut TaskContext) -> Result<()> {
        self(ctx)
    }
}

/// Task-code registry: name -> code (the dlopen/dlsym analogue).
#[derive(Default, Clone)]
pub struct Registry {
    map: HashMap<String, Arc<dyn TaskCode>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn register(&mut self, name: &str, code: Arc<dyn TaskCode>) {
        self.map.insert(name.to_string(), code);
    }

    pub fn register_fn<F>(&mut self, name: &str, f: F)
    where
        F: Fn(&mut TaskContext) -> Result<()> + Send + Sync + 'static,
    {
        self.register(name, Arc::new(f));
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn TaskCode>> {
        self.map.get(name).cloned().ok_or_else(|| {
            WilkinsError::Task(format!(
                "task code {name:?} not registered (known: {:?})",
                self.names()
            ))
        })
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;
    use crate::lowfive::Vol;

    fn ctx_with_params(yaml_params: &str) -> TaskContext {
        let doc = crate::configyaml::parse(yaml_params).unwrap();
        let mut params = BTreeMap::new();
        if let Some(m) = doc.as_map() {
            for (k, v) in m {
                params.insert(k.clone(), v.clone());
            }
        }
        let world = World::new(1);
        let comm = world.comm_world(0);
        TaskContext {
            vol: Vol::new(comm.clone(), std::env::temp_dir()),
            comm,
            instance: 2,
            nwriters: 1,
            name: "t".into(),
            params,
            engine: None,
            recorder: None,
            global_rank: 0,
            time_scale: 1.0,
        }
    }

    #[test]
    fn params_typed_access_with_defaults() {
        let ctx = ctx_with_params("steps: 7\nrate: 2.5\nmode: fast\n");
        assert_eq!(ctx.param_i64("steps", 1), 7);
        assert_eq!(ctx.param_i64("missing", 42), 42);
        assert!((ctx.param_f64("rate", 0.0) - 2.5).abs() < 1e-12);
        assert!((ctx.param_f64("steps", 0.0) - 7.0).abs() < 1e-12);
        assert_eq!(ctx.param_str("mode", "slow"), "fast");
        assert_eq!(ctx.param_str("missing", "slow"), "slow");
    }

    #[test]
    fn engine_absent_is_a_clean_error() {
        let ctx = ctx_with_params("");
        assert!(ctx.engine().is_err());
    }

    #[test]
    fn registry_resolution_and_errors() {
        let mut r = Registry::new();
        r.register_fn("alpha", |_ctx| Ok(()));
        r.register_fn("beta", |_ctx| Ok(()));
        assert!(r.get("alpha").is_ok());
        assert_eq!(r.names(), vec!["alpha".to_string(), "beta".to_string()]);
        let err = match r.get("gamma") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("gamma should not resolve"),
        };
        assert!(err.contains("gamma") && err.contains("alpha"), "{err}");
    }

    #[test]
    fn compute_records_span_when_recorder_attached() {
        let mut ctx = ctx_with_params("");
        let rec = std::sync::Arc::new(crate::metrics::Recorder::new());
        ctx.recorder = Some(std::sync::Arc::clone(&rec));
        let out = ctx.compute("work", || 5);
        assert_eq!(out, 5);
        assert_eq!(rec.spans().len(), 1);
        assert_eq!(rec.spans()[0].label, "work");
    }
}
