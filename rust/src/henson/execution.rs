//! The per-rank driver: launches a task code with the right lifecycle
//! for its role and consumer kind (Sec. 3.5.1), then finalizes the
//! transport so coupled tasks shut down cleanly.

use std::sync::Arc;

use crate::config::ConsumerKind;
use crate::error::{Result, WilkinsError};

use super::{TaskCode, TaskContext};

/// A node's role, derived from its ports by the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Producer,
    Consumer,
    /// Both producer and consumer (pipeline middle stage).
    Intermediate,
}

/// Run one rank of a task to completion.
///
/// * Producers / intermediates / stateful consumers run once; the code
///   itself loops over timesteps.
/// * Stateless consumers are relaunched per incoming file: the driver
///   pre-opens the next served file (blocking on the producer query
///   protocol) and launches the code only when data exists, exactly
///   like Wilkins' "launched as many times as there are incoming data".
///
/// Finalization always runs, even on error paths that leave coupled
/// tasks waiting — otherwise a failing consumer would deadlock its
/// producer instead of surfacing the error.
pub fn drive_rank(
    code: Arc<dyn TaskCode>,
    role: Role,
    kind: ConsumerKind,
    ctx: &mut TaskContext,
) -> Result<()> {
    let result = run_body(&code, role, kind, ctx);
    let fin_p = ctx.vol.finalize_producer();
    let fin_c = ctx.vol.finalize_consumer();
    result.and(fin_p).and(fin_c)
}

fn run_body(
    code: &Arc<dyn TaskCode>,
    role: Role,
    kind: ConsumerKind,
    ctx: &mut TaskContext,
) -> Result<()> {
    let stateless_consumer = role == Role::Consumer && kind == ConsumerKind::Stateless;
    if !stateless_consumer {
        return code.run(ctx);
    }
    loop {
        match ctx.vol.preopen_next() {
            Ok(_) => code.run(ctx)?,
            Err(WilkinsError::EndOfStream) => return Ok(()),
            Err(e) => return Err(e),
        }
    }
}
