//! Wilkins: HPC In Situ Workflows Made Easy — a Rust + JAX + Pallas
//! reproduction of the paper's workflow system.
//!
//! Layering (see DESIGN.md at the repository root):
//! * [`ensemble`] — co-scheduling of N workflow instances against a
//!   shared rank budget (the campaign layer above single runs).
//! * [`coordinator`] — Wilkins-master: the user-facing workflow driver.
//! * [`config`] / [`configyaml`] / [`graph`] — the data-centric YAML
//!   interface and its expansion into a task/channel graph.
//! * [`lowfive`] / [`flow`] — the HDF5-like routed data plane:
//!   producer/consumer engines with per-dataset transport routing
//!   (memory | file | write-through), M×N redistribution, a zero-copy
//!   same-process serve path and callbacks, over the credit-based
//!   streaming flow-control layer (per-link policies, bounded round
//!   buffers, coordinated drop plans; see docs/flow-control.md).
//! * [`comm`] / [`henson`] — the virtual-MPI substrate and the
//!   Henson-like execution model.
//! * [`net`] — the multi-process execution substrate: socket-backed
//!   [`comm::Transport`], worker processes, rendezvous, and the
//!   worker pool behind `wilkins up`.
//! * [`runtime`] — PJRT engine executing AOT-compiled JAX/Pallas
//!   payloads (`artifacts/*.hlo.txt`), shared across ensemble
//!   instances.
//! * [`tasks`] / [`actions`] — built-in task codes and custom actions.
//! * [`obs`] — the unified observability plane: the structured
//!   [`obs::TraceRecorder`], the counter registry, live worker
//!   telemetry, the `WILKINS_TRACE_WIRE` frame tap, and the
//!   Chrome-trace / JSON exporters (docs/observability.md).
//! * [`metrics`] — Gantt tracing and per-run statistics, including
//!   merged ensemble traces — a *view* over the [`obs`] trace.

pub mod actions;
pub mod baseline;
pub mod bench_util;
pub mod comm;
pub mod config;
pub mod configyaml;
pub mod coordinator;
pub mod ensemble;
pub mod error;
// The flow layer is part of the documented surface (docs/flow-control.md
// maps paper Sec. 3.6 onto it); the lint feeds the `-D warnings` gates
// in ci/check.sh so new public items cannot land undocumented.
#[warn(missing_docs)]
pub mod flow;
pub mod graph;
pub mod henson;
// The whole routed data plane is likewise documented surface (DESIGN.md
// data-plane section, docs/yaml-schema.md routing matrix): every public
// item in lowfive — engines, routes, model, protocol, disk format —
// must carry docs or the ci/check.sh doc/clippy gates fail.
#[warn(missing_docs)]
pub mod lowfive;
pub mod metrics;
pub mod net;
// The observability plane is documented surface end to end
// (docs/observability.md: trace model, wire-tap format, JSON schemas).
#[warn(missing_docs)]
pub mod obs;
pub mod proptest_lite;
pub mod runtime;
pub mod sim;
pub mod tasks;

pub use coordinator::{FaultStats, RunReport, Wilkins};
pub use ensemble::{Ensemble, EnsembleReport, EnsembleSpec};
pub use error::{Result, WilkinsError};
