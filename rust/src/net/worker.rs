//! The `wilkins worker` process mode: one member of a worker pool.
//!
//! A worker connects back to the coordinator that spawned it, binds a
//! peer-mesh listener, introduces itself, and then serves commands
//! until `Shutdown`:
//!
//! * `LaunchWorld` — join a distributed workflow: rebuild the graph
//!   from the shipped YAML, build the socket mesh, and run exactly the
//!   global ranks the owner map assigns here via
//!   `Wilkins::run_hosted`. Task codes, `lowfive::Vol`, flow control
//!   and collectives run unmodified — they only ever see `Comm`s.
//! * `RunInstance` — run one whole ensemble instance single-process
//!   inside this worker (the `process-per-instance` placement) and
//!   ship back the `RunReport` plus spans.
//!
//! Liveness: a dedicated thread beats [`proto::Heartbeat`] frames on
//! the control socket every `heartbeat` interval (sharing the write
//! half under a mutex with command replies), so the coordinator can
//! tell a busy worker from a dead one. Each beat piggybacks a
//! `K_TELEMETRY` frame — a cumulative snapshot of the process-global
//! counters plus a clock sample — so the coordinator's live telemetry
//! survives a worker dying mid-run. The serve loop also consults
//! the process's [`FaultPlan`] on every `RunInstance` and
//! `LaunchWorld` (`at=launch` directives) — a no-op unless
//! `WILKINS_FAULT` armed it (tests and chaos smokes only).
//!
//! Workers deliberately hold their distributed world open until the
//! coordinator's `Shutdown`: our ranks finishing does not mean our
//! peers are done reading from us.

use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::Wilkins;
use crate::ensemble::EnsembleSpec;
use crate::error::{Result, WilkinsError};
use crate::obs::{global_snapshot, Clock, Ctr, TelemetrySample};
use crate::tasks::builtin_registry;

use super::codec;
use super::faults::{FaultKind, FaultPlan};
use super::proto::{
    self, Heartbeat, InstanceDone, LaunchWorld, RankOutcomeWire, RunInstance, WorldDone,
};
use super::rendezvous;

/// How a worker process conducts itself: beat cadence + fault plan.
pub struct WorkerOpts {
    /// Control-socket heartbeat period; zero disables beating.
    pub heartbeat: Duration,
    /// Fault-injection schedule (empty in production).
    pub faults: FaultPlan,
}

impl WorkerOpts {
    /// The environment's prescription: `WILKINS_FAULT` for the plan
    /// (almost always empty), the pool's default cadence for beats.
    pub fn from_env() -> Result<WorkerOpts> {
        Ok(WorkerOpts {
            heartbeat: super::pool::HeartbeatConfig::default().interval,
            faults: FaultPlan::from_env()?,
        })
    }
}

/// Entry point behind `wilkins worker --connect ADDR --id K`. Also
/// callable from any other binary built on this crate (the benches
/// re-enter here so a bench executable can serve as its own pool).
pub fn worker_main(coordinator_addr: &str, worker_id: usize) -> Result<()> {
    worker_main_with(coordinator_addr, worker_id, WorkerOpts::from_env()?)
}

/// [`worker_main`] with explicit options — the CLI passes the
/// coordinator's `--heartbeat-ms` through here, and the fault tests
/// run emulated workers on threads with hand-built plans.
pub fn worker_main_with(
    coordinator_addr: &str,
    worker_id: usize,
    opts: WorkerOpts,
) -> Result<()> {
    let peer_listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| WilkinsError::Comm(format!("bind peer listener: {e}")))?;
    let peer_addr = peer_listener
        .local_addr()
        .map_err(|e| WilkinsError::Comm(format!("peer local_addr: {e}")))?
        .to_string();
    let control = rendezvous::join(coordinator_addr, worker_id, &peer_addr)?;
    let faults = Arc::new(opts.faults);
    // The worker's run-relative clock: every telemetry sample and
    // every span shipped back is stamped against this one origin, so
    // the coordinator can align them with a single offset estimate.
    let clock = Clock::new();

    // Replies and heartbeats share the write half under one mutex so
    // concurrent writers can never interleave mid-frame; the serve
    // loop keeps the original stream as its read half.
    let write_half = control
        .try_clone()
        .map_err(|e| WilkinsError::Comm(format!("clone control stream: {e}")))?;
    let writer = Arc::new(Mutex::new(write_half));
    let stop_beats = Arc::new(AtomicBool::new(false));
    let _beats = spawn_beat_thread(
        Arc::clone(&writer),
        worker_id,
        opts.heartbeat,
        Arc::clone(&faults),
        Arc::clone(&stop_beats),
        clock,
    );

    let out = serve_loop(control, &writer, worker_id, &peer_listener, &faults, clock);
    stop_beats.store(true, Ordering::SeqCst);
    out
}

/// Beat every `interval` until stopped, silenced by a fired fault, or
/// the socket dies (coordinator gone — nothing left to reassure).
/// Every beat carries a heartbeat frame plus a telemetry frame with a
/// cumulative counter snapshot (so the coordinator's totals survive
/// this worker dying one interval later).
fn spawn_beat_thread(
    writer: Arc<Mutex<TcpStream>>,
    worker_id: usize,
    interval: Duration,
    faults: Arc<FaultPlan>,
    stop: Arc<AtomicBool>,
    clock: Clock,
) -> Option<std::thread::JoinHandle<()>> {
    if interval.is_zero() {
        return None;
    }
    std::thread::Builder::new()
        .name(format!("wk-beat-{worker_id}"))
        .spawn(move || {
            let mut seq = 0u64;
            loop {
                std::thread::sleep(interval);
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if faults.silenced() {
                    return;
                }
                seq += 1;
                let beat = Heartbeat { worker_id: worker_id as u64, seq };
                // Snapshot before sending: the snapshot deliberately
                // excludes this very beat (cumulative frames make the
                // next one pick it up).
                let telem = TelemetrySample {
                    worker_id: worker_id as u64,
                    seq,
                    t_mono_s: clock.now_s(),
                    counters: global_snapshot(),
                };
                let mut w = writer.lock().unwrap();
                if codec::write_frame(&mut *w, proto::K_HEARTBEAT, &beat.encode()).is_err() {
                    return;
                }
                Ctr::HeartbeatsSent.bump(1);
                if codec::write_frame(&mut *w, proto::K_TELEMETRY, &telem.encode()).is_err() {
                    return;
                }
                Ctr::TelemetrySent.bump(1);
            }
        })
        .ok()
}

fn serve_loop(
    mut control: TcpStream,
    writer: &Arc<Mutex<TcpStream>>,
    worker_id: usize,
    peer_listener: &TcpListener,
    faults: &Arc<FaultPlan>,
    clock: Clock,
) -> Result<()> {
    // A worker that served a LaunchWorld keeps the mesh world alive
    // until shutdown (peers may still drain our streams).
    let mut held: Option<rendezvous::MeshWorld> = None;

    loop {
        let frame = codec::read_frame(&mut control)?;
        match frame {
            None | Some((proto::K_SHUTDOWN, _)) => break,
            Some((proto::K_LAUNCH_WORLD, body)) => {
                let msg = LaunchWorld::decode(&body)?;
                match faults.on_launch_world(worker_id) {
                    Some(FaultKind::Kill) => {
                        if std::env::var("WILKINS_FAULT_HARD").as_deref() == Ok("1") {
                            std::process::exit(9);
                        }
                        faults.silence();
                        let _ = control.shutdown(Shutdown::Both);
                        return Ok(());
                    }
                    Some(FaultKind::Wedge) => park_forever(),
                    Some(FaultKind::Delay(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    // The reply-shaped faults have no meaning at this
                    // seam (a world has exactly one reply): serve
                    // normally.
                    Some(FaultKind::DupDone) | Some(FaultKind::DropDone) | None => {}
                }
                let reply = match serve_world(worker_id, peer_listener, &msg, clock) {
                    Ok((done, mesh)) => {
                        held = Some(mesh);
                        done
                    }
                    Err(e) => WorldDone { error: e.to_string(), ..WorldDone::default() },
                };
                send_reply(writer, proto::K_WORLD_DONE, &reply.encode())?;
            }
            Some((proto::K_RUN_INSTANCE, body)) => {
                let msg = RunInstance::decode(&body)?;
                let fired = faults.on_run_instance(worker_id);
                match fired {
                    Some(FaultKind::Kill) => {
                        if std::env::var("WILKINS_FAULT_HARD").as_deref() == Ok("1") {
                            std::process::exit(9);
                        }
                        // Emulated kill (threaded workers): vanish
                        // abruptly — close the control socket with no
                        // goodbye and stop beating.
                        faults.silence();
                        let _ = control.shutdown(Shutdown::Both);
                        return Ok(());
                    }
                    Some(FaultKind::Wedge) => {
                        // Alive but unresponsive: the case plain EOF
                        // detection can never catch.
                        park_forever();
                    }
                    Some(FaultKind::Delay(ms)) => {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    Some(FaultKind::DupDone) | Some(FaultKind::DropDone) | None => {}
                }
                let reply = match serve_instance(&msg) {
                    Ok(done) => done,
                    Err(e) => InstanceDone {
                        error: e.to_string(),
                        report: None,
                        spans: Vec::new(),
                        idem_key: msg.idem_key,
                    },
                };
                match fired {
                    Some(FaultKind::DropDone) => {
                        // Work done, acknowledgement lost — then go
                        // silent so the coordinator re-dispatches.
                        park_forever();
                    }
                    Some(FaultKind::DupDone) => {
                        let body = reply.encode();
                        send_reply(writer, proto::K_INSTANCE_DONE, &body)?;
                        send_reply(writer, proto::K_INSTANCE_DONE, &body)?;
                    }
                    _ => send_reply(writer, proto::K_INSTANCE_DONE, &reply.encode())?,
                }
            }
            Some((proto::K_HEARTBEAT, _)) => {
                // Coordinators don't beat at workers today; tolerate
                // it anyway (a future bidirectional lease costs us
                // nothing here).
            }
            Some((kind, _)) => {
                return Err(WilkinsError::Comm(format!(
                    "worker {worker_id}: unexpected control frame kind {kind}"
                )));
            }
        }
    }
    if let Some(mesh) = held.take() {
        mesh.shutdown();
    }
    Ok(())
}

/// Never returns: the thread (or process) plays dead without closing
/// its sockets.
fn park_forever() -> ! {
    loop {
        std::thread::park();
    }
}

fn send_reply(writer: &Arc<Mutex<TcpStream>>, kind: u8, body: &[u8]) -> Result<()> {
    let mut w = writer.lock().unwrap();
    codec::write_frame(&mut *w, kind, body)
}

/// Attach the AOT engine when the run names an artifacts dir that
/// actually holds a manifest (same sniff as the CLI's run path).
fn with_engine_if_present(w: Wilkins, artifacts: &str) -> Result<Wilkins> {
    if artifacts.is_empty() {
        return Ok(w);
    }
    let dir = PathBuf::from(artifacts);
    if !dir.join("manifest.tsv").exists() {
        return Ok(w);
    }
    let handle = crate::runtime::shared_engine(&dir)?;
    Ok(w.with_engine(handle))
}

fn serve_world(
    my_id: usize,
    peer_listener: &TcpListener,
    msg: &LaunchWorld,
    clock: Clock,
) -> Result<(WorldDone, rendezvous::MeshWorld)> {
    let mut w = Wilkins::from_yaml_str(&msg.config_src, builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;

    let mesh = rendezvous::build_mesh_world(my_id, peer_listener, msg)?;
    let hosted: Vec<usize> = msg
        .owner_of
        .iter()
        .enumerate()
        .filter(|(_, &owner)| owner as usize == my_id)
        .map(|(r, _)| r)
        .collect();
    let recorder = w.recorder();
    let outcomes = w.run_hosted(&mesh.world, &hosted)?;
    // The recorder's spans are relative to the recorder's own origin
    // (created with the Wilkins above); rebase them onto the worker
    // clock so they share a timeline with the telemetry samples the
    // coordinator aligned clocks from.
    let base = clock.since_origin(recorder.origin_instant());
    let spans = recorder
        .spans()
        .into_iter()
        .map(|mut s| {
            s.start += base;
            s.end += base;
            s
        })
        .collect();
    let done = WorldDone {
        bytes_sent: mesh.world.bytes_sent(),
        msgs_sent: mesh.world.msgs_sent(),
        outcomes: outcomes
            .into_iter()
            .map(|o| RankOutcomeWire {
                node: o.node as u64,
                stats: o.stats,
                error: o.error.unwrap_or_default(),
            })
            .collect(),
        error: String::new(),
        spans,
        t_mono_s: clock.now_s(),
    };
    Ok((done, mesh))
}

fn serve_instance(msg: &RunInstance) -> Result<InstanceDone> {
    let spec = EnsembleSpec::from_yaml_str(&msg.spec_src, Path::new(&msg.base_dir))?;
    let idx = msg.instance_idx as usize;
    let inst = spec.instances.get(idx).ok_or_else(|| {
        WilkinsError::Config(format!(
            "RunInstance names instance #{idx} but the spec has {}",
            spec.instances.len()
        ))
    })?;
    let mut w = Wilkins::new(inst.cfg.clone(), builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;
    let recorder = w.recorder();
    match w.run() {
        Ok(report) => Ok(InstanceDone {
            error: String::new(),
            report: Some(report),
            spans: recorder.spans(),
            idem_key: msg.idem_key,
        }),
        Err(e) => Ok(InstanceDone {
            error: e.to_string(),
            report: None,
            spans: recorder.spans(),
            idem_key: msg.idem_key,
        }),
    }
}
