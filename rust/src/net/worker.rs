//! The `wilkins worker` process mode: one member of a worker pool.
//!
//! A worker connects back to the coordinator that spawned it, binds a
//! peer-mesh listener, introduces itself, and then serves commands
//! until `Shutdown`:
//!
//! * `LaunchWorld` — join a distributed workflow: rebuild the graph
//!   from the shipped YAML, build the socket mesh, and run exactly the
//!   global ranks the owner map assigns here via
//!   `Wilkins::run_hosted`. Task codes, `lowfive::Vol`, flow control
//!   and collectives run unmodified — they only ever see `Comm`s.
//! * `RunInstance` — run one whole ensemble instance single-process
//!   inside this worker (the `process-per-instance` placement) and
//!   ship back the `RunReport` plus spans.
//!
//! Workers deliberately hold their distributed world open until the
//! coordinator's `Shutdown`: our ranks finishing does not mean our
//! peers are done reading from us.

use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};

use crate::coordinator::Wilkins;
use crate::ensemble::EnsembleSpec;
use crate::error::{Result, WilkinsError};
use crate::tasks::builtin_registry;

use super::codec;
use super::proto::{
    self, InstanceDone, LaunchWorld, RankOutcomeWire, RunInstance, WorldDone,
};
use super::rendezvous;

/// Entry point behind `wilkins worker --connect ADDR --id K`. Also
/// callable from any other binary built on this crate (the benches
/// re-enter here so a bench executable can serve as its own pool).
pub fn worker_main(coordinator_addr: &str, worker_id: usize) -> Result<()> {
    let peer_listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| WilkinsError::Comm(format!("bind peer listener: {e}")))?;
    let peer_addr = peer_listener
        .local_addr()
        .map_err(|e| WilkinsError::Comm(format!("peer local_addr: {e}")))?
        .to_string();
    let mut control = rendezvous::join(coordinator_addr, worker_id, &peer_addr)?;

    // A worker that served a LaunchWorld keeps the mesh world alive
    // until shutdown (peers may still drain our streams).
    let mut held: Option<rendezvous::MeshWorld> = None;

    loop {
        let frame = codec::read_frame(&mut control)?;
        match frame {
            None | Some((proto::K_SHUTDOWN, _)) => break,
            Some((proto::K_LAUNCH_WORLD, body)) => {
                let msg = LaunchWorld::decode(&body)?;
                let reply = match serve_world(worker_id, &peer_listener, &msg) {
                    Ok((done, mesh)) => {
                        held = Some(mesh);
                        done
                    }
                    Err(e) => WorldDone { error: e.to_string(), ..WorldDone::default() },
                };
                send_reply(&mut control, proto::K_WORLD_DONE, &reply.encode())?;
            }
            Some((proto::K_RUN_INSTANCE, body)) => {
                let msg = RunInstance::decode(&body)?;
                let reply = match serve_instance(&msg) {
                    Ok(done) => done,
                    Err(e) => InstanceDone {
                        error: e.to_string(),
                        report: None,
                        spans: Vec::new(),
                    },
                };
                send_reply(&mut control, proto::K_INSTANCE_DONE, &reply.encode())?;
            }
            Some((kind, _)) => {
                return Err(WilkinsError::Comm(format!(
                    "worker {worker_id}: unexpected control frame kind {kind}"
                )));
            }
        }
    }
    if let Some(mesh) = held.take() {
        mesh.shutdown();
    }
    Ok(())
}

fn send_reply(control: &mut TcpStream, kind: u8, body: &[u8]) -> Result<()> {
    codec::write_frame(control, kind, body)
}

/// Attach the AOT engine when the run names an artifacts dir that
/// actually holds a manifest (same sniff as the CLI's run path).
fn with_engine_if_present(w: Wilkins, artifacts: &str) -> Result<Wilkins> {
    if artifacts.is_empty() {
        return Ok(w);
    }
    let dir = PathBuf::from(artifacts);
    if !dir.join("manifest.tsv").exists() {
        return Ok(w);
    }
    let handle = crate::runtime::shared_engine(&dir)?;
    Ok(w.with_engine(handle))
}

fn serve_world(
    my_id: usize,
    peer_listener: &TcpListener,
    msg: &LaunchWorld,
) -> Result<(WorldDone, rendezvous::MeshWorld)> {
    let mut w = Wilkins::from_yaml_str(&msg.config_src, builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;

    let mesh = rendezvous::build_mesh_world(my_id, peer_listener, msg)?;
    let hosted: Vec<usize> = msg
        .owner_of
        .iter()
        .enumerate()
        .filter(|(_, &owner)| owner as usize == my_id)
        .map(|(r, _)| r)
        .collect();
    let outcomes = w.run_hosted(&mesh.world, &hosted)?;
    let done = WorldDone {
        bytes_sent: mesh.world.bytes_sent(),
        msgs_sent: mesh.world.msgs_sent(),
        outcomes: outcomes
            .into_iter()
            .map(|o| RankOutcomeWire {
                node: o.node as u64,
                stats: o.stats,
                error: o.error.unwrap_or_default(),
            })
            .collect(),
        error: String::new(),
    };
    Ok((done, mesh))
}

fn serve_instance(msg: &RunInstance) -> Result<InstanceDone> {
    let spec = EnsembleSpec::from_yaml_str(&msg.spec_src, Path::new(&msg.base_dir))?;
    let idx = msg.instance_idx as usize;
    let inst = spec.instances.get(idx).ok_or_else(|| {
        WilkinsError::Config(format!(
            "RunInstance names instance #{idx} but the spec has {}",
            spec.instances.len()
        ))
    })?;
    let mut w = Wilkins::new(inst.cfg.clone(), builtin_registry())?
        .with_workdir(PathBuf::from(&msg.workdir))
        .with_time_scale(msg.time_scale);
    w = with_engine_if_present(w, &msg.artifacts)?;
    let recorder = w.recorder();
    match w.run() {
        Ok(report) => Ok(InstanceDone {
            error: String::new(),
            report: Some(report),
            spans: recorder.spans(),
        }),
        Err(e) => Ok(InstanceDone {
            error: e.to_string(),
            report: None,
            spans: recorder.spans(),
        }),
    }
}
